"""Double-buffered slice staging for the temporal engine (paper §V read
optimizations: overlap GoFS slice reads with Gopher computation).

The GoFFish paper's co-design argument is that iterative BSP execution is
only as fast as the store can feed it time-series instances; its storage
section overlaps slice materialization with computation so the engine never
waits on disk.  :class:`SlicePrefetcher` is that pipeline for the blocked
engine: it reads an edge attribute's (bin, pack) slices on a background
thread pool, assembles them into ready ``(I_chunk, P, T, B, B)`` instance
tile tensors (through the batched in-place ``BlockedGraph`` ``out=``
fills), and hands chunks to the consumer through a bounded in-order
window — the same shape as the shard prefetch in
``repro.train.data.PackedShardDataset``.

``prefetch_depth`` semantics:

* ``1``  — degenerate/synchronous: no thread is created; each chunk is read
  and filled on demand when the consumer asks for it.
* ``d>=2`` — double (d=2) or deeper buffering: up to ``d - 1`` chunks are
  staged ahead on the pool while the consumer processes the current one.

``inflight`` (default ``num_workers``) decouples read CONCURRENCY from
the window depth: up to ``max(prefetch_depth - 1, inflight)`` chunks are
submitted ahead, so ``num_workers`` pool threads really do read
concurrently without inflating ``prefetch_depth``.

Each chunk OWNS its buffers: they are allocated on the producer (so the
allocation cost overlaps execution too) and never rewritten after handoff,
which is what lets a device consumer alias them with no further copy
(``jnp.asarray`` zero-copy-aliases aligned host buffers on CPU, and even
``jnp.array(..., copy=True)`` defers the host read until execution —
reusing a buffer ring here corrupts in-flight chunks; the engine parity
tests pin this down).  In-flight memory stays bounded by the window: at
most ``max(prefetch_depth - 1, inflight) + 2`` chunks exist before the
consumer releases theirs.

Cancellation: ``close()`` (or exiting the ``with`` block) stops the
producer, cancels not-yet-started reads, and joins the pool — no leaked
threads; abandoning the iterator mid-stream triggers the same cleanup.

Doctest (in-memory source; the GoFS-backed form is
``GoFSStore.load_blocked_stream``):

>>> import numpy as np
>>> from repro.core.graph import GraphTemplate
>>> from repro.core.blocked import build_blocked
>>> from repro.gofs.prefetch import SlicePrefetcher
>>> tmpl = GraphTemplate(num_vertices=4,
...     src=np.array([0, 1, 2, 0]), dst=np.array([1, 2, 3, 2]))
>>> bg = build_blocked(tmpl, np.array([0, 0, 1, 1]), block_size=2)
>>> w = np.ones((5, 4), np.float32)  # 5 instances x 4 edges
>>> with SlicePrefetcher.from_weights(bg, w, zero=np.inf,
...                                   chunk_instances=2) as pf:
...     [(c.start, c.count) for c in pf]
[(0, 2), (2, 2), (4, 1)]
"""
from __future__ import annotations

import threading
from collections import deque
from concurrent.futures import CancelledError, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Iterator, List, Optional, Tuple

import numpy as np

THREAD_PREFIX = "gofs-prefetch"


@dataclass
class StagedChunk:
    """A contiguous run of staged instances, ready for the engine.

    The chunk owns ``tiles``/``btiles`` (and, for the block-sparse layout,
    the tile-index arrays): the prefetcher never touches them again after
    handoff, so consumers may alias them (``jnp.asarray``) for as long as
    they hold the chunk.

    Dense layout: ``tiles``/``btiles`` span the full template tile axis
    and the index fields are ``None``.  Sparse layout
    (``repro.core.blocked.SparseBlocked`` fields): the tile axes are
    packed pow2 buckets and ``rows``/``cols``/``brows``/``bcols`` carry
    the per-instance active-tile index (``-1`` padding).
    """

    start: int  # first (visible) instance index covered by this chunk
    count: int
    tiles: np.ndarray  # (count, P, T|K, B, B) local adjacency tiles
    btiles: np.ndarray  # (count, P, Tb|Kb, B, B) boundary tiles
    rows: Optional[np.ndarray] = None  # (count, P, K) int32, sparse only
    cols: Optional[np.ndarray] = None  # (count, P, K)
    brows: Optional[np.ndarray] = None  # (count, P, Kb)
    bcols: Optional[np.ndarray] = None  # (count, P, Kb)
    nnz: Optional[np.ndarray] = None  # (count, P) active local tiles
    bnnz: Optional[np.ndarray] = None  # (count, P) active boundary tiles
    # bytes materialized from the store for this chunk, when less than the
    # arrays' nbytes — a delta-chain reconstruction decodes each unique
    # tile payload once per chunk (GoFSStore.load_blocked_stream).  None =
    # fully materialized.
    staged_bytes: Optional[int] = None

    @property
    def is_sparse(self) -> bool:
        return self.rows is not None


# reader(start, end) -> (end - start, E) float32 edge weights for the
# visible-instance span [start, end)
Reader = Callable[[int, int], np.ndarray]


class SlicePrefetcher:
    """Stage (bin, pack) attribute reads ahead of the engine run.

    Construct via :meth:`GoFSStore.load_blocked_stream
    <repro.gofs.store.GoFSStore.load_blocked_stream>` (disk slices) or
    :meth:`from_weights` (an in-memory ``(I, E)`` array — what
    ``TemporalEngine(staging="async")`` uses when handed raw weights).

    Iterating yields :class:`StagedChunk` in instance order.  The iterator
    is re-entrant: each ``iter()`` starts a fresh pass; only one pass may
    be active at a time.

    A pass is VERSION-CONSISTENT: the instance span set is pinned at
    construction, so a collection appended to mid-stream neither extends
    nor tears the pass — the stream covers exactly the instances visible
    when it was built.  A reader that wants the appended tail closes the
    stream (``close()`` is safe against an active consumer: the pass ends
    cleanly, never with a leaked ``CancelledError``) and opens a fresh one
    after ``GoFSStore.refresh()``.
    """

    def __init__(
        self,
        bg,
        reader: Optional[Reader],
        num_instances: int,
        *,
        zero: float,
        prefetch_depth: int = 2,
        chunk_instances: int = 1,
        num_workers: int = 1,
        inflight: Optional[int] = None,
        layout: str = "dense",
        bucket: Optional[int] = None,
        bbucket: Optional[int] = None,
        transform: Optional[Callable[[np.ndarray], np.ndarray]] = None,
        stage_fn: Optional[Callable[[int, int], StagedChunk]] = None,
    ):
        assert prefetch_depth >= 1, "prefetch_depth must be >= 1"
        assert chunk_instances >= 1 and num_workers >= 1
        assert layout in ("dense", "sparse"), layout
        assert reader is not None or stage_fn is not None
        self.bg = bg
        self.reader = reader
        self.num_instances = int(num_instances)
        self.zero = float(zero)
        self.prefetch_depth = int(prefetch_depth)
        self.chunk_instances = int(chunk_instances)
        self.num_workers = int(num_workers)
        # ``inflight`` decouples read concurrency from the ready-chunk
        # window: ``prefetch_depth`` alone bounded the submitted-ahead
        # count, so extra pool workers never actually overlapped reads
        # (depth=2 keeps exactly one read in flight no matter how many
        # workers).  The submit window is max(prefetch_depth - 1,
        # inflight); the default (num_workers) makes the worker count
        # mean what callers expect — num_workers concurrent reads.
        self.inflight = int(num_workers if inflight is None else inflight)
        assert self.inflight >= 1, "inflight must be >= 1"
        # block-sparse staging: pack only active tiles per chunk.  A shared
        # ``bucket``/``bbucket`` (e.g. precomputed from GoFS-recorded tile
        # maps or a whole-batch activity scan) keeps every chunk on one jit
        # shape; left None, each chunk picks its own pow2 bucket — still at
        # most O(log T) distinct shapes over the stream.
        self.layout = layout
        self.bucket = bucket
        self.bbucket = bbucket
        # ``transform``: applied to each chunk's (n, E) rows on the POOL
        # thread before the fill — row-wise derived weights (e.g.
        # PageRank's outdegree normalization) stream chunk-wise instead of
        # forcing a full (I, E) materialization up front.  Must be
        # per-instance independent: transform(w[s:e]) == transform(w)[s:e].
        # ``stage_fn``: replaces the read+fill entirely (e.g. the store's
        # delta-chain reconstruction); the windowing/cancellation machinery
        # is unchanged.
        self.transform = transform
        self.stage_fn = stage_fn
        self._spans: List[Tuple[int, int]] = [
            (s, min(s + self.chunk_instances, self.num_instances))
            for s in range(0, self.num_instances, self.chunk_instances)
        ]
        self._stop = threading.Event()
        self._lock = threading.Lock()  # guards _pool/_pending handoff
        self._pool: Optional[ThreadPoolExecutor] = None
        self._pending: deque = deque()

    # ------------------------------------------------------------ sources
    @classmethod
    def from_weights(
        cls,
        bg,
        weights: np.ndarray,
        *,
        zero: float,
        prefetch_depth: int = 2,
        chunk_instances: int = 1,
        num_workers: int = 1,
        inflight: Optional[int] = None,
        layout: str = "dense",
        bucket: Optional[int] = None,
        bbucket: Optional[int] = None,
    ) -> "SlicePrefetcher":
        """Prefetch from an in-memory (I, E) weight matrix (the fills —
        the expensive host-side scatter — still overlap the engine run)."""
        w = np.asarray(weights, np.float32)
        if w.ndim == 1:
            w = w[None]
        if layout == "sparse" and bucket is None:
            # the weights are all in memory: one cheap activity scan pins
            # a batch-wide bucket so every chunk shares one jit shape
            bucket, bbucket = bg.sparse_buckets(w, zero=zero)
        return cls(
            bg, lambda s, e: w[s:e], w.shape[0], zero=zero,
            prefetch_depth=prefetch_depth, chunk_instances=chunk_instances,
            num_workers=num_workers, inflight=inflight, layout=layout,
            bucket=bucket, bbucket=bbucket,
        )

    # ------------------------------------------------------------ staging
    def _stage(self, span: Tuple[int, int]) -> StagedChunk:
        """Read + fill one chunk into chunk-owned buffers (runs on the
        pool, so both the reads AND the fill/allocation overlap the
        consumer's execution)."""
        s, e = span
        n = e - s
        if self.stage_fn is not None:
            return self.stage_fn(s, e)
        w = self.reader(s, e)
        if self.transform is not None:
            w = np.asarray(self.transform(w), np.float32)
            assert w.shape[0] == n, (w.shape, n)
        if self.layout == "sparse":
            out_l = out_b = None
            if self.bucket is not None and self.bbucket is not None:
                out_l, out_b = self.bg.alloc_batch_buffers(
                    n, bucket=self.bucket, bbucket=self.bbucket
                )
            tiles, rows, cols, nnz = self.bg.fill_local_batch_sparse(
                w, zero=self.zero, bucket=self.bucket, out=out_l
            )
            btiles, brows, bcols, bnnz = self.bg.fill_boundary_batch_sparse(
                w, zero=self.zero, bucket=self.bbucket, out=out_b
            )
            return StagedChunk(
                start=s, count=n, tiles=tiles, btiles=btiles,
                rows=rows, cols=cols, brows=brows, bcols=bcols,
                nnz=nnz, bnnz=bnnz,
            )
        lt_buf, bt_buf = self.bg.alloc_batch_buffers(n)
        tiles = self.bg.fill_local_batch(w, zero=self.zero, out=lt_buf)
        btiles = self.bg.fill_boundary_batch(w, zero=self.zero, out=bt_buf)
        return StagedChunk(start=s, count=n, tiles=tiles, btiles=btiles)

    def __iter__(self) -> Iterator[StagedChunk]:
        if self.prefetch_depth == 1:
            return self._iter_sync()
        return self._iter_async()

    def _iter_sync(self) -> Iterator[StagedChunk]:
        self._stop.clear()  # fresh pass
        for span in self._spans:
            if self._stop.is_set():
                return
            yield self._stage(span)

    def _iter_async(self) -> Iterator[StagedChunk]:
        assert self._pool is None, "one prefetch pass at a time"
        self._stop.clear()  # fresh pass
        pool = ThreadPoolExecutor(
            max_workers=self.num_workers, thread_name_prefix=THREAD_PREFIX
        )
        self._pool = pool
        pending = self._pending
        pending.clear()
        todo = iter(self._spans)

        def submit_one() -> None:
            with self._lock:
                if self._stop.is_set() or self._pool is not pool:
                    return  # a concurrent close() ended this pass
                try:
                    span = next(todo)
                except StopIteration:
                    return
                try:
                    pending.append(pool.submit(self._guarded_stage, span))
                except RuntimeError:  # pool shut down under us
                    return

        try:
            # keep the window full: up to max(depth-1, inflight) chunks
            # submitted ahead (inflight of them reading concurrently)
            for _ in range(max(self.prefetch_depth - 1, self.inflight)):
                submit_one()
            while True:
                try:
                    fut = pending.popleft()
                except IndexError:  # drained, or cleared by close()
                    return
                try:
                    chunk = fut.result()
                except CancelledError:
                    # a concurrent close() — e.g. a session observing an
                    # append mid-stream — cancelled this chunk between our
                    # popleft and its snapshot; end the pass cleanly
                    return
                # Submit BEFORE the yield: the next chunk's read + fill
                # must already be running while the consumer executes this
                # one (on CPU the jit call itself is where execution time
                # is spent, so a submit deferred to the next pull would
                # never overlap it).
                submit_one()
                if chunk is None:  # producer observed stop mid-pass
                    return
                yield chunk
        finally:
            self.close()

    def _guarded_stage(self, span) -> Optional[StagedChunk]:
        if self._stop.is_set():
            return None
        return self._stage(span)

    # ------------------------------------------------------------- cancel
    def close(self) -> None:
        """Stop producing, cancel queued reads, join the pool (idempotent).

        Safe to call mid-stream, from the consumer or any other thread
        (a lock serializes the pool/pending handoff against the consumer's
        submits): in-flight chunks finish (their buffer writes must not be
        torn), queued chunks are cancelled, and the pool threads exit
        before this returns."""
        self._stop.set()
        with self._lock:
            pool, self._pool = self._pool, None
            futs = list(self._pending)
            self._pending.clear()
        if pool is not None:
            for fut in futs:
                fut.cancel()
            pool.shutdown(wait=True)

    def __enter__(self) -> "SlicePrefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
