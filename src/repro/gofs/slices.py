"""Slice files — GoFS's unit of disk storage and access (paper §V-A).

A slice is one file holding a serialized block of logically-related data
(template topology, one attribute x one bin x one time pack, or metadata).
Bulk-reading a slice amortizes disk latency over a chunk of co-accessed
bytes; slice sizes span O(MB) by construction of the packing knobs.

Format: raw ``numpy.save``/``numpy.load`` for arrays (zero-copy mmap-able),
JSON for metadata slices.  Read accounting (count, bytes, wall time) feeds
the Fig. 6/8 benchmarks.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import numpy as np


@dataclass
class ReadStats:
    slices_read: int = 0
    bytes_read: int = 0
    read_seconds: float = 0.0

    def reset(self) -> None:
        self.slices_read = 0
        self.bytes_read = 0
        self.read_seconds = 0.0

    def snapshot(self) -> Dict[str, float]:
        return {
            "slices_read": self.slices_read,
            "bytes_read": self.bytes_read,
            "read_seconds": self.read_seconds,
        }


def write_array_slice(path: str, arrays: Dict[str, np.ndarray]) -> int:
    """Write a multi-array slice (npz, uncompressed).  Returns bytes.

    The write is atomic (temp file + ``os.replace``): a concurrent reader
    sees either the previous slice or the new one, never a torn file.
    Append-time pack rewrites (``append_instances``) rely on this.
    """
    os.makedirs(os.path.dirname(path), exist_ok=True)
    final = path if path.endswith(".npz") else path + ".npz"
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, final)
    return os.path.getsize(final)


def read_array_slice(path: str, stats: Optional[ReadStats] = None) -> Dict[str, np.ndarray]:
    """Read a full slice from disk (bulk read — the GoFS access grain).

    A corrupt file (truncated zip, bad compression stream — e.g. a pack
    damaged after an append) raises ``ValueError`` rather than leaking
    format-library exceptions, so every fallback site that already
    handles unreadable slices handles damaged ones too."""
    import zipfile
    import zlib

    p = path if path.endswith(".npz") else path + ".npz"
    t0 = time.perf_counter()
    try:
        with np.load(p) as z:
            out = {k: z[k] for k in z.files}
    except (zipfile.BadZipFile, zlib.error) as e:
        raise ValueError(f"corrupt slice {p}: {e}") from e
    dt = time.perf_counter() - t0
    if stats is not None:
        stats.slices_read += 1
        stats.bytes_read += os.path.getsize(p)
        stats.read_seconds += dt
    return out


def write_json_slice(path: str, obj: Any) -> None:
    """Atomic JSON metadata write (temp file + ``os.replace``).

    ``collection.json`` is the collection's version manifest: an append
    commits by replacing it *after* all data slices are durable, so a
    reader always observes a complete collection at some version."""
    os.makedirs(os.path.dirname(path), exist_ok=True)

    def default(o):
        if isinstance(o, np.integer):
            return int(o)
        if isinstance(o, np.floating):
            return float(o)
        if isinstance(o, np.ndarray):
            return o.tolist()
        raise TypeError(type(o))

    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, default=default)
    os.replace(tmp, path)


def read_json_slice(path: str, stats: Optional[ReadStats] = None) -> Any:
    t0 = time.perf_counter()
    with open(path) as f:
        out = json.load(f)
    if stats is not None:
        stats.slices_read += 1
        stats.bytes_read += os.path.getsize(path)
        stats.read_seconds += time.perf_counter() - t0
    return out
