"""LRU slice cache (paper §V-E).

Slots hold whole deserialized slices; eviction is least-recently-used.
``slots=0`` disables caching (the paper's c0 configuration), ``slots=14``
fits one slice per attribute (c14).  Hit/miss counters feed the layout
micro-benchmarks; the cache is transparent to the GoFS API user.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional


class SliceCache:
    """Thread-safe: the prefetcher's background pool (gofs.prefetch) and the
    caller's thread may hit the same store concurrently.  The lock guards
    the LRU bookkeeping only; the ``loader`` disk read runs outside it (two
    threads may race the same cold key and both read — harmless, the LRU
    keeps one copy)."""

    def __init__(self, slots: int = 14):
        self.slots = slots
        self._data: "OrderedDict[str, Any]" = OrderedDict()
        self._pinned: Dict[str, Any] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: str, loader: Callable[[], Any],
            pin: bool = False) -> Any:
        """``pin=True`` keeps the value resident outside the LRU slots —
        for metadata-grade slices (tile maps, delta payload pools) that
        every staging pass re-derives from; they must survive ``slots=0``
        (the c0 configuration disables *value* caching, not metadata)."""
        if pin:
            with self._lock:
                if key in self._pinned:
                    self.hits += 1
                    return self._pinned[key]
                self.misses += 1
            val = loader()
            with self._lock:
                self._pinned.setdefault(key, val)
                return self._pinned[key]
        if self.slots <= 0:
            with self._lock:
                self.misses += 1
            return loader()
        with self._lock:
            if key in self._data:
                self.hits += 1
                self._data.move_to_end(key)
                return self._data[key]
            self.misses += 1
        val = loader()
        with self._lock:
            self._data[key] = val
            if len(self._data) > self.slots:
                self._data.popitem(last=False)
        return val

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self._pinned.clear()

    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "resident": len(self._data),
            "pinned": len(self._pinned),
        }
