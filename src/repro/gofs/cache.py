"""LRU slice cache (paper §V-E).

Slots hold whole deserialized slices; eviction is least-recently-used.
``slots=0`` disables caching (the paper's c0 configuration), ``slots=14``
fits one slice per attribute (c14).  ``byte_budget`` optionally bounds the
LRU tier by RESIDENT BYTES as well — eviction runs until both the slot
count and the byte budget hold, which is what a long-lived serving
process needs (slot counts say nothing about slice size).  Hit/miss
counters feed the layout micro-benchmarks; the cache is transparent to
the GoFS API user.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, Optional


def _value_nbytes(val: Any) -> int:
    """Best-effort byte size of a cached slice: ndarray-likes report
    ``nbytes``; containers sum their values; everything else counts 0
    (budgeting is for bulk slice payloads, not tiny metadata)."""
    n = getattr(val, "nbytes", None)
    if n is not None:
        return int(n)
    if isinstance(val, dict):
        return sum(_value_nbytes(v) for v in val.values())
    if isinstance(val, (list, tuple)):
        return sum(_value_nbytes(v) for v in val)
    return 0


class SliceCache:
    """Thread-safe: the prefetcher's background pool (gofs.prefetch) and the
    caller's thread may hit the same store concurrently.  The lock guards
    the LRU bookkeeping only; the ``loader`` disk read runs outside it (two
    threads may race the same cold key and both read — harmless, the LRU
    keeps one copy).

    Pinned entries (``pin=True``) live outside both the slot count and the
    byte budget: they are metadata-grade values (tile maps, delta payload
    pools) that every staging pass re-derives from and must never be
    evicted (the no-lost-pins invariant the concurrency stress test
    hammers)."""

    def __init__(self, slots: int = 14, byte_budget: Optional[int] = None):
        self.slots = slots
        self.byte_budget = byte_budget
        self._data: "OrderedDict[str, Any]" = OrderedDict()
        self._sizes: Dict[str, int] = {}
        self._bytes = 0  # resident bytes in the LRU tier
        self._pinned: Dict[str, Any] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: str, loader: Callable[[], Any],
            pin: bool = False) -> Any:
        """``pin=True`` keeps the value resident outside the LRU slots —
        for metadata-grade slices (tile maps, delta payload pools) that
        every staging pass re-derives from; they must survive ``slots=0``
        (the c0 configuration disables *value* caching, not metadata)."""
        if pin:
            with self._lock:
                if key in self._pinned:
                    self.hits += 1
                    return self._pinned[key]
                self.misses += 1
            val = loader()
            with self._lock:
                self._pinned.setdefault(key, val)
                return self._pinned[key]
        if self.slots <= 0:
            with self._lock:
                self.misses += 1
            return loader()
        with self._lock:
            if key in self._data:
                self.hits += 1
                self._data.move_to_end(key)
                return self._data[key]
            self.misses += 1
        val = loader()
        nb = _value_nbytes(val)
        with self._lock:
            if key not in self._data:
                self._data[key] = val
                self._sizes[key] = nb
                self._bytes += nb
            self._evict_locked()
        return val

    def _evict_locked(self) -> None:
        """Evict LRU entries until the slot count AND byte budget hold.
        Caller holds the lock.  A single value larger than the whole
        budget is evicted immediately after insertion — residency is never
        allowed to exceed the budget at lock release."""
        while self._data and (
            len(self._data) > self.slots
            or (self.byte_budget is not None
                and self._bytes > self.byte_budget)
        ):
            k, _ = self._data.popitem(last=False)
            self._bytes -= self._sizes.pop(k, 0)
            self.evictions += 1

    def invalidate(self, predicate: Callable[[str], bool]) -> int:
        """Drop every entry — LRU *and* pinned — whose key satisfies
        ``predicate``.  Returns the number of entries dropped.

        This is the append-observation hook: when a collection grows in
        place, the rewritten tail pack slices and the extended tile-map /
        delta-pool metadata must leave the cache (a stale pinned payload
        pool would silently serve pre-append values forever), while every
        untouched slice stays resident.  Unlike ``clear`` this is
        targeted: survivors keep their LRU position and pin status."""
        dropped = 0
        with self._lock:
            for k in [k for k in self._data if predicate(k)]:
                del self._data[k]
                self._bytes -= self._sizes.pop(k, 0)
                dropped += 1
            for k in [k for k in self._pinned if predicate(k)]:
                del self._pinned[k]
                dropped += 1
        return dropped

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self._sizes.clear()
            self._bytes = 0
            self._pinned.clear()

    def stats(self) -> Dict[str, float]:
        with self._lock:
            total = self.hits + self.misses
            return {
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0,
                "resident": len(self._data),
                "resident_bytes": self._bytes,
                "byte_budget": self.byte_budget,
                "pinned": len(self._pinned),
                "evictions": self.evictions,
            }
