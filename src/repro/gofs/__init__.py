"""GoFS — Graph-oriented File System (paper §V).

Distributed slice-based storage for time-series graph collections:
partitioned by topology, subgraphs bin-packed into slices (§V-D), instances
temporally packed (§V-C), attributes projected into separate slices (§V-B),
LRU slice caching (§V-E).  ``GoFSStore`` implements the iBSP engine's
``InstanceProvider`` protocol — Gopher-on-GoFS, as co-designed in the paper.
"""
from repro.gofs.cache import SliceCache
from repro.gofs.layout import append_instances, deploy_collection
from repro.gofs.prefetch import SlicePrefetcher, StagedChunk
from repro.gofs.store import GoFSStore

__all__ = [
    "SliceCache", "SlicePrefetcher", "StagedChunk", "append_instances",
    "deploy_collection", "GoFSStore",
]
