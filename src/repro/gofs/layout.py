"""GoFS on-disk layout: deployment-time packing (paper §V-A..D).

Directory structure (one collection)::

    <root>/collection.json                     global metadata slice
    <root>/part_<p>/template_<b>.npz           topology slice per bin
    <root>/part_<p>/meta.json                  partition metadata slice
    <root>/part_<p>/attr_<kind>_<name>_b<b>_t<k>.npz
                                               attribute slice: one attribute
                                               x one subgraph bin x one time
                                               pack of ``instances_per_slice``

Deployment-time knobs (fixed at write time, as the paper requires):
``bins_per_partition`` (s20/s40 §V-D) and ``instances_per_slice`` (i1/i20
§V-C).  Constant attributes are stored once in the template slice and never
per instance; default-valued attributes are stored per instance only when
the instance actually overrides them (§V-B value inheritance).

Block-sparse tile maps (``sparse_absent=``): for each named edge
attribute, deployment additionally records one ``tilemap_<attr>.npz``
slice at the collection root holding the PER-PACK nonzero-tile maps —
which (partition, tile) blocks of the blocked layout
(``repro.core.blocked.build_blocked`` on this collection's partitioning)
contain at least one edge whose value differs from the declared *absent*
value in that instance.  ``GoFSStore.load_blocked(...,
layout="sparse")`` consumes these maps to emit packed
:class:`~repro.core.blocked.SparseBlocked` tensors without re-scanning
the values, and ``load_blocked_stream`` uses them to pin a stream-wide
pow2 tile bucket before any value slice is read.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import GraphConfig
from repro.core.graph import TimeSeriesGraph
from repro.core.partition import (
    bin_pack_subgraphs,
    discover_subgraphs,
    partition_graph,
)
from repro.core.subgraph import SubgraphTopology, build_subgraphs
from repro.gofs.slices import write_array_slice, write_json_slice


def attr_slice_name(kind: str, attr: str, b: int, pack: int) -> str:
    return f"attr_{kind}_{attr}_b{b}_t{pack}"


def tile_map_name(attr: str) -> str:
    return f"tilemap_{attr}"


def _write_tile_maps(
    tsg: TimeSeriesGraph,
    cfg: GraphConfig,
    root: str,
    assign: np.ndarray,
    sparse_absent: Dict[str, float],
    n_packs: int,
    ipack: int,
) -> None:
    """Record per-pack nonzero-tile maps for the named edge attributes.

    One ``tilemap_<attr>.npz`` at the collection root per attribute: the
    blocked tile index fingerprint (``tiles_rc``/``btiles_rc`` +
    ``block_size``, so a reader can verify its ``BlockedGraph`` matches
    the deployment's) plus, per time pack *k*, ``local_k``
    (rows, P, T) and ``boundary_k`` (rows, P, Tb) uint8 active-tile maps
    relative to the attribute's declared absent value."""
    from repro.core.blocked import build_blocked

    tmpl = tsg.template
    bg = build_blocked(tmpl, assign, cfg.block_size)
    n_inst = len(tsg)
    n_valid = int(bg.n_tiles.sum()) + int(bg.n_btiles.sum())
    for name, absent in sparse_absent.items():
        tmpl.edge_attr(name)  # KeyError on unknown attribute
        arrs: Dict[str, np.ndarray] = {
            "tiles_rc": bg.tiles_rc,
            "btiles_rc": bg.btiles_rc,
            "block_size": np.asarray(bg.block_size, np.int64),
            "absent": np.asarray(absent, np.float64),
            "n_packs": np.asarray(n_packs, np.int64),
        }
        n_active = 0
        for k in range(n_packs):
            t0, t1 = k * ipack, min((k + 1) * ipack, n_inst)
            w = np.stack([tsg.edge_values(t, name) for t in range(t0, t1)])
            act_l, act_b = bg.active_tile_maps(w, zero=float(absent))
            n_active += int(act_l.sum()) + int(act_b.sum())
            arrs[f"local_{k}"] = act_l.astype(np.uint8)
            arrs[f"boundary_{k}"] = act_b.astype(np.uint8)
        # collection-wide active-tile fraction: the planner's layout
        # decision needs only this scalar, recorded so a reader can price
        # the sparse layout without touching a single value slice — even
        # when its own BlockedGraph differs from the deployment's
        arrs["occupancy"] = np.asarray(
            n_active / max(1, n_inst * n_valid), np.float64
        )
        write_array_slice(os.path.join(root, tile_map_name(name)), arrs)


def deploy_collection(
    tsg: TimeSeriesGraph,
    cfg: GraphConfig,
    root: str,
    *,
    assign: Optional[np.ndarray] = None,
    sparse_absent: Optional[Dict[str, float]] = None,
) -> Dict:
    """Partition, bin-pack, time-pack, and write the collection to disk.

    ``sparse_absent``: {edge attribute -> absent value} — for each entry a
    per-pack nonzero-tile map slice is recorded at the root (see module
    docstring), enabling the store's block-sparse staging path.

    Returns the global metadata dict (also written to collection.json).
    """
    tmpl = tsg.template
    if assign is None:
        assign = partition_graph(tmpl, cfg.num_partitions, seed=cfg.seed)
    sg_ids = discover_subgraphs(tmpl, assign)
    subgraphs = build_subgraphs(tmpl, assign, sg_ids)
    n_inst = len(tsg)
    ipack = max(1, cfg.instances_per_slice)
    n_packs = -(-n_inst // ipack)

    # group subgraphs per partition, bin-pack by vertex count (§V-D)
    by_part: Dict[int, List[int]] = {}
    for g, topo in subgraphs.items():
        by_part.setdefault(topo.pid, []).append(g)
    global_meta = {
        "name": tmpl.name,
        "num_vertices": int(tmpl.num_vertices),
        "num_edges": int(tmpl.num_edges),
        "num_instances": n_inst,
        "num_partitions": int(cfg.num_partitions),
        "instances_per_slice": ipack,
        "bins_per_partition": int(cfg.bins_per_partition),
        "timestamps": [float(g.timestamp) for g in tsg.instances],
        "durations": [float(g.duration) for g in tsg.instances],
        "vertex_attrs": [
            {"name": a.name, "dtype": a.dtype, "default": a.default,
             "constant": a.constant} for a in tmpl.vertex_attrs
        ],
        "edge_attrs": [
            {"name": a.name, "dtype": a.dtype, "default": a.default,
             "constant": a.constant} for a in tmpl.edge_attrs
        ],
        "partitions": {},
    }

    for p in range(cfg.num_partitions):
        gids = sorted(by_part.get(p, []))
        sizes = np.array([subgraphs[g].num_vertices for g in gids], np.int64)
        ids = np.array(gids, np.int64)
        n_bins = min(cfg.bins_per_partition, max(1, len(gids)))
        bins = bin_pack_subgraphs(sizes, ids, n_bins) if len(gids) else []
        pdir = os.path.join(root, f"part_{p}")
        part_meta = {"pid": p, "bins": [], "n_bins": len(bins)}

        for b, bin_gids in enumerate(bins):
            # ---- template slice: topology of this bin's subgraphs --------
            tarrs: Dict[str, np.ndarray] = {}
            bin_meta = {"subgraphs": [], "bin": b}
            for g in bin_gids.tolist():
                topo = subgraphs[g]
                tarrs[f"sg{g}_vertices"] = topo.vertices
                tarrs[f"sg{g}_lsrc"] = topo.local_src
                tarrs[f"sg{g}_ldst"] = topo.local_dst
                tarrs[f"sg{g}_leid"] = topo.local_edge_id
                tarrs[f"sg{g}_rsrc"] = topo.remote_src
                tarrs[f"sg{g}_rdstv"] = topo.remote_dst_vertex
                tarrs[f"sg{g}_rdstg"] = topo.remote_dst_sgid
                tarrs[f"sg{g}_reid"] = topo.remote_edge_id
                bin_meta["subgraphs"].append(
                    {"sgid": int(g), "n_vertices": int(topo.num_vertices),
                     "n_local_edges": int(topo.num_local_edges),
                     "n_remote_edges": int(len(topo.remote_src))}
                )
            write_array_slice(os.path.join(pdir, f"template_{b}"), tarrs)
            part_meta["bins"].append(bin_meta)

            # ---- attribute slices: kind x attr x time pack ---------------
            # concatenated vertex / edge index spaces for the whole bin
            v_cat = np.concatenate(
                [subgraphs[g].vertices for g in bin_gids.tolist()]
            ) if len(bin_gids) else np.array([], np.int64)
            le_cat = np.concatenate(
                [subgraphs[g].local_edge_id for g in bin_gids.tolist()]
            ) if len(bin_gids) else np.array([], np.int64)
            re_cat = np.concatenate(
                [subgraphs[g].remote_edge_id for g in bin_gids.tolist()]
            ) if len(bin_gids) else np.array([], np.int64)

            for a in tmpl.vertex_attrs:
                if a.constant is not None:
                    continue  # stored once in template metadata (§V-B)
                for k in range(n_packs):
                    t0, t1 = k * ipack, min((k + 1) * ipack, n_inst)
                    vals = np.stack([
                        tsg.vertex_values(t, a.name)[v_cat] for t in range(t0, t1)
                    ])
                    write_array_slice(
                        os.path.join(pdir, attr_slice_name("v", a.name, b, k)),
                        {"vals": vals},
                    )
            for a in tmpl.edge_attrs:
                if a.constant is not None:
                    continue
                for k in range(n_packs):
                    t0, t1 = k * ipack, min((k + 1) * ipack, n_inst)
                    lvals = np.stack([
                        tsg.edge_values(t, a.name)[le_cat] for t in range(t0, t1)
                    ])
                    rvals = np.stack([
                        tsg.edge_values(t, a.name)[re_cat] for t in range(t0, t1)
                    ])
                    write_array_slice(
                        os.path.join(pdir, attr_slice_name("e", a.name, b, k)),
                        {"local": lvals, "remote": rvals},
                    )
        write_json_slice(os.path.join(pdir, "meta.json"), part_meta)
        global_meta["partitions"][str(p)] = {
            "n_subgraphs": len(gids),
            "n_bins": len(bins),
        }

    if sparse_absent:
        _write_tile_maps(tsg, cfg, root, assign, sparse_absent,
                         n_packs, ipack)
        global_meta["sparse_absent"] = {
            k: float(v) for k, v in sparse_absent.items()
        }
    write_json_slice(os.path.join(root, "collection.json"), global_meta)
    return global_meta
