"""GoFS on-disk layout: deployment-time packing (paper §V-A..D).

Directory structure (one collection)::

    <root>/collection.json                     global metadata slice
    <root>/part_<p>/template_<b>.npz           topology slice per bin
    <root>/part_<p>/meta.json                  partition metadata slice
    <root>/part_<p>/attr_<kind>_<name>_b<b>_t<k>.npz
                                               attribute slice: one attribute
                                               x one subgraph bin x one time
                                               pack of ``instances_per_slice``

Deployment-time knobs (fixed at write time, as the paper requires):
``bins_per_partition`` (s20/s40 §V-D) and ``instances_per_slice`` (i1/i20
§V-C).  Constant attributes are stored once in the template slice and never
per instance; default-valued attributes are stored per instance only when
the instance actually overrides them (§V-B value inheritance).

Block-sparse tile maps (``sparse_absent=``): for each named edge
attribute, deployment additionally records one ``tilemap_<attr>.npz``
slice at the collection root holding the PER-PACK nonzero-tile maps —
which (partition, tile) blocks of the blocked layout
(``repro.core.blocked.build_blocked`` on this collection's partitioning)
contain at least one edge whose value differs from the declared *absent*
value in that instance.  ``GoFSStore.load_blocked(...,
layout="sparse")`` consumes these maps to emit packed
:class:`~repro.core.blocked.SparseBlocked` tensors without re-scanning
the values, and ``load_blocked_stream`` uses them to pin a stream-wide
pow2 tile bucket before any value slice is read.

Delta tile chain (written alongside the tile maps): the paper's
time-series graphs vary slowly, so consecutive instances share most of
their packed tile *contents*.  Deployment content-hashes every active
(instance, partition, tile) block into a deduplicated payload pool and
stores one ``delta_<attr>.npz`` slice at the root: ``payloads_local`` /
``payloads_boundary`` (U, B, B) unique tile values plus ``ref_local``
(I, P, T) / ``ref_boundary`` (I, P, Tb) int32 maps from each active
template-tile slot to its payload id (-1 = inactive).  A tile unchanged
since instance *t-1* resolves to the same payload id — stored once,
referenced I times.  Two summary scalars ride in the (metadata-sized)
``tilemap_<attr>.npz`` so ``GopherSession.plan`` can price delta staging
without opening the payload slice: ``delta_unique_ratio`` (unique
payloads / active tile-instances) and ``delta_monotone`` (1 iff every
instance's values are elementwise <= the previous instance's — the
warm-start exactness precondition for min-plus, see
docs/ARCHITECTURE.md).
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import GraphConfig
from repro.core.graph import TimeSeriesGraph
from repro.core.partition import (
    bin_pack_subgraphs,
    discover_subgraphs,
    partition_graph,
)
from repro.core.subgraph import SubgraphTopology, build_subgraphs
from repro.gofs.slices import (
    read_array_slice,
    read_json_slice,
    write_array_slice,
    write_json_slice,
)


def attr_slice_name(kind: str, attr: str, b: int, pack: int) -> str:
    return f"attr_{kind}_{attr}_b{b}_t{pack}"


def tile_map_name(attr: str) -> str:
    return f"tilemap_{attr}"


def delta_slice_name(attr: str) -> str:
    return f"delta_{attr}"


def _intern_tiles(
    vals: np.ndarray,
    act: np.ndarray,
    pool: Dict[bytes, int],
    payloads: List[np.ndarray],
    ref_out: np.ndarray,
) -> None:
    """Content-hash the active tiles of one dense pack fill into the
    payload pool, writing payload ids into ``ref_out`` (rows, P, T) in
    place.  Exact-bytes dedup: two tiles share a payload iff their float32
    contents are bitwise identical."""
    ii, pp, tt = np.nonzero(act)
    for i, p, t in zip(ii.tolist(), pp.tolist(), tt.tolist()):
        tile = np.ascontiguousarray(vals[i, p, t])
        key = tile.tobytes()
        pid = pool.get(key)
        if pid is None:
            pid = len(payloads)
            pool[key] = pid
            payloads.append(tile)
        ref_out[i, p, t] = pid


def _write_tile_maps(
    tsg: TimeSeriesGraph,
    cfg: GraphConfig,
    root: str,
    assign: np.ndarray,
    sparse_absent: Dict[str, float],
    n_packs: int,
    ipack: int,
) -> None:
    """Record per-pack nonzero-tile maps for the named edge attributes.

    One ``tilemap_<attr>.npz`` at the collection root per attribute: the
    blocked tile index fingerprint (``tiles_rc``/``btiles_rc`` +
    ``block_size``, so a reader can verify its ``BlockedGraph`` matches
    the deployment's) plus, per time pack *k*, ``local_k``
    (rows, P, T) and ``boundary_k`` (rows, P, Tb) uint8 active-tile maps
    relative to the attribute's declared absent value.

    Alongside each tile map, one ``delta_<attr>.npz`` payload slice
    records the deduplicated tile chain (module docstring): unique tile
    contents once, plus per-instance payload references."""
    from repro.core.blocked import build_blocked

    tmpl = tsg.template
    bg = build_blocked(tmpl, assign, cfg.block_size)
    n_inst = len(tsg)
    B = int(bg.block_size)
    n_valid = int(bg.n_tiles.sum()) + int(bg.n_btiles.sum())
    for name, absent in sparse_absent.items():
        tmpl.edge_attr(name)  # KeyError on unknown attribute
        arrs: Dict[str, np.ndarray] = {
            "tiles_rc": bg.tiles_rc,
            "btiles_rc": bg.btiles_rc,
            "block_size": np.asarray(bg.block_size, np.int64),
            "absent": np.asarray(absent, np.float64),
            "n_packs": np.asarray(n_packs, np.int64),
        }
        pool_l: Dict[bytes, int] = {}
        pool_b: Dict[bytes, int] = {}
        pay_l: List[np.ndarray] = []
        pay_b: List[np.ndarray] = []
        ref_l = np.full((n_inst, bg.n_parts, bg.t_max), -1, np.int32)
        ref_b = np.full((n_inst, bg.n_parts, bg.tb_max), -1, np.int32)
        monotone = True
        prev_w: Optional[np.ndarray] = None
        n_active = 0
        for k in range(n_packs):
            t0, t1 = k * ipack, min((k + 1) * ipack, n_inst)
            w = np.stack([tsg.edge_values(t, name) for t in range(t0, t1)])
            act_l, act_b = bg.active_tile_maps(w, zero=float(absent))
            n_active += int(act_l.sum()) + int(act_b.sum())
            arrs[f"local_{k}"] = act_l.astype(np.uint8)
            arrs[f"boundary_{k}"] = act_b.astype(np.uint8)
            # ---- delta chain: dedup active tile contents across time -----
            dl = bg.fill_local_batch(w, zero=float(absent))
            db = bg.fill_boundary_batch(w, zero=float(absent))
            _intern_tiles(dl, act_l, pool_l, pay_l, ref_l[t0:t1])
            _intern_tiles(db, act_b, pool_b, pay_b, ref_b[t0:t1])
            for j in range(t1 - t0):
                wj = np.asarray(w[j], np.float32)
                if prev_w is not None:
                    monotone = monotone and bool(np.all(wj <= prev_w))
                prev_w = wj
        # collection-wide active-tile fraction: the planner's layout
        # decision needs only this scalar, recorded so a reader can price
        # the sparse layout without touching a single value slice — even
        # when its own BlockedGraph differs from the deployment's
        arrs["occupancy"] = np.asarray(
            n_active / max(1, n_inst * n_valid), np.float64
        )
        # delta summary scalars (planner-facing; payloads stay in the
        # separate delta slice so planning never pays the value bytes)
        n_unique = len(pay_l) + len(pay_b)
        arrs["delta_unique_ratio"] = np.asarray(
            n_unique / max(1, n_active), np.float64
        )
        arrs["delta_monotone"] = np.asarray(int(monotone), np.int64)
        write_array_slice(os.path.join(root, tile_map_name(name)), arrs)
        write_array_slice(
            os.path.join(root, delta_slice_name(name)),
            {
                "tiles_rc": bg.tiles_rc,
                "btiles_rc": bg.btiles_rc,
                "block_size": np.asarray(bg.block_size, np.int64),
                "absent": np.asarray(absent, np.float64),
                "n_instances": np.asarray(n_inst, np.int64),
                "payloads_local": (
                    np.stack(pay_l) if pay_l
                    else np.zeros((0, B, B), np.float32)
                ),
                "payloads_boundary": (
                    np.stack(pay_b) if pay_b
                    else np.zeros((0, B, B), np.float32)
                ),
                "ref_local": ref_l,
                "ref_boundary": ref_b,
            },
        )


def deploy_collection(
    tsg: TimeSeriesGraph,
    cfg: GraphConfig,
    root: str,
    *,
    assign: Optional[np.ndarray] = None,
    sparse_absent: Optional[Dict[str, float]] = None,
    append: bool = False,
) -> Dict:
    """Partition, bin-pack, time-pack, and write the collection to disk.

    ``sparse_absent``: {edge attribute -> absent value} — for each entry a
    per-pack nonzero-tile map slice is recorded at the root (see module
    docstring), enabling the store's block-sparse staging path.

    ``append=True``: ``root`` must already hold a deployed collection and
    ``tsg`` holds ONLY the new instances — delegates to
    :func:`append_instances` (partitioning, binning, and sparse/delta
    recording are inherited from the existing deployment; ``cfg``,
    ``assign`` and ``sparse_absent`` are ignored).

    Returns the global metadata dict (also written to collection.json).
    """
    if append:
        return append_instances(tsg, root)
    tmpl = tsg.template
    if assign is None:
        assign = partition_graph(tmpl, cfg.num_partitions, seed=cfg.seed)
    sg_ids = discover_subgraphs(tmpl, assign)
    subgraphs = build_subgraphs(tmpl, assign, sg_ids)
    n_inst = len(tsg)
    ipack = max(1, cfg.instances_per_slice)
    n_packs = -(-n_inst // ipack)

    # group subgraphs per partition, bin-pack by vertex count (§V-D)
    by_part: Dict[int, List[int]] = {}
    for g, topo in subgraphs.items():
        by_part.setdefault(topo.pid, []).append(g)
    global_meta = {
        "name": tmpl.name,
        "num_vertices": int(tmpl.num_vertices),
        "num_edges": int(tmpl.num_edges),
        "num_instances": n_inst,
        "num_partitions": int(cfg.num_partitions),
        "instances_per_slice": ipack,
        "bins_per_partition": int(cfg.bins_per_partition),
        "timestamps": [float(g.timestamp) for g in tsg.instances],
        "durations": [float(g.duration) for g in tsg.instances],
        "vertex_attrs": [
            {"name": a.name, "dtype": a.dtype, "default": a.default,
             "constant": a.constant} for a in tmpl.vertex_attrs
        ],
        "edge_attrs": [
            {"name": a.name, "dtype": a.dtype, "default": a.default,
             "constant": a.constant} for a in tmpl.edge_attrs
        ],
        "partitions": {},
        # manifest version: bumped by every append_instances commit, so a
        # live reader can detect growth with one metadata read
        "version": 0,
    }

    for p in range(cfg.num_partitions):
        gids = sorted(by_part.get(p, []))
        sizes = np.array([subgraphs[g].num_vertices for g in gids], np.int64)
        ids = np.array(gids, np.int64)
        n_bins = min(cfg.bins_per_partition, max(1, len(gids)))
        bins = bin_pack_subgraphs(sizes, ids, n_bins) if len(gids) else []
        pdir = os.path.join(root, f"part_{p}")
        part_meta = {"pid": p, "bins": [], "n_bins": len(bins)}

        for b, bin_gids in enumerate(bins):
            # ---- template slice: topology of this bin's subgraphs --------
            tarrs: Dict[str, np.ndarray] = {}
            bin_meta = {"subgraphs": [], "bin": b}
            for g in bin_gids.tolist():
                topo = subgraphs[g]
                tarrs[f"sg{g}_vertices"] = topo.vertices
                tarrs[f"sg{g}_lsrc"] = topo.local_src
                tarrs[f"sg{g}_ldst"] = topo.local_dst
                tarrs[f"sg{g}_leid"] = topo.local_edge_id
                tarrs[f"sg{g}_rsrc"] = topo.remote_src
                tarrs[f"sg{g}_rdstv"] = topo.remote_dst_vertex
                tarrs[f"sg{g}_rdstg"] = topo.remote_dst_sgid
                tarrs[f"sg{g}_reid"] = topo.remote_edge_id
                bin_meta["subgraphs"].append(
                    {"sgid": int(g), "n_vertices": int(topo.num_vertices),
                     "n_local_edges": int(topo.num_local_edges),
                     "n_remote_edges": int(len(topo.remote_src))}
                )
            write_array_slice(os.path.join(pdir, f"template_{b}"), tarrs)
            part_meta["bins"].append(bin_meta)

            # ---- attribute slices: kind x attr x time pack ---------------
            # concatenated vertex / edge index spaces for the whole bin
            v_cat = np.concatenate(
                [subgraphs[g].vertices for g in bin_gids.tolist()]
            ) if len(bin_gids) else np.array([], np.int64)
            le_cat = np.concatenate(
                [subgraphs[g].local_edge_id for g in bin_gids.tolist()]
            ) if len(bin_gids) else np.array([], np.int64)
            re_cat = np.concatenate(
                [subgraphs[g].remote_edge_id for g in bin_gids.tolist()]
            ) if len(bin_gids) else np.array([], np.int64)

            for a in tmpl.vertex_attrs:
                if a.constant is not None:
                    continue  # stored once in template metadata (§V-B)
                for k in range(n_packs):
                    t0, t1 = k * ipack, min((k + 1) * ipack, n_inst)
                    vals = np.stack([
                        tsg.vertex_values(t, a.name)[v_cat] for t in range(t0, t1)
                    ])
                    write_array_slice(
                        os.path.join(pdir, attr_slice_name("v", a.name, b, k)),
                        {"vals": vals},
                    )
            for a in tmpl.edge_attrs:
                if a.constant is not None:
                    continue
                for k in range(n_packs):
                    t0, t1 = k * ipack, min((k + 1) * ipack, n_inst)
                    lvals = np.stack([
                        tsg.edge_values(t, a.name)[le_cat] for t in range(t0, t1)
                    ])
                    rvals = np.stack([
                        tsg.edge_values(t, a.name)[re_cat] for t in range(t0, t1)
                    ])
                    write_array_slice(
                        os.path.join(pdir, attr_slice_name("e", a.name, b, k)),
                        {"local": lvals, "remote": rvals},
                    )
        write_json_slice(os.path.join(pdir, "meta.json"), part_meta)
        global_meta["partitions"][str(p)] = {
            "n_subgraphs": len(gids),
            "n_bins": len(bins),
        }

    if sparse_absent:
        _write_tile_maps(tsg, cfg, root, assign, sparse_absent,
                         n_packs, ipack)
        global_meta["sparse_absent"] = {
            k: float(v) for k, v in sparse_absent.items()
        }
    write_json_slice(os.path.join(root, "collection.json"), global_meta)
    return global_meta


# --------------------------------------------------------------------------
# streaming ingestion: append-only growth of a deployed collection
# --------------------------------------------------------------------------

def _pool_from_payloads(pays: np.ndarray) -> Tuple[Dict[bytes, int], List[np.ndarray]]:
    """Rehydrate the content-hash dedup pool from a recorded payload stack
    so appended tiles intern against the SAME payload ids — fingerprint
    continuity: an unchanged tile in an appended instance resolves to the
    payload the original deploy wrote."""
    pool: Dict[bytes, int] = {}
    payloads: List[np.ndarray] = []
    for i in range(len(pays)):
        tile = np.ascontiguousarray(pays[i])
        pool.setdefault(tile.tobytes(), i)
        payloads.append(tile)
    return pool, payloads


def _append_attr_slices(
    store, tsg_new: TimeSeriesGraph, root: str,
    old_n: int, new_n: int, ipack: int, n_packs: int,
) -> None:
    """Write the appended instances' attribute values: the tail pack (when
    ``old_n`` is not pack-aligned) is rewritten with its preserved old rows
    plus the new ones (atomically — old readers keep indexing the same
    rows), and each fully-new pack gets a fresh slice per (partition, bin,
    attribute)."""
    meta = store.meta
    k_first = old_n // ipack
    for p in range(int(meta["num_partitions"])):
        pdir = os.path.join(root, f"part_{p}")
        for b in range(len(store._part_meta[p]["bins"])):
            v_cat = store._bin_concat_ids(p, b, "vertices")
            le_cat = store._bin_concat_ids(p, b, "local_edge_id")
            re_cat = store._bin_concat_ids(p, b, "remote_edge_id")
            for a in meta["vertex_attrs"]:
                if a["constant"] is not None:
                    continue  # stored once in template metadata (§V-B)
                name = a["name"]
                for k in range(k_first, n_packs):
                    t0, t1 = k * ipack, min((k + 1) * ipack, new_n)
                    s = max(t0, old_n)
                    vals = np.stack([
                        np.asarray(tsg_new.vertex_values(t - old_n, name))[v_cat]
                        for t in range(s, t1)
                    ])
                    path = os.path.join(pdir, attr_slice_name("v", name, b, k))
                    if s > t0:
                        old = read_array_slice(path)["vals"][: s - t0]
                        vals = np.concatenate(
                            [old, vals.astype(old.dtype, copy=False)]
                        )
                    write_array_slice(path, {"vals": vals})
            for a in meta["edge_attrs"]:
                if a["constant"] is not None:
                    continue
                name = a["name"]
                for k in range(k_first, n_packs):
                    t0, t1 = k * ipack, min((k + 1) * ipack, new_n)
                    s = max(t0, old_n)
                    lvals = np.stack([
                        np.asarray(tsg_new.edge_values(t - old_n, name))[le_cat]
                        for t in range(s, t1)
                    ])
                    rvals = np.stack([
                        np.asarray(tsg_new.edge_values(t - old_n, name))[re_cat]
                        for t in range(s, t1)
                    ])
                    path = os.path.join(pdir, attr_slice_name("e", name, b, k))
                    if s > t0:
                        sl = read_array_slice(path)
                        ol, orr = sl["local"][: s - t0], sl["remote"][: s - t0]
                        lvals = np.concatenate(
                            [ol, lvals.astype(ol.dtype, copy=False)]
                        )
                        rvals = np.concatenate(
                            [orr, rvals.astype(orr.dtype, copy=False)]
                        )
                    write_array_slice(path, {"local": lvals, "remote": rvals})


def _append_tile_maps(
    store, tsg_new: TimeSeriesGraph, root: str,
    old_n: int, new_n: int, ipack: int, n_packs: int,
) -> None:
    """Extend each recorded tile map + delta chain with the appended
    instances.

    Fast path (fingerprint continuity): when the existing slices validate
    against the deployment's blocked structure and instance count, only
    the new instances are tiled — existing payload ids, per-pack maps, and
    old instances' refs are preserved bitwise, and new tiles intern into
    the rehydrated pool.  When either slice is missing/stale/corrupt the
    chain is rebuilt from scratch over the full (read-back + appended)
    history, restoring the validate-or-fallback invariant rather than
    propagating a broken chain."""
    from repro.core.blocked import build_blocked

    meta = store.meta
    sparse_absent = meta.get("sparse_absent") or {}
    if not sparse_absent:
        return
    tmpl = tsg_new.template
    # partition assignment reconstructed from the deployed subgraph homes
    assign = np.zeros(int(meta["num_vertices"]), np.int64)
    for topo in store.iter_subgraphs():
        assign[np.asarray(topo.vertices, np.int64)] = topo.pid
    n_old_packs = -(-old_n // ipack) if old_n else 0

    for name, absent in sparse_absent.items():
        tm_path = os.path.join(root, tile_map_name(name))
        dl_path = os.path.join(root, delta_slice_name(name))
        tm = dl = None
        try:
            tm = read_array_slice(tm_path)
            dl = read_array_slice(dl_path)
        except (OSError, ValueError, KeyError, EOFError):
            pass
        bsz = None
        for src in (tm, dl):
            if src is not None and "block_size" in src:
                bsz = int(src["block_size"])
                break
        if bsz is None:
            raise ValueError(
                f"append_instances: tile maps for {name!r} are unreadable "
                "and record no block size — cannot extend the chain"
            )
        bg = build_blocked(tmpl, assign, bsz)
        B = int(bg.block_size)

        def _matches(src) -> bool:
            return (
                src is not None
                and int(src["block_size"]) == bg.block_size
                and float(src["absent"]) == float(absent)
                and src["tiles_rc"].shape == bg.tiles_rc.shape
                and np.array_equal(src["tiles_rc"], bg.tiles_rc)
                and src["btiles_rc"].shape == bg.btiles_rc.shape
                and np.array_equal(src["btiles_rc"], bg.btiles_rc)
            )

        incremental = (
            _matches(tm) and _matches(dl)
            and int(dl["n_instances"]) == old_n
            and dl["ref_local"].shape == (old_n, bg.n_parts, bg.t_max)
            and dl["ref_boundary"].shape == (old_n, bg.n_parts, bg.tb_max)
            and int(tm["n_packs"]) == n_old_packs
            and all(f"local_{k}" in tm and f"boundary_{k}" in tm
                    for k in range(n_old_packs))
        )

        def new_row(t: int) -> np.ndarray:
            return np.asarray(tsg_new.edge_values(t - old_n, name), np.float32)

        if incremental:
            pool_l, pay_l = _pool_from_payloads(dl["payloads_local"])
            pool_b, pay_b = _pool_from_payloads(dl["payloads_boundary"])
            ref_l = np.concatenate([
                np.asarray(dl["ref_local"], np.int32),
                np.full((new_n - old_n, bg.n_parts, bg.t_max), -1, np.int32),
            ])
            ref_b = np.concatenate([
                np.asarray(dl["ref_boundary"], np.int32),
                np.full((new_n - old_n, bg.n_parts, bg.tb_max), -1, np.int32),
            ])
            arrs = {k: tm[k] for k in tm}
            monotone = bool(int(tm["delta_monotone"]))
            start_t = old_n
            prev_w = (store.edge_attr_rows(name, [old_n - 1])[0]
                      if old_n else None)
            row = new_row
        else:
            # full rebuild: read the old history back through the store
            w_old = (store.edge_attr_rows(name, range(old_n))
                     if old_n else np.zeros((0, int(meta["num_edges"])),
                                            np.float32))
            pool_l, pay_l = {}, []
            pool_b, pay_b = {}, []
            ref_l = np.full((new_n, bg.n_parts, bg.t_max), -1, np.int32)
            ref_b = np.full((new_n, bg.n_parts, bg.tb_max), -1, np.int32)
            arrs = {
                "tiles_rc": bg.tiles_rc,
                "btiles_rc": bg.btiles_rc,
                "block_size": np.asarray(bg.block_size, np.int64),
                "absent": np.asarray(absent, np.float64),
            }
            monotone = True
            start_t = 0
            prev_w = None
            row = lambda t: w_old[t] if t < old_n else new_row(t)  # noqa: E731

        for k in range(start_t // ipack, n_packs):
            t0, t1 = k * ipack, min((k + 1) * ipack, new_n)
            s = max(t0, start_t)
            w = np.stack([row(t) for t in range(s, t1)])
            act_l, act_b = bg.active_tile_maps(w, zero=float(absent))
            dlv = bg.fill_local_batch(w, zero=float(absent))
            dbv = bg.fill_boundary_batch(w, zero=float(absent))
            _intern_tiles(dlv, act_l, pool_l, pay_l, ref_l[s:t1])
            _intern_tiles(dbv, act_b, pool_b, pay_b, ref_b[s:t1])
            if s > t0:  # partial tail pack: keep the recorded old rows
                arrs[f"local_{k}"] = np.concatenate(
                    [arrs[f"local_{k}"][: s - t0], act_l.astype(np.uint8)]
                )
                arrs[f"boundary_{k}"] = np.concatenate(
                    [arrs[f"boundary_{k}"][: s - t0], act_b.astype(np.uint8)]
                )
            else:
                arrs[f"local_{k}"] = act_l.astype(np.uint8)
                arrs[f"boundary_{k}"] = act_b.astype(np.uint8)
            for j in range(t1 - s):
                wj = np.asarray(w[j], np.float32)
                if prev_w is not None:
                    monotone = monotone and bool(np.all(wj <= prev_w))
                prev_w = wj

        arrs["n_packs"] = np.asarray(n_packs, np.int64)
        n_valid = int(bg.n_tiles.sum()) + int(bg.n_btiles.sum())
        n_active = sum(
            int(arrs[f"local_{k}"].sum()) + int(arrs[f"boundary_{k}"].sum())
            for k in range(n_packs)
        )
        arrs["occupancy"] = np.asarray(
            n_active / max(1, new_n * n_valid), np.float64
        )
        arrs["delta_unique_ratio"] = np.asarray(
            (len(pay_l) + len(pay_b)) / max(1, n_active), np.float64
        )
        arrs["delta_monotone"] = np.asarray(int(monotone), np.int64)
        # delta first, tile map second, manifest (caller) last: each write
        # is individually atomic and every intermediate combination an old
        # reader can observe validates (refs/maps only grow, prefix rows
        # are preserved bitwise)
        write_array_slice(dl_path, {
            "tiles_rc": bg.tiles_rc,
            "btiles_rc": bg.btiles_rc,
            "block_size": np.asarray(bg.block_size, np.int64),
            "absent": np.asarray(absent, np.float64),
            "n_instances": np.asarray(new_n, np.int64),
            "payloads_local": (
                np.stack(pay_l) if pay_l else np.zeros((0, B, B), np.float32)
            ),
            "payloads_boundary": (
                np.stack(pay_b) if pay_b else np.zeros((0, B, B), np.float32)
            ),
            "ref_local": ref_l,
            "ref_boundary": ref_b,
        })
        write_array_slice(tm_path, arrs)


def append_instances(tsg_new: TimeSeriesGraph, root: str) -> Dict:
    """Grow the collection deployed at ``root`` by ``tsg_new``'s instances
    — streaming ingestion, no re-deploy.

    ``tsg_new`` holds ONLY the new instances and must share the deployed
    template (same vertex/edge count and attribute schema).  Partitioning,
    bin packing, the temporal pack size, and sparse/delta recording are
    all inherited from the existing deployment.

    Atomicity contract (docs/ARCHITECTURE.md "Streaming ingestion"): data
    slices are written first, each with an atomic replace; the
    ``collection.json`` manifest — carrying the bumped ``version`` and the
    extended instance count/timestamps — is replaced LAST.  A concurrent
    reader therefore always observes a complete collection: the old
    version until the manifest lands, the new one after.  Old-version
    readers stay valid across the commit because appended writes only add
    rows/packs — every previously-readable row is preserved bitwise.

    Returns the new global metadata dict."""
    from repro.gofs.store import GoFSStore

    meta_path = os.path.join(root, "collection.json")
    n_new = len(tsg_new)
    if n_new == 0:
        return read_json_slice(meta_path)
    store = GoFSStore(root, cache_slots=0)
    meta = dict(store.meta)
    tmpl = tsg_new.template
    if (int(tmpl.num_vertices) != int(meta["num_vertices"])
            or int(tmpl.num_edges) != int(meta["num_edges"])):
        raise ValueError(
            "append_instances: template does not match the deployed "
            f"collection ({tmpl.num_vertices}v/{tmpl.num_edges}e vs "
            f"{meta['num_vertices']}v/{meta['num_edges']}e)"
        )
    old_n = int(meta["num_instances"])
    ipack = int(meta["instances_per_slice"])
    new_n = old_n + n_new
    n_packs = -(-new_n // ipack)

    _append_attr_slices(store, tsg_new, root, old_n, new_n, ipack, n_packs)
    _append_tile_maps(store, tsg_new, root, old_n, new_n, ipack, n_packs)

    meta["num_instances"] = new_n
    meta["timestamps"] = list(meta["timestamps"]) + [
        float(g.timestamp) for g in tsg_new.instances
    ]
    meta["durations"] = list(meta["durations"]) + [
        float(g.duration) for g in tsg_new.instances
    ]
    meta["version"] = int(meta.get("version", 0)) + 1
    write_json_slice(meta_path, meta)
    return meta
