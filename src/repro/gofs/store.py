"""GoFS access API (paper §V-B): subgraph-centric iterators over a deployed
collection, with temporal filtering, attribute projection, value
inheritance, bin-major ordering, and transparent LRU slice caching.

``GoFSStore`` implements ``repro.core.ibsp.InstanceProvider`` so the Gopher
engine runs directly on GoFS.  The API only touches slices of the local
deployment root — network movement belongs to the Gopher layer, exactly the
paper's separation.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import AttributeDef
from repro.core.ibsp import InstanceProvider, SubgraphInstance
from repro.core.subgraph import SubgraphTopology
from repro.gofs.cache import SliceCache
from repro.gofs.layout import attr_slice_name, delta_slice_name, tile_map_name
from repro.gofs.slices import ReadStats, read_array_slice, read_json_slice


class GoFSStore(InstanceProvider):
    def __init__(
        self,
        root: str,
        *,
        cache_slots: int = 14,
        vertex_projection: Optional[Sequence[str]] = None,
        edge_projection: Optional[Sequence[str]] = None,
        time_range: Optional[Tuple[float, float]] = None,
    ):
        self.root = root
        self.stats = ReadStats()
        self.cache = SliceCache(cache_slots)
        self._time_range = time_range
        self.meta = read_json_slice(os.path.join(root, "collection.json"),
                                    self.stats)
        self.version = int(self.meta.get("version", 0))
        self.ipack = int(self.meta["instances_per_slice"])
        self._v_attrs = {a["name"]: AttributeDef(**a)
                         for a in self.meta["vertex_attrs"]}
        self._e_attrs = {a["name"]: AttributeDef(**a)
                         for a in self.meta["edge_attrs"]}
        self.vertex_projection = tuple(
            vertex_projection if vertex_projection is not None
            else self._v_attrs
        )
        self.edge_projection = tuple(
            edge_projection if edge_projection is not None else self._e_attrs
        )
        self._bind_timeline()

        # partition metadata + bin-major subgraph order (§V-D)
        self._part_meta: Dict[int, Any] = {}
        self._sg_home: Dict[int, Tuple[int, int]] = {}  # sgid -> (pid, bin)
        self._order: List[int] = []
        for p in range(int(self.meta["num_partitions"])):
            pm = read_json_slice(
                os.path.join(root, f"part_{p}", "meta.json"), self.stats
            )
            self._part_meta[p] = pm
            for b, bin_meta in enumerate(pm["bins"]):
                for sg in bin_meta["subgraphs"]:
                    g = int(sg["sgid"])
                    self._sg_home[g] = (p, b)
                    self._order.append(g)
        self._topo_cache: Dict[int, SubgraphTopology] = {}
        self._bin_offsets: Dict[Tuple[int, int], Dict[str, Dict[int, Tuple[int, int]]]] = {}

    def _bind_timeline(self) -> None:
        """(Re)derive the visible-instance map from the current manifest —
        the temporal filter (§V-B) applied to the collection's timeline."""
        ts = np.asarray(self.meta["timestamps"], np.float64)
        dur = np.asarray(self.meta["durations"], np.float64)
        if self._time_range is not None:
            lo, hi = self._time_range
            sel = np.nonzero((ts < hi) & (ts + dur > lo))[0]
        else:
            sel = np.arange(len(ts))
        self._t_map: List[int] = [int(i) for i in sel]
        self.timestamps = ts

    # ---------------- streaming ingestion ----------------------------------
    def refresh(self) -> bool:
        """Observe an in-place append: re-read the collection manifest and,
        on a version change, rebind the timeline and invalidate exactly the
        cache entries the append may have rewritten — the partial tail
        pack's value slices plus every tile-map / delta-pool metadata slice
        (their pinned payload pools would otherwise serve pre-append
        values forever).  Untouched slices stay resident; template and
        partition metadata never change across an append.

        Returns True iff the collection changed.  An unreadable manifest
        (e.g. mid-replace on a non-atomic filesystem) leaves the store at
        its current version."""
        try:
            meta = read_json_slice(
                os.path.join(self.root, "collection.json"), self.stats
            )
        except (OSError, ValueError):
            return False
        version = int(meta.get("version", 0))
        n_inst = int(meta["num_instances"])
        if (version == self.version
                and n_inst == int(self.meta["num_instances"])):
            return False
        old_n = int(self.meta["num_instances"])
        k_dirty = old_n // self.ipack  # tail pack rewritten by the append
        self.meta = meta
        self.version = version
        self._bind_timeline()

        def stale(key: str) -> bool:
            if key.startswith("tilemap/") or key.startswith("delta/"):
                return True
            name = key.partition("/")[2]
            if not name.startswith("attr_"):
                return False
            try:
                return int(name.rsplit("_t", 1)[1]) >= k_dirty
            except (IndexError, ValueError):
                return True  # unparseable attr key: drop, never serve stale

        self.cache.invalidate(stale)
        return True

    def append_instances(self, tsg_new) -> Dict:
        """Append new instances to this store's collection in place (see
        :func:`repro.gofs.layout.append_instances`) and refresh this
        reader to the committed version."""
        from repro.gofs.layout import append_instances as _append

        meta = _append(tsg_new, self.root)
        self.refresh()
        return meta

    # ---------------- InstanceProvider ------------------------------------
    def subgraph_ids(self) -> Sequence[int]:
        """Bin-major partition order — the paper's balanced iterator."""
        return list(self._order)

    def num_timesteps(self) -> int:
        return len(self._t_map)

    def get_instance(self, t_idx: int, sgid: int) -> SubgraphInstance:
        t_real = self._t_map[t_idx]
        topo = self.get_topology(sgid)
        p, b = self._sg_home[sgid]
        offs = self._offsets(p, b)
        k, r = divmod(t_real, self.ipack)

        vv: Dict[str, np.ndarray] = {}
        for name in self.vertex_projection:
            a = self._v_attrs[name]
            if a.constant is not None:
                vv[name] = np.full(topo.num_vertices, a.constant,
                                   np.dtype(a.dtype))
                continue
            sl = self._load(p, attr_slice_name("v", name, b, k))
            o0, o1 = offs["v"][sgid]
            vv[name] = sl["vals"][r, o0:o1]
        lev: Dict[str, np.ndarray] = {}
        rev: Dict[str, np.ndarray] = {}
        for name in self.edge_projection:
            a = self._e_attrs[name]
            if a.constant is not None:
                lev[name] = np.full(topo.num_local_edges, a.constant,
                                    np.dtype(a.dtype))
                rev[name] = np.full(len(topo.remote_src), a.constant,
                                    np.dtype(a.dtype))
                continue
            sl = self._load(p, attr_slice_name("e", name, b, k))
            lo0, lo1 = offs["le"][sgid]
            ro0, ro1 = offs["re"][sgid]
            lev[name] = sl["local"][r, lo0:lo1]
            rev[name] = sl["remote"][r, ro0:ro1]
        return SubgraphInstance(
            topology=topo,
            timestep=t_idx,
            timestamp=float(self.timestamps[t_real]),
            vertex_values=vv,
            local_edge_values=lev,
            remote_edge_values=rev,
        )

    # ---------------- topology / template access --------------------------
    def get_topology(self, sgid: int) -> SubgraphTopology:
        if sgid in self._topo_cache:
            return self._topo_cache[sgid]
        p, b = self._sg_home[sgid]
        sl = self._load(p, f"template_{b}")
        for sg in self._part_meta[p]["bins"][b]["subgraphs"]:
            g = int(sg["sgid"])
            if g in self._topo_cache:
                continue
            verts = sl[f"sg{g}_vertices"]
            topo = SubgraphTopology(
                sgid=g, pid=p,
                vertices=verts,
                local_src=sl[f"sg{g}_lsrc"],
                local_dst=sl[f"sg{g}_ldst"],
                local_edge_id=sl[f"sg{g}_leid"],
                remote_src=sl[f"sg{g}_rsrc"],
                remote_dst_vertex=sl[f"sg{g}_rdstv"],
                remote_dst_sgid=sl[f"sg{g}_rdstg"],
                remote_edge_id=sl[f"sg{g}_reid"],
                global_to_local={int(v): i for i, v in enumerate(verts)},
            )
            self._topo_cache[g] = topo
        return self._topo_cache[sgid]

    def iter_subgraphs(self, pid: Optional[int] = None) -> Iterator[SubgraphTopology]:
        """Space iterator: subgraphs in bin-major order (§V-D)."""
        for g in self._order:
            if pid is None or self._sg_home[g][0] == pid:
                yield self.get_topology(g)

    def iter_instances(self, sgid: int) -> Iterator[SubgraphInstance]:
        """Time iterator: a subgraph's instances in time order (§V-B)."""
        for t in range(self.num_timesteps()):
            yield self.get_instance(t, sgid)

    # ---------------- bulk staging (blocked engine path) -------------------
    def _visible_packs(
        self, t_indices: Optional[Sequence[int]] = None
    ) -> Dict[int, List[Tuple[int, int]]]:
        """Visible timesteps grouped by time pack: {pack: [(row, offset)]}.

        ``t_indices``: subset of visible instance indices (default: all);
        ``row`` indexes into that subset."""
        if t_indices is None:
            t_indices = range(len(self._t_map))
        packs: Dict[int, List[Tuple[int, int]]] = {}
        for j, i in enumerate(t_indices):
            k, r = divmod(self._t_map[i], self.ipack)
            packs.setdefault(k, []).append((j, r))
        return packs

    def _bin_concat_ids(self, p: int, b: int, field: str) -> np.ndarray:
        """Template ids for a bin's concatenated value arrays, in slice
        order.  field: 'vertices' | 'local_edge_id' | 'remote_edge_id'."""
        sgs = [int(sg["sgid"]) for sg in self._part_meta[p]["bins"][b]["subgraphs"]]
        if not sgs:
            return np.array([], np.int64)
        return np.concatenate(
            [getattr(self.get_topology(g), field) for g in sgs]
        )

    def edge_attr_rows(
        self, name: str, t_indices: Sequence[int],
        parts: Optional[Sequence[int]] = None,
        fill: float = np.nan,
        halo: bool = False,
    ) -> np.ndarray:
        """Bulk-read an edge attribute for a subset of visible instances
        into template edge order: (len(t_indices), E) float32.

        One slice read per (partition, bin, pack) touched by the subset —
        the chunk grain of ``load_blocked_stream``'s prefetcher.

        ``parts`` restricts the read to those partitions' slice files —
        the shard-local staging path (``repro.cluster.staging``): a
        process reads only the slices of partitions it owns, so its store
        byte traffic is ~its shard fraction of the collection.  Edge
        positions no selected partition references hold ``fill``.

        A partition's slice files record its *outgoing* cut edges (the
        deployment stores each cut edge with its SOURCE subgraph), but the
        consuming ``fill_boundary_batch(parts=...)`` scatters the cut
        edges *incoming* to the owned partitions — which live in the
        PEER partitions' remote arrays.  ``halo=True`` adds that halo
        read: for every non-selected partition, only the ``remote`` half
        of its slices is read (cut edges are the partitioner-minimized
        sliver of the collection), so a shard-local stage is complete
        without reading the peers' local-edge bulk."""
        a = self._e_attrs[name]
        n = len(t_indices)
        E = int(self.meta["num_edges"])
        if a.constant is not None:
            return np.full((n, E), a.constant, np.float32)
        if parts is None:
            parts = range(int(self.meta["num_partitions"]))
            halo = False  # full read: nothing left to halo
            out = np.empty((n, E), np.float32)
        else:
            out = np.full((n, E), fill, np.float32)
        packs = self._visible_packs(t_indices)
        for p in parts:
            for b in range(len(self._part_meta[p]["bins"])):
                le_ids = self._bin_concat_ids(p, b, "local_edge_id")
                re_ids = self._bin_concat_ids(p, b, "remote_edge_id")
                for k, rows in packs.items():
                    sl = self._load(p, attr_slice_name("e", name, b, k))
                    for j, r in rows:
                        out[j, le_ids] = sl["local"][r]
                        out[j, re_ids] = sl["remote"][r]
        if halo:
            owned = set(parts)
            for p in range(int(self.meta["num_partitions"])):
                if p in owned:
                    continue
                for b in range(len(self._part_meta[p]["bins"])):
                    re_ids = self._bin_concat_ids(p, b, "remote_edge_id")
                    if re_ids.size == 0:
                        continue
                    for k, rows in packs.items():
                        sl = self._load(p, attr_slice_name("e", name, b, k))
                        for j, r in rows:
                            out[j, re_ids] = sl["remote"][r]
        return out

    def edge_attr_matrix(self, name: str) -> np.ndarray:
        """Bulk-read an edge attribute for every visible instance into
        template edge order: (I, E) float32.

        One slice read per (partition, bin, pack) instead of one per
        (timestep, subgraph) — the staging path the temporal engine batches
        through ``BlockedGraph.fill_*_batch``.
        """
        return self.edge_attr_rows(name, range(self.num_timesteps()))

    def vertex_attr_matrix(self, name: str) -> np.ndarray:
        """Bulk-read a vertex attribute for every visible instance: (I, V)."""
        a = self._v_attrs[name]
        I = self.num_timesteps()
        V = int(self.meta["num_vertices"])
        dt = np.dtype(a.dtype)
        if a.constant is not None:
            return np.full((I, V), a.constant, dt)
        out = np.empty((I, V), dt)
        packs = self._visible_packs()
        for p in range(int(self.meta["num_partitions"])):
            for b in range(len(self._part_meta[p]["bins"])):
                v_ids = self._bin_concat_ids(p, b, "vertices")
                for k, rows in packs.items():
                    sl = self._load(p, attr_slice_name("v", name, b, k))
                    for i, r in rows:
                        out[i, v_ids] = sl["vals"][r]
        return out

    # -------------------------------------------------- sparse tile maps
    def edge_tile_maps(self, name: str) -> Optional[Dict[str, np.ndarray]]:
        """The deployment-recorded per-pack nonzero-tile maps for an edge
        attribute (``repro.gofs.layout`` ``sparse_absent=``), or ``None``
        when the deployment recorded none."""
        path = os.path.join(self.root, tile_map_name(name))
        if not os.path.exists(path + ".npz"):
            return None
        try:
            return self.cache.get(
                f"tilemap/{name}",
                lambda: read_array_slice(path, self.stats),
                pin=True,  # metadata-grade: survives the c0 (slots=0) config
            )
        except (OSError, ValueError, KeyError, EOFError):
            return None  # truncated/corrupt map: activity unknown, not fatal

    def _recorded_activity(
        self, bg, name: str, zero: float,
        t_indices: Sequence[int],
    ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Assemble (act_local (I, P, T), act_boundary (I, P, Tb)) for the
        visible-instance subset from the recorded per-pack maps.  Returns
        ``None`` when no map was recorded, the absent value differs from
        the requested semiring ``zero``, or the recorded blocked structure
        does not match the caller's ``bg`` (different partitioning, block
        size, or vertex order) — callers then fall back to scanning the
        staged values, which is always correct."""
        maps = self.edge_tile_maps(name)
        if maps is None:
            return None
        if float(maps["absent"]) != float(zero):
            return None
        if int(maps["block_size"]) != bg.block_size:
            return None
        if (maps["tiles_rc"].shape != bg.tiles_rc.shape
                or not np.array_equal(maps["tiles_rc"], bg.tiles_rc)
                or maps["btiles_rc"].shape != bg.btiles_rc.shape
                or not np.array_equal(maps["btiles_rc"], bg.btiles_rc)):
            return None
        n = len(t_indices)
        act_l = np.zeros((n, bg.n_parts, bg.t_max), bool)
        act_b = np.zeros((n, bg.n_parts, bg.tb_max), bool)
        for j, i in enumerate(t_indices):
            k, r = divmod(self._t_map[i], self.ipack)
            act_l[j] = maps[f"local_{k}"][r].astype(bool)
            act_b[j] = maps[f"boundary_{k}"][r].astype(bool)
        return act_l, act_b

    def tile_occupancy(
        self, bg, name: str, *, zero: float = np.inf
    ) -> Optional[float]:
        """Active-tile fraction of the visible collection for an edge
        attribute, computed from the deployment-recorded tile maps ALONE —
        no value slice is read, so a planner can price the sparse layout
        (``repro.gopher``) before staging anything.

        Preference order: per-pack maps matching the caller's ``bg``
        (exact, respects a temporal filter); else the deployment-recorded
        collection-wide ``occupancy`` scalar (an estimate when the
        caller's blocked structure differs from the deployment's); else
        ``None`` — activity unknown without reading values."""
        acts = self._recorded_activity(
            bg, name, zero, range(self.num_timesteps())
        )
        if acts is None:
            maps = self.edge_tile_maps(name)
            if (maps is not None and "occupancy" in maps
                    and float(maps["absent"]) == float(zero)):
                return float(maps["occupancy"])
            return None
        act_l, act_b = acts
        denom = self.num_timesteps() * (
            int(bg.n_tiles.sum()) + int(bg.n_btiles.sum())
        )
        if denom == 0:
            return 0.0
        return float(int(act_l.sum()) + int(act_b.sum())) / denom

    def sparse_buckets(
        self, bg, name: str, *, zero: float = np.inf
    ) -> Optional[Tuple[int, int]]:
        """Pow2 (local, boundary) tile buckets for the visible collection,
        derived from the recorded tile maps ALONE — no value slice is
        read, so a stream can pin one jit shape before staging starts.
        ``None`` when no usable map is recorded."""
        from repro.core.blocked import pow2_bucket

        acts = self._recorded_activity(
            bg, name, zero, range(self.num_timesteps())
        )
        if acts is None:
            return None
        act_l, act_b = acts
        lmax = int(act_l.sum(-1).max()) if act_l.size else 0
        bmax = int(act_b.sum(-1).max()) if act_b.size else 0
        return pow2_bucket(lmax), pow2_bucket(bmax)

    # -------------------------------------------------- delta tile chain
    def edge_delta_index(self, name: str) -> Optional[Dict[str, np.ndarray]]:
        """The deployment-recorded delta tile chain for an edge attribute
        (``repro.gofs.layout`` module docstring): deduplicated payload
        pools + per-instance payload references.  ``None`` when the
        deployment recorded none or the slice is unreadable (corrupt /
        truncated) — readers then fall back to the full value slices."""
        path = os.path.join(self.root, delta_slice_name(name))
        if not os.path.exists(path + ".npz"):
            return None
        try:
            # pinned: the payload pool IS the staging working set — one
            # decode feeds every chunk of every stream (c0 exempts it)
            return self.cache.get(
                f"delta/{name}",
                lambda: read_array_slice(path, self.stats), pin=True,
            )
        except (OSError, ValueError, KeyError, EOFError):
            return None

    def _delta_chain(
        self, bg, name: str, zero: float, t_indices: Sequence[int],
    ) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]]:
        """Validated (ref_local, ref_boundary, payloads_local,
        payloads_boundary) for the visible-instance subset, or ``None``
        when the chain is absent, stale (recorded against a different
        blocked structure / absent value than the caller's), or corrupt
        (refs out of pool range, shape drift) — the same
        validate-or-fallback contract as ``_recorded_activity``."""
        d = self.edge_delta_index(name)
        if d is None:
            return None
        try:
            if float(d["absent"]) != float(zero):
                return None
            if int(d["block_size"]) != bg.block_size:
                return None
            if (d["tiles_rc"].shape != bg.tiles_rc.shape
                    or not np.array_equal(d["tiles_rc"], bg.tiles_rc)
                    or d["btiles_rc"].shape != bg.btiles_rc.shape
                    or not np.array_equal(d["btiles_rc"], bg.btiles_rc)):
                return None
            B = bg.block_size
            n_total = int(d["n_instances"])
            ref_l, ref_b = d["ref_local"], d["ref_boundary"]
            pay_l, pay_b = d["payloads_local"], d["payloads_boundary"]
            if ref_l.shape != (n_total, bg.n_parts, bg.t_max):
                return None
            if ref_b.shape != (n_total, bg.n_parts, bg.tb_max):
                return None
            if pay_l.ndim != 3 or pay_l.shape[1:] != (B, B):
                return None
            if pay_b.ndim != 3 or pay_b.shape[1:] != (B, B):
                return None
            if ref_l.size and int(ref_l.max()) >= len(pay_l):
                return None
            if ref_b.size and int(ref_b.max()) >= len(pay_b):
                return None
            idx = [self._t_map[i] for i in t_indices]
            if idx and max(idx) >= n_total:
                return None
            return (ref_l[idx].astype(np.int64), ref_b[idx].astype(np.int64),
                    np.asarray(pay_l, np.float32),
                    np.asarray(pay_b, np.float32))
        except (KeyError, ValueError, TypeError):
            return None

    def delta_stats(
        self, name: str, *, zero: Optional[float] = None
    ) -> Tuple[Optional[float], Optional[bool]]:
        """Deploy-recorded delta summary for an edge attribute, read from
        the tile-map METADATA slice alone (planning never opens the
        payload slice): (unique-payload / active-tile-instance ratio,
        monotone-nonincreasing flag).  (None, None) when not recorded or
        recorded against a different absent value than ``zero``."""
        maps = self.edge_tile_maps(name)
        if maps is None or "delta_unique_ratio" not in maps:
            return None, None
        if zero is not None and float(maps["absent"]) != float(zero):
            return None, None
        return (float(maps["delta_unique_ratio"]),
                bool(int(maps["delta_monotone"])))

    def _stage_delta(self, bg, zero: float, chain):
        """Packed batch reconstructed from a validated delta chain: each
        unique payload's bytes enter RAM once (from the pinned pool) and
        fan out by gather.  Bitwise-identical to the full sparse fill —
        the payloads were recorded from the same fill at deploy time and
        ``pack_tile_index`` assigns the same slots."""
        from repro.core.blocked import SparseBlocked

        ref_l, ref_b, pay_l, pay_b = chain
        tiles, rows, cols, nnz = bg.pack_payload_tiles(
            ref_l, pay_l, bg.tiles_rc, zero)
        btiles, brows, bcols, bnnz = bg.pack_payload_tiles(
            ref_b, pay_b, bg.btiles_rc, zero)
        B2 = bg.block_size * bg.block_size
        uniq = (len(np.unique(ref_l[ref_l >= 0]))
                + len(np.unique(ref_b[ref_b >= 0])))
        src_bytes = int(uniq) * B2 * 4 + int(
            rows.nbytes + cols.nbytes + brows.nbytes + bcols.nbytes
        )
        return SparseBlocked(
            block_size=bg.block_size,
            tiles=tiles, btiles=btiles,
            rows=rows, cols=cols, brows=brows, bcols=bcols,
            nnz=nnz, bnnz=bnnz,
            total_tiles=int(bg.n_tiles.sum()),
            total_btiles=int(bg.n_btiles.sum()),
            source_bytes=src_bytes,
        )

    def load_blocked(
        self, bg, name: str, *, zero: float = np.inf, layout: str = "dense",
        delta: Optional[bool] = None,
    ):
        """Stage an edge attribute straight into blocked instance tensors.

        ``layout="dense"``: (tiles (I, P, T, B, B), btiles (I, P, Tb, B,
        B)) spanning every template tile slot.  ``layout="sparse"``: a
        packed :class:`~repro.core.blocked.SparseBlocked` batch holding
        only each instance's active tiles; the deployment-recorded
        per-pack tile maps (``sparse_absent=`` at deploy time) skip the
        activity re-scan when they match ``bg`` and ``zero``.

        ``delta``: ``None``/``True`` reconstruct the sparse batch from the
        recorded delta tile chain when one validates against ``bg`` and
        ``zero`` (bitwise-identical, unique tile bytes decoded once,
        ``SparseBlocked.source_bytes`` reports the dedup); a stale or
        corrupt chain falls back to the full value slices.  ``False``
        never touches the chain."""
        assert layout in ("dense", "sparse"), layout
        if layout == "sparse":
            if delta is not False:
                chain = self._delta_chain(
                    bg, name, zero, range(self.num_timesteps())
                )
                if chain is not None:
                    return self._stage_delta(bg, zero, chain)
            w = self.edge_attr_matrix(name)
            acts = self._recorded_activity(
                bg, name, zero, range(self.num_timesteps())
            )
            act_l, act_b = acts if acts is not None else (None, None)
            return bg.stage_sparse(
                w, zero=zero, act_local=act_l, act_boundary=act_b,
            )
        w = self.edge_attr_matrix(name)
        return bg.fill_local_batch(w, zero=zero), \
            bg.fill_boundary_batch(w, zero=zero)

    def load_blocked_stream(
        self,
        bg,
        name: str,
        *,
        zero: float = np.inf,
        prefetch_depth: int = 2,
        chunk_instances: Optional[int] = None,
        num_workers: int = 1,
        inflight: Optional[int] = None,
        layout: str = "dense",
        delta: Optional[bool] = None,
        transform=None,
    ):
        """Streaming variant of ``load_blocked``: a
        :class:`~repro.gofs.prefetch.SlicePrefetcher` yielding instance
        chunks as their (bin, pack) slices land, so the engine can execute
        chunk *k* while chunk *k+1* stages (``TemporalEngine.run(...,
        stream=...)`` / ``staging="async"``).

        ``chunk_instances`` defaults to the deployment's temporal pack size
        (``instances_per_slice``) — the natural disk grain: one chunk reads
        each (partition, bin) attribute slice of one time pack exactly once.

        ``layout="sparse"`` stages packed active-tile chunks; when the
        deployment recorded tile maps for this attribute, the stream-wide
        pow2 bucket is pinned from the maps up front (one jit shape for
        the whole stream, no value read needed), else each chunk buckets
        itself.

        ``delta``: as in ``load_blocked`` — a validated delta tile chain
        makes each chunk a payload-pool reconstruction (unique tile bytes
        staged once per chunk, reported via ``StagedChunk.staged_bytes``)
        with no per-chunk value-slice reads; stale/corrupt chains fall
        back to the full read+fill path.  ``transform``: per-instance
        row-wise derived weights computed chunk-wise on the prefetch pool
        (see :class:`~repro.gofs.prefetch.SlicePrefetcher`); transformed
        values bypass the delta chain and recorded buckets, which describe
        the RAW attribute.
        """
        from repro.core.blocked import pow2_bucket
        from repro.gofs.prefetch import SlicePrefetcher, StagedChunk

        assert layout in ("dense", "sparse"), layout
        chunk = int(chunk_instances or self.ipack)
        if layout == "sparse" and delta is not False and transform is None:
            chain = self._delta_chain(
                bg, name, zero, range(self.num_timesteps())
            )
            if chain is not None:
                ref_l, ref_b, pay_l, pay_b = chain
                # stream-wide pow2 buckets straight from the refs: exact,
                # and identical to the bulk delta load's bucket choice
                lnnz = (ref_l >= 0).sum(-1)
                bnz = (ref_b >= 0).sum(-1)
                buck = pow2_bucket(int(lnnz.max()) if lnnz.size else 0)
                bbuck = pow2_bucket(int(bnz.max()) if bnz.size else 0)
                B2 = bg.block_size * bg.block_size

                def stage_delta_chunk(s: int, e: int) -> StagedChunk:
                    rl, rb = ref_l[s:e], ref_b[s:e]
                    tiles, rows, cols, nnz = bg.pack_payload_tiles(
                        rl, pay_l, bg.tiles_rc, zero, bucket=buck)
                    btiles, brows, bcols, bn = bg.pack_payload_tiles(
                        rb, pay_b, bg.btiles_rc, zero, bucket=bbuck)
                    uniq = (len(np.unique(rl[rl >= 0]))
                            + len(np.unique(rb[rb >= 0])))
                    staged = int(uniq) * B2 * 4 + int(
                        rows.nbytes + cols.nbytes
                        + brows.nbytes + bcols.nbytes)
                    return StagedChunk(
                        start=s, count=e - s, tiles=tiles, btiles=btiles,
                        rows=rows, cols=cols, brows=brows, bcols=bcols,
                        nnz=nnz, bnnz=bn, staged_bytes=staged)

                return SlicePrefetcher(
                    bg, None, self.num_timesteps(), zero=zero,
                    prefetch_depth=prefetch_depth, chunk_instances=chunk,
                    num_workers=num_workers, inflight=inflight,
                    layout=layout, stage_fn=stage_delta_chunk,
                )
        bucket = bbucket = None
        if layout == "sparse" and transform is None:
            buckets = self.sparse_buckets(bg, name, zero=zero)
            if buckets is not None:
                bucket, bbucket = buckets
        return SlicePrefetcher(
            bg,
            lambda s, e: self.edge_attr_rows(name, range(s, e)),
            self.num_timesteps(),
            zero=zero,
            prefetch_depth=prefetch_depth,
            chunk_instances=chunk,
            num_workers=num_workers,
            inflight=inflight,
            layout=layout,
            bucket=bucket,
            bbucket=bbucket,
            transform=transform,
        )

    # ---------------- internals -------------------------------------------
    def _load(self, pid: int, slice_name: str) -> Dict[str, np.ndarray]:
        path = os.path.join(self.root, f"part_{pid}", slice_name)
        return self.cache.get(
            f"{pid}/{slice_name}", lambda: read_array_slice(path, self.stats)
        )

    def _offsets(self, p: int, b: int):
        """Start/end offsets of each subgraph inside the bin's concatenated
        vertex/edge value arrays."""
        key = (p, b)
        if key in self._bin_offsets:
            return self._bin_offsets[key]
        offs = {"v": {}, "le": {}, "re": {}}
        ov = ole = ore = 0
        for sg in self._part_meta[p]["bins"][b]["subgraphs"]:
            g = int(sg["sgid"])
            nv, nle, nre = (int(sg["n_vertices"]), int(sg["n_local_edges"]),
                            int(sg["n_remote_edges"]))
            offs["v"][g] = (ov, ov + nv)
            offs["le"][g] = (ole, ole + nle)
            offs["re"][g] = (ore, ore + nre)
            ov += nv
            ole += nle
            ore += nre
        self._bin_offsets[key] = offs
        return offs

    # ---------------- accounting -------------------------------------------
    def reset_stats(self) -> None:
        self.stats.reset()
        self.cache.hits = 0
        self.cache.misses = 0

    def snapshot_stats(self) -> Dict[str, float]:
        return {**self.stats.snapshot(), **self.cache.stats()}
