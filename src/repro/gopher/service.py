"""GopherService: warm analytic query serving with source-axis batching.

The paper's GoFFish platform is a long-lived cluster service: collections
stay deployed, analytics arrive as *queries*.  ``GopherService`` is that
serving layer for this repo — one warm :class:`~repro.gopher.session
.GopherSession` held over a collection, answering "SSSP from vertex v" /
"N-hop around u" / "rank at instance t" requests at interactive latency
under concurrent load.  Three mechanisms make it cheap:

* **Warm staging** — the session is built with a session-lifetime staging
  cache (``staging_cache_bytes``, LRU by byte budget), so an analytic's
  tile batch is materialized and device-put once; every later query over
  the same (graph, attr, transform, zero, layout) re-stages **zero
  bytes** (``session.last_run_report`` proves it per batch).
* **Source-axis query batching** — concurrent requests to the same
  analytic that differ only in their seed vertex (the registry's
  ``source_axis`` parameter: SSSP's/N-hop's ``source``) coalesce into ONE
  plan whose seed is the list of Q sources; the engine runs them as one
  vectorized (Q, P, Vp) semiring state pass and the service splits the
  leading axis back per request.  Results are bitwise identical to Q
  independent runs (the engine's batched while_loop masks converged
  sources lane-wise).
* **Continuous batching** — requests enqueue at any time; the serve loop
  admits everything queued into the next batch at *run boundaries* (the
  engine's jitted fixpoint pass is uninterruptible, so admission points
  are between engine passes / instance chunks, not inside a superstep).
  Requests arriving while a batch executes accumulate and ride the next
  one — under load the batch width grows toward ``max_batch_queries``
  with no idle waiting.

Streaming: the service is append-aware.  The serve loop refreshes the
session at BATCH BOUNDARIES only (``GopherSession.refresh`` — the
manifest poll), so every executed batch sees one consistent collection
version — a query racing an append observes pre- or post-append state,
never a mix.  :meth:`GopherService.subscribe` registers a standing
tailing query: each observed append delivers one warm incremental
:class:`~repro.gopher.session.TailUpdate` (``GopherSession.tail``).

Request lifecycle::

      submit("sssp", source=v) ──> queue ──┐  (continuous admission)
                                           v
       serve loop:  drain queue -> group by (analytic, non-source params)
                    -> merge sources -> session.run_many(plans)   (shared
                    staging + one engine pass per group) -> split query
                    axis -> resolve tickets
                                           │
      ticket.wait() <──────────────────────┘  per-request AnalyticResult

Single-threaded execution model: ONE serve-loop thread owns the session
(and therefore the engine and staging cache); arbitrary caller threads
only touch the queue and their own tickets, so no session state is ever
accessed concurrently.

>>> import numpy as np
>>> from repro.core.blocked import build_blocked
>>> from repro.core.graph import GraphTemplate
>>> from repro.gopher import GopherSession
>>> from repro.gopher.service import GopherService
>>> tmpl = GraphTemplate(num_vertices=4,
...     src=np.array([0, 1, 2, 0]), dst=np.array([1, 2, 3, 2]))
>>> bg = build_blocked(tmpl, np.array([0, 0, 1, 1]), block_size=2)
>>> sess = GopherSession.from_blocked(
...     bg, weights={"latency": np.ones((2, 4), np.float32)})
>>> with GopherService(session=sess) as svc:
...     one = svc.query("sssp", source=0)           # single query
...     many = svc.query_many([("sssp", {"source": 0}),
...                            ("sssp", {"source": 1})])  # batched pair
>>> one.output["final"]
array([0., 1., 1., 2.], dtype=float32)
>>> many[1].output["final"]           # row 1 of the (Q, V) batched pass
array([inf,  0.,  1.,  2.], dtype=float32)
>>> bool(np.array_equal(many[0].output["final"], one.output["final"]))
True
>>> svc.report()["served"]
3
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.gopher.registry import get_analytic
from repro.gopher.session import (AnalyticResult, GopherSession, TailUpdate,
                                  _StagingCache)

# default session-lifetime staging budget for a serving process: enough
# for every stock analytic's staged batch over the bench-scale
# collections while bounding residency on shared hosts
DEFAULT_CACHE_BYTES = 256 << 20

# session.plan() knobs a request may override (everything else in a
# request's kwargs is an analytic parameter)
_PLAN_KNOBS = ("pattern", "merge", "layout", "comm", "staging", "delta",
               "warm")


@dataclass
class QueryTicket:
    """One in-flight request: resolves to an :class:`AnalyticResult`.

    ``wait()`` blocks until the serve loop delivers (re-raising the
    batch's exception if execution failed); ``latency_s`` is
    submit-to-delivery wall time once done."""

    analytic: str
    params: Dict[str, Any]
    plan_kw: Dict[str, Any] = field(default_factory=dict)
    t_submit: float = 0.0
    t_done: Optional[float] = None
    result: Optional[AnalyticResult] = None
    error: Optional[BaseException] = None
    _event: threading.Event = field(default_factory=threading.Event)

    def wait(self, timeout: Optional[float] = None) -> AnalyticResult:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"query {self.analytic!r} not served within {timeout}s")
        if self.error is not None:
            raise self.error
        assert self.result is not None
        return self.result

    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit


@dataclass
class Subscription:
    """One tailing subscription: a standing ``session.tail`` driven by the
    serve loop.

    The serve loop delivers one :class:`~repro.gopher.session.TailUpdate`
    when the subscription is registered (the initial full run) and one
    per observed append (a warm incremental step).  ``callback`` (if
    given) runs ON THE SERVE THREAD — keep it cheap; a raised exception
    is captured into ``error`` and stops further deliveries.  Waiters
    can also poll: ``wait_update(n)`` blocks until ``delivered >= n``."""

    analytic: str
    params: Dict[str, Any]
    plan_kw: Dict[str, Any] = field(default_factory=dict)
    callback: Optional[Any] = None
    delivered: int = 0
    last: Optional[TailUpdate] = None
    error: Optional[BaseException] = None
    _cv: threading.Condition = field(default_factory=threading.Condition)
    _cancelled: bool = False
    _pending_initial: bool = True

    def cancel(self) -> None:
        """Stop future deliveries (the held ``last`` update stays)."""
        with self._cv:
            self._cancelled = True
            self._cv.notify_all()

    def wait_update(self, count: int = 1,
                    timeout: Optional[float] = None) -> TailUpdate:
        """Block until at least ``count`` updates were delivered; returns
        the latest (re-raising a captured callback/execution error)."""
        with self._cv:
            ok = self._cv.wait_for(
                lambda: self.delivered >= count or self.error is not None,
                timeout)
            if self.error is not None:
                raise self.error
            if not ok:
                raise TimeoutError(
                    f"subscription {self.analytic!r}: update {count} not "
                    f"delivered within {timeout}s")
            assert self.last is not None
            return self.last


class GopherService:
    """Warm analytic query service over one collection (module docstring).

    ``source`` is anything :class:`GopherSession` accepts (a
    ``GoFSStore``, a ``TimeSeriesGraph``), or pass a pre-built
    ``session=``; a session without a session-lifetime staging cache is
    promoted to one (``staging_cache_bytes``).  ``max_batch_queries``
    caps how many requests one admission drains into a single
    ``run_many`` batch (source-merged groups are chunked to it as well).
    """

    def __init__(
        self,
        source=None,
        *,
        session: Optional[GopherSession] = None,
        staging_cache_bytes: float = DEFAULT_CACHE_BYTES,
        max_batch_queries: int = 32,
        poll_interval: float = 0.05,
        auto_refresh: bool = True,
        **session_kw,
    ):
        if session is None:
            assert source is not None, \
                "GopherService needs a data source or a session"
            session = GopherSession(
                source, staging_cache_bytes=staging_cache_bytes,
                **session_kw)
        else:
            assert source is None and not session_kw, \
                "pass either session= or a source (+ session kwargs)"
            if session._staging_cache is None:
                # serving without residency would re-stage every query
                session._staging_cache = _StagingCache(
                    byte_budget=staging_cache_bytes)
        self.session = session
        self.max_batch_queries = int(max_batch_queries)
        # streaming: the serve loop polls the collection manifest when
        # idle (subscriptions registered) and refreshes the session at
        # BATCH BOUNDARIES only — the loop owns the session, so every
        # executed batch sees one consistent collection version (queries
        # racing an append observe pre- or post-append state, never a mix)
        self.poll_interval = float(poll_interval)
        self.auto_refresh = bool(auto_refresh)
        self._queue: "deque[QueryTicket]" = deque()
        self._cond = threading.Condition()
        self._stopping = False
        self._thread: Optional[threading.Thread] = None
        self._subs: List[Subscription] = []
        self._appends_observed = 0
        self._latencies: "deque[float]" = deque(maxlen=4096)
        self._served = 0
        self._batches = 0
        self._widest_batch = 0
        self._t_started: Optional[float] = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "GopherService":
        """Spawn the serve loop (idempotent).  The loop thread owns the
        session; it exits after draining the queue once ``stop()`` is
        called."""
        if self._thread is None or not self._thread.is_alive():
            self._stopping = False
            self._t_started = time.perf_counter()
            self._thread = threading.Thread(
                target=self._serve_loop, name="gopher-serve", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        """Graceful shutdown: serve everything already queued, then stop."""
        with self._cond:
            self._stopping = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "GopherService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------- requests
    def _make_ticket(self, analytic: str, plan_kw: Optional[Dict[str, Any]],
                     params: Dict[str, Any]) -> QueryTicket:
        """Validate eagerly — unknown analytic / bad parameters raise on
        the CALLER's thread, not in the serve loop."""
        a = get_analytic(analytic)  # raises on unknown name
        a.resolve_params(params)  # raises on unknown/missing params
        plan_kw = dict(plan_kw or {})
        unknown = sorted(set(plan_kw) - set(_PLAN_KNOBS))
        if unknown:
            raise TypeError(f"unknown plan knob(s) {unknown}; "
                            f"valid: {list(_PLAN_KNOBS)}")
        return QueryTicket(analytic=analytic, params=dict(params),
                           plan_kw=plan_kw, t_submit=time.perf_counter())

    def _enqueue(self, tickets: List[QueryTicket]) -> None:
        if self._thread is None or not self._thread.is_alive():
            self.start()
        with self._cond:
            assert not self._stopping, "service is stopping"
            self._queue.extend(tickets)
            self._cond.notify_all()

    def submit(self, analytic: str, *, plan_kw: Optional[Dict[str, Any]]
               = None, **params) -> QueryTicket:
        """Enqueue one query; returns immediately with a ticket.

        ``params`` are the analytic's parameters (``source=...``);
        ``plan_kw`` optionally overrides plan knobs (``layout=...``)."""
        t = self._make_ticket(analytic, plan_kw, params)
        self._enqueue([t])
        return t

    def submit_many(
        self, requests: Sequence[Tuple[str, Dict[str, Any]]],
    ) -> List[QueryTicket]:
        """Enqueue ``[(analytic, params), ...]`` atomically — one lock
        acquisition, one serve-loop wakeup — so an idle service admits
        them as ONE batch (stable source-axis width; per-ticket submits
        can land across two admissions)."""
        tickets = [self._make_ticket(name, None, params)
                   for name, params in requests]
        self._enqueue(tickets)
        return tickets

    def query(self, analytic: str, *, timeout: Optional[float] = None,
              plan_kw: Optional[Dict[str, Any]] = None,
              **params) -> AnalyticResult:
        """Submit one query and wait for its result."""
        return self.submit(analytic, plan_kw=plan_kw, **params).wait(timeout)

    def query_many(
        self, requests: Sequence[Tuple[str, Dict[str, Any]]],
        *, timeout: Optional[float] = None,
    ) -> List[AnalyticResult]:
        """Submit ``[(analytic, params), ...]`` concurrently and wait for
        all — the natural shape for source-axis batching: N same-analytic
        requests land in one admission and run as one engine pass."""
        return [t.wait(timeout) for t in self.submit_many(requests)]

    def prestage(self, analytic: str, **params) -> None:
        """Materialize an analytic's main staged batch into the warm cache
        ahead of traffic (first-query latency moves here)."""
        plan = self.session.plan(analytic, **params)
        a = get_analytic(analytic)
        cache = self.session._staging_cache
        assert cache is not None
        self.session._staged(cache, a, plan.layout.value,
                             delta=bool(plan.delta.value))

    def subscribe(self, analytic: str, *, callback=None,
                  plan_kw: Optional[Dict[str, Any]] = None,
                  **params) -> Subscription:
        """Register a tailing subscription (live query over a growing
        collection).

        The serve loop delivers an initial full result, then one warm
        incremental :class:`~repro.gopher.session.TailUpdate` per
        observed append (``GopherSession.tail`` semantics — exact; see
        its docstring for the seeding rules).  ``callback(update)`` runs
        on the serve thread; omit it and poll
        :meth:`Subscription.wait_update` instead."""
        a = get_analytic(analytic)  # raises on unknown name
        a.resolve_params(params)
        plan_kw = dict(plan_kw or {})
        unknown = sorted(set(plan_kw) - set(_PLAN_KNOBS))
        if unknown:
            raise TypeError(f"unknown plan knob(s) {unknown}; "
                            f"valid: {list(_PLAN_KNOBS)}")
        sub = Subscription(analytic=analytic, params=dict(params),
                           plan_kw=plan_kw, callback=callback)
        if self._thread is None or not self._thread.is_alive():
            self.start()
        with self._cond:
            assert not self._stopping, "service is stopping"
            self._subs.append(sub)
            self._cond.notify_all()
        return sub

    # -------------------------------------------------------------- serving
    def _serve_loop(self) -> None:
        while True:
            batch = self._admit()
            if batch is None:
                return
            self._refresh_and_notify()
            if batch:
                self._execute(batch)

    def _admit(self) -> Optional[List[QueryTicket]]:
        """Block until work, a poll tick, or shutdown; drain up to
        ``max_batch_queries`` tickets.  Everything queued while the
        previous batch executed is admitted together — continuous
        batching without a timed window.  With subscriptions registered
        the wait times out every ``poll_interval`` seconds so an idle
        service still observes appends; a tick returns an empty batch
        (refresh + notify only).  ``None`` means stopping and drained."""
        with self._cond:
            while not self._queue and not self._stopping:
                if any(s._pending_initial and not s._cancelled
                       for s in self._subs):
                    break  # run the initial tail without waiting
                timeout = self.poll_interval if self._subs else None
                if not self._cond.wait(timeout=timeout):
                    break  # poll tick
            if self._stopping and not self._queue:
                return None
            batch = []
            while self._queue and len(batch) < self.max_batch_queries:
                batch.append(self._queue.popleft())
            return batch

    def _refresh_and_notify(self) -> None:
        """Batch-boundary streaming hook (serve thread only): observe an
        append, then drive every live subscription one tail step.  Runs
        between batches — never inside one — so each batch executes
        against a single collection version."""
        if not self.auto_refresh:
            return
        changed = self.session.refresh()
        if changed:
            self._appends_observed += 1
        with self._cond:
            subs = [s for s in self._subs if not s._cancelled]
            self._subs = subs
        for sub in subs:
            if sub.error is not None:
                continue
            if not (changed or sub._pending_initial):
                continue
            try:
                update = self.session.tail(
                    sub.analytic, refresh=False,
                    **sub.plan_kw, **sub.params)
            except BaseException as e:
                with sub._cv:
                    sub.error = e
                    sub._cv.notify_all()
                continue
            if update.mode == "noop" and not sub._pending_initial:
                continue
            sub._pending_initial = False
            with sub._cv:
                sub.delivered += 1
                sub.last = update
                sub._cv.notify_all()
            if sub.callback is not None:
                try:
                    sub.callback(update)
                except BaseException as e:
                    with sub._cv:
                        sub.error = e
                        sub._cv.notify_all()

    def _group_key(self, t: QueryTicket, axis: str) -> Tuple:
        rest = tuple(sorted(
            (k, _freeze(v)) for k, v in t.params.items() if k != axis))
        return (t.analytic, rest, tuple(sorted(t.plan_kw.items())))

    def _execute(self, batch: List[QueryTicket]) -> None:
        """Group the admitted tickets, run them as one ``run_many`` pass
        (shared staging across groups), split the query axis, deliver."""
        # ---- coalesce: same analytic + same non-source params -> one plan
        merged: Dict[Tuple, List[QueryTicket]] = {}
        solo: List[QueryTicket] = []
        for t in batch:
            a = get_analytic(t.analytic)
            axis = a.source_axis
            if axis is not None and np.isscalar(t.params.get(axis)):
                merged.setdefault(self._group_key(t, axis), []).append(t)
            else:
                solo.append(t)
        plans = []
        deliveries: List[Tuple[List[QueryTicket], Optional[str]]] = []
        try:
            for key, group in merged.items():
                axis = get_analytic(group[0].analytic).source_axis
                for i in range(0, len(group), self.max_batch_queries):
                    chunk = group[i:i + self.max_batch_queries]
                    if len(chunk) == 1:
                        t = chunk[0]
                        plans.append(self.session.plan(
                            t.analytic, **t.plan_kw, **t.params))
                        deliveries.append((chunk, None))
                        continue
                    params = dict(chunk[0].params)
                    params[axis] = [t.params[axis] for t in chunk]
                    plans.append(self.session.plan(
                        chunk[0].analytic, **chunk[0].plan_kw, **params))
                    deliveries.append((chunk, axis))
            for t in solo:
                plans.append(self.session.plan(
                    t.analytic, **t.plan_kw, **t.params))
                deliveries.append(([t], None))
            results = self.session.run_many(plans)
        except BaseException as e:  # deliver the failure to every waiter
            now = time.perf_counter()
            for t in batch:
                t.error, t.t_done = e, now
                t._event.set()
            return
        now = time.perf_counter()
        self._batches += 1
        self._widest_batch = max(self._widest_batch, len(batch))
        for res, (tickets, axis) in zip(results, deliveries):
            if axis is None:
                outs = [res]
            else:
                outs = [_slice_query(res, q, len(tickets))
                        for q in range(len(tickets))]
            for t, r in zip(tickets, outs):
                t.result, t.t_done = r, now
                self._latencies.append(now - t.t_submit)
                self._served += 1
                t._event.set()

    # ------------------------------------------------------------ reporting
    def report(self) -> Dict[str, Any]:
        """Serving stats: latency percentiles over the last requests,
        batch shape, and the warm cache's staging economy."""
        lats = np.asarray(self._latencies, np.float64)
        elapsed = (time.perf_counter() - self._t_started) \
            if self._t_started is not None else 0.0
        return {
            "served": self._served,
            "batches": self._batches,
            "widest_batch": self._widest_batch,
            "p50_ms": float(np.percentile(lats, 50) * 1e3) if lats.size
            else None,
            "p95_ms": float(np.percentile(lats, 95) * 1e3) if lats.size
            else None,
            "throughput_qps": self._served / elapsed if elapsed > 0
            else 0.0,
            "staging_cache": self.session.staging_cache_stats(),
            "subscriptions": len(self._subs),
            "appends_observed": self._appends_observed,
        }


def _freeze(v: Any) -> Any:
    """Hashable view of a request parameter (group keys)."""
    if isinstance(v, np.ndarray):
        return ("ndarray",) + tuple(v.reshape(-1).tolist()) + (v.shape,)
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    return v


def _slice_query(res: AnalyticResult, q: int, n: int) -> AnalyticResult:
    """Per-request view of a source-batched result: output arrays whose
    leading axis is the query axis are sliced at ``q``; the plan and the
    (shared) engine result ride along for provenance."""
    out: Dict[str, Any] = {}
    for k, v in res.output.items():
        if isinstance(v, np.ndarray) and v.ndim >= 1 and v.shape[0] == n:
            out[k] = v[q]
        else:
            out[k] = v
    return AnalyticResult(plan=res.plan, engine=res.engine, output=out)
