"""Gopher session API — the declarative entry point (paper §III–V).

``GopherSession`` wraps one time-series graph collection (a deployed
``GoFSStore``, an in-memory ``TimeSeriesGraph``, or pre-blocked arrays)
behind three verbs: ``plan`` (auto-tuned, costed, explainable execution
plans for registered analytics), ``run`` (execute one plan), and
``run_many`` (execute several with shared staging — one
``load_blocked``/prefetch pass feeding N engine runners).

Registry → planner → executor; see docs/ARCHITECTURE.md ("Gopher session
API") for the diagrams and auto-selection tables.
"""
from repro.gopher.planner import ExecutionPlan, PlanChoice, SPARSE_OCCUPANCY_MAX
from repro.gopher.registry import (
    Analytic,
    REQUIRED,
    get_analytic,
    list_analytics,
    register_analytic,
)
from repro.gopher.service import GopherService, QueryTicket
from repro.gopher.session import AnalyticResult, GopherSession, PlanContext

__all__ = [
    "Analytic",
    "AnalyticResult",
    "ExecutionPlan",
    "GopherService",
    "GopherSession",
    "PlanChoice",
    "PlanContext",
    "QueryTicket",
    "REQUIRED",
    "SPARSE_OCCUPANCY_MAX",
    "get_analytic",
    "list_analytics",
    "register_analytic",
]
