"""Execution planning: turn a declared analytic into costed engine knobs.

``GopherSession.plan(...)`` produces an :class:`ExecutionPlan` — every
knob the execution machinery exposes (tile layout, comm backend, staging
mode, placement), each resolved either by the caller (``source ==
"override"``) or by the planner's cost models (``source == "auto"``),
with the reasoning and byte estimates attached.  Plans are plain data:
deterministic for a given collection (the planner reads only recorded
metadata — per-pack tile maps, blocked structure, mesh shape — never a
value slice), comparable with ``==``, and renderable with
:meth:`ExecutionPlan.explain` before anything executes.

Auto-selection rules (each individually overridable):

==========  ==============================================================
knob        rule
==========  ==============================================================
layout      recorded/measured tile occupancy ``<= 25%`` -> ``sparse``
            (the `BENCH_temporal.json` crossover); above, or unknown
            without reading values -> ``dense`` (always correct)
comm        mesh given -> ``repro.launch.mesh.recommended_comm`` with the
            REAL cut (``boundary_nnz``): DCI exchange axes and a large
            cut -> ``ring``, else ``dense``; no mesh -> ``dense`` (the
            stacked in-process fold; ``"host"`` targets mesh-free
            multi-process clusters and stays an explicit override)
staging     store-backed analytics -> ``async`` (slice reads overlap
            execution), including derived weights whose transform is
            declared ``rowwise`` (applied chunk-wise on the prefetch
            pool); in-memory weights, non-row-wise transforms, and
            composite analytics -> ``sync``
delta       store-backed + sparse layout + a recorded delta chain whose
            unique-tile ratio ``< 1`` -> ``True`` (stage each unique
            tile's bytes once per chunk); otherwise ``False`` (full
            tiles cost the same or less to reconstruct)
warm        collection recorded monotone-improving at deploy AND the
            analytic stages with the min-plus zero (+inf) -> ``True``
            (seed instance *t* from *t-1*'s converged fixpoint — exact;
            see docs/ARCHITECTURE.md); plus-mul fixed-iterate or
            non-monotone collections -> ``False`` (cold start)
kernel      jax backend not ``tpu`` -> ``off`` (the jnp oracle path IS
            the lowering — interpreted Pallas on CPU only checks
            semantics, slower than jnp); ``tpu`` + recorded occupancy
            ``<= 25%`` -> ``fused`` (packed active-tile walk: the fused
            superstep kernel keeps state VMEM-resident, double-buffers
            tile DMA, and folds the halt vote in-kernel); ``tpu``
            otherwise -> ``spmv`` (per-stage SpMV kernel; dense template
            walks gain little from fusing the vote)
placement   mesh given -> shard partitions over ``model_axes`` and
            temporally concurrent instances over ``data_axis``;
            else stacked
==========  ==============================================================
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import numpy as np

# occupancy at or below which the packed active-tile layout wins (the
# measured crossover regime — see the `sparse` row of BENCH_temporal.json
# and the selection table in docs/ARCHITECTURE.md)
SPARSE_OCCUPANCY_MAX = 0.25


@dataclass(frozen=True)
class PlanChoice:
    """One resolved knob: value + who chose it + why.

    >>> str(PlanChoice("sparse", "auto", "occupancy 12.5% <= 25%"))
    'sparse [auto] occupancy 12.5% <= 25%'
    """

    value: Any
    source: str  # "auto" | "override"
    reason: str

    def __str__(self) -> str:
        return f"{self.value} [{self.source}] {self.reason}"


def choice(value: Any, reason: str) -> PlanChoice:
    return PlanChoice(value, "auto", reason)


def override(value: Any) -> PlanChoice:
    return PlanChoice(value, "override", "caller override")


def _norm_param(v: Any) -> Any:
    """Plan params must compare/render cleanly (and hash, so a plan can
    key a cache): arrays and lists become tuples."""
    if isinstance(v, np.ndarray):
        return tuple(v.tolist())
    if isinstance(v, (list, tuple)):
        return tuple(_norm_param(x) for x in v)
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    return v


@dataclass(frozen=True)
class ExecutionPlan:
    """A fully resolved, costed execution of one analytic.

    Immutable and deterministic: planning the same analytic against the
    same collection yields an ``==``-equal plan (regression-tested), so a
    plan doubles as a reproducible record of *how* a result was computed
    — :class:`~repro.gopher.session.AnalyticResult` carries it along.
    """

    analytic: str
    pattern: str
    merge: Optional[str]
    params: Tuple[Tuple[str, Any], ...]  # resolved, sorted by name
    graph: str  # "template" | "symmetrized"
    layout: PlanChoice  # "dense" | "sparse"
    comm: PlanChoice  # "dense" | "ring" | "host"
    staging: PlanChoice  # "sync" | "async"
    delta: PlanChoice  # True | False — delta-chain tile staging
    warm: PlanChoice  # True | False — warm-started fixpoints
    kernel: PlanChoice  # "off" | "spmv" | "fused" — Pallas kernel mode
    placement: PlanChoice  # "stacked" | mesh descriptor string
    estimates: Tuple[Tuple[str, Any], ...]  # cost-model outputs, sorted

    @property
    def param_dict(self) -> Dict[str, Any]:
        return dict(self.params)

    @property
    def estimate_dict(self) -> Dict[str, Any]:
        return dict(self.estimates)

    def explain(self) -> str:
        """Render the plan: decisions, their provenance, and the cost
        estimates — the paper's 'platform picks the execution' made
        inspectable (``run_graph --explain`` prints exactly this)."""
        est = self.estimate_dict
        lines = [
            f"ExecutionPlan: {self.analytic} (pattern={self.pattern}"
            + (f", merge={self.merge}" if self.merge else "") + ")",
            "  params: " + (", ".join(
                f"{k}={v!r}" for k, v in self.params) or "(none)"),
            f"  graph: {self.graph}"
            + (f" — {est['num_vertices']} vertices, "
               f"{est['n_parts']} partitions x block {est['block_size']}, "
               f"cut {est['boundary_nnz']} published vertices"
               if "num_vertices" in est else ""),
        ]
        for knob in ("layout", "comm", "staging", "delta", "warm",
                     "kernel", "placement"):
            c: PlanChoice = getattr(self, knob)
            lines.append(f"  {knob:<9} = {c.value!s:<8} [{c.source}] "
                         f"{c.reason}")
        byte_lines = []
        if "staged_bytes_dense" in est:
            s = f"    staged bytes: dense {est['staged_bytes_dense']:,}"
            if est.get("staged_bytes_sparse") is not None:
                s += (f" | sparse ~{est['staged_bytes_sparse']:,} "
                      f"(occupancy {est['occupancy']:.1%})")
            elif est.get("occupancy") is not None:
                s += f" (occupancy {est['occupancy']:.1%})"
            else:
                s += " (activity unknown without reading values)"
            byte_lines.append(s)
        if est.get("source_bytes_delta") is not None:
            byte_lines.append(
                f"    delta staging: ~{est['source_bytes_delta']:,} B "
                f"from store (unique-tile ratio "
                f"{est['delta_unique_ratio']:.1%} of "
                f"{est['staged_bytes_sparse'] or est['staged_bytes_dense']:,}"
                f" B reconstructed)")
        if est.get("n_sources", 1) > 1:
            byte_lines.append(
                f"    query axis: {est['n_sources']} sources batched into "
                f"one ({est['n_sources']}, P, Vp) state pass — "
                f"{est['state_bytes']:,} B of state, staged tiles shared")
        if self.warm.value:
            byte_lines.append(
                "    warm start: instance t seeds from t-1's converged "
                "fixpoint — supersteps shrink toward the per-instance "
                "change radius (collection recorded monotone-improving; "
                "exact for min-plus)")
        if "exchange_bytes_per_device" in est:
            byte_lines.append(
                f"    boundary exchange/superstep: "
                f"{est['exchange_kind']} moves "
                f"{est['exchange_bytes_per_device']:,.0f} B/device in "
                f"{est['exchange_hops']} hop(s) "
                f"({est['n_parts']} partitions, "
                f"{est['boundary_nnz']} published vertices)")
        if "mesh_split_data" in est:
            byte_lines.append(
                f"    mesh proposal: {est['mesh_split_devices']} device(s) "
                f"-> data {est['mesh_split_data']} x model "
                f"{est['mesh_split_model']} — {est['mesh_split_why']}")
        if byte_lines:
            lines.append("  estimates:")
            lines.extend(byte_lines)
        return "\n".join(lines)


def extend_plan(plan: ExecutionPlan, num_instances: int) -> ExecutionPlan:
    """Extend a plan to a grown collection without replanning.

    Appends only lengthen the instance axis — the blocked structure,
    cut, and layout/comm/placement decisions are append-invariant, so a
    held plan stays valid; only the instance-count-proportional byte
    estimates change.  Returns a plan ``==``-identical except for those
    estimates (knob provenance intact).  NOT a substitute for replanning
    when a data-dependent choice could flip (an append can break the
    recorded monotone-improving property and with it the auto ``warm``
    choice — the session's tail path replans for exactly that reason);
    use it where the knobs are pinned and only the scale moved."""
    import dataclasses

    est = dict(plan.estimate_dict)
    old_n = int(est.get("num_instances") or 0)
    if old_n == int(num_instances) or old_n <= 0:
        return plan
    for k in ("staged_bytes_dense", "staged_bytes_sparse",
              "source_bytes_delta"):
        v = est.get(k)
        if v is not None:
            est[k] = (int(v) // old_n) * int(num_instances)
    est["num_instances"] = int(num_instances)
    return dataclasses.replace(plan,
                               estimates=tuple(sorted(est.items())))


def propose_mesh_split(
    num_devices: int,
    num_instances: int,
    n_parts: int,
    pattern: str,
    *,
    num_boundary: int,
    boundary_nnz: int,
    comm: str = "dense",
) -> Dict[str, Any]:
    """Propose how ``num_devices`` should split between the instance
    (data) and partition (model) mesh axes.

    The paper exposes BOTH parallelism axes — timesteps and subgraphs —
    and the split decides what each superstep pays: partitions sharded
    ``m``-way exchange their boundary every superstep
    (``boundary_exchange_bytes``), while instances sharded ``d``-way are
    temporally concurrent and exchange NOTHING (independent/eventually
    patterns never communicate across instances).  So the proposal gives
    the data axis every device that divisibility allows and prices the
    remaining partition split:

    * enumerate the divisor splits ``d * m == num_devices`` where ``m``
      divides the partition count and (for ``d > 1``) the pattern is
      temporally concurrent and ``d`` divides the instance count;
    * score each by per-device exchange volume over the whole pass,
      ``ceil(I / d) * bytes_per_device(m)`` — the term the data axis
      amortizes and the model axis inflates;
    * ties (e.g. a zero-exchange single-partition-group) break toward
      fewer model shards.

    ``sequential`` chains instances, so the data axis is off the table
    and the proposal is all-model.  Returns ``{"data", "model",
    "exchange_bytes_per_device", "why"}``; callers embed it in plan
    estimates (``explain()`` renders it).

    >>> p = propose_mesh_split(8, 16, 8, "independent",
    ...                        num_boundary=128, boundary_nnz=64)
    >>> (p["data"], p["model"])
    (8, 1)
    >>> p = propose_mesh_split(8, 16, 8, "sequential",
    ...                        num_boundary=128, boundary_nnz=64)
    >>> (p["data"], p["model"])
    (1, 8)
    """
    from repro.dist.collectives import boundary_exchange_bytes

    D = max(1, int(num_devices))
    temporal = pattern in ("independent", "eventually")
    best = None
    for m in range(1, D + 1):
        if D % m or m > n_parts or n_parts % m:
            continue
        d = D // m
        if d > 1 and not (temporal and num_instances % d == 0
                          and num_instances >= d):
            continue
        ex = boundary_exchange_bytes(num_boundary, m, comm,
                                     boundary_nnz=boundary_nnz)
        cost = -(-num_instances // d) * float(ex["bytes_per_device"])
        if best is None or (cost, m) < (best[0], best[2]):
            best = (cost, d, m, ex)
    if best is None:
        # nothing divides: stack everything (the engine replicates
        # instances when the axis does not divide — correct, no speedup)
        return {
            "data": 1, "model": 1, "exchange_bytes_per_device": 0.0,
            "why": f"no divisor split of {D} device(s) fits "
                   f"{n_parts} partitions x {num_instances} instances — "
                   f"run stacked/replicated",
        }
    cost, d, m, ex = best
    if not temporal:
        why = (f"{pattern} chains instances (no data axis); all {m} "
               f"device(s) shard partitions, exchanging "
               f"~{ex['bytes_per_device']:,.0f} B/device/superstep")
    elif m == 1:
        why = (f"temporal pattern pays no cross-instance exchange — "
               f"{d} instance shard(s) take every device; single "
               f"partition group exchanges nothing off-device")
    else:
        why = (f"{d} instance shard(s) x {m} partition shard(s): data "
               f"axis takes what divides I={num_instances}, remaining "
               f"{m}-way partition split moves "
               f"~{ex['bytes_per_device']:,.0f} B/device/superstep")
    return {
        "data": int(d), "model": int(m),
        "exchange_bytes_per_device": float(ex["bytes_per_device"]),
        "why": why,
    }


def plan_analytic(
    analytic,
    resolved_params: Dict[str, Any],
    *,
    bg,
    mesh,
    model_axes: Tuple[str, ...],
    store_backed: bool,
    occupancy: Optional[float],
    sparse_buckets: Optional[Tuple[int, int]],
    num_instances: int,
    delta_ratio: Optional[float] = None,
    delta_monotone: Optional[bool] = None,
    zero_fill: Optional[float] = None,
    pattern: Optional[str] = None,
    merge: Optional[str] = None,
    layout: Optional[str] = None,
    comm: Optional[str] = None,
    staging: Optional[str] = None,
    delta: Optional[bool] = None,
    warm: Optional[bool] = None,
    kernel: Optional[str] = None,
    backend: Optional[str] = None,
) -> ExecutionPlan:
    """Resolve every knob for one analytic (see module docstring rules).

    ``occupancy``/``sparse_buckets`` come from recorded tile maps or an
    in-memory activity scan — ``None`` means unknown without reading
    values, which the planner treats as 'stay dense'.  ``delta_ratio``/
    ``delta_monotone`` are the deploy-time delta-chain stats
    (``GoFSStore.delta_stats``): unique-tile fraction across the
    collection and whether consecutive instances only ever tighten
    weights — ``None`` when no delta chain was recorded.

    ``backend`` — the jax platform the session dispatches to (the
    session passes ``repro.kernels.semiring_spmm.ops.resolved_backend``'s
    cached probe); it drives the ``kernel`` knob's auto rule.  ``None``
    is treated as not-TPU (kernel off)."""
    from repro.dist.collectives import boundary_exchange_bytes
    from repro.launch.mesh import recommended_comm

    pattern = pattern or analytic.pattern
    assert pattern in ("sequential", "independent", "eventually"), pattern
    merge = merge if merge is not None else analytic.merge
    if merge is not None and pattern != "eventually":
        raise ValueError(
            f"merge={merge!r} is the eventually-dependent Merge; "
            f"pattern {pattern!r} has none")

    # ---- layout ----------------------------------------------------------
    if layout is not None:
        lay = override(layout)
    elif occupancy is None:
        lay = choice("dense", "tile activity unknown without reading "
                              "values — dense is always correct")
    elif occupancy <= SPARSE_OCCUPANCY_MAX:
        lay = choice("sparse",
                     f"recorded tile occupancy {occupancy:.1%} <= "
                     f"{SPARSE_OCCUPANCY_MAX:.0%} — packed active tiles "
                     f"cut staged bytes and SpMV work")
    else:
        lay = choice("dense",
                     f"recorded tile occupancy {occupancy:.1%} > "
                     f"{SPARSE_OCCUPANCY_MAX:.0%} — packing would buy "
                     f"little over template tiles")

    # ---- comm ------------------------------------------------------------
    nnz = int(bg.boundary_nnz)
    if comm is not None:
        cm = override(comm)
    elif mesh is None:
        cm = choice("dense", "stacked in-process fold (no mesh; 'host' "
                             "targets mesh-free multi-process clusters)")
    else:
        rec = recommended_comm(mesh, model_axes, boundary_nnz=nnz)
        cm = choice(rec,
                    f"recommended_comm over exchange axes {model_axes} "
                    f"with boundary_nnz={nnz}")

    # ---- staging ---------------------------------------------------------
    if staging is not None:
        st = override(staging)
    elif not store_backed:
        st = choice("sync", "weights already in memory — nothing to "
                            "overlap but the tile fill")
    elif analytic.composite:
        st = choice("sync", "composite analytic re-reads its staged "
                            "tiles across runs — staged once via the "
                            "shared cache")
    elif analytic.weights is not None and not analytic.rowwise:
        st = choice("sync", f"derived weights ({analytic.transform_name}) "
                            f"need the full attribute matrix before "
                            f"staging")
    elif analytic.weights is not None:
        st = choice("async", f"row-wise transform "
                             f"({analytic.transform_name}) applies "
                             f"chunk-by-chunk on the prefetch pool — "
                             f"slice reads + derived fills overlap "
                             f"execution")
    else:
        st = choice("async", "streaming from the GoFS store — slice "
                             "reads + fills overlap execution")

    # ---- delta -----------------------------------------------------------
    # delta reconstruction only pays off on the packed layout (the tile
    # index IS the dedupe unit) when the recorded chain shows real
    # temporal redundancy; derived-weight transforms see a synthesized
    # matrix the chain does not describe
    delta_ok = (store_backed and lay.value == "sparse"
                and analytic.weights is None)
    if delta is not None:
        dl = override(bool(delta))
    elif not delta_ok:
        dl = choice(False,
                    "delta chain needs a store-backed sparse staging of "
                    "the raw attribute"
                    if not (store_backed and analytic.weights is None)
                    else "dense layout restages template tiles — no "
                         "packed index to dedupe against")
    elif delta_ratio is None:
        dl = choice(False, "no delta chain recorded at deploy")
    elif delta_ratio < 1.0:
        dl = choice(True,
                    f"recorded unique-tile ratio {delta_ratio:.1%} — "
                    f"unchanged tiles stage once per chunk")
    else:
        dl = choice(False,
                    f"recorded unique-tile ratio {delta_ratio:.1%} — "
                    f"every tile changes every instance; nothing to dedupe")

    # ---- warm ------------------------------------------------------------
    # exact only for monotone fixpoints (min-plus, zero_fill=+inf) on
    # collections recorded monotone-improving at deploy; the engine
    # additionally cold-starts iterate programs at run time
    from repro.core.semiring import INF

    warm_ok = (store_backed and delta_monotone is not None
               and zero_fill is not None and zero_fill == INF)
    if warm is not None:
        wm = override(bool(warm))
    elif not warm_ok:
        if zero_fill is not None and zero_fill != INF:
            wm = choice(False, "warm seeding is exact only for min-plus "
                               "fixpoints (zero_fill=+inf); this staging "
                               "is not")
        else:
            wm = choice(False, "no monotonicity record for this "
                               "attribute — cold start is the only "
                               "provably exact seed")
    elif delta_monotone:
        wm = choice(True, "collection recorded monotone-improving at "
                          "deploy — warm min-plus seeds converge to the "
                          "identical fixpoint in fewer supersteps")
    else:
        wm = choice(False, "weights increase somewhere in the chain — a "
                           "warm min-plus seed could lock in a stale "
                           "shorter path")

    # ---- kernel ----------------------------------------------------------
    from repro.core.superstep import KERNEL_MODES

    if kernel is not None:
        assert kernel in KERNEL_MODES, \
            f"kernel={kernel!r}; pick from {KERNEL_MODES}"
        kn = override(kernel)
    elif backend != "tpu":
        kn = choice("off", f"jax backend {backend or 'unknown'!s} != tpu — "
                           f"the jnp oracle path is the native lowering; "
                           f"interpreted Pallas only checks semantics")
    elif occupancy is not None and occupancy <= SPARSE_OCCUPANCY_MAX:
        kn = choice("fused",
                    f"tpu + recorded occupancy {occupancy:.1%} <= "
                    f"{SPARSE_OCCUPANCY_MAX:.0%} — fused superstep kernel "
                    f"walks the packed active tiles with VMEM-resident "
                    f"state, double-buffered DMA, in-kernel halt vote")
    else:
        kn = choice("spmv",
                    "tpu, dense-regime tiles — per-stage SpMV kernel; "
                    "template walks gain little from fusing the vote")

    # ---- placement -------------------------------------------------------
    if mesh is None:
        pl = choice("stacked", "no mesh — partitions stacked on one "
                               "device, instances scanned")
    else:
        shape = dict(zip(mesh.axis_names, mesh.shape.values())) \
            if hasattr(mesh.shape, "values") else dict(mesh.shape)
        pl = choice(f"mesh{shape}",
                    f"partitions over {model_axes}; temporally concurrent "
                    f"patterns shard instances over the data axis")

    # ---- estimates -------------------------------------------------------
    # query axis: a sequence on the analytic's source parameter widens the
    # semiring state to (Q, P, Vp) — Q requests in one engine pass whose
    # staged tiles are shared (priced once), only the state scales with Q
    n_sources = 1
    if analytic.source_axis is not None:
        sv = resolved_params.get(analytic.source_axis)
        if isinstance(sv, (list, tuple, np.ndarray)):
            n_sources = int(len(sv))
    B = bg.block_size
    dense_bytes = int(num_instances * bg.n_parts
                      * (bg.t_max + bg.tb_max) * B * B * 4)
    sparse_bytes = None
    if sparse_buckets is not None:
        kb, kbb = sparse_buckets
        sparse_bytes = int(num_instances * bg.n_parts
                           * ((kb + kbb) * (B * B * 4 + 8)))
    ex = boundary_exchange_bytes(bg.num_boundary, bg.n_parts, cm.value,
                                 boundary_nnz=nnz)
    source_bytes_delta = None
    if dl.value and delta_ratio is not None:
        # store -> host traffic under delta staging: each unique tile's
        # payload once, priced against the reconstructed sparse batch
        base = sparse_bytes if sparse_bytes is not None else dense_bytes
        source_bytes_delta = int(round(base * delta_ratio))
    # mesh-shape proposal: how the available device pool SHOULD split
    # between the instance (data) and partition (model) axes — advisory
    # when no mesh was given, a review of the split when one was
    if mesh is not None:
        num_devices = 1
        for n in shape.values():
            num_devices *= int(n)
    else:
        import jax

        num_devices = jax.local_device_count()
    split = propose_mesh_split(
        num_devices, num_instances, bg.n_parts, pattern,
        num_boundary=bg.num_boundary, boundary_nnz=nnz, comm=cm.value)
    estimates = {
        "num_vertices": int(len(bg.part_of)),
        "num_instances": int(num_instances),
        "n_sources": n_sources,
        "state_bytes": int(n_sources * bg.n_parts
                           * bg.global_of.shape[1] * 4),
        "n_parts": int(bg.n_parts),
        "block_size": int(B),
        "boundary_nnz": nnz,
        "occupancy": occupancy,
        "staged_bytes_dense": dense_bytes,
        "staged_bytes_sparse": sparse_bytes,
        "delta_unique_ratio": delta_ratio,
        "source_bytes_delta": source_bytes_delta,
        "exchange_kind": ex["kind"],
        "exchange_hops": int(ex["hops"]),
        "exchange_bytes_per_device": float(ex["bytes_per_device"]),
        "mesh_split_devices": int(num_devices),
        "mesh_split_data": split["data"],
        "mesh_split_model": split["model"],
        "mesh_split_why": split["why"],
    }
    return ExecutionPlan(
        analytic=analytic.name,
        pattern=pattern,
        merge=merge,
        params=tuple(sorted(
            (k, _norm_param(v)) for k, v in resolved_params.items()
        )),
        graph=analytic.graph,
        layout=lay,
        comm=cm,
        staging=st,
        delta=dl,
        warm=wm,
        kernel=kn,
        placement=pl,
        estimates=tuple(sorted(estimates.items())),
    )
