"""Analytic registry: named, declarative specs for every Gopher analytic.

The paper's pitch is that Gopher is a *programming abstraction* — a user
declares a sub-graph-centric analytic and the platform decides how to run
it over the distributed temporal layout.  The registry is the declaration
half of that contract: each ``core/algorithms/*`` module registers an
:class:`Analytic` spec (which edge attribute feeds it, the semiring zero
its staging uses, its iBSP pattern, a program factory or a composite
executor, parameter schema), and :class:`repro.gopher.GopherSession`
resolves names against it — ``session.plan("sssp", source=0)`` instead of
hand-assembling store → fill → engine → run.

Two registration shapes:

* ``kind="program"`` — the decorated function is a **program factory**
  ``(ctx, **params) -> SemiringProgram``; the session executes it as one
  engine run under the plan's pattern.  This covers SSSP, PageRank and
  connected components.
* ``kind="composite"`` — the decorated function is an **executor**
  ``(ctx, **params) -> payload dict`` that drives multiple engine runs
  itself through the :class:`~repro.gopher.session.PlanContext` (N-hop's
  hop + latency fixpoints, tracking's per-timestep probes), still drawing
  every staged tensor from the session's shared staging cache.

>>> import repro.core.algorithms  # registration side effect
>>> from repro.gopher.registry import list_analytics, get_analytic
>>> list_analytics()
['components', 'nhop', 'pagerank', 'sssp', 'tracking']
>>> get_analytic("sssp").pattern
'sequential'
>>> get_analytic("pagerank").attr
'active'
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional


class _Required:
    """Sentinel default marking an analytic parameter as mandatory."""

    def __repr__(self) -> str:  # shown in explain()/error messages
        return "<required>"


REQUIRED = _Required()

_REGISTRY: Dict[str, "Analytic"] = {}


@dataclass(frozen=True)
class Analytic:
    """One registered analytic: staging contract + execution recipe.

    ``attr``/``zero_fill`` describe the staged batch the analytic's MAIN
    engine run consumes — the shared-staging key ``run_many`` amortizes
    over: two analytics with the same ``(graph, attr, transform,
    zero_fill)`` stage tiles once.  ``weights`` optionally transforms the
    raw ``(I, E)`` attribute matrix before staging (PageRank's outdegree
    normalization); its name rides in the staging key so different
    transforms never alias.
    """

    name: str
    pattern: str  # default iBSP pattern ("sequential"|"independent"|"eventually")
    attr: str  # edge attribute feeding the main staging
    zero_fill: float  # semiring zero of the staged tiles
    params: Dict[str, Any] = field(default_factory=dict)  # name -> default
    graph: str = "template"  # blocked structure: "template" | "symmetrized"
    merge: Optional[str] = None  # default eventually-Merge mode
    make_program: Optional[Callable] = None  # (ctx, **params) -> SemiringProgram
    execute: Optional[Callable] = None  # (ctx, **params) -> payload dict
    weights: Optional[Callable] = None  # (ctx, raw (I, E)) -> staged (I, E')
    postprocess: Optional[Callable] = None  # (ctx, EngineResult, **params) -> payload
    # the weights transform is ROW-WISE: transform(w)[s:e] ==
    # transform(w[s:e]) for any instance window, i.e. each instance's
    # derived weights depend only on that instance's raw row.  Row-wise
    # transforms can run chunk-by-chunk on the prefetcher's pool thread,
    # so store-backed derived-weight analytics stream asynchronously
    # instead of materializing the full (I, E) matrix up front.
    rowwise: bool = False
    # name of the parameter that seeds the semiring state from one vertex
    # (e.g. "source").  When set, the analytic accepts a SEQUENCE there as
    # well as a scalar: Q values become one vectorized multi-source engine
    # pass whose results are bitwise identical to Q scalar runs — the
    # query-batching axis GopherService coalesces concurrent requests on.
    source_axis: Optional[str] = None
    describe: str = ""

    @property
    def composite(self) -> bool:
        return self.execute is not None

    @property
    def transform_name(self) -> str:
        """Staging-key component naming the weights transform."""
        return "raw" if self.weights is None else \
            getattr(self.weights, "__name__", self.name)

    def resolve_params(self, overrides: Dict[str, Any]) -> Dict[str, Any]:
        """Declared defaults + caller overrides; unknown or missing
        required parameters raise ``TypeError`` (the declarative API's
        equivalent of a bad function signature)."""
        unknown = sorted(set(overrides) - set(self.params))
        if unknown:
            raise TypeError(
                f"analytic {self.name!r} got unknown parameter(s) "
                f"{unknown}; declared: {sorted(self.params)}"
            )
        resolved = dict(self.params)
        resolved.update(overrides)
        missing = sorted(
            k for k, v in resolved.items() if isinstance(v, _Required)
        )
        if missing:
            raise TypeError(
                f"analytic {self.name!r} missing required parameter(s) "
                f"{missing}"
            )
        return resolved


def register_analytic(
    name: str,
    *,
    pattern: str,
    attr: str,
    zero_fill: float,
    params: Optional[Dict[str, Any]] = None,
    graph: str = "template",
    merge: Optional[str] = None,
    kind: str = "program",
    weights: Optional[Callable] = None,
    rowwise: bool = False,
    postprocess: Optional[Callable] = None,
    source_axis: Optional[str] = None,
    describe: str = "",
):
    """Class the decorated function as a named analytic.

    ``kind="program"`` decorates a program factory, ``kind="composite"``
    a multi-run executor (see module docstring).  Registering a name
    twice raises — analytics are platform-level declarations, not
    session-local state."""
    assert kind in ("program", "composite"), kind
    assert pattern in ("sequential", "independent", "eventually"), pattern
    assert graph in ("template", "symmetrized"), graph

    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(
                f"analytic {name!r} is already registered "
                f"(by {_REGISTRY[name].describe or 'an earlier module'!r})"
            )
        _REGISTRY[name] = Analytic(
            name=name, pattern=pattern, attr=attr, zero_fill=zero_fill,
            params=dict(params or {}), graph=graph, merge=merge,
            make_program=fn if kind == "program" else None,
            execute=fn if kind == "composite" else None,
            weights=weights, rowwise=rowwise, postprocess=postprocess,
            source_axis=source_axis,
            describe=describe or (fn.__doc__ or "").strip().split("\n")[0],
        )
        return fn

    return deco


def get_analytic(name: str) -> Analytic:
    """Look up a registered analytic; unknown names raise ``KeyError``
    listing what IS registered (typo-friendly)."""
    _ensure_registered()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown analytic {name!r}; registered: {list_analytics()}"
        ) from None


def list_analytics() -> List[str]:
    """Sorted names of every registered analytic."""
    _ensure_registered()
    return sorted(_REGISTRY)


def _ensure_registered() -> None:
    """Import the stock algorithm modules (registration side effect).

    Lazy so ``repro.gopher`` and ``repro.core.algorithms`` can import in
    either order without a cycle."""
    import repro.core.algorithms  # noqa: F401
