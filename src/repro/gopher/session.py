"""GopherSession: the declarative entry point for temporal graph analytics.

The paper positions Gopher as a *programming abstraction*: the user says
WHAT to compute over the time-series collection, the platform (co-designed
with GoFS) decides HOW.  ``GopherSession`` is that contract for this
repo's execution machinery — one object wrapping a data source, with
three verbs:

* ``plan(analytic, **params)`` — resolve a registered analytic
  (:mod:`repro.gopher.registry`) into a costed
  :class:`~repro.gopher.planner.ExecutionPlan`: tile layout from the
  recorded occupancy, comm backend from the real cut size, staging mode
  from the source, placement from the mesh — every choice overridable
  and rendered by ``plan.explain()`` before anything runs.
* ``run(plan)`` — execute one plan, returning an
  :class:`AnalyticResult` (the engine outputs + the plan that produced
  them).
* ``run_many([plans])`` — execute several plans over the SAME collection
  with **shared staging**: analytics whose staged batches coincide
  (same graph variant, attribute, transform, semiring zero, layout)
  stage tiles once — one ``load_blocked``/prefetch pass feeding N engine
  runners — the shared-scan amortization concurrent temporal queries
  need (cf. Kairos in PAPERS.md).

Data sources (all expose the same verbs):

* a :class:`~repro.gofs.store.GoFSStore` — the deployed collection; the
  blocked structure is reconstructed from the stored topology slices,
  attributes stream from disk;
* a :class:`~repro.core.graph.TimeSeriesGraph` — an in-memory collection
  (examples, generators); the session partitions and blocks it;
* :meth:`GopherSession.from_blocked` — a pre-built
  :class:`~repro.core.blocked.BlockedGraph` plus raw ``(I, E)`` weight
  matrices (what the legacy ``run_blocked`` wrappers use).

>>> import numpy as np
>>> from repro.core.blocked import build_blocked
>>> from repro.core.graph import GraphTemplate
>>> from repro.gopher import GopherSession
>>> tmpl = GraphTemplate(num_vertices=4,
...     src=np.array([0, 1, 2, 0]), dst=np.array([1, 2, 3, 2]))
>>> bg = build_blocked(tmpl, np.array([0, 0, 1, 1]), block_size=2)
>>> sess = GopherSession.from_blocked(
...     bg, weights={"latency": np.ones((2, 4), np.float32)})
>>> plan = sess.plan("sssp", source=0)     # every knob auto-selected
>>> (plan.layout.value, plan.comm.value, plan.staging.value,
...  plan.placement.value)
('dense', 'dense', 'sync', 'stacked')
>>> sess.run(plan).output["final"]
array([0., 1., 1., 2.], dtype=float32)
>>> both = sess.run_many([plan, sess.plan("sssp", source=1)])  # shared staging
>>> both[1].output["final"]
array([inf,  0.,  1.,  2.], dtype=float32)
>>> sess.last_run_report["staging_passes"]  # two analytics, one staging
1
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.blocked import BlockedGraph, SparseBlocked, pow2_bucket
from repro.core.engine import EngineResult, RunSpec, TemporalEngine
from repro.gopher.planner import ExecutionPlan, plan_analytic
from repro.gopher.registry import Analytic, get_analytic

ONES_ATTR = "__ones__"  # pseudo-attribute: unit weights on every edge


# ---------------------------------------------------------------------------
# Staged batches + the shared-staging cache
# ---------------------------------------------------------------------------

@dataclass
class StagedBatch:
    """One materialized instance batch (dense tensors or a packed sparse
    batch) plus the host bytes it cost — the unit ``run_many`` shares."""

    layout: str
    tiles: Optional[np.ndarray] = None  # dense (I, P, T, B, B)
    btiles: Optional[np.ndarray] = None  # dense (I, P, Tb, B, B)
    sp: Optional[SparseBlocked] = None  # sparse packed batch
    nbytes: int = 0


class _StagingCache:
    """Cache of staged batches, keyed on (graph variant, attribute,
    transform, zero_fill, layout).

    Default scope is one ``run_many`` call (``byte_budget=None``: no
    eviction, dropped with the call).  With a byte budget it becomes a
    SESSION-lifetime cache — ``GopherSession(staging_cache_bytes=...)`` —
    holding batches LRU-resident up to the budget so repeated queries
    over a warm session re-stage nothing (the serving path).  Counters
    are cumulative; callers snapshot/diff them per run (the
    shared-staging and serving bench rows gate on the diffs)."""

    def __init__(self, byte_budget: Optional[float] = None):
        self.entries: "OrderedDict[Tuple, StagedBatch]" = OrderedDict()
        self.byte_budget = byte_budget
        self.staged_bytes = 0  # host tile/index bytes materialized (cum.)
        self.staging_passes = 0  # distinct batch materializations (cum.)
        self.hits = 0  # re-staging avoided by residency (cum.)
        self.evictions = 0
        self.resident_bytes = 0  # bytes currently held

    def staged(self, key: Tuple, maker: Callable[[], StagedBatch]) -> StagedBatch:
        batch = self.entries.get(key)
        if batch is not None:
            self.hits += 1
            self.entries.move_to_end(key)
            return batch
        batch = maker()
        self.staged_bytes += batch.nbytes
        self.staging_passes += 1
        self.entries[key] = batch
        self.resident_bytes += batch.nbytes
        if self.byte_budget is not None:
            # evict least-recently-used down to the budget; the returned
            # batch stays valid either way (the caller holds a reference),
            # an over-budget sole entry simply isn't retained for reuse
            while self.entries and self.resident_bytes > self.byte_budget:
                _, old = self.entries.popitem(last=False)
                self.resident_bytes -= old.nbytes
                self.evictions += 1
        return batch

    def stats(self) -> Dict[str, Any]:
        return {
            "entries": len(self.entries),
            "resident_bytes": self.resident_bytes,
            "byte_budget": self.byte_budget,
            "staged_bytes": self.staged_bytes,
            "staging_passes": self.staging_passes,
            "hits": self.hits,
            "evictions": self.evictions,
        }


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclass
class TailUpdate:
    """One ``GopherSession.tail`` observation over a growing collection.

    ``result`` always reflects EVERY instance visible at the update
    (full history, not just the appended tail); ``mode`` records how it
    was obtained: ``"full"`` (cold run over the whole collection —
    first call, or the incremental preconditions failed),
    ``"incremental"`` (one warm-started step over just the appended
    instances, seeded from the previous converged state), or ``"noop"``
    (nothing new arrived; the held result is returned unchanged)."""

    result: "AnalyticResult"
    new_instances: int
    mode: str  # "full" | "incremental" | "noop"
    version: Optional[int] = None  # backing collection version observed


@dataclass
class _TailState:
    """Held state of one tailing computation: how far the instance axis
    has been consumed and the last combined result (whose engine
    ``final`` seeds the next incremental step).  ``program`` is the
    compiled semiring program reused across steps — a tail key pins the
    analytic's params, and programs are append-invariant, so reusing the
    object keeps the engine's traced runner cache hot (a fresh program
    per step would re-trace every append)."""

    processed: int
    result: "AnalyticResult"
    program: Any = None


@dataclass
class AnalyticResult:
    """An executed plan: analytic-specific outputs + provenance.

    ``output`` holds the analytic's payload (``final`` distances for
    SSSP, ``ranks`` for PageRank, ``labels``, ``composite`` histograms,
    ``trace`` ...); ``engine`` the underlying
    :class:`~repro.core.engine.EngineResult` of the main run (``None``
    only for analytics with no single main run); ``plan`` the exact
    execution that produced them."""

    plan: ExecutionPlan
    engine: Optional[EngineResult]
    output: Dict[str, Any] = field(default_factory=dict)


# ---------------------------------------------------------------------------
# Execution context handed to program factories / composite executors
# ---------------------------------------------------------------------------

class PlanContext:
    """What a registered analytic sees at execution time: the blocked
    structure, template arrays, raw attributes, and ``run`` — all staging
    routed through the shared cache so composite analytics amortize with
    their neighbors."""

    def __init__(self, session: "GopherSession", plan: ExecutionPlan,
                 analytic: Analytic, cache: _StagingCache):
        self.session = session
        self.plan = plan
        self.analytic = analytic
        self.cache = cache
        self.params = plan.param_dict

    # ---- graph access ----------------------------------------------------
    @property
    def bg(self) -> BlockedGraph:
        return self.session._blocked(self.plan.graph)

    @property
    def num_vertices(self) -> int:
        return int(len(self.session.bg.part_of))

    @property
    def num_instances(self) -> int:
        return self.session.num_instances

    @property
    def num_edges(self) -> int:
        return self.session.num_edges

    @property
    def src(self) -> np.ndarray:
        return self.session.src

    @property
    def dst(self) -> np.ndarray:
        return self.session.dst

    # ---- staged data -----------------------------------------------------
    def staged(self) -> StagedBatch:
        """The analytic's MAIN staged batch (attr/transform/zero from the
        registry, layout from the plan) via the shared cache."""
        return self.session._staged(
            self.cache, self.analytic, self.plan.layout.value,
            delta=bool(self.plan.delta.value),
        )

    def staged_ones(self) -> StagedBatch:
        """Unit weights on every template edge, one instance — the
        topology-only batch hop-count fixpoints and probe traversals use
        (dense: every edge is live)."""
        return self.session._staged_ones(self.cache)

    def vertex_attr(self, name: str) -> np.ndarray:
        """(I, V) vertex attribute matrix for the visible collection."""
        return self.session._vertex_attr(name)

    # ---- execution -------------------------------------------------------
    def run(self, program, *, pattern: Optional[str] = None,
            merge: Optional[str] = None, x0: Optional[np.ndarray] = None,
            staged: Optional[StagedBatch] = None) -> EngineResult:
        """One engine run over a staged batch under this plan's engine
        configuration (comm/placement).  Defaults: the plan's pattern and
        merge, the analytic's main staged batch."""
        staged = staged if staged is not None else self.staged()
        pattern = pattern or self.plan.pattern
        merge = merge if merge is not None else (
            self.plan.merge if pattern == "eventually" else None)
        engine = self.session._engine(self.plan.graph, self.plan.comm.value,
                                      self.plan.kernel.value)
        spec = RunSpec(program, pattern, x0=x0, merge=merge)
        return self.session._dispatch_specs(engine, [spec], staged)[0]


# ---------------------------------------------------------------------------
# Session
# ---------------------------------------------------------------------------

class GopherSession:
    """Declarative session over one time-series graph collection.

    See the module docstring for the data sources and verbs.  Placement
    is session-level (``mesh``/``data_axis``/``model_axes``/
    ``use_pallas``), analytics and their knobs are plan-level."""

    def __init__(
        self,
        source=None,
        *,
        num_partitions: Optional[int] = None,
        block_size: Optional[int] = None,
        seed: int = 0,
        mesh=None,
        data_axis: str = "data",
        model_axes: Tuple[str, ...] = ("model",),
        use_pallas=None,
        bg: Optional[BlockedGraph] = None,
        src: Optional[np.ndarray] = None,
        dst: Optional[np.ndarray] = None,
        weights: Optional[Dict[str, np.ndarray]] = None,
        vertex_attrs: Optional[Dict[str, np.ndarray]] = None,
        staging_cache_bytes: Optional[float] = None,
        cluster=None,
    ):
        from repro.core.graph import TimeSeriesGraph
        from repro.gofs.store import GoFSStore

        self.mesh = mesh
        # ``cluster``: a repro.cluster.runtime.ClusterRuntime.  When
        # distributed, every engine this session builds becomes one shard
        # of the N-process run (its partition range, with the real
        # inter-process boundary exchange) and store-backed streamed
        # staging goes shard-local (repro.cluster.staging.shard_stream) —
        # per-host staged bytes drop to ~1/num_processes.  Results stay
        # bitwise-identical to the single-process session; a
        # single-process runtime (or None) changes nothing.
        self.cluster = cluster if (cluster is not None
                                   and cluster.is_distributed) else None
        if self.cluster is not None:
            assert mesh is None, \
                "cluster sessions are stacked per process (mesh-free)"
        self.data_axis = data_axis
        self.model_axes = tuple(model_axes)
        # kernel-mode policy: None -> the planner's auto rule picks
        # off/spmv/fused per plan from the jax backend and recorded
        # occupancy; anything else (bool, mode string, (mode, interpret)
        # tuple — see repro.core.superstep.kernel_mode) is a session-wide
        # override recorded on every plan.
        self.use_pallas = use_pallas
        self.store: Optional[GoFSStore] = None
        self.tsg: Optional[TimeSeriesGraph] = None
        self._weights = dict(weights or {})
        self._vertex_attrs = dict(vertex_attrs or {})
        self._engines: Dict[Tuple[str, str, str], TemporalEngine] = {}
        self._bg_variants: Dict[str, BlockedGraph] = {}
        self._w_cache: Dict[Tuple, np.ndarray] = {}
        self._activity_cache: Dict[Tuple, Tuple] = {}
        self.last_run_report: Dict[str, Any] = {}
        # staging_cache_bytes promotes the per-call staging cache to a
        # session-lifetime LRU with that byte budget: staged batches stay
        # resident across run_many calls, so a warm session (GopherService)
        # re-stages nothing for repeated queries.  None keeps the default
        # call-scoped cache.
        self._staging_cache: Optional[_StagingCache] = (
            _StagingCache(byte_budget=staging_cache_bytes)
            if staging_cache_bytes is not None else None)
        self._tails: Dict[Tuple, _TailState] = {}

        if isinstance(source, GoFSStore):
            self.store = source
            s, d, assign = _store_template_arrays(source)
            self.src, self.dst = s, d
            bsz = block_size or _store_block_size(source) or 64
            tmpl = _template_of(int(source.meta["num_vertices"]), s, d)
            from repro.core.blocked import build_blocked

            self.bg = build_blocked(tmpl, assign, bsz)
            self.num_instances = source.num_timesteps()
            self.num_edges = int(source.meta["num_edges"])
        elif isinstance(source, TimeSeriesGraph):
            self.tsg = source
            tmpl = source.template
            from repro.core.blocked import build_blocked
            from repro.core.partition import partition_graph

            assign = partition_graph(tmpl, num_partitions or 4, seed=seed)
            self.src, self.dst = tmpl.src, tmpl.dst
            self.bg = build_blocked(tmpl, assign, block_size or 64)
            self.num_instances = len(source)
            self.num_edges = int(tmpl.num_edges)
        elif bg is not None:
            self.bg = bg
            self.src, self.dst = src, dst
            self.num_edges = len(bg.le_edge_id) + len(bg.re_edge_id)
            n_i = [np.asarray(w).shape[0] if np.asarray(w).ndim > 1 else 1
                   for w in self._weights.values()]
            n_i += [np.asarray(v).shape[0]
                    for v in self._vertex_attrs.values()]
            assert n_i, "from_blocked needs weights= or vertex_attrs="
            self.num_instances = max(n_i)
        else:
            raise TypeError(
                "GopherSession needs a GoFSStore, a TimeSeriesGraph, or "
                "GopherSession.from_blocked(bg, weights=...)")
        self._bg_variants["template"] = self.bg

    @classmethod
    def from_blocked(
        cls,
        bg: BlockedGraph,
        *,
        weights: Optional[Dict[str, np.ndarray]] = None,
        vertex_attrs: Optional[Dict[str, np.ndarray]] = None,
        src: Optional[np.ndarray] = None,
        dst: Optional[np.ndarray] = None,
        **kw,
    ) -> "GopherSession":
        """Session over a pre-built blocked structure + raw ``(I, E)``
        attribute matrices (``weights``) and ``(I, V)`` vertex matrices
        (``vertex_attrs``).  ``src``/``dst`` (template edge endpoints)
        are only needed by analytics that derive weights from topology
        (PageRank's outdegree normalization, components' symmetrized
        graph)."""
        return cls(None, bg=bg, weights=weights, vertex_attrs=vertex_attrs,
                   src=src, dst=dst, **kw)

    # ------------------------------------------------------------ planning
    def plan(
        self,
        analytic: str,
        *,
        pattern: Optional[str] = None,
        merge: Optional[str] = None,
        layout: Optional[str] = None,
        comm: Optional[str] = None,
        staging: Optional[str] = None,
        delta: Optional[bool] = None,
        warm: Optional[bool] = None,
        kernel: Optional[str] = None,
        **params,
    ) -> ExecutionPlan:
        """Resolve ``analytic`` into a costed :class:`ExecutionPlan`.

        Every knob (``layout``/``comm``/``staging``/``delta``/``warm``/
        ``kernel``, plus ``pattern`` and ``merge`` for program analytics)
        defaults to
        the planner's auto-selection — pass a value to override; the plan
        records which happened and why (``plan.explain()``).  Planning
        never reads a value slice: activity comes from
        deployment-recorded tile maps (stores) or an in-memory scan
        (arrays); delta/warm read the deploy-recorded chain summary
        (unique-tile ratio, monotonicity) from the same tile-map slice."""
        from repro.core.comm import COMM_BACKENDS
        from repro.core.superstep import KERNEL_MODES, kernel_mode
        from repro.kernels.semiring_spmm.ops import resolved_backend

        assert layout in (None, "dense", "sparse"), layout
        assert comm in (None,) + COMM_BACKENDS, comm
        assert staging in (None, "sync", "async"), staging
        assert kernel in (None,) + KERNEL_MODES, kernel
        if kernel is None and self.use_pallas is not None:
            # session-wide kernel policy becomes a per-plan override
            kernel = kernel_mode(self.use_pallas)[0]
        a = get_analytic(analytic)
        resolved = a.resolve_params(params)
        # activity only matters to the layout decision; an override skips
        # the scan (estimates then omit occupancy)
        occupancy, buckets = (None, None) if layout is not None \
            else self._plan_activity(a)
        delta_ratio = delta_monotone = None
        if (self.store is not None and a.weights is None
                and a.graph == "template" and a.attr != ONES_ATTR):
            delta_ratio, delta_monotone = self.store.delta_stats(
                a.attr, zero=a.zero_fill)
        return plan_analytic(
            a, resolved,
            bg=self._blocked(a.graph),
            mesh=self.mesh,
            model_axes=self.model_axes,
            store_backed=self.store is not None,
            occupancy=occupancy,
            sparse_buckets=buckets,
            num_instances=self.num_instances,
            delta_ratio=delta_ratio,
            delta_monotone=delta_monotone,
            zero_fill=float(a.zero_fill),
            pattern=pattern, merge=merge,
            layout=layout, comm=comm, staging=staging,
            delta=delta, warm=warm,
            kernel=kernel, backend=resolved_backend(),
        )

    def explain(self, analytic: str, **kw) -> str:
        """``plan(...).explain()`` in one call."""
        return self.plan(analytic, **kw).explain()

    # ----------------------------------------------------------- execution
    def run(self, plan, *, resume: bool = False,
            checkpoint_dir: Optional[str] = None,
            checkpoint_every: int = 1,
            checkpoint_chunk: Optional[int] = None,
            **params) -> AnalyticResult:
        """Execute one plan (or plan an analytic by name and execute it).

        ``checkpoint_dir=`` makes the run resumable: the pass consumes
        the instance axis in spans and snapshots its engine state (carry,
        accumulated values, superstep counters, staging cursor) every
        ``checkpoint_every`` spans through the atomic-rename machinery of
        ``repro.train.checkpoint``; ``resume=True`` then continues a
        killed run from its last committed snapshot, bitwise-identical to
        the uninterrupted pass (:mod:`repro.cluster.checkpoint`)."""
        if isinstance(plan, str):
            plan = self.plan(plan, **params)
        else:
            assert not params, "params belong to plan(); got a built plan"
        if checkpoint_dir is not None:
            from repro.cluster.checkpoint import ResumableRun

            return ResumableRun(
                self, plan, checkpoint_dir=checkpoint_dir,
                every=checkpoint_every, chunk_instances=checkpoint_chunk,
            ).run(resume=resume)
        assert not resume, "resume=True needs checkpoint_dir="
        return self.run_many([plan])[0]

    def run_many(self, plans: Sequence[ExecutionPlan]) -> List[AnalyticResult]:
        """Execute several plans over this collection with shared staging.

        Plans whose staged batches coincide (same graph variant,
        attribute, weight transform, semiring zero, and layout) stage
        tiles ONCE; program analytics sharing a batch additionally share
        one :meth:`TemporalEngine.run_many` pass — for async store-backed
        groups that is a single disk prefetch pass feeding N runners.
        Results come back in plan order, bitwise identical to running
        each plan alone; ``session.last_run_report`` records the staging
        economy (bytes, passes)."""
        plans = list(plans)
        # session-lifetime cache when configured (warm serving), else one
        # cache per call; counters are cumulative so report deltas below
        cache = self._staging_cache if self._staging_cache is not None \
            else _StagingCache()
        base = (cache.staged_bytes, cache.staging_passes, cache.hits)
        results: List[Optional[AnalyticResult]] = [None] * len(plans)
        resolved = [get_analytic(p.analytic) for p in plans]

        # staging keys composite analytics will pull from the cache — a
        # program group sharing one of these must stage through the cache
        # (not a private stream) or the sharing is lost
        composite_keys = {
            self._main_key(a, p.layout.value)
            for a, p in zip(resolved, plans) if a.composite
        }

        # ---- program analytics: group by (staging key, comm) -------------
        groups: Dict[Tuple, List[int]] = {}
        for i, (a, p) in enumerate(zip(resolved, plans)):
            if not a.composite:
                key = self._main_key(a, p.layout.value) + (
                    p.comm.value, p.kernel.value)
                groups.setdefault(key, []).append(i)
        # a staging key split across comm/kernel backends must stage via
        # the cache (a private stream per group would re-read the disk)
        skey_groups: Dict[Tuple, int] = {}
        for key in groups:
            skey_groups[key[:-2]] = skey_groups.get(key[:-2], 0) + 1
        for key, idxs in groups.items():
            skey, comm, kern = key[:-2], key[-2], key[-1]
            graph, attr, transform, zero, layout = skey
            specs = []
            for i in idxs:
                ctx = PlanContext(self, plans[i], resolved[i], cache)
                program = resolved[i].make_program(
                    ctx, **plans[i].param_dict)
                specs.append(RunSpec(program, plans[i].pattern,
                                     merge=plans[i].merge,
                                     warm_start=bool(plans[i].warm.value)))
            engine = self._engine(graph, comm, kern)
            a0 = resolved[idxs[0]]
            # row-wise transforms stream too: the derived weights compute
            # chunk-by-chunk on the prefetch pool (registry `rowwise`)
            rowwise_stream = (transform != "raw" and a0.rowwise
                              and a0.weights is not None)
            # results are bitwise-identical either way, so one member
            # planning delta staging turns it on for the shared pass
            use_delta = any(bool(plans[i].delta.value) for i in idxs)
            stream_ok = (
                self.store is not None
                # a session-lifetime cache favors residency over streaming:
                # materialize through the cache so the NEXT query re-stages
                # nothing (streamed chunks leave nothing resident)
                and self._staging_cache is None
                and (transform == "raw" or rowwise_stream)
                and attr != ONES_ATTR
                and graph == "template"
                and skey not in composite_keys
                and skey_groups[skey] == 1
                and skey not in cache.entries
                and all(plans[i].staging.value == "async" for i in idxs)
            )
            if stream_ok:
                # ONE disk prefetch pass feeds all N runners; chunk bytes
                # are counted by the wrapper so the staging economy report
                # is comparable with the cache path
                tf = None if transform == "raw" else \
                    (lambda rows: a0.weights(self, rows))
                if self.cluster is not None:
                    # shard-local staging: read + fill only this process's
                    # partition range (delta chains describe the full
                    # collection, so the shard path stages from the value
                    # slices); staged_bytes then reports the PER-HOST cost
                    from repro.cluster.staging import shard_stream

                    stream = shard_stream(
                        self.store, self.bg, attr, self.cluster,
                        zero=zero, layout=layout, transform=tf)
                else:
                    stream = self.store.load_blocked_stream(
                        self.bg, attr, zero=zero, layout=layout,
                        delta=use_delta, transform=tf)
                cache.staging_passes += 1
                outs = engine.run_many(
                    specs, stream=_counted_chunks(stream, cache))
            else:
                # any member analytic materializes the same batch (the
                # transform rides in the group key)
                staged = self._staged(cache, resolved[idxs[0]], layout,
                                      delta=use_delta)
                outs = self._dispatch_specs(engine, specs, staged)
            for i, res in zip(idxs, outs):
                results[i] = self._wrap(plans[i], resolved[i], res, cache)

        # ---- composite analytics (draw from the same cache) --------------
        for i, (a, p) in enumerate(zip(resolved, plans)):
            if a.composite:
                ctx = PlanContext(self, p, a, cache)
                payload = a.execute(ctx, **p.param_dict)
                engine_res = payload.pop("__engine__", None)
                results[i] = AnalyticResult(plan=p, engine=engine_res,
                                            output=payload)

        self.last_run_report = {
            "staged_bytes": cache.staged_bytes - base[0],
            "staging_passes": cache.staging_passes - base[1],
            "cache_hits": cache.hits - base[2],
            "resident_bytes": cache.resident_bytes,
            "analytics": [p.analytic for p in plans],
        }
        return results  # type: ignore[return-value]

    def staging_cache_stats(self) -> Optional[Dict[str, Any]]:
        """Cumulative counters of the session-lifetime staging cache
        (``None`` unless the session was built with
        ``staging_cache_bytes=``)."""
        return None if self._staging_cache is None \
            else self._staging_cache.stats()

    # ----------------------------------------------------- streaming ingest
    def refresh(self) -> bool:
        """Observe an append on the backing GoFS collection.

        Polls the store's manifest (``GoFSStore.refresh``); when the
        collection grew, rebinds ``num_instances`` and invalidates ONLY
        the affected tail of the session's caches:

        * ``("raw", attr)`` host matrices are tail-EXTENDED in place of a
          drop — the appended rows are read and concatenated, so a warm
          serving session keeps its history resident;
        * derived entries (transformed weights, vertex attributes,
          activity summaries) are dropped and recomputed lazily;
        * session-lifetime staged batches (``staging_cache_bytes=``) are
          tail-extended for dense raw template batches (new instance
          tiles filled and concatenated into a NEW :class:`StagedBatch`
          — a reader holding the old batch keeps a complete, unchanged
          view) and dropped otherwise; topology-only ``__ones__``
          batches are append-invariant and survive untouched.

        Returns ``True`` iff a new collection version was observed.
        Sessions over in-memory sources never refresh (``False``)."""
        if self.store is None or not self.store.refresh():
            return False
        old_n = self.num_instances
        new_n = self.store.num_timesteps()
        self.num_instances = new_n
        self._activity_cache.clear()
        # a time-filtered view may not grow even though the store did;
        # extension is only exact when the visible axis is the full axis
        grew = new_n > old_n and self.store._time_range is None
        for key in list(self._w_cache):
            kind, name = key[0], key[1]
            if kind == "raw" and name == ONES_ATTR:
                continue  # one synthetic instance: append-invariant
            w = self._w_cache[key]
            if (grew and kind == "raw"
                    and getattr(w, "shape", (0,))[0] == old_n):
                rows = self.store.edge_attr_rows(name, range(old_n, new_n))
                self._w_cache[key] = np.concatenate(
                    [w, rows.astype(w.dtype, copy=False)])
            else:
                del self._w_cache[key]
        if self._staging_cache is not None:
            self._extend_staging_cache(old_n, new_n, grew)
        return True

    def _extend_staging_cache(self, old_n: int, new_n: int,
                              grew: bool) -> None:
        """Tail-extend or drop resident staged batches after an append."""
        cache = self._staging_cache
        for key in list(cache.entries):
            graph, attr, transform, zero, layout = key
            batch = cache.entries[key]
            if attr == ONES_ATTR:
                continue
            extendable = (
                grew and graph == "template" and transform == "raw"
                and layout == "dense" and batch.tiles is not None
                and batch.tiles.shape[0] == old_n
            )
            if not extendable:
                cache.entries.pop(key)
                cache.resident_bytes -= batch.nbytes
                continue
            rows = self.store.edge_attr_rows(attr, range(old_n, new_n))
            bg = self._blocked(graph)
            t_new = bg.fill_local_batch(rows, zero=zero)
            b_new = bg.fill_boundary_batch(rows, zero=zero)
            nb = t_new.nbytes + b_new.nbytes
            cache.entries[key] = StagedBatch(
                layout="dense",
                tiles=np.concatenate([batch.tiles, t_new]),
                btiles=np.concatenate([batch.btiles, b_new]),
                nbytes=batch.nbytes + nb,
            )
            cache.staged_bytes += nb
            cache.staging_passes += 1
            cache.resident_bytes += nb
        if cache.byte_budget is not None:
            while cache.entries and cache.resident_bytes > cache.byte_budget:
                _, old = cache.entries.popitem(last=False)
                cache.resident_bytes -= old.nbytes
                cache.evictions += 1

    def tail(self, analytic: str, *, refresh: bool = True,
             **kw) -> TailUpdate:
        """Incremental analytics over a growing collection.

        The first call runs ``analytic`` cold over everything visible
        and holds the result.  After an append (observed via
        :meth:`refresh`, or pass ``refresh=False`` when the caller
        already polled), the next call runs ONE step over just the
        appended instances, seeded from the held converged state:

        * ``sequential`` programs carry their continuation state — the
          suffix run's ``x0`` is the previous final, exact by the
          pattern's definition;
        * ``independent`` fixpoints under a warm plan seed the first new
          instance from the previous final (exact for monotone min-plus,
          the same contract as ``RunSpec.warm_start``); otherwise the
          suffix cold-starts from the program's own init, exact because
          instances never communicate.  Fixed-iterate programs are never
          seeded (a warm seed would change their result).

        Suffix values/stats are concatenated onto the held result, so
        ``update.result`` always covers the full history.  Composite
        analytics, eventually-merge plans, non-rowwise weight
        transforms, variant graphs, and time-filtered views fall back to
        a cold full re-run.  ``**kw`` takes the same params and knob
        overrides as :meth:`plan`; each distinct combination tails
        independently."""
        if refresh:
            self.refresh()
        key = (analytic, _freeze_value(kw))
        st = self._tails.get(key)
        n = self.num_instances
        version = self.store.version if self.store is not None else None
        if st is not None and st.processed == n:
            return TailUpdate(st.result, 0, "noop", version)
        plan = self.plan(analytic, **kw)
        a = get_analytic(analytic)
        n_new = n - (st.processed if st is not None else 0)
        prev = st.result.engine if st is not None else None
        incremental = (
            st is not None and 0 < n_new
            and not a.composite
            and plan.pattern in ("sequential", "independent")
            and a.attr != ONES_ATTR
            and a.graph == "template"
            and (a.weights is None or a.rowwise)
            and prev is not None
            and (self.store is None or self.store._time_range is None)
        )
        if incremental:
            raw = self._raw(a.attr)
            incremental = raw.shape[0] >= n
        if not incremental:
            result = self.run_many([plan])[0]
            self._tails[key] = _TailState(processed=n, result=result)
            return TailUpdate(result, n_new, "full", version)

        w = raw[st.processed:n]
        if a.weights is not None:
            w = a.weights(self, w)
        cache = self._staging_cache if self._staging_cache is not None \
            else _StagingCache()
        ctx = PlanContext(self, plan, a, cache)
        program = st.program
        if program is None:
            program = a.make_program(ctx, **plan.param_dict)
        engine = self._engine(plan.graph, plan.comm.value,
                              plan.kernel.value)
        warm = bool(plan.warm.value) and program.kind == "fixpoint"
        if plan.pattern == "sequential":
            spec = RunSpec(program, plan.pattern,
                           x0=engine.resume_seed(prev.final,
                                                 pad=float(a.zero_fill)))
        elif warm:
            spec = RunSpec(program, plan.pattern,
                           x0=engine.resume_seed(prev.final,
                                                 pad=float(a.zero_fill)),
                           warm_start=True)
        else:
            spec = RunSpec(program, plan.pattern)  # cold suffix: exact
        # suffix rows are already in host memory — sync staging skips the
        # prefetcher a store-backed plan would spin up for a full pass
        res_new = engine.run_many([spec], w, staging="sync")[0]
        combined = EngineResult(
            pattern=res_new.pattern,
            values=np.concatenate([prev.values, res_new.values], axis=-2),
            final=res_new.final,
            merged=None,
            stats={k: np.concatenate([prev.stats[k], res_new.stats[k]],
                                     axis=-1) for k in res_new.stats},
            occupancy=res_new.occupancy,
            warm_start=res_new.warm_start,
            n_sources=res_new.n_sources,
            _n_published=res_new._n_published,
            _n_parts=res_new._n_parts,
            _num_vertices=res_new._num_vertices,
        )
        result = self._wrap(plan, a, combined, cache)
        self._tails[key] = _TailState(processed=n, result=result,
                                      program=program)
        return TailUpdate(result, n_new, "incremental", version)

    # ------------------------------------------------------------ internals
    def _wrap(self, plan: ExecutionPlan, a: Analytic, res: EngineResult,
              cache: _StagingCache) -> AnalyticResult:
        payload: Dict[str, Any] = {}
        if a.postprocess is not None:
            ctx = PlanContext(self, plan, a, cache)
            payload = a.postprocess(ctx, res, **plan.param_dict)
        return AnalyticResult(plan=plan, engine=res, output=payload)

    def _dispatch_specs(self, engine: TemporalEngine,
                        specs: List[RunSpec],
                        staged: StagedBatch) -> List[EngineResult]:
        if staged.layout == "sparse":
            return engine.run_many(specs, sparse=staged.sp)
        return engine.run_many(specs, tiles=staged.tiles,
                               btiles=staged.btiles)

    def _engine(self, graph: str, comm: str,
                kernel: str = "off") -> TemporalEngine:
        key = (graph, comm, kernel)
        if key not in self._engines:
            # the plan's kernel knob already folded in any session-wide
            # use_pallas override; a (mode, interpret) tuple additionally
            # forces the interpret flag through to the kernels
            up = kernel
            if isinstance(self.use_pallas, tuple):
                up = (kernel, self.use_pallas[1])
            self._engines[key] = TemporalEngine(
                self._blocked(graph), mesh=self.mesh,
                data_axis=self.data_axis, model_axes=self.model_axes,
                use_pallas=up, comm=comm, cluster=self.cluster,
            )
        return self._engines[key]

    def _blocked(self, graph: str) -> BlockedGraph:
        if graph not in self._bg_variants:
            assert graph == "symmetrized", graph
            assert self.src is not None and self.dst is not None, \
                "symmetrized-graph analytics need template src/dst " \
                "(pass src=/dst= to from_blocked)"
            from repro.core.algorithms.components import symmetrized_blocked

            self._bg_variants[graph] = symmetrized_blocked(
                self.bg, self.src, self.dst)
        return self._bg_variants[graph]

    # ---- raw + transformed weights ---------------------------------------
    def _raw(self, attr: str) -> np.ndarray:
        """(I, E) raw edge-attribute matrix (cached per attribute)."""
        key = ("raw", attr)
        if key in self._w_cache:
            return self._w_cache[key]
        if attr == ONES_ATTR:
            w = np.ones((1, self.num_edges), np.float32)
        elif self.store is not None:
            w = self.store.edge_attr_matrix(attr)
        elif self.tsg is not None:
            w = np.stack([
                np.asarray(self.tsg.edge_values(t, attr), np.float32)
                for t in range(self.num_instances)
            ])
        else:
            try:
                w = np.asarray(self._weights[attr], np.float32)
            except KeyError:
                raise KeyError(
                    f"session has no weights for attribute {attr!r}; "
                    f"available: {sorted(self._weights)}") from None
            if w.ndim == 1:
                w = w[None]
        self._w_cache[key] = w
        return w

    def _vertex_attr(self, name: str) -> np.ndarray:
        key = ("vattr", name)
        if key in self._w_cache:
            return self._w_cache[key]
        if self.store is not None:
            v = self.store.vertex_attr_matrix(name)
        elif self.tsg is not None:
            v = np.stack([
                np.asarray(self.tsg.vertex_values(t, name))
                for t in range(self.num_instances)
            ])
        else:
            try:
                v = np.asarray(self._vertex_attrs[name])
            except KeyError:
                raise KeyError(
                    f"session has no vertex attribute {name!r}; "
                    f"available: {sorted(self._vertex_attrs)}") from None
        self._w_cache[key] = v
        return v

    def _staged_weights(self, a: Analytic) -> np.ndarray:
        """The analytic's transformed (I, E') staging weights (cached)."""
        key = ("w", a.graph, a.attr, a.transform_name)
        if key in self._w_cache:
            return self._w_cache[key]
        raw = self._raw(a.attr)
        w = raw if a.weights is None else a.weights(self, raw)
        self._w_cache[key] = w
        return w

    # ---- staging ----------------------------------------------------------
    def _main_key(self, a: Analytic, layout: str) -> Tuple:
        return (a.graph, a.attr, a.transform_name, float(a.zero_fill),
                layout)

    def cache_staged(self, cache: _StagingCache, skey: Tuple,
                     delta: Optional[bool] = None) -> StagedBatch:
        graph, attr, transform, zero, layout = skey

        def maker() -> StagedBatch:
            bg = self._blocked(graph)
            if (self.store is not None and transform == "raw"
                    and graph == "template" and attr != ONES_ATTR):
                out = self.store.load_blocked(bg, attr, zero=zero,
                                              layout=layout, delta=delta)
                if layout == "sparse":
                    # under delta staging the bytes that actually moved
                    # from the store are the deduped payloads, not the
                    # reconstructed batch
                    return StagedBatch(
                        layout=layout, sp=out,
                        nbytes=out.source_bytes
                        if out.source_bytes is not None
                        else out.staged_bytes())
                tiles, btiles = out
                return StagedBatch(layout=layout, tiles=tiles,
                                   btiles=btiles,
                                   nbytes=tiles.nbytes + btiles.nbytes)
            w = self._staged_weights_by_key(graph, attr, transform)
            if layout == "sparse":
                sp = bg.stage_sparse(w, zero=zero)
                return StagedBatch(layout=layout, sp=sp,
                                   nbytes=sp.staged_bytes())
            tiles = bg.fill_local_batch(w, zero=zero)
            btiles = bg.fill_boundary_batch(w, zero=zero)
            return StagedBatch(layout=layout, tiles=tiles, btiles=btiles,
                               nbytes=tiles.nbytes + btiles.nbytes)

        return cache.staged(skey, maker)

    def _staged_weights_by_key(self, graph: str, attr: str,
                               transform: str) -> np.ndarray:
        key = ("w", graph, attr, transform)
        if key in self._w_cache:
            return self._w_cache[key]
        assert transform == "raw", \
            f"transform {transform!r} must be materialized via its analytic"
        return self._raw(attr)

    def _staged(self, cache: _StagingCache, a: Analytic, layout: str,
                delta: Optional[bool] = None) -> StagedBatch:
        self._staged_weights(a)  # materialize the transform into _w_cache
        return self.cache_staged(cache, self._main_key(a, layout),
                                 delta=delta)

    def _staged_ones(self, cache: _StagingCache) -> StagedBatch:
        from repro.core.semiring import INF

        return self.cache_staged(
            cache, ("template", ONES_ATTR, "raw", float(INF), "dense"))

    # ---- planning inputs ---------------------------------------------------
    def _plan_activity(self, a: Analytic):
        """(occupancy, pow2 buckets) for the analytic's main staging —
        from recorded tile maps (stores: no value read) or an in-memory
        activity scan (arrays); (None, None) when unknowable cheaply."""
        key = (a.graph, a.attr, a.transform_name, float(a.zero_fill))
        if key in self._activity_cache:
            return self._activity_cache[key]
        bg = self._blocked(a.graph)
        if self.store is not None:
            if a.weights is None and a.graph == "template":
                occ = self.store.tile_occupancy(bg, a.attr,
                                                zero=a.zero_fill)
                buckets = self.store.sparse_buckets(bg, a.attr,
                                                    zero=a.zero_fill)
            else:
                occ, buckets = None, None  # needs a value read: stay dense
        else:
            w = self._staged_weights(a)
            act_l, act_b = bg.active_tile_maps(w, zero=a.zero_fill)
            denom = w.shape[0] * (int(bg.n_tiles.sum())
                                  + int(bg.n_btiles.sum()))
            occ = (float(int(act_l.sum()) + int(act_b.sum())) / denom
                   if denom else 0.0)
            buckets = (
                pow2_bucket(int(act_l.sum(-1).max()) if act_l.size else 0),
                pow2_bucket(int(act_b.sum(-1).max()) if act_b.size else 0),
            )
        self._activity_cache[key] = (occ, buckets)
        return occ, buckets


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _freeze_value(v) -> Any:
    """Hashable key for tail/subscription params (lists and dicts become
    tuples, arrays their contents)."""
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze_value(x)) for k, x in v.items()))
    if isinstance(v, (list, tuple)):
        return tuple(_freeze_value(x) for x in v)
    if isinstance(v, np.ndarray):
        return (v.shape, v.tobytes())
    return v


def _counted_chunks(stream, cache: _StagingCache):
    """Pass chunks through, accounting their staged bytes so streamed and
    cached staging report comparably.  Delta-reconstructed chunks report
    the bytes that actually moved from the store (``ch.staged_bytes``,
    unique payloads only) rather than the reconstructed tensors."""
    for ch in stream:
        if ch.staged_bytes is not None:
            cache.staged_bytes += int(ch.staged_bytes)
            yield ch
            continue
        n = ch.tiles.nbytes + ch.btiles.nbytes
        for a in (ch.rows, ch.cols, ch.brows, ch.bcols):
            if a is not None:
                n += a.nbytes
        cache.staged_bytes += n
        yield ch


def _template_of(num_vertices: int, src: np.ndarray, dst: np.ndarray):
    from repro.core.graph import GraphTemplate

    return GraphTemplate(num_vertices=num_vertices, src=src, dst=dst)


def _store_template_arrays(store):
    """Reconstruct (src, dst, partition assignment) in template order from
    the stored topology slices — the session's blocked structure needs no
    regeneration of the original collection (every edge is local XOR
    remote in exactly one subgraph)."""
    V = int(store.meta["num_vertices"])
    E = int(store.meta["num_edges"])
    src = np.full(E, -1, np.int64)
    dst = np.full(E, -1, np.int64)
    assign = np.zeros(V, np.int32)
    for g in store.subgraph_ids():
        topo = store.get_topology(g)
        assign[topo.vertices] = topo.pid
        if len(topo.local_edge_id):
            src[topo.local_edge_id] = topo.vertices[topo.local_src]
            dst[topo.local_edge_id] = topo.vertices[topo.local_dst]
        if len(topo.remote_edge_id):
            src[topo.remote_edge_id] = topo.vertices[topo.remote_src]
            dst[topo.remote_edge_id] = topo.remote_dst_vertex
    assert (src >= 0).all() and (dst >= 0).all(), \
        "store topology does not cover every template edge"
    return src, dst, assign


def _store_block_size(store) -> Optional[int]:
    """Deployment-recorded block size, when any tile map was recorded
    (deterministic: first attribute in sorted order)."""
    for name in sorted(store.meta.get("sparse_absent", {})):
        maps = store.edge_tile_maps(name)
        if maps is not None:
            return int(maps["block_size"])
    return None
