"""Unified temporal execution engine: one pattern-aware runner for every
semiring analytic over a blocked graph collection (paper §IV-B on TPU).

The paper's claim is that a single iBSP abstraction expresses *all*
temporal graph analytics through three execution patterns; this module is
the blocked-engine counterpart of ``repro.core.ibsp.run_ibsp``.  An
algorithm is declared as a :class:`SemiringProgram` — a semiring plus
either a *fixpoint* spec (idempotent relaxation to quiescence: SSSP,
components, reachability, N-hop) or an *iterate* spec (a fixed-count
superstep function: PageRank) — and the engine executes it under any
pattern in any placement mode:

========================  =================================================
pattern                   execution
========================  =================================================
``sequential``            one ``lax.scan`` over the instance axis carrying
                          the vertex state (incremental aggregation — the
                          previous timestep's end state seeds the next)
``independent``           every instance runs from the same initial state;
                          on a mesh, instances shard over the ``data`` axis
                          while partitions stay on ``model`` (both forms of
                          the paper's parallelism at once)
``eventually``            independent + a Merge reduction across instances
                          (``merge="mean"`` on-device; ``None`` leaves the
                          per-instance states for a host-side Merge)
========================  =================================================

Placement: ``mesh=None`` runs stacked on one device (tests, benches);
with a mesh the engine lowers to ``shard_map`` — partitions one-per-device
over ``model_axes``, and for the temporally concurrent patterns instances
over ``data_axis``.  The boundary exchange stays a single dense
psum/pmin per superstep either way (see ``repro.core.superstep``).

Instance staging is batched: edge-attribute matrices (I, E) land in
(I, P, T, B, B) tile tensors through ``BlockedGraph.fill_local_batch`` /
``fill_boundary_batch`` (or straight from GoFS slices via
``GoFSStore.load_blocked``) — no per-instance Python fill loops.

Stats are reported in the same :class:`repro.core.ibsp.BSPStats` shape as
the host engine so the two paths are directly comparable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.core.blocked import BlockedGraph
from repro.core.ibsp import BSPStats
from repro.core.semiring import INF, MIN_PLUS, PLUS_MUL, Semiring
from repro.core.superstep import (
    Comm,
    DeviceGraph,
    bsp_fixpoint,
    pagerank_step,
)

PATTERNS = ("sequential", "independent", "eventually")


# ---------------------------------------------------------------------------
# Program declarations
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SemiringProgram:
    """A blocked iBSP analytic: semiring + step semantics + init.

    ``kind="fixpoint"`` iterates BSP supersteps to global quiescence
    (requires an idempotent semiring).  ``kind="iterate"`` applies ``step``
    exactly ``iters`` times — the fixed-count form keeps every instance's
    loop in lockstep, which is what lets the mesh run instances
    concurrently over the ``data`` axis.
    """

    name: str
    semiring: Semiring
    zero_fill: float  # tile value for absent edges (sr.zero of the fill op)
    kind: str = "fixpoint"  # "fixpoint" | "iterate"
    # fixpoint knobs
    subgraph_centric: bool = True
    max_supersteps: int = 64
    max_local_sweeps: int = 1024
    # iterate knobs
    iters: int = 0
    # step(x, dg, comm, use_pallas) -> x  (iterate kind only)
    step: Optional[Callable] = None
    # host-side initial state: init(bg) -> (P, Vp) float32
    init: Optional[Callable[[BlockedGraph], np.ndarray]] = None

    def __post_init__(self):
        assert self.kind in ("fixpoint", "iterate"), self.kind
        if self.kind == "fixpoint":
            assert self.semiring.idempotent, \
                "fixpoint programs need an idempotent semiring"
        else:
            assert self.step is not None and self.iters > 0


def source_init(source_vertex: int, pad: float = INF):
    """x0 = pad everywhere, 0 at the source (SSSP-style frontier seed)."""

    def init(bg: BlockedGraph) -> np.ndarray:
        x0 = bg.scatter_vertex(np.full(bg.part_of.shape, pad, np.float32), pad)
        x0[bg.part_of[source_vertex], bg.local_of[source_vertex]] = 0.0
        return x0

    return init


def label_init():
    """x0 = own vertex id (label propagation / components seed)."""

    def init(bg: BlockedGraph) -> np.ndarray:
        V = len(bg.part_of)
        return bg.scatter_vertex(np.arange(V, dtype=np.float32), INF)

    return init


def min_plus_program(
    name: str = "min_plus_fixpoint",
    *,
    init: Optional[Callable] = None,
    subgraph_centric: bool = True,
    max_supersteps: int = 64,
    max_local_sweeps: int = 1024,
) -> SemiringProgram:
    """Min-plus fixpoint (SSSP / reachability / label propagation)."""
    return SemiringProgram(
        name=name, semiring=MIN_PLUS, zero_fill=INF, kind="fixpoint",
        subgraph_centric=subgraph_centric, max_supersteps=max_supersteps,
        max_local_sweeps=max_local_sweeps, init=init,
    )


def pagerank_program(
    num_vertices: int, *, damping: float = 0.85, iters: int = 30
) -> SemiringProgram:
    """Fixed-iteration plus-mul PageRank (independent pattern workload)."""

    def step(x, dg, comm, use_pallas):
        return pagerank_step(
            x, dg, comm, damping=damping, num_vertices=num_vertices,
            use_pallas=use_pallas,
        )

    def init(bg: BlockedGraph) -> np.ndarray:
        valid = (bg.global_of >= 0)
        return np.where(valid, 1.0 / num_vertices, 0.0).astype(np.float32)

    return SemiringProgram(
        name="pagerank", semiring=PLUS_MUL, zero_fill=0.0, kind="iterate",
        iters=iters, step=step, init=init,
    )


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclass
class EngineResult:
    """Gathered outputs + iBSP-comparable statistics."""

    pattern: str
    values: np.ndarray  # (I, V) per-instance vertex values (global order)
    final: np.ndarray  # (V,) carried end state (sequential) or values[-1]
    merged: Optional[np.ndarray]  # (V,) Merge output (eventually + on-device)
    stats: Dict[str, np.ndarray]  # {"supersteps": (I,), "local_sweeps": (I,)}
    _n_published: int = 0  # boundary vertices published per superstep
    _n_parts: int = 0
    _num_vertices: int = 0

    def bsp_stats(self) -> BSPStats:
        """The host engine's accounting shape (run_ibsp comparability):
        compute_calls = partition activations, superstep_messages =
        published boundary values, timestep_messages = carried vertex
        states (sequential), merge_messages = instances folded."""
        ss = int(np.sum(self.stats["supersteps"]))
        I = len(self.stats["supersteps"])
        return BSPStats(
            supersteps=ss,
            compute_calls=ss * self._n_parts,
            superstep_messages=ss * self._n_published,
            timestep_messages=(I - 1) * self._num_vertices
            if self.pattern == "sequential" else 0,
            merge_messages=I if self.pattern == "eventually" else 0,
        )


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class TemporalEngine:
    """Pattern-aware runner for semiring programs over one blocked graph.

    Modes:

    * ``mesh=None`` — stacked: all partitions on one device, instances
      scanned (CPU tests and benchmarks).
    * ``mesh=...`` — SPMD: partitions sharded one-per-device over
      ``model_axes``; for ``independent``/``eventually`` the instance axis
      additionally shards over ``data_axis`` (temporal parallelism).

    Jitted runners are cached per (program, pattern, instance count), so
    repeated calls (e.g. tracking's per-timestep probes) recompile nothing.
    """

    def __init__(
        self,
        bg: BlockedGraph,
        *,
        mesh=None,
        data_axis: str = "data",
        model_axes: Tuple[str, ...] = ("model",),
        use_pallas: bool = False,
    ):
        self.bg = bg
        self.mesh = mesh
        self.data_axis = data_axis
        self.model_axes = tuple(model_axes)
        self.use_pallas = use_pallas
        self.comm = Comm(axis_name=None if mesh is None else self.model_axes)
        out_mask = np.arange(bg.o_max)[None, :] < bg.n_out[:, None]
        self._struct = (
            jnp.asarray(bg.tiles_rc[:, :, 0]), jnp.asarray(bg.tiles_rc[:, :, 1]),
            jnp.asarray(bg.btiles_rc[:, :, 0]), jnp.asarray(bg.btiles_rc[:, :, 1]),
            jnp.asarray(bg.out_slot), jnp.asarray(bg.out_local),
            jnp.asarray(out_mask), jnp.asarray(bg.global_of >= 0),
        )
        self._runners: Dict[Any, Callable] = {}

    # ------------------------------------------------------------ staging
    def stage(
        self, instance_weights: np.ndarray, zero_fill: float
    ) -> Tuple[jax.Array, jax.Array]:
        """(I, E) edge weights -> device tile tensors, batched scatter."""
        w = np.asarray(instance_weights, np.float32)
        if w.ndim == 1:
            w = w[None]
        return (
            jnp.asarray(self.bg.fill_local_batch(w, zero=zero_fill)),
            jnp.asarray(self.bg.fill_boundary_batch(w, zero=zero_fill)),
        )

    # ------------------------------------------------------- instance step
    def _device_graph(self, tiles_l, btiles_l, struct) -> DeviceGraph:
        rows, cols, brows, bcols, out_slot, out_local, out_mask, vmask = struct
        return DeviceGraph(
            block_size=self.bg.block_size, num_boundary=self.bg.num_boundary,
            rows=rows, cols=cols, tiles=tiles_l,
            brows=brows, bcols=bcols, btiles=btiles_l,
            out_slot=out_slot, out_local=out_local,
            out_mask=out_mask, vmask=vmask,
        )

    def _run_instance(self, program: SemiringProgram, x, tiles_l, btiles_l,
                      struct):
        """One instance's BSP on the local shard.  Returns (x, (ss, lsw))."""
        dg = self._device_graph(tiles_l, btiles_l, struct)
        if program.kind == "fixpoint":
            x, st = bsp_fixpoint(
                x, dg, program.semiring, comm=self.comm,
                subgraph_centric=program.subgraph_centric,
                max_supersteps=program.max_supersteps,
                max_local_sweeps=program.max_local_sweeps,
                use_pallas=self.use_pallas,
            )
            return x, (st["supersteps"], st["local_sweeps"])

        def body(r, _):
            return program.step(r, dg, self.comm, self.use_pallas), None

        x, _ = jax.lax.scan(body, x, None, length=program.iters)
        return x, (jnp.asarray(program.iters, jnp.int32),
                   jnp.asarray(0, jnp.int32))

    # ------------------------------------------------------------- runners
    def _scan_instances(self, program: SemiringProgram, pattern: str,
                        x0, tiles, btiles, struct):
        """Scan the instance axis on the local shard.  Returns
        (xs (I, P_l, Vp), final (P_l, Vp), ss (I,), lsw (I,))."""

        def step(carry, tb):
            tiles_l, btiles_l = tb
            seed = carry if pattern == "sequential" else x0
            x, (ss, lsw) = self._run_instance(
                program, seed, tiles_l, btiles_l, struct
            )
            return x, (x, ss, lsw)

        final, (xs, ss, lsw) = jax.lax.scan(step, x0, (tiles, btiles))
        return xs, final, ss, lsw

    def _make_stacked_runner(self, program: SemiringProgram, pattern: str,
                             merge: Optional[str]):
        def run(tiles, btiles, x0, *struct):
            xs, final, ss, lsw = self._scan_instances(
                program, pattern, x0, tiles, btiles, struct
            )
            if pattern == "eventually" and merge == "mean":
                merged = jnp.mean(xs, axis=0)
            else:
                merged = jnp.zeros_like(final)
            return xs, final, merged, ss, lsw

        return jax.jit(run)

    def _data_size(self) -> int:
        axes = (self.data_axis,) if isinstance(self.data_axis, str) \
            else tuple(self.data_axis)
        n = 1
        for a in axes:
            n *= int(self.mesh.shape[a])
        return n

    def _make_mesh_runner(self, program: SemiringProgram, pattern: str,
                          merge: Optional[str], n_instances: int):
        from jax.sharding import PartitionSpec as P_

        mesh = self.mesh
        maxes = self.model_axes if len(self.model_axes) > 1 \
            else self.model_axes[0]
        daxis = self.data_axis
        # temporal concurrency: shard the instance axis over data only when
        # it divides — single-instance probes (tracking, nhop hops) and
        # ragged collections fall back to replicated instances, which stays
        # correct (every data group computes the same states; the Merge
        # psum normalizes by the psum'd instance count).
        temporal = pattern in ("independent", "eventually")
        shard_instances = (temporal and n_instances % self._data_size() == 0
                           and n_instances >= self._data_size())

        def local_fn(tiles, btiles, x0, *struct):
            xs, final, ss, lsw = self._scan_instances(
                program, pattern, x0, tiles, btiles, struct
            )
            if pattern == "eventually" and merge == "mean":
                # eventually-dependent Merge across ALL instances (data axis)
                part = jnp.sum(xs, axis=0)
                total = jax.lax.psum(part, daxis)
                n = jax.lax.psum(
                    jnp.asarray(xs.shape[0], jnp.float32), daxis
                )
                merged = total / n
            else:
                merged = jnp.zeros_like(final)
            return xs, final, merged, ss, lsw

        iaxis = daxis if shard_instances else None

        def lead(extra_dims: int, *front):
            return P_(*front, *([None] * extra_dims))

        in_specs = (
            lead(3, iaxis, maxes),  # tiles (I, P, T, B, B)
            lead(3, iaxis, maxes),  # btiles
            lead(1, maxes),         # x0 (P, Vp)
        ) + tuple(lead(s.ndim - 1, maxes) for s in self._struct)
        out_specs = (
            lead(2, iaxis, maxes),  # xs (I, P, Vp)
            lead(1, maxes),         # final
            lead(1, maxes),         # merged (replicated over data)
            P_(iaxis), P_(iaxis),   # ss, lsw (I,)
        )
        fn = shard_map(
            local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
        return jax.jit(fn)

    def _runner(self, program: SemiringProgram, pattern: str,
                merge: Optional[str], n_instances: int):
        key = (program, pattern, merge, n_instances)
        if key not in self._runners:
            if self.mesh is None:
                self._runners[key] = self._make_stacked_runner(
                    program, pattern, merge
                )
            else:
                self._runners[key] = self._make_mesh_runner(
                    program, pattern, merge, n_instances
                )
        return self._runners[key]

    # ----------------------------------------------------------------- run
    def run(
        self,
        program: SemiringProgram,
        instance_weights: Optional[np.ndarray] = None,
        *,
        pattern: str,
        x0: Optional[np.ndarray] = None,
        tiles: Optional[jax.Array] = None,
        btiles: Optional[jax.Array] = None,
        merge: Optional[str] = None,
    ) -> EngineResult:
        """Execute ``program`` over the instance collection.

        Provide either ``instance_weights`` (I, E) — staged through the
        batched fill — or pre-staged ``tiles``/``btiles`` (I, P, T|Tb, B, B)
        (e.g. from ``GoFSStore.load_blocked``).  ``x0`` overrides
        ``program.init(bg)``.  ``merge="mean"`` computes the on-device
        eventually-dependent Merge.
        """
        assert pattern in PATTERNS, pattern
        assert merge is None or pattern == "eventually", \
            "merge is the eventually-dependent Merge step; use pattern='eventually'"
        if tiles is None or btiles is None:
            assert instance_weights is not None, \
                "need instance_weights or pre-staged tiles+btiles"
            tiles, btiles = self.stage(instance_weights, program.zero_fill)
        if x0 is None:
            assert program.init is not None, "program has no init; pass x0"
            x0 = program.init(self.bg)
        x0 = jnp.asarray(x0, jnp.float32)

        run_fn = self._runner(program, pattern, merge, int(tiles.shape[0]))
        if self.mesh is not None:
            with self.mesh:
                xs, final, merged, ss, lsw = run_fn(
                    tiles, btiles, x0, *self._struct
                )
        else:
            xs, final, merged, ss, lsw = run_fn(
                tiles, btiles, x0, *self._struct
            )

        bg = self.bg
        xs = np.asarray(xs)
        values = np.stack([bg.gather_vertex(xs[i]) for i in range(xs.shape[0])])
        result = EngineResult(
            pattern=pattern,
            values=values,
            final=bg.gather_vertex(np.asarray(final)),
            merged=bg.gather_vertex(np.asarray(merged))
            if (pattern == "eventually" and merge == "mean") else None,
            stats={
                "supersteps": np.asarray(ss),
                "local_sweeps": np.asarray(lsw),
            },
            _n_published=int(bg.n_out.sum()),
            _n_parts=bg.n_parts,
            _num_vertices=len(bg.part_of),
        )
        return result
