"""Unified temporal execution engine: one pattern-aware runner for every
semiring analytic over a blocked graph collection (paper §IV-B on TPU).

The paper's claim is that a single iBSP abstraction expresses *all*
temporal graph analytics through three execution patterns; this module is
the blocked-engine counterpart of ``repro.core.ibsp.run_ibsp``.  An
algorithm is declared as a :class:`SemiringProgram` — a semiring plus
either a *fixpoint* spec (idempotent relaxation to quiescence: SSSP,
components, reachability, N-hop) or an *iterate* spec (a fixed-count
superstep function: PageRank) — and the engine executes it under any
pattern in any placement mode:

========================  =================================================
pattern                   execution
========================  =================================================
``sequential``            one ``lax.scan`` over the instance axis carrying
                          the vertex state (incremental aggregation — the
                          previous timestep's end state seeds the next)
``independent``           every instance runs from the same initial state;
                          on a mesh, instances shard over the ``data`` axis
                          while partitions stay on ``model`` (both forms of
                          the paper's parallelism at once)
``eventually``            independent + a Merge reduction across instances
                          (``merge="mean"`` on-device; ``None`` leaves the
                          per-instance states for a host-side Merge)
========================  =================================================

Placement: ``mesh=None`` runs stacked on one device (tests, benches);
with a mesh the engine lowers to ``shard_map`` — partitions one-per-device
over ``model_axes``, and for the temporally concurrent patterns instances
over ``data_axis``.  The boundary exchange is ONE combine per superstep
either way, routed through a pluggable comm backend
(``comm="dense" | "ring" | "host"`` — see ``repro.core.comm``): the dense
psum/pmin all-reduce (default), a collective-permute ring for multi-pod
DCI topologies, or a mesh-free host-side gather for CPU clusters.
Algorithms never see the difference.

Instance staging is batched: edge-attribute matrices (I, E) land in
(I, P, T, B, B) tile tensors through ``BlockedGraph.fill_local_batch`` /
``fill_boundary_batch`` (or straight from GoFS slices via
``GoFSStore.load_blocked``) — no per-instance Python fill loops.

Staging is also *layout-aware* (``layout="dense" | "sparse"``): the sparse
layout packs only each instance's ACTIVE tiles (those holding at least one
edge whose weight differs from the semiring zero) into pow2-bucket
tensors plus a per-instance tile index
(:class:`repro.core.blocked.SparseBlocked`), and the runners scan the
index alongside the values so the local SpMV gather-folds only active
tiles.  Memory and FLOPs drop from ``O(P·T·B²)`` to ``O(nnz_tiles·B²)``
per instance; results are identical (bitwise for min-plus) because
skipped tiles contribute exact semiring zeros.  The boundary buffers and
comm backends are untouched — the dense/sparse boundary is the local
SpMV.

Staging can also be *overlapped* with execution (``staging="async"`` or an
explicit ``stream=``): chunks of instances arrive from a
:class:`repro.gofs.prefetch.SlicePrefetcher` double-buffer while the device
executes the previous chunk — the paper's §V storage/compute overlap.  See
``TemporalEngine`` and ``docs/ARCHITECTURE.md`` for the pipeline diagram.

Stats are reported in the same :class:`repro.core.ibsp.BSPStats` shape as
the host engine so the two paths are directly comparable.
"""
from __future__ import annotations

import weakref
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.core.blocked import BlockedGraph, SparseBlocked
from repro.core.comm import CommBackend, make_comm
from repro.core.ibsp import BSPStats
from repro.core.semiring import INF, MIN_PLUS, PLUS_MUL, Semiring
from repro.core.superstep import (
    KERNEL_MODES,
    DeviceGraph,
    bsp_fixpoint,
    kernel_mode,
    pagerank_step,
)

PATTERNS = ("sequential", "independent", "eventually")

# staged-batch device cache entries kept per engine (LRU); each entry is one
# staged instance collection, so a handful covers any run_many working set
_STAGED_CACHE_SLOTS = 4


def _device_put(x) -> jax.Array:
    """Host buffer -> device array.  All staged-value uploads route through
    this seam so tests (and the re-upload regression gate) can count them;
    a no-op for arrays already on device."""
    return jnp.asarray(x)


# ---------------------------------------------------------------------------
# Program declarations
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SemiringProgram:
    """A blocked iBSP analytic: semiring + step semantics + init.

    ``kind="fixpoint"`` iterates BSP supersteps to global quiescence
    (requires an idempotent semiring).  ``kind="iterate"`` applies ``step``
    exactly ``iters`` times — the fixed-count form keeps every instance's
    loop in lockstep, which is what lets the mesh run instances
    concurrently over the ``data`` axis.

    Programs are declarative and engine-agnostic: the same program object
    runs under any pattern, stacked or mesh, sync or async staging.  The
    two stock constructors cover the paper's workloads:

    >>> from repro.core.engine import min_plus_program, pagerank_program
    >>> min_plus_program("sssp").kind          # idempotent -> fixpoint
    'fixpoint'
    >>> min_plus_program("sssp").semiring.name
    'min_plus'
    >>> pagerank_program(100, iters=5).iters   # non-idempotent -> iterate
    5
    """

    name: str
    semiring: Semiring
    zero_fill: float  # tile value for absent edges (sr.zero of the fill op)
    kind: str = "fixpoint"  # "fixpoint" | "iterate"
    # fixpoint knobs
    subgraph_centric: bool = True
    max_supersteps: int = 64
    max_local_sweeps: int = 1024
    # iterate knobs
    iters: int = 0
    # step(x, dg, comm, use_pallas) -> x  (iterate kind only)
    step: Optional[Callable] = None
    # host-side initial state: init(bg) -> (P, Vp) float32
    init: Optional[Callable[[BlockedGraph], np.ndarray]] = None

    def __post_init__(self):
        assert self.kind in ("fixpoint", "iterate"), self.kind
        if self.kind == "fixpoint":
            assert self.semiring.idempotent, \
                "fixpoint programs need an idempotent semiring"
        else:
            assert self.step is not None and self.iters > 0


def source_init(source_vertex: int, pad: float = INF):
    """x0 = pad everywhere, 0 at the source (SSSP-style frontier seed)."""

    def init(bg: BlockedGraph) -> np.ndarray:
        x0 = bg.scatter_vertex(np.full(bg.part_of.shape, pad, np.float32), pad)
        x0[bg.part_of[source_vertex], bg.local_of[source_vertex]] = 0.0
        return x0

    return init


def sources_init(sources: Sequence[int], pad: float = INF):
    """Batched multi-source seed: ``x0[q]`` is ``source_init(sources[q])``,
    stacked into a (Q, P, Vp) state tensor — the *query axis* that lets Q
    concurrent SSSP/N-hop requests run as ONE vectorized engine pass.

    The engine detects the extra leading axis (``x0.ndim == 3``) and vmaps
    the per-source runner over it; each source's fixpoint halts
    independently (JAX's batched ``while_loop`` masks converged lanes), so
    every result — values, final state, superstep counts — is bitwise
    identical to Q separate single-source runs.

    >>> import numpy as np
    >>> from repro.core.blocked import build_blocked
    >>> from repro.core.graph import GraphTemplate
    >>> from repro.core.engine import sources_init
    >>> tmpl = GraphTemplate(num_vertices=4,
    ...     src=np.array([0, 1, 2, 0]), dst=np.array([1, 2, 3, 2]))
    >>> bg = build_blocked(tmpl, np.array([0, 0, 1, 1]), block_size=2)
    >>> sources_init([0, 3])(bg).shape   # (Q, P, Vp)
    (2, 2, 2)
    """
    srcs = [int(s) for s in np.asarray(sources).reshape(-1)]

    def init(bg: BlockedGraph) -> np.ndarray:
        return np.stack([source_init(s, pad)(bg) for s in srcs])

    return init


def label_init():
    """x0 = own vertex id (label propagation / components seed)."""

    def init(bg: BlockedGraph) -> np.ndarray:
        V = len(bg.part_of)
        return bg.scatter_vertex(np.arange(V, dtype=np.float32), INF)

    return init


def min_plus_program(
    name: str = "min_plus_fixpoint",
    *,
    init: Optional[Callable] = None,
    subgraph_centric: bool = True,
    max_supersteps: int = 64,
    max_local_sweeps: int = 1024,
) -> SemiringProgram:
    """Min-plus fixpoint (SSSP / reachability / label propagation)."""
    return SemiringProgram(
        name=name, semiring=MIN_PLUS, zero_fill=INF, kind="fixpoint",
        subgraph_centric=subgraph_centric, max_supersteps=max_supersteps,
        max_local_sweeps=max_local_sweeps, init=init,
    )


def pagerank_program(
    num_vertices: int, *, damping: float = 0.85, iters: int = 30
) -> SemiringProgram:
    """Fixed-iteration plus-mul PageRank (independent pattern workload)."""

    def step(x, dg, comm, use_pallas):
        return pagerank_step(
            x, dg, comm, damping=damping, num_vertices=num_vertices,
            use_pallas=use_pallas,
        )

    def init(bg: BlockedGraph) -> np.ndarray:
        valid = (bg.global_of >= 0)
        return np.where(valid, 1.0 / num_vertices, 0.0).astype(np.float32)

    return SemiringProgram(
        name="pagerank", semiring=PLUS_MUL, zero_fill=0.0, kind="iterate",
        iters=iters, step=step, init=init,
    )


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclass
class EngineResult:
    """Gathered outputs + iBSP-comparable statistics."""

    pattern: str
    values: np.ndarray  # (I, V) per-instance vertex values (global order);
    # multi-source runs (n_sources=Q) prepend the query axis: (Q, I, V)
    final: np.ndarray  # (V,) carried end state (sequential) or values[-1];
    # (Q, V) for multi-source runs
    merged: Optional[np.ndarray]  # (V,) Merge output (eventually + on-device)
    stats: Dict[str, np.ndarray]  # {"supersteps": (I,), "local_sweeps": (I,)}
    # — (Q, I) per source for multi-source runs
    occupancy: Optional[float] = None  # active-tile fraction (sparse layout)
    warm_start: bool = False  # fixpoints seeded from the previous instance
    n_sources: Optional[int] = None  # query-axis width Q (None = unbatched)
    _n_published: int = 0  # boundary vertices published per superstep
    _n_parts: int = 0
    _num_vertices: int = 0

    def supersteps_saved(self) -> Optional[np.ndarray]:
        """Per-instance supersteps the warm seed saved, relative to the
        cold-seeded FIRST instance (which has no predecessor and always
        pays the full fixpoint — the natural in-run cold baseline for a
        slowly varying collection).  ``None`` unless the run was
        warm-started."""
        if not self.warm_start:
            return None
        ss = self.stats["supersteps"]
        # per-source baselines under the query axis ((Q, I) stats)
        return np.maximum(0, ss[..., :1].astype(np.int64) - ss.astype(np.int64))

    def bsp_stats(self) -> BSPStats:
        """The host engine's accounting shape (run_ibsp comparability):
        compute_calls = partition activations, superstep_messages =
        published boundary values, timestep_messages = carried vertex
        states (sequential), merge_messages = instances folded.  Counts
        sum over the query axis for multi-source runs."""
        ss = int(np.sum(self.stats["supersteps"]))
        I = int(self.stats["supersteps"].shape[-1])
        q = self.n_sources or 1
        return BSPStats(
            supersteps=ss,
            compute_calls=ss * self._n_parts,
            superstep_messages=ss * self._n_published,
            timestep_messages=(I - 1) * self._num_vertices * q
            if self.pattern == "sequential" else 0,
            merge_messages=I * q if self.pattern == "eventually" else 0,
        )


@dataclass(frozen=True)
class RunSpec:
    """One analytic execution inside a shared-staging ``run_many`` pass.

    Every spec in a pass executes over the SAME staged instance batch
    (tiles are filled / device-put once, then each spec's jitted runner
    consumes them), so the programs must agree on ``zero_fill`` — the one
    property of the staged values an analytic can observe."""

    program: SemiringProgram
    pattern: str
    x0: Optional[np.ndarray] = None  # overrides program.init(bg)
    merge: Optional[str] = None
    # seed instance t's fixpoint from instance t-1's converged state
    # instead of x0 (incremental recompute).  EXACT for monotone
    # semirings on monotone-improving collections (min-plus where no
    # edge's weight ever increases between consecutive instances — see
    # docs/ARCHITECTURE.md for the contract and proof sketch); fixed-
    # iterate programs (plus-mul PageRank) silently fall back to a cold
    # start, where the seed would change the result.  No-op for the
    # sequential pattern, which already carries state by definition.
    warm_start: bool = False

    def effective_warm(self) -> bool:
        """Warm seeding actually applies: requested AND the program is a
        fixpoint (iterate programs run a fixed count of non-idempotent
        steps — a warm seed would change their result, so they cold
        start)."""
        return self.warm_start and self.program.kind == "fixpoint"


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class TemporalEngine:
    """Pattern-aware runner for semiring programs over one blocked graph.

    **Pattern contracts** (paper §IV-B; identical semantics in every
    placement/staging mode):

    * ``sequential`` — *incrementally aggregated*: instance ``t``'s end
      state seeds instance ``t + 1`` (``SendToNextTimeStep``); the result's
      ``final`` is the last carried state.  Chunked/async staging preserves
      the carry across chunk boundaries.
    * ``independent`` — every instance starts from the same ``x0``;
      instances never communicate.  ``values[t]`` is instance ``t``'s
      converged state.
    * ``eventually`` — independent execution plus a Merge fold across
      instances (``merge="mean"`` computes it on device into ``merged``;
      ``merge=None`` leaves per-instance states for a host-side Merge).

    **Placement** (stacked vs mesh):

    * ``mesh=None`` — stacked: all partitions stacked on one device's
      leading axis, instances scanned (CPU tests and benchmarks).
    * ``mesh=...`` — SPMD ``shard_map``: partitions sharded one-per-device
      over ``model_axes``; for ``independent``/``eventually`` the instance
      axis additionally shards over ``data_axis`` (temporal parallelism)
      whenever the instance count divides the data-axis size, else
      instances are replicated (still correct, no speedup).

    **Comm backend** (how the boundary exchange moves bytes; see
    ``repro.core.comm`` and the selection table in
    ``docs/ARCHITECTURE.md``):

    * ``comm="dense"`` — psum/pmin all-reduce of the boundary buffer
      (default; single-pod meshes and stacked mode).
    * ``comm="ring"`` — ``lax.ppermute`` ring over ``model_axes``:
      P-1 neighbor-to-neighbor hops folding semiring partials (multi-pod
      DCI regime).  Stacked mode degenerates to the dense fold.
    * ``comm="host"`` — mesh-free host-side numpy semiring fold
      (``jax.pure_callback``); requires ``mesh=None``.

    Min-plus programs are bitwise identical across backends; plus-mul
    (PageRank) reassociates the sum on the mesh ring (low-order float
    bits).  The backend changes only the collective's lowering — never
    the program, pattern, staging mode, or result semantics.

    **Layout** (how instance tiles are materialized; see the block-sparse
    section of ``docs/ARCHITECTURE.md``):

    * ``layout="dense"`` — every template tile slot per instance:
      (I, P, T, B, B) tensors.  Simple, and right when most tiles are
      active every timestep.
    * ``layout="sparse"`` — only each instance's ACTIVE tiles (holding an
      edge whose weight differs from the semiring zero) are packed into
      pow2-bucket tensors plus a per-instance (row, col) tile index
      (:class:`repro.core.blocked.SparseBlocked`); the runners scan the
      index with the values, so staging bytes and SpMV work scale with
      ``nnz_tiles`` instead of ``T``.  Results are identical — bitwise
      for min-plus — because skipped tiles contribute exact semiring
      zeros; ``result.occupancy`` reports the measured active fraction.
      Boundary buffers and comm backends are untouched (the dense/sparse
      boundary is the local SpMV).

    **Staging** (how instance tensors reach the device):

    * ``staging="sync"`` — stage the whole (I, P, T, B, B) batch, then run.
    * ``staging="async"`` — double-buffered: instances are staged in chunks
      on a background thread (:class:`repro.gofs.prefetch.SlicePrefetcher`)
      while the device executes the previous chunk; results are bitwise
      identical to sync staging (one caveat: on a mesh, the ``eventually``
      ``merge="mean"`` fold reduces in a different grouping than the
      in-``shard_map`` psum, so ``merged`` may differ in low-order float
      bits there — ``values``/``final`` stay identical).  ``run(...,
      stream=...)`` accepts an explicit prefetcher (e.g.
      ``GoFSStore.load_blocked_stream``) so disk slice reads themselves
      overlap execution; for mesh runs pick a ``chunk_instances`` that is
      a multiple of the data-axis size or the per-chunk runners fall back
      to replicated instances.

    Jitted runners are cached per (program, pattern, instance count), so
    repeated calls (e.g. tracking's per-timestep probes) recompile nothing.

    Example — one tiny graph, all three patterns, sync and async staging:

    >>> import numpy as np
    >>> from repro.core.blocked import build_blocked
    >>> from repro.core.graph import GraphTemplate
    >>> from repro.core.engine import (
    ...     TemporalEngine, min_plus_program, source_init)
    >>> tmpl = GraphTemplate(num_vertices=4,
    ...     src=np.array([0, 1, 2, 0]), dst=np.array([1, 2, 3, 2]))
    >>> bg = build_blocked(tmpl, np.array([0, 0, 1, 1]), block_size=2)
    >>> eng = TemporalEngine(bg)
    >>> sssp = min_plus_program("sssp", init=source_init(0))
    >>> w = np.ones((2, 4), np.float32)     # 2 instances, unit latency
    >>> eng.run(sssp, w, pattern="sequential").final
    array([0., 1., 1., 2.], dtype=float32)
    >>> eng.run(sssp, w, pattern="independent").values.shape
    (2, 4)
    >>> eng.run(sssp, w, pattern="eventually", merge="mean").merged
    array([0., 1., 1., 2.], dtype=float32)
    >>> eng_async = TemporalEngine(bg, staging="async")
    >>> bool(np.array_equal(eng_async.run(sssp, w, pattern="sequential").final,
    ...                     eng.run(sssp, w, pattern="sequential").final))
    True
    >>> eng_host = TemporalEngine(bg, comm="host")  # mesh-free host combine
    >>> bool(np.array_equal(eng_host.run(sssp, w, pattern="sequential").final,
    ...                     eng.run(sssp, w, pattern="sequential").final))
    True
    >>> eng_sp = TemporalEngine(bg, layout="sparse")  # packed active tiles
    >>> r_sp = eng_sp.run(sssp, w, pattern="sequential")
    >>> bool(np.array_equal(r_sp.final, eng.run(sssp, w,
    ...                                         pattern="sequential").final))
    True
    >>> 0.0 < r_sp.occupancy <= 1.0  # measured active-tile fraction
    True
    """

    def __init__(
        self,
        bg: BlockedGraph,
        *,
        mesh=None,
        data_axis: str = "data",
        model_axes: Tuple[str, ...] = ("model",),
        use_pallas=False,
        kernel_interpret: Optional[bool] = None,
        staging: str = "sync",
        prefetch_depth: int = 2,
        chunk_instances: Optional[int] = None,
        comm: Union[str, CommBackend] = "dense",
        layout: str = "dense",
        cluster=None,
    ):
        assert staging in ("sync", "async"), staging
        assert layout in ("dense", "sparse"), layout
        self.bg = bg
        self.mesh = mesh
        self.data_axis = data_axis
        self.model_axes = tuple(model_axes)
        # ``cluster``: a repro.cluster.runtime.ClusterRuntime.  When it is
        # distributed, this engine becomes ONE SHARD of the run: it holds
        # only its process's contiguous partition range (structure, staged
        # tiles, and state are all sliced to it), the boundary exchange
        # and halt vote go through the inter-process ClusterGather, and
        # results are re-assembled across processes at gather time —
        # bitwise-identical to the single-process stacked run (the
        # exchange reconstructs the exact (P, NB) buffer and applies the
        # same 0..P-1 fold; the cross-process halt vote keeps superstep
        # counts lockstep).  A single-process runtime (or None) leaves
        # every path untouched.
        self.cluster = cluster if (cluster is not None
                                   and cluster.is_distributed) else None
        if self.cluster is not None:
            assert mesh is None, \
                "cluster placement is stacked per process (mesh-free); " \
                "per-process meshes are a future composition"
            self.parts: Optional[Tuple[int, int]] = \
                self.cluster.partition_shard(bg.n_parts)
            from repro.cluster.gather import ClusterGather

            if not isinstance(comm, ClusterGather):
                assert comm in ("dense", "host", "cluster"), \
                    f"cluster runs exchange through ClusterGather; " \
                    f"comm={comm!r} has no inter-process form"
                comm = ClusterGather(runtime=self.cluster)
        else:
            self.parts = None
        # ``use_pallas`` is the three-valued kernel mode ("off" | "spmv" |
        # "fused"; bools keep their historical meaning).  It is validated
        # here and passed down opaquely — ``kernel_interpret`` rides along
        # so tests can pin the interpret tier regardless of backend.
        self.kernel_mode = kernel_mode(use_pallas)[0]
        self.use_pallas = self.kernel_mode if kernel_interpret is None \
            else (self.kernel_mode, kernel_interpret)
        self.staging = staging
        self.prefetch_depth = prefetch_depth
        self.chunk_instances = chunk_instances
        self.layout = layout
        self.comm = make_comm(comm, mesh=mesh, model_axes=self.model_axes)
        out_mask = np.arange(bg.o_max)[None, :] < bg.n_out[:, None]

        def shard(a):  # partition-lead structure -> this process's rows
            return a if self.parts is None else a[self.parts[0]:self.parts[1]]

        # template structure: (rows, cols, brows, bcols) tile index + the
        # layout-independent tail.  The sparse layout replaces the first
        # four with PER-INSTANCE packed indices scanned alongside the tile
        # values; the tail is shared by both layouts.
        self._struct_tail = (
            jnp.asarray(shard(bg.out_slot)), jnp.asarray(shard(bg.out_local)),
            jnp.asarray(shard(out_mask)), jnp.asarray(shard(bg.global_of >= 0)),
        )
        self._struct = (
            jnp.asarray(shard(bg.tiles_rc[:, :, 0])),
            jnp.asarray(shard(bg.tiles_rc[:, :, 1])),
            jnp.asarray(shard(bg.btiles_rc[:, :, 0])),
            jnp.asarray(shard(bg.btiles_rc[:, :, 1])),
        ) + self._struct_tail
        self._runners: Dict[Any, Callable] = {}
        self._merge_fns: Dict[int, Callable] = {}
        # staged-batch device cache: host-array identity (weakly held) ->
        # device arrays (see _cached_device) so repeated runs over one
        # staged batch (run_many, tracking's probes, shared-staging
        # sessions) upload once without extending the batch's lifetime
        self._staged_device: "OrderedDict[Tuple[int, ...], Tuple[Tuple[weakref.ref, ...], Tuple[jax.Array, ...]]]" = OrderedDict()

    # ------------------------------------------------------------ staging
    def stage(
        self, instance_weights: np.ndarray, zero_fill: float
    ) -> Tuple[jax.Array, jax.Array]:
        """(I, E) edge weights -> device tile tensors, batched scatter.
        A cluster-sharded engine fills only its own partition range."""
        w = np.asarray(instance_weights, np.float32)
        if w.ndim == 1:
            w = w[None]
        return (
            jnp.asarray(self.bg.fill_local_batch(w, zero=zero_fill,
                                                 parts=self.parts)),
            jnp.asarray(self.bg.fill_boundary_batch(w, zero=zero_fill,
                                                    parts=self.parts)),
        )

    def stage_sparse(
        self, instance_weights: np.ndarray, zero_fill: float
    ) -> SparseBlocked:
        """(I, E) edge weights -> packed active-tile batch (host arrays)."""
        return self.bg.stage_sparse(instance_weights, zero=zero_fill,
                                    parts=self.parts)

    # ------------------------------------------------------- instance step
    def _device_graph(self, tiles_l, btiles_l, struct) -> DeviceGraph:
        rows, cols, brows, bcols, out_slot, out_local, out_mask, vmask = struct
        return DeviceGraph(
            block_size=self.bg.block_size, num_boundary=self.bg.num_boundary,
            rows=rows, cols=cols, tiles=tiles_l,
            brows=brows, bcols=bcols, btiles=btiles_l,
            out_slot=out_slot, out_local=out_local,
            out_mask=out_mask, vmask=vmask,
        )

    def _run_instance(self, program: SemiringProgram, x, tiles_l, btiles_l,
                      struct, comm: CommBackend):
        """One instance's BSP on the local shard.  Returns (x, (ss, lsw))."""
        dg = self._device_graph(tiles_l, btiles_l, struct)
        if program.kind == "fixpoint":
            x, st = bsp_fixpoint(
                x, dg, program.semiring, comm=comm,
                subgraph_centric=program.subgraph_centric,
                max_supersteps=program.max_supersteps,
                max_local_sweeps=program.max_local_sweeps,
                use_pallas=self.use_pallas,
            )
            return x, (st["supersteps"], st["local_sweeps"])

        def body(r, _):
            return program.step(r, dg, comm, self.use_pallas), None

        x, _ = jax.lax.scan(body, x, None, length=program.iters)
        return x, (jnp.asarray(program.iters, jnp.int32),
                   jnp.asarray(0, jnp.int32))

    # ------------------------------------------------------------- runners
    def _scan_instances(self, program: SemiringProgram, pattern: str,
                        x0, tiles, btiles, struct,
                        comm: Optional[CommBackend] = None, idx=None,
                        warm: bool = False):
        """Scan the instance axis on the local shard.  Returns
        (xs (I, P_l, Vp), final (P_l, Vp), ss (I,), lsw (I,)).

        ``idx=None`` (dense): ``struct`` is the full 8-tuple with the
        template tile index.  Sparse: ``struct`` is the 4-tuple tail and
        ``idx`` the per-instance (rows, cols, brows, bcols) packed index,
        scanned alongside the tile values.

        ``warm=True`` seeds each instance's fixpoint from the previous
        instance's converged state rather than ``x0`` — for monotone
        fixpoints on slowly varying collections the chain converges in
        far fewer supersteps and to the identical state (RunSpec.warm_start
        documents the exactness contract)."""
        comm = self.comm if comm is None else comm

        def step(carry, tb):
            if idx is None:
                tiles_l, btiles_l = tb
                s = struct
            else:
                tiles_l, btiles_l, rows_l, cols_l, brows_l, bcols_l = tb
                s = (rows_l, cols_l, brows_l, bcols_l) + struct
            seed = carry if (pattern == "sequential" or warm) else x0
            x, (ss, lsw) = self._run_instance(
                program, seed, tiles_l, btiles_l, s, comm
            )
            return x, (x, ss, lsw)

        xs_in = (tiles, btiles) if idx is None else (tiles, btiles) + tuple(idx)
        final, (xs, ss, lsw) = jax.lax.scan(step, x0, xs_in)
        return xs, final, ss, lsw

    def _make_stacked_runner(self, program: SemiringProgram, pattern: str,
                             merge: Optional[str], sparse: bool = False,
                             warm: bool = False, multi: bool = False):
        def run_dense(tiles, btiles, x0, *struct):
            return finish(*self._scan_instances(
                program, pattern, x0, tiles, btiles, struct, warm=warm
            ))

        def run_sparse(tiles, btiles, rows, cols, brows, bcols, x0, *struct):
            return finish(*self._scan_instances(
                program, pattern, x0, tiles, btiles, struct,
                idx=(rows, cols, brows, bcols), warm=warm,
            ))

        def finish(xs, final, ss, lsw):
            if pattern == "eventually" and merge == "mean":
                merged = jnp.mean(xs, axis=0)
            else:
                merged = jnp.zeros_like(final)
            return xs, final, merged, ss, lsw

        fn = run_sparse if sparse else run_dense
        if multi:
            # query axis: vmap over the leading (Q,) dim of x0 only — tile
            # values and template structure broadcast.  Batched while_loops
            # mask converged sources lane-wise, so each source's fixpoint
            # (and its superstep count) is exactly its single-source run.
            before = 6 if sparse else 2  # positional args ahead of x0
            tail = len(self._struct_tail) if sparse else len(self._struct)
            fn = jax.vmap(fn, in_axes=(None,) * before + (0,)
                          + (None,) * tail)
        return jax.jit(fn)

    def _data_size(self) -> int:
        axes = (self.data_axis,) if isinstance(self.data_axis, str) \
            else tuple(self.data_axis)
        n = 1
        for a in axes:
            n *= int(self.mesh.shape[a])
        return n

    def _make_mesh_runner(self, program: SemiringProgram, pattern: str,
                          merge: Optional[str], n_instances: int,
                          sparse: bool = False, warm: bool = False,
                          multi: bool = False):
        from jax.sharding import PartitionSpec as P_

        mesh = self.mesh
        maxes = self.model_axes if len(self.model_axes) > 1 \
            else self.model_axes[0]
        daxis = self.data_axis
        # temporal concurrency: shard the instance axis over data only when
        # it divides — single-instance probes (tracking, nhop hops) and
        # ragged collections fall back to replicated instances, which stays
        # correct (every data group computes the same states; the Merge
        # psum normalizes by the psum'd instance count).
        temporal = pattern in ("independent", "eventually")
        # warm-started fixpoints chain state from instance t-1 to t, so the
        # instance axis cannot be data-sharded (a shard's first instance
        # would lose its predecessor); replicated instances keep the chain
        # intact on every data group and stay bitwise-correct.
        shard_instances = (temporal and not warm
                           and n_instances % self._data_size() == 0
                           and n_instances >= self._data_size())
        # data-sharded instances run data-dependent superstep loops
        # concurrently; backends with globally scheduled collectives (the
        # ppermute ring) must equalize trip counts over the data axis or
        # the permutes deadlock (see CommBackend.bind_sync)
        comm = self.comm
        if shard_instances:
            daxes = (daxis,) if isinstance(daxis, str) else tuple(daxis)
            comm = comm.bind_sync(daxes)

        def merged_of(xs, final):
            if pattern == "eventually" and merge == "mean":
                # eventually-dependent Merge across ALL instances (data axis)
                part = jnp.sum(xs, axis=0)
                total = jax.lax.psum(part, daxis)
                n = jax.lax.psum(
                    jnp.asarray(xs.shape[0], jnp.float32), daxis
                )
                return total / n
            return jnp.zeros_like(final)

        def local_dense(tiles, btiles, x0, *struct):
            xs, final, ss, lsw = self._scan_instances(
                program, pattern, x0, tiles, btiles, struct, comm, warm=warm
            )
            return xs, final, merged_of(xs, final), ss, lsw

        def local_sparse(tiles, btiles, rows, cols, brows, bcols, x0,
                         *struct):
            xs, final, ss, lsw = self._scan_instances(
                program, pattern, x0, tiles, btiles, struct, comm,
                idx=(rows, cols, brows, bcols), warm=warm,
            )
            return xs, final, merged_of(xs, final), ss, lsw

        iaxis = daxis if shard_instances else None

        local = local_sparse if sparse else local_dense
        if multi:
            # query axis: the vmap sits INSIDE shard_map (vmap-of-shard_map
            # composes poorly), batching the per-shard scan over the
            # leading (Q,) of x0; the data/model sharding of tiles and
            # instances is unchanged, and collectives batch lane-wise.
            before = 6 if sparse else 2
            tail = len(self._struct_tail) if sparse else len(self._struct)
            local = jax.vmap(local, in_axes=(None,) * before + (0,)
                             + (None,) * tail)

        def lead(extra_dims: int, *front):
            return P_(*front, *([None] * extra_dims))

        q = (None,) if multi else ()  # replicated leading query axis
        if sparse:
            in_specs = (
                lead(3, iaxis, maxes),  # tiles (I, P, K, B, B)
                lead(3, iaxis, maxes),  # btiles
                lead(1, iaxis, maxes),  # rows (I, P, K)
                lead(1, iaxis, maxes),  # cols
                lead(1, iaxis, maxes),  # brows (I, P, Kb)
                lead(1, iaxis, maxes),  # bcols
                lead(1, *q, maxes),     # x0 ([Q,] P, Vp)
            ) + tuple(lead(s.ndim - 1, maxes) for s in self._struct_tail)
        else:
            in_specs = (
                lead(3, iaxis, maxes),  # tiles (I, P, T, B, B)
                lead(3, iaxis, maxes),  # btiles
                lead(1, *q, maxes),     # x0 ([Q,] P, Vp)
            ) + tuple(lead(s.ndim - 1, maxes) for s in self._struct)
        out_specs = (
            lead(2, *q, iaxis, maxes),  # xs ([Q,] I, P, Vp)
            lead(1, *q, maxes),         # final
            lead(1, *q, maxes),         # merged (replicated over data)
            P_(*q, iaxis), P_(*q, iaxis),  # ss, lsw ([Q,] I)
        )
        fn = shard_map(
            local, mesh=mesh,
            in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
        return jax.jit(fn)

    def _runner(self, program: SemiringProgram, pattern: str,
                merge: Optional[str], n_instances: int,
                sparse: bool = False, warm: bool = False,
                multi: bool = False):
        key = (program, pattern, merge, n_instances, sparse, warm, multi)
        if key not in self._runners:
            if self.mesh is None:
                self._runners[key] = self._make_stacked_runner(
                    program, pattern, merge, sparse, warm=warm, multi=multi
                )
            else:
                self._runners[key] = self._make_mesh_runner(
                    program, pattern, merge, n_instances, sparse, warm=warm,
                    multi=multi,
                )
        return self._runners[key]

    # ------------------------------------------------- cluster shard slicing
    def _shard_axis(self, a, axis: int = 1):
        """Slice a full-width partition axis to this process's range.
        No-op for a single-process engine or an already shard-local
        array (its axis is ``hi - lo`` wide)."""
        if a is None or self.parts is None:
            return a
        lo, hi = self.parts
        if a.shape[axis] == hi - lo:
            return a
        assert a.shape[axis] == self.bg.n_parts, (a.shape, axis)
        idx = [slice(None)] * a.ndim
        idx[axis] = slice(lo, hi)
        return a[tuple(idx)]

    def _shard_sparse_batch(self, sp: SparseBlocked) -> SparseBlocked:
        """Slice a full-width pre-staged packed batch to the shard."""
        import dataclasses

        lo, hi = self.parts
        if sp.tiles.shape[1] == hi - lo:
            return sp
        return dataclasses.replace(
            sp,
            tiles=sp.tiles[:, lo:hi], btiles=sp.btiles[:, lo:hi],
            rows=sp.rows[:, lo:hi], cols=sp.cols[:, lo:hi],
            brows=sp.brows[:, lo:hi], bcols=sp.bcols[:, lo:hi],
            nnz=sp.nnz[:, lo:hi], bnnz=sp.bnnz[:, lo:hi],
        )

    # ------------------------------------------------------------ dispatch
    def _dispatch(self, run_fn, *args):
        if self.mesh is not None:
            with self.mesh:
                return run_fn(*args)
        out = run_fn(*args)
        if self.parts is not None:
            # cluster mode: the runner's pure_callback exchanges ride the
            # SEQUENCED inter-process channel, and so do the host-side
            # operations that follow a dispatch (chunk consistency checks,
            # result gathers).  Draining the computation here keeps every
            # process's exchange schedule a single deterministic order —
            # an async dispatch could interleave the two streams
            # differently per process and trip the tag verification.
            out = jax.block_until_ready(out)
        return out

    def _cached_device(self, host_arrays: Tuple[Any, ...]) -> Tuple[jax.Array, ...]:
        """Device arrays for one staged batch, uploaded once per identity.

        The boundary/tile structure of a staged graph is immutable once
        handed to the engine, so the device copy is keyed on the ``id`` of
        every host array (verified against weak references, so id reuse
        cannot alias) and LRU-bounded to ``_STAGED_CACHE_SLOTS`` batches:
        ``run_many`` over one staged collection — or tracking's repeated
        probes over one tile set — re-uploads nothing.  Host batches are
        held WEAKLY: once the caller drops a staged batch (e.g. a
        ``run_many`` staging cache going out of scope) its entry — and
        the device copy it pins — is purged on the next call, so the
        cache never extends a batch's lifetime."""
        for k in [k for k, (refs, _) in self._staged_device.items()
                  if any(r() is None for r in refs)]:
            del self._staged_device[k]
        key = tuple(map(id, host_arrays))
        hit = self._staged_device.get(key)
        if hit is not None and all(r() is a for r, a in
                                   zip(hit[0], host_arrays)):
            self._staged_device.move_to_end(key)
            return hit[1]
        dev = tuple(_device_put(a) for a in host_arrays)
        self._staged_device[key] = (
            tuple(weakref.ref(a) for a in host_arrays), dev,
        )
        while len(self._staged_device) > _STAGED_CACHE_SLOTS:
            self._staged_device.popitem(last=False)
        return dev

    def _dispatch_sparse(self, run_fn, sp: SparseBlocked, x0):
        """Device-put a packed batch (cached on identity) and dispatch."""
        bufs = self._cached_device(
            (sp.tiles, sp.btiles, sp.rows, sp.cols, sp.brows, sp.bcols)
        )
        return self._dispatch(run_fn, *bufs, x0, *self._struct_tail)

    def _merge_mean(self, xs, axis: int = 0):
        """On-device Merge over the full instance axis (async path).
        Stacked: the same ``jnp.mean`` the sync runner computes in-graph,
        on the same (I, P, Vp) values — bitwise-identical output.  Mesh:
        the sync runner reduces as psum-of-shard-sums inside ``shard_map``,
        a different float grouping — equal up to low-order bits.
        ``axis=1`` folds the instance axis of multi-source (Q, I, …)
        states."""
        fn = self._merge_fns.get(axis)
        if fn is None:
            fn = self._merge_fns[axis] = jax.jit(
                lambda v: jnp.mean(v, axis=axis))
        if self.mesh is not None:
            with self.mesh:
                return fn(xs)
        return fn(xs)

    def _run_stream_many(self, specs: Sequence[RunSpec], chunks, x0s):
        """Consume a chunk stream (SlicePrefetcher or any iterable of
        StagedChunk) ONCE, feeding every spec's runner: each chunk is
        device-put a single time, then dispatched to all N runners before
        the next chunk is pulled — so slice reads + tile fills (on the
        prefetcher's background pool) overlap the whole fan-out, and N
        concurrent analytics cost one staging pass (the shared-scan
        amortization behind ``GopherSession.run_many``).  Sequential
        patterns carry their end state across chunk boundaries per spec;
        eventually Merges fold once over the concatenated states.
        Sparse-layout chunks (packed tiles + per-instance index) dispatch
        through the sparse runners; dense chunks through the dense ones.
        Returns ([(xs, final, merged, ss, lsw)] per spec, occupancy | None).
        """
        N = len(specs)
        xs_p: List[list] = [[] for _ in range(N)]
        ss_p: List[list] = [[] for _ in range(N)]
        lsw_p: List[list] = [[] for _ in range(N)]
        carry = list(x0s)
        final: List[Optional[jax.Array]] = [None] * N
        n_total = nnz_total = 0
        sparse_seen = False
        for ch in chunks:
            # Aliasing (no copy) is safe ONLY because each chunk owns
            # its buffers (see SlicePrefetcher): JAX's device put
            # zero-copy-aliases aligned host buffers on CPU and defers
            # the host read even under copy=True, so a reused staging
            # buffer would be overwritten mid-execution.
            n = int(ch.tiles.shape[0])
            n_total += n
            is_sparse = bool(getattr(ch, "is_sparse", False))
            # cluster shards keep only their partition rows: chunks from a
            # shard-local stream (repro.cluster.staging) are already
            # P_local-wide and pass through; full-width chunks (e.g. a
            # plain load_blocked_stream) are sliced here
            if is_sparse:
                sparse_seen = True
                nnz_total += (int(self._shard_axis(ch.nnz).sum())
                              + int(self._shard_axis(ch.bnnz).sum()))
                bufs = tuple(_device_put(self._shard_axis(a)) for a in (
                    ch.tiles, ch.btiles, ch.rows, ch.cols, ch.brows, ch.bcols
                ))
                tail = self._struct_tail
            else:
                bufs = (_device_put(self._shard_axis(ch.tiles)),
                        _device_put(self._shard_axis(ch.btiles)))
                tail = self._struct
            for k, s in enumerate(specs):
                warm_k = s.effective_warm()
                # warm chunks chain exactly like sequential: the carry is
                # the last instance's converged state, which seeds the
                # next chunk's first instance inside the runner's scan
                seed = carry[k] if (s.pattern == "sequential" or warm_k) \
                    else x0s[k]
                run_fn = self._runner(s.program, s.pattern, None, n,
                                      sparse=is_sparse, warm=warm_k,
                                      multi=x0s[k].ndim == 3)
                xs, fin, _, ss, lsw = self._dispatch(
                    run_fn, *bufs, seed, *tail
                )
                carry[k] = final[k] = fin
                xs_p[k].append(xs)
                ss_p[k].append(ss)
                lsw_p[k].append(lsw)
        outs = []
        for k, s in enumerate(specs):
            assert final[k] is not None, "empty instance stream"
            # multi-source chunks stack per-chunk outputs on the instance
            # axis, which sits AFTER the leading query axis
            iax = 1 if x0s[k].ndim == 3 else 0
            xs = xs_p[k][0] if len(xs_p[k]) == 1 \
                else jnp.concatenate(xs_p[k], axis=iax)
            ss = ss_p[k][0] if len(ss_p[k]) == 1 \
                else jnp.concatenate(ss_p[k], axis=iax)
            lsw = lsw_p[k][0] if len(lsw_p[k]) == 1 \
                else jnp.concatenate(lsw_p[k], axis=iax)
            if s.pattern == "eventually" and s.merge == "mean":
                merged = self._merge_mean(xs, axis=iax)
            else:
                merged = jnp.zeros_like(final[k])
            outs.append((xs, final[k], merged, ss, lsw))
        occ = None
        if sparse_seen:
            lo, hi = self.parts or (0, self.bg.n_parts)
            total = n_total * (int(self.bg.n_tiles[lo:hi].sum())
                               + int(self.bg.n_btiles[lo:hi].sum()))
            occ = nnz_total / total if total else 0.0
        return outs, occ

    # ------------------------------------------------------ resumable state
    def resume_seed(self, final: np.ndarray, *, pad: float) -> np.ndarray:
        """Re-scatter a prior run's gathered ``EngineResult.final`` into
        the engine's padded (P, Vp) state layout — the resumable-run-state
        hook streaming ingestion uses: a tail run over appended instances
        passes this as ``RunSpec.x0`` (with ``warm_start=True`` for
        fixpoints, or under the sequential pattern, which carries state by
        definition) and continues the instance chain exactly where the
        previous run converged.  ``pad`` fills padding slots and must be
        the program's ``zero_fill``.  A (Q, V) multi-source final maps to
        a (Q, P, Vp) seed."""
        f = np.asarray(final, np.float32)
        if f.ndim == 1:
            return self.bg.scatter_vertex(f, pad)
        assert f.ndim == 2, f.shape
        return np.stack([self.bg.scatter_vertex(fi, pad) for fi in f])

    # ----------------------------------------------------------------- run
    def run(
        self,
        program: SemiringProgram,
        instance_weights: Optional[np.ndarray] = None,
        *,
        pattern: str,
        x0: Optional[np.ndarray] = None,
        tiles: Optional[jax.Array] = None,
        btiles: Optional[jax.Array] = None,
        sparse: Optional[SparseBlocked] = None,
        merge: Optional[str] = None,
        stream=None,
        staging: Optional[str] = None,
        warm_start: bool = False,
    ) -> EngineResult:
        """Execute ``program`` over the instance collection.

        Instance sources (exactly one):

        * ``instance_weights`` (I, E) — staged through the batched fill in
          the engine's ``layout`` (dense tensors or packed active tiles);
          with ``staging="async"`` (call or constructor) the fill is
          chunked behind a background prefetcher and overlaps execution.
        * pre-staged ``tiles``/``btiles`` (I, P, T|Tb, B, B) — e.g. from
          ``GoFSStore.load_blocked`` (always synchronous: already staged).
        * pre-staged ``sparse`` — a :class:`repro.core.blocked
          .SparseBlocked` packed batch (e.g. ``GoFSStore.load_blocked``
          with ``layout="sparse"``).
        * ``stream`` — an iterable of :class:`repro.gofs.prefetch
          .StagedChunk` (dense or sparse chunks; e.g.
          ``GoFSStore.load_blocked_stream``): chunks execute as they land,
          so disk reads overlap device compute.

        ``x0`` overrides ``program.init(bg)``.  ``merge="mean"`` computes
        the on-device eventually-dependent Merge.  All staging modes AND
        layouts are result-identical (bitwise for min-plus); sparse runs
        report the measured active-tile fraction in ``result.occupancy``.
        See the class docstring for pattern contracts.
        """
        return self.run_many(
            [RunSpec(program, pattern, x0=x0, merge=merge,
                     warm_start=warm_start)],
            instance_weights, tiles=tiles, btiles=btiles, sparse=sparse,
            stream=stream, staging=staging,
        )[0]

    def run_many(
        self,
        specs: Sequence[RunSpec],
        instance_weights: Optional[np.ndarray] = None,
        *,
        tiles: Optional[jax.Array] = None,
        btiles: Optional[jax.Array] = None,
        sparse: Optional[SparseBlocked] = None,
        stream=None,
        staging: Optional[str] = None,
    ) -> List[EngineResult]:
        """Execute N :class:`RunSpec` over ONE staged instance collection.

        The staging sources are the same as :meth:`run`, but the staged
        batch is materialized (and device-put) exactly once and every
        spec's runner consumes it — N concurrent analytics for one
        staging pass.  With ``stream=`` the sharing goes all the way to
        disk: a single prefetch pass feeds all N runners chunk by chunk
        (see ``_run_stream_many``).  Programs must agree on ``zero_fill``
        (the one property of the staged values an analytic observes);
        everything else — pattern, fixpoint vs iterate, x0, merge — may
        differ per spec.  Results are bitwise identical to running each
        spec alone."""
        specs = list(specs)
        assert specs, "run_many needs at least one RunSpec"
        for s in specs:
            assert s.pattern in PATTERNS, s.pattern
            assert s.merge is None or s.pattern == "eventually", \
                "merge is the eventually-dependent Merge step; " \
                "use pattern='eventually'"
        zero_fills = {s.program.zero_fill for s in specs}
        assert len(zero_fills) == 1, \
            f"programs disagree on zero_fill ({zero_fills}); they cannot " \
            f"share one staged batch — split into separate run_many calls"
        zero_fill = zero_fills.pop()
        staging = staging or self.staging
        # pre-staged batches carry their own layout: sparse= flips a dense
        # engine to the sparse runner for this call, tiles=/btiles= flip a
        # sparse engine to the dense runner — symmetric, nothing dropped
        assert sparse is None or tiles is None, \
            "pass either sparse= or tiles=/btiles=, not both"
        if sparse is not None:
            layout = "sparse"
        elif tiles is not None:
            layout = "dense"
        else:
            layout = self.layout
        x0s = []
        for s in specs:
            x0 = s.x0
            if x0 is None:
                assert s.program.init is not None, \
                    f"program {s.program.name!r} has no init; pass x0"
                x0 = s.program.init(self.bg)
            x0 = jnp.asarray(x0, jnp.float32)
            if self.parts is not None:
                # x0 is always FULL-width ([Q,] P, Vp) — program inits and
                # resume_seed scatter globally; the shard keeps its rows
                x0 = x0[..., self.parts[0]:self.parts[1], :]
            x0s.append(x0)
        if self.parts is not None:
            # pre-staged full-width batches slice to the shard's rows too
            if sparse is not None:
                sparse = self._shard_sparse_batch(sparse)
            tiles = self._shard_axis(tiles)
            btiles = self._shard_axis(btiles)
        occ: Optional[float] = None

        if (stream is None and staging == "async" and tiles is None
                and sparse is None):
            assert instance_weights is not None, \
                "need instance_weights or pre-staged tiles+btiles"
            from repro.gofs.prefetch import SlicePrefetcher

            w = np.asarray(instance_weights, np.float32)
            if w.ndim == 1:
                w = w[None]
            # <= ~4 chunks by default: enough overlap, few compile shapes
            chunk = self.chunk_instances or max(1, -(-w.shape[0] // 4))
            if self.mesh is not None and self.chunk_instances is None:
                # keep each chunk's instance axis divisible by the data
                # axis, else per-chunk mesh runners fall back to replicated
                # instances and temporal parallelism is silently lost
                d = self._data_size()
                chunk = max(1, -(-chunk // d)) * d
            stream = SlicePrefetcher.from_weights(
                self.bg, w, zero=zero_fill,
                prefetch_depth=self.prefetch_depth, chunk_instances=chunk,
                layout=layout,
            )

        if stream is not None:
            outs, occ = self._run_stream_many(specs, stream, x0s)
        elif layout == "sparse":
            if sparse is None:
                assert instance_weights is not None, \
                    "need instance_weights, a SparseBlocked batch, or stream"
                sparse = self.stage_sparse(instance_weights, zero_fill)
            occ = sparse.occupancy()
            outs = []
            for s, x0 in zip(specs, x0s):
                run_fn = self._runner(s.program, s.pattern, s.merge,
                                      sparse.num_instances, sparse=True,
                                      warm=s.effective_warm(),
                                      multi=x0.ndim == 3)
                outs.append(self._dispatch_sparse(run_fn, sparse, x0))
        else:
            if tiles is None or btiles is None:
                assert instance_weights is not None, \
                    "need instance_weights, tiles+btiles, or stream"
                tiles, btiles = self.stage(instance_weights, zero_fill)
            elif not (isinstance(tiles, jax.Array)
                      and isinstance(btiles, jax.Array)):
                # host-staged dense batch: upload once per identity
                tiles, btiles = self._cached_device((tiles, btiles))
            outs = []
            for s, x0 in zip(specs, x0s):
                run_fn = self._runner(s.program, s.pattern, s.merge,
                                      int(tiles.shape[0]),
                                      warm=s.effective_warm(),
                                      multi=x0.ndim == 3)
                outs.append(self._dispatch(
                    run_fn, tiles, btiles, x0, *self._struct
                ))

        return [
            self._wrap_result(s.pattern, s.merge, out, occ,
                              warm=s.effective_warm(),
                              n_sources=int(x0.shape[0])
                              if x0.ndim == 3 else None)
            for s, out, x0 in zip(specs, outs, x0s)
        ]

    def _wrap_result(self, pattern: str, merge: Optional[str], out,
                     occ: Optional[float], warm: bool = False,
                     n_sources: Optional[int] = None) -> EngineResult:
        """Gather device outputs back to global vertex order + stats."""
        xs, final, merged, ss, lsw = out
        bg = self.bg
        if self.parts is not None:
            # re-assemble the global partition axis in rank order before
            # the vertex gather (contiguous shards -> plain concatenation
            # reconstructs the exact stacked layout).  Superstep stats are
            # identical on every process — the global halt vote keeps the
            # loops lockstep — so they stay local.
            cat = self.cluster.allgather_concat
            xs = cat(np.asarray(xs), axis=-2, tag="gather/xs")
            final = cat(np.asarray(final), axis=-2, tag="gather/final")
            if pattern == "eventually" and merge == "mean":
                merged = cat(np.asarray(merged), axis=-2,
                             tag="gather/merged")

        def gather(x):  # (..., P, Vp) -> (..., V), any leading axes
            x = np.asarray(x)
            lead_shape = x.shape[:-2]
            flat = x.reshape((-1,) + x.shape[-2:])
            out = np.stack([bg.gather_vertex(flat[i])
                            for i in range(flat.shape[0])])
            return out.reshape(lead_shape + out.shape[-1:])

        return EngineResult(
            pattern=pattern,
            values=gather(xs),
            final=gather(final),
            merged=gather(merged)
            if (pattern == "eventually" and merge == "mean") else None,
            stats={
                "supersteps": np.asarray(ss),
                "local_sweeps": np.asarray(lsw),
            },
            occupancy=occ,
            warm_start=warm,
            n_sources=n_sources,
            _n_published=int(bg.n_out.sum()),
            _n_parts=bg.n_parts,
            _num_vertices=len(bg.part_of),
        )
