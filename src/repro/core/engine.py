"""Unified temporal execution engine: one pattern-aware runner for every
semiring analytic over a blocked graph collection (paper §IV-B on TPU).

The paper's claim is that a single iBSP abstraction expresses *all*
temporal graph analytics through three execution patterns; this module is
the blocked-engine counterpart of ``repro.core.ibsp.run_ibsp``.  An
algorithm is declared as a :class:`SemiringProgram` — a semiring plus
either a *fixpoint* spec (idempotent relaxation to quiescence: SSSP,
components, reachability, N-hop) or an *iterate* spec (a fixed-count
superstep function: PageRank) — and the engine executes it under any
pattern in any placement mode:

========================  =================================================
pattern                   execution
========================  =================================================
``sequential``            one ``lax.scan`` over the instance axis carrying
                          the vertex state (incremental aggregation — the
                          previous timestep's end state seeds the next)
``independent``           every instance runs from the same initial state;
                          on a mesh, instances shard over the ``data`` axis
                          while partitions stay on ``model`` (both forms of
                          the paper's parallelism at once)
``eventually``            independent + a Merge reduction across instances
                          (``merge="mean"`` on-device; ``None`` leaves the
                          per-instance states for a host-side Merge)
========================  =================================================

Placement: ``mesh=None`` runs stacked on one device (tests, benches);
with a mesh the engine lowers to ``shard_map`` — partitions one-per-device
over ``model_axes``, and for the temporally concurrent patterns instances
over ``data_axis``.  The boundary exchange is ONE combine per superstep
either way, routed through a pluggable comm backend
(``comm="dense" | "ring" | "host"`` — see ``repro.core.comm``): the dense
psum/pmin all-reduce (default), a collective-permute ring for multi-pod
DCI topologies, or a mesh-free host-side gather for CPU clusters.
Algorithms never see the difference.

Instance staging is batched: edge-attribute matrices (I, E) land in
(I, P, T, B, B) tile tensors through ``BlockedGraph.fill_local_batch`` /
``fill_boundary_batch`` (or straight from GoFS slices via
``GoFSStore.load_blocked``) — no per-instance Python fill loops.

Staging can also be *overlapped* with execution (``staging="async"`` or an
explicit ``stream=``): chunks of instances arrive from a
:class:`repro.gofs.prefetch.SlicePrefetcher` double-buffer while the device
executes the previous chunk — the paper's §V storage/compute overlap.  See
``TemporalEngine`` and ``docs/ARCHITECTURE.md`` for the pipeline diagram.

Stats are reported in the same :class:`repro.core.ibsp.BSPStats` shape as
the host engine so the two paths are directly comparable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.core.blocked import BlockedGraph
from repro.core.comm import CommBackend, make_comm
from repro.core.ibsp import BSPStats
from repro.core.semiring import INF, MIN_PLUS, PLUS_MUL, Semiring
from repro.core.superstep import (
    DeviceGraph,
    bsp_fixpoint,
    pagerank_step,
)

PATTERNS = ("sequential", "independent", "eventually")


# ---------------------------------------------------------------------------
# Program declarations
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SemiringProgram:
    """A blocked iBSP analytic: semiring + step semantics + init.

    ``kind="fixpoint"`` iterates BSP supersteps to global quiescence
    (requires an idempotent semiring).  ``kind="iterate"`` applies ``step``
    exactly ``iters`` times — the fixed-count form keeps every instance's
    loop in lockstep, which is what lets the mesh run instances
    concurrently over the ``data`` axis.

    Programs are declarative and engine-agnostic: the same program object
    runs under any pattern, stacked or mesh, sync or async staging.  The
    two stock constructors cover the paper's workloads:

    >>> from repro.core.engine import min_plus_program, pagerank_program
    >>> min_plus_program("sssp").kind          # idempotent -> fixpoint
    'fixpoint'
    >>> min_plus_program("sssp").semiring.name
    'min_plus'
    >>> pagerank_program(100, iters=5).iters   # non-idempotent -> iterate
    5
    """

    name: str
    semiring: Semiring
    zero_fill: float  # tile value for absent edges (sr.zero of the fill op)
    kind: str = "fixpoint"  # "fixpoint" | "iterate"
    # fixpoint knobs
    subgraph_centric: bool = True
    max_supersteps: int = 64
    max_local_sweeps: int = 1024
    # iterate knobs
    iters: int = 0
    # step(x, dg, comm, use_pallas) -> x  (iterate kind only)
    step: Optional[Callable] = None
    # host-side initial state: init(bg) -> (P, Vp) float32
    init: Optional[Callable[[BlockedGraph], np.ndarray]] = None

    def __post_init__(self):
        assert self.kind in ("fixpoint", "iterate"), self.kind
        if self.kind == "fixpoint":
            assert self.semiring.idempotent, \
                "fixpoint programs need an idempotent semiring"
        else:
            assert self.step is not None and self.iters > 0


def source_init(source_vertex: int, pad: float = INF):
    """x0 = pad everywhere, 0 at the source (SSSP-style frontier seed)."""

    def init(bg: BlockedGraph) -> np.ndarray:
        x0 = bg.scatter_vertex(np.full(bg.part_of.shape, pad, np.float32), pad)
        x0[bg.part_of[source_vertex], bg.local_of[source_vertex]] = 0.0
        return x0

    return init


def label_init():
    """x0 = own vertex id (label propagation / components seed)."""

    def init(bg: BlockedGraph) -> np.ndarray:
        V = len(bg.part_of)
        return bg.scatter_vertex(np.arange(V, dtype=np.float32), INF)

    return init


def min_plus_program(
    name: str = "min_plus_fixpoint",
    *,
    init: Optional[Callable] = None,
    subgraph_centric: bool = True,
    max_supersteps: int = 64,
    max_local_sweeps: int = 1024,
) -> SemiringProgram:
    """Min-plus fixpoint (SSSP / reachability / label propagation)."""
    return SemiringProgram(
        name=name, semiring=MIN_PLUS, zero_fill=INF, kind="fixpoint",
        subgraph_centric=subgraph_centric, max_supersteps=max_supersteps,
        max_local_sweeps=max_local_sweeps, init=init,
    )


def pagerank_program(
    num_vertices: int, *, damping: float = 0.85, iters: int = 30
) -> SemiringProgram:
    """Fixed-iteration plus-mul PageRank (independent pattern workload)."""

    def step(x, dg, comm, use_pallas):
        return pagerank_step(
            x, dg, comm, damping=damping, num_vertices=num_vertices,
            use_pallas=use_pallas,
        )

    def init(bg: BlockedGraph) -> np.ndarray:
        valid = (bg.global_of >= 0)
        return np.where(valid, 1.0 / num_vertices, 0.0).astype(np.float32)

    return SemiringProgram(
        name="pagerank", semiring=PLUS_MUL, zero_fill=0.0, kind="iterate",
        iters=iters, step=step, init=init,
    )


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclass
class EngineResult:
    """Gathered outputs + iBSP-comparable statistics."""

    pattern: str
    values: np.ndarray  # (I, V) per-instance vertex values (global order)
    final: np.ndarray  # (V,) carried end state (sequential) or values[-1]
    merged: Optional[np.ndarray]  # (V,) Merge output (eventually + on-device)
    stats: Dict[str, np.ndarray]  # {"supersteps": (I,), "local_sweeps": (I,)}
    _n_published: int = 0  # boundary vertices published per superstep
    _n_parts: int = 0
    _num_vertices: int = 0

    def bsp_stats(self) -> BSPStats:
        """The host engine's accounting shape (run_ibsp comparability):
        compute_calls = partition activations, superstep_messages =
        published boundary values, timestep_messages = carried vertex
        states (sequential), merge_messages = instances folded."""
        ss = int(np.sum(self.stats["supersteps"]))
        I = len(self.stats["supersteps"])
        return BSPStats(
            supersteps=ss,
            compute_calls=ss * self._n_parts,
            superstep_messages=ss * self._n_published,
            timestep_messages=(I - 1) * self._num_vertices
            if self.pattern == "sequential" else 0,
            merge_messages=I if self.pattern == "eventually" else 0,
        )


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class TemporalEngine:
    """Pattern-aware runner for semiring programs over one blocked graph.

    **Pattern contracts** (paper §IV-B; identical semantics in every
    placement/staging mode):

    * ``sequential`` — *incrementally aggregated*: instance ``t``'s end
      state seeds instance ``t + 1`` (``SendToNextTimeStep``); the result's
      ``final`` is the last carried state.  Chunked/async staging preserves
      the carry across chunk boundaries.
    * ``independent`` — every instance starts from the same ``x0``;
      instances never communicate.  ``values[t]`` is instance ``t``'s
      converged state.
    * ``eventually`` — independent execution plus a Merge fold across
      instances (``merge="mean"`` computes it on device into ``merged``;
      ``merge=None`` leaves per-instance states for a host-side Merge).

    **Placement** (stacked vs mesh):

    * ``mesh=None`` — stacked: all partitions stacked on one device's
      leading axis, instances scanned (CPU tests and benchmarks).
    * ``mesh=...`` — SPMD ``shard_map``: partitions sharded one-per-device
      over ``model_axes``; for ``independent``/``eventually`` the instance
      axis additionally shards over ``data_axis`` (temporal parallelism)
      whenever the instance count divides the data-axis size, else
      instances are replicated (still correct, no speedup).

    **Comm backend** (how the boundary exchange moves bytes; see
    ``repro.core.comm`` and the selection table in
    ``docs/ARCHITECTURE.md``):

    * ``comm="dense"`` — psum/pmin all-reduce of the boundary buffer
      (default; single-pod meshes and stacked mode).
    * ``comm="ring"`` — ``lax.ppermute`` ring over ``model_axes``:
      P-1 neighbor-to-neighbor hops folding semiring partials (multi-pod
      DCI regime).  Stacked mode degenerates to the dense fold.
    * ``comm="host"`` — mesh-free host-side numpy semiring fold
      (``jax.pure_callback``); requires ``mesh=None``.

    Min-plus programs are bitwise identical across backends; plus-mul
    (PageRank) reassociates the sum on the mesh ring (low-order float
    bits).  The backend changes only the collective's lowering — never
    the program, pattern, staging mode, or result semantics.

    **Staging** (how instance tensors reach the device):

    * ``staging="sync"`` — stage the whole (I, P, T, B, B) batch, then run.
    * ``staging="async"`` — double-buffered: instances are staged in chunks
      on a background thread (:class:`repro.gofs.prefetch.SlicePrefetcher`)
      while the device executes the previous chunk; results are bitwise
      identical to sync staging (one caveat: on a mesh, the ``eventually``
      ``merge="mean"`` fold reduces in a different grouping than the
      in-``shard_map`` psum, so ``merged`` may differ in low-order float
      bits there — ``values``/``final`` stay identical).  ``run(...,
      stream=...)`` accepts an explicit prefetcher (e.g.
      ``GoFSStore.load_blocked_stream``) so disk slice reads themselves
      overlap execution; for mesh runs pick a ``chunk_instances`` that is
      a multiple of the data-axis size or the per-chunk runners fall back
      to replicated instances.

    Jitted runners are cached per (program, pattern, instance count), so
    repeated calls (e.g. tracking's per-timestep probes) recompile nothing.

    Example — one tiny graph, all three patterns, sync and async staging:

    >>> import numpy as np
    >>> from repro.core.blocked import build_blocked
    >>> from repro.core.graph import GraphTemplate
    >>> from repro.core.engine import (
    ...     TemporalEngine, min_plus_program, source_init)
    >>> tmpl = GraphTemplate(num_vertices=4,
    ...     src=np.array([0, 1, 2, 0]), dst=np.array([1, 2, 3, 2]))
    >>> bg = build_blocked(tmpl, np.array([0, 0, 1, 1]), block_size=2)
    >>> eng = TemporalEngine(bg)
    >>> sssp = min_plus_program("sssp", init=source_init(0))
    >>> w = np.ones((2, 4), np.float32)     # 2 instances, unit latency
    >>> eng.run(sssp, w, pattern="sequential").final
    array([0., 1., 1., 2.], dtype=float32)
    >>> eng.run(sssp, w, pattern="independent").values.shape
    (2, 4)
    >>> eng.run(sssp, w, pattern="eventually", merge="mean").merged
    array([0., 1., 1., 2.], dtype=float32)
    >>> eng_async = TemporalEngine(bg, staging="async")
    >>> bool(np.array_equal(eng_async.run(sssp, w, pattern="sequential").final,
    ...                     eng.run(sssp, w, pattern="sequential").final))
    True
    >>> eng_host = TemporalEngine(bg, comm="host")  # mesh-free host combine
    >>> bool(np.array_equal(eng_host.run(sssp, w, pattern="sequential").final,
    ...                     eng.run(sssp, w, pattern="sequential").final))
    True
    """

    def __init__(
        self,
        bg: BlockedGraph,
        *,
        mesh=None,
        data_axis: str = "data",
        model_axes: Tuple[str, ...] = ("model",),
        use_pallas: bool = False,
        staging: str = "sync",
        prefetch_depth: int = 2,
        chunk_instances: Optional[int] = None,
        comm: Union[str, CommBackend] = "dense",
    ):
        assert staging in ("sync", "async"), staging
        self.bg = bg
        self.mesh = mesh
        self.data_axis = data_axis
        self.model_axes = tuple(model_axes)
        self.use_pallas = use_pallas
        self.staging = staging
        self.prefetch_depth = prefetch_depth
        self.chunk_instances = chunk_instances
        self.comm = make_comm(comm, mesh=mesh, model_axes=self.model_axes)
        out_mask = np.arange(bg.o_max)[None, :] < bg.n_out[:, None]
        self._struct = (
            jnp.asarray(bg.tiles_rc[:, :, 0]), jnp.asarray(bg.tiles_rc[:, :, 1]),
            jnp.asarray(bg.btiles_rc[:, :, 0]), jnp.asarray(bg.btiles_rc[:, :, 1]),
            jnp.asarray(bg.out_slot), jnp.asarray(bg.out_local),
            jnp.asarray(out_mask), jnp.asarray(bg.global_of >= 0),
        )
        self._runners: Dict[Any, Callable] = {}
        self._merge_fn: Optional[Callable] = None

    # ------------------------------------------------------------ staging
    def stage(
        self, instance_weights: np.ndarray, zero_fill: float
    ) -> Tuple[jax.Array, jax.Array]:
        """(I, E) edge weights -> device tile tensors, batched scatter."""
        w = np.asarray(instance_weights, np.float32)
        if w.ndim == 1:
            w = w[None]
        return (
            jnp.asarray(self.bg.fill_local_batch(w, zero=zero_fill)),
            jnp.asarray(self.bg.fill_boundary_batch(w, zero=zero_fill)),
        )

    # ------------------------------------------------------- instance step
    def _device_graph(self, tiles_l, btiles_l, struct) -> DeviceGraph:
        rows, cols, brows, bcols, out_slot, out_local, out_mask, vmask = struct
        return DeviceGraph(
            block_size=self.bg.block_size, num_boundary=self.bg.num_boundary,
            rows=rows, cols=cols, tiles=tiles_l,
            brows=brows, bcols=bcols, btiles=btiles_l,
            out_slot=out_slot, out_local=out_local,
            out_mask=out_mask, vmask=vmask,
        )

    def _run_instance(self, program: SemiringProgram, x, tiles_l, btiles_l,
                      struct, comm: CommBackend):
        """One instance's BSP on the local shard.  Returns (x, (ss, lsw))."""
        dg = self._device_graph(tiles_l, btiles_l, struct)
        if program.kind == "fixpoint":
            x, st = bsp_fixpoint(
                x, dg, program.semiring, comm=comm,
                subgraph_centric=program.subgraph_centric,
                max_supersteps=program.max_supersteps,
                max_local_sweeps=program.max_local_sweeps,
                use_pallas=self.use_pallas,
            )
            return x, (st["supersteps"], st["local_sweeps"])

        def body(r, _):
            return program.step(r, dg, comm, self.use_pallas), None

        x, _ = jax.lax.scan(body, x, None, length=program.iters)
        return x, (jnp.asarray(program.iters, jnp.int32),
                   jnp.asarray(0, jnp.int32))

    # ------------------------------------------------------------- runners
    def _scan_instances(self, program: SemiringProgram, pattern: str,
                        x0, tiles, btiles, struct,
                        comm: Optional[CommBackend] = None):
        """Scan the instance axis on the local shard.  Returns
        (xs (I, P_l, Vp), final (P_l, Vp), ss (I,), lsw (I,))."""
        comm = self.comm if comm is None else comm

        def step(carry, tb):
            tiles_l, btiles_l = tb
            seed = carry if pattern == "sequential" else x0
            x, (ss, lsw) = self._run_instance(
                program, seed, tiles_l, btiles_l, struct, comm
            )
            return x, (x, ss, lsw)

        final, (xs, ss, lsw) = jax.lax.scan(step, x0, (tiles, btiles))
        return xs, final, ss, lsw

    def _make_stacked_runner(self, program: SemiringProgram, pattern: str,
                             merge: Optional[str]):
        def run(tiles, btiles, x0, *struct):
            xs, final, ss, lsw = self._scan_instances(
                program, pattern, x0, tiles, btiles, struct
            )
            if pattern == "eventually" and merge == "mean":
                merged = jnp.mean(xs, axis=0)
            else:
                merged = jnp.zeros_like(final)
            return xs, final, merged, ss, lsw

        return jax.jit(run)

    def _data_size(self) -> int:
        axes = (self.data_axis,) if isinstance(self.data_axis, str) \
            else tuple(self.data_axis)
        n = 1
        for a in axes:
            n *= int(self.mesh.shape[a])
        return n

    def _make_mesh_runner(self, program: SemiringProgram, pattern: str,
                          merge: Optional[str], n_instances: int):
        from jax.sharding import PartitionSpec as P_

        mesh = self.mesh
        maxes = self.model_axes if len(self.model_axes) > 1 \
            else self.model_axes[0]
        daxis = self.data_axis
        # temporal concurrency: shard the instance axis over data only when
        # it divides — single-instance probes (tracking, nhop hops) and
        # ragged collections fall back to replicated instances, which stays
        # correct (every data group computes the same states; the Merge
        # psum normalizes by the psum'd instance count).
        temporal = pattern in ("independent", "eventually")
        shard_instances = (temporal and n_instances % self._data_size() == 0
                           and n_instances >= self._data_size())
        # data-sharded instances run data-dependent superstep loops
        # concurrently; backends with globally scheduled collectives (the
        # ppermute ring) must equalize trip counts over the data axis or
        # the permutes deadlock (see CommBackend.bind_sync)
        comm = self.comm
        if shard_instances:
            daxes = (daxis,) if isinstance(daxis, str) else tuple(daxis)
            comm = comm.bind_sync(daxes)

        def local_fn(tiles, btiles, x0, *struct):
            xs, final, ss, lsw = self._scan_instances(
                program, pattern, x0, tiles, btiles, struct, comm
            )
            if pattern == "eventually" and merge == "mean":
                # eventually-dependent Merge across ALL instances (data axis)
                part = jnp.sum(xs, axis=0)
                total = jax.lax.psum(part, daxis)
                n = jax.lax.psum(
                    jnp.asarray(xs.shape[0], jnp.float32), daxis
                )
                merged = total / n
            else:
                merged = jnp.zeros_like(final)
            return xs, final, merged, ss, lsw

        iaxis = daxis if shard_instances else None

        def lead(extra_dims: int, *front):
            return P_(*front, *([None] * extra_dims))

        in_specs = (
            lead(3, iaxis, maxes),  # tiles (I, P, T, B, B)
            lead(3, iaxis, maxes),  # btiles
            lead(1, maxes),         # x0 (P, Vp)
        ) + tuple(lead(s.ndim - 1, maxes) for s in self._struct)
        out_specs = (
            lead(2, iaxis, maxes),  # xs (I, P, Vp)
            lead(1, maxes),         # final
            lead(1, maxes),         # merged (replicated over data)
            P_(iaxis), P_(iaxis),   # ss, lsw (I,)
        )
        fn = shard_map(
            local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
        return jax.jit(fn)

    def _runner(self, program: SemiringProgram, pattern: str,
                merge: Optional[str], n_instances: int):
        key = (program, pattern, merge, n_instances)
        if key not in self._runners:
            if self.mesh is None:
                self._runners[key] = self._make_stacked_runner(
                    program, pattern, merge
                )
            else:
                self._runners[key] = self._make_mesh_runner(
                    program, pattern, merge, n_instances
                )
        return self._runners[key]

    # ------------------------------------------------------------ dispatch
    def _dispatch(self, run_fn, tiles, btiles, x0):
        if self.mesh is not None:
            with self.mesh:
                return run_fn(tiles, btiles, x0, *self._struct)
        return run_fn(tiles, btiles, x0, *self._struct)

    def _merge_mean(self, xs):
        """On-device Merge over the full instance axis (async path).
        Stacked: the same ``jnp.mean`` the sync runner computes in-graph,
        on the same (I, P, Vp) values — bitwise-identical output.  Mesh:
        the sync runner reduces as psum-of-shard-sums inside ``shard_map``,
        a different float grouping — equal up to low-order bits."""
        if self._merge_fn is None:
            self._merge_fn = jax.jit(lambda v: jnp.mean(v, axis=0))
        if self.mesh is not None:
            with self.mesh:
                return self._merge_fn(xs)
        return self._merge_fn(xs)

    def _run_stream(self, program: SemiringProgram, pattern: str,
                    merge: Optional[str], chunks):
        """Consume a chunk stream (SlicePrefetcher or any iterable of
        StagedChunk): dispatch chunk *k* to the device, then pull chunk
        *k+1* — whose slice reads + tile fills happen on the prefetcher's
        background pool — while *k* executes (JAX dispatch is async).  The
        sequential pattern carries the end state across chunk boundaries;
        the eventually Merge folds once over the concatenated states."""

        def body(x0):
            xs_p, ss_p, lsw_p = [], [], []
            carry = x0
            final = None
            for ch in chunks:
                # Aliasing (no copy) is safe ONLY because each chunk owns
                # its buffers (see SlicePrefetcher): JAX's device put
                # zero-copy-aliases aligned host buffers on CPU and defers
                # the host read even under copy=True, so a reused staging
                # buffer would be overwritten mid-execution.
                tiles = jnp.asarray(ch.tiles)
                btiles = jnp.asarray(ch.btiles)
                run_fn = self._runner(program, pattern, None,
                                      int(tiles.shape[0]))
                seed = carry if pattern == "sequential" else x0
                xs, fin, _, ss, lsw = self._dispatch(
                    run_fn, tiles, btiles, seed
                )
                carry = final = fin
                xs_p.append(xs)
                ss_p.append(ss)
                lsw_p.append(lsw)
            assert final is not None, "empty instance stream"
            xs = xs_p[0] if len(xs_p) == 1 else jnp.concatenate(xs_p)
            ss = ss_p[0] if len(ss_p) == 1 else jnp.concatenate(ss_p)
            lsw = lsw_p[0] if len(lsw_p) == 1 else jnp.concatenate(lsw_p)
            if pattern == "eventually" and merge == "mean":
                merged = self._merge_mean(xs)
            else:
                merged = jnp.zeros_like(final)
            return xs, final, merged, ss, lsw

        return body

    # ----------------------------------------------------------------- run
    def run(
        self,
        program: SemiringProgram,
        instance_weights: Optional[np.ndarray] = None,
        *,
        pattern: str,
        x0: Optional[np.ndarray] = None,
        tiles: Optional[jax.Array] = None,
        btiles: Optional[jax.Array] = None,
        merge: Optional[str] = None,
        stream=None,
        staging: Optional[str] = None,
    ) -> EngineResult:
        """Execute ``program`` over the instance collection.

        Instance sources (exactly one):

        * ``instance_weights`` (I, E) — staged through the batched fill;
          with ``staging="async"`` (call or constructor) the fill is
          chunked behind a background prefetcher and overlaps execution.
        * pre-staged ``tiles``/``btiles`` (I, P, T|Tb, B, B) — e.g. from
          ``GoFSStore.load_blocked`` (always synchronous: already staged).
        * ``stream`` — an iterable of :class:`repro.gofs.prefetch
          .StagedChunk` (e.g. ``GoFSStore.load_blocked_stream``): chunks
          execute as they land, so disk reads overlap device compute.

        ``x0`` overrides ``program.init(bg)``.  ``merge="mean"`` computes
        the on-device eventually-dependent Merge.  All staging modes are
        result-identical; see the class docstring for pattern contracts.
        """
        assert pattern in PATTERNS, pattern
        assert merge is None or pattern == "eventually", \
            "merge is the eventually-dependent Merge step; use pattern='eventually'"
        staging = staging or self.staging
        if x0 is None:
            assert program.init is not None, "program has no init; pass x0"
            x0 = program.init(self.bg)
        x0 = jnp.asarray(x0, jnp.float32)

        if stream is None and staging == "async" and tiles is None:
            assert instance_weights is not None, \
                "need instance_weights or pre-staged tiles+btiles"
            from repro.gofs.prefetch import SlicePrefetcher

            w = np.asarray(instance_weights, np.float32)
            if w.ndim == 1:
                w = w[None]
            # <= ~4 chunks by default: enough overlap, few compile shapes
            chunk = self.chunk_instances or max(1, -(-w.shape[0] // 4))
            if self.mesh is not None and self.chunk_instances is None:
                # keep each chunk's instance axis divisible by the data
                # axis, else per-chunk mesh runners fall back to replicated
                # instances and temporal parallelism is silently lost
                d = self._data_size()
                chunk = max(1, -(-chunk // d)) * d
            stream = SlicePrefetcher.from_weights(
                self.bg, w, zero=program.zero_fill,
                prefetch_depth=self.prefetch_depth, chunk_instances=chunk,
            )

        if stream is not None:
            xs, final, merged, ss, lsw = self._run_stream(
                program, pattern, merge, stream
            )(x0)
        else:
            if tiles is None or btiles is None:
                assert instance_weights is not None, \
                    "need instance_weights, tiles+btiles, or stream"
                tiles, btiles = self.stage(instance_weights,
                                           program.zero_fill)
            run_fn = self._runner(program, pattern, merge,
                                  int(tiles.shape[0]))
            xs, final, merged, ss, lsw = self._dispatch(
                run_fn, tiles, btiles, x0
            )

        bg = self.bg
        xs = np.asarray(xs)
        values = np.stack([bg.gather_vertex(xs[i]) for i in range(xs.shape[0])])
        result = EngineResult(
            pattern=pattern,
            values=values,
            final=bg.gather_vertex(np.asarray(final)),
            merged=bg.gather_vertex(np.asarray(merged))
            if (pattern == "eventually" and merge == "mean") else None,
            stats={
                "supersteps": np.asarray(ss),
                "local_sweeps": np.asarray(lsw),
            },
            _n_published=int(bg.n_out.sum()),
            _n_parts=bg.n_parts,
            _num_vertices=len(bg.part_of),
        )
        return result
