"""Block-sparse partitioned graph: the TPU-facing layout (DESIGN.md §2).

The paper's template/instance split is what makes this layout efficient:
*topology* (which 128x128 adjacency tiles are non-empty, which tile slot each
edge occupies, the boundary-vertex index space) is computed ONCE from the
template; each *instance* only re-fills tile values from its edge-attribute
array with a precomputed O(E) scatter.

Per-partition data (all partitions padded to identical shapes so they stack
into SPMD arrays with a leading partition axis):

* local adjacency   — tiles over (local vertex) x (local vertex), transposed
  orientation: tile[t, i, j] = weight of edge (row_block*B + i -> col_block*B
  + j), reduced over i during SpMV, i.e. y[dst] = add_u mul(x[src], w).
* incoming boundary — tiles over (global boundary slot) x (local vertex) for
  cut edges arriving at this partition.
* out_slot          — local index -> global boundary slot scatter map for
  vertices this partition must publish (it owns them and some other
  partition reads them).

The boundary exchange is a single ``psum``/``pmin`` of a dense
(num_boundary,) buffer per superstep — O(cut vertices), the blocked analogue
of Gopher's O(cut edges) message win over vertex-centric O(edges).

Two instance-value layouts share this template structure:

* **dense** — every template tile slot is materialized per instance:
  ``(I, P, T, B, B)`` tensors (``fill_local_batch``).  Cost is
  ``O(P·T·B²)`` per instance regardless of how many tiles the instance
  actually touches.
* **sparse** (:class:`SparseBlocked`) — only the tiles *active in that
  instance* (holding at least one edge whose weight differs from the
  semiring zero) are packed, together with a per-(instance, partition)
  ``(row, col)`` tile index.  The packed tile axis is padded to a
  power-of-two bucket (:func:`pow2_bucket`) so the number of distinct
  jit shapes stays O(log T).  Cost is ``O(nnz_tiles·B²)`` — the GoFS
  compact-slice claim carried all the way to the device tensors.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.graph import GraphTemplate
from repro.core.semiring import INF


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= max(1, n) — the padded tile-count bucket.

    Bucketing bounds the set of distinct staged shapes (and therefore jit
    cache entries) to O(log T) while wasting at most 2x padding tiles.

    >>> [pow2_bucket(n) for n in (0, 1, 2, 3, 8, 9)]
    [1, 1, 2, 4, 8, 16]
    """
    return 1 << max(0, int(n) - 1).bit_length()


@dataclass
class SparseBlocked:
    """Block-sparse instance batch: packed active tiles + per-instance index.

    The template's tile axis (length T) is replaced by a packed axis of
    length ``bucket`` (a power of two >= the largest per-(instance,
    partition) active-tile count).  ``rows``/``cols`` carry the tile index
    — (row_block, col_block) per packed slot, ``-1`` padding — in template
    order, which is col-major sorted per partition, so the packed list
    keeps the contiguous-output-runs invariant the Pallas kernel needs.
    Skipped tiles hold only semiring zeros, so staging them sparse is
    result-identical (bitwise for min-plus) to the dense layout.
    """

    block_size: int
    tiles: np.ndarray  # (I, P, K, B, B) float32 packed local tile values
    btiles: np.ndarray  # (I, P, Kb, B, B) float32 packed boundary tiles
    rows: np.ndarray  # (I, P, K) int32 row block per packed slot, -1 = pad
    cols: np.ndarray  # (I, P, K) int32 col block per packed slot, -1 = pad
    brows: np.ndarray  # (I, P, Kb) int32 boundary block index, -1 = pad
    bcols: np.ndarray  # (I, P, Kb) int32 local dst block index, -1 = pad
    nnz: np.ndarray  # (I, P) int32 active local tiles
    bnnz: np.ndarray  # (I, P) int32 active boundary tiles
    total_tiles: int  # template valid local tiles, summed over partitions
    total_btiles: int  # template valid boundary tiles
    # bytes actually materialized from the backing store, when that is less
    # than ``staged_bytes()`` — a delta-encoded GoFS read decodes each unique
    # tile payload once and reconstructs repeats by RAM gather (gofs.store).
    # None = fully materialized (source == staged).
    source_bytes: Optional[int] = None

    @property
    def num_instances(self) -> int:
        return self.tiles.shape[0]

    @property
    def bucket(self) -> int:
        return self.tiles.shape[2]

    @property
    def bbucket(self) -> int:
        return self.btiles.shape[2]

    def occupancy(self) -> float:
        """Fraction of template tiles active, averaged over instances."""
        total = self.num_instances * (self.total_tiles + self.total_btiles)
        if total == 0:
            return 0.0
        return float(self.nnz.sum() + self.bnnz.sum()) / total

    def staged_bytes(self) -> int:
        """Host bytes materialized for this batch (values + tile index)."""
        return int(
            self.tiles.nbytes + self.btiles.nbytes + self.rows.nbytes
            + self.cols.nbytes + self.brows.nbytes + self.bcols.nbytes
        )


@dataclass
class BlockedGraph:
    """Static blocked structure for all partitions (host-side, numpy)."""

    block_size: int
    n_parts: int
    # --- vertex numbering -------------------------------------------------
    # global vertex id -> (partition, local index); locals are contiguous,
    # grouped bin-major (paper §V-D ordered iterators), padded to B multiple.
    part_of: np.ndarray  # (V,) int32
    local_of: np.ndarray  # (V,) int32
    global_of: np.ndarray  # (P, Vp) int64, -1 = padding
    vp: int  # padded local vertex count (same for all partitions)
    # --- local adjacency tiles ---------------------------------------------
    tiles_rc: np.ndarray  # (P, T, 2) int32 (row_block, col_block), -1 = pad
    n_tiles: np.ndarray  # (P,) int32 valid tile count
    # edge -> (partition, tile, i, j) fill map for local edges
    le_edge_id: np.ndarray  # (Lp_total,) int64 template edge ids
    le_part: np.ndarray  # (Lp_total,) int32
    le_flat: np.ndarray  # (Lp_total,) int64 flat index into (T*B*B) per part
    # --- boundary ----------------------------------------------------------
    num_boundary: int  # padded to B multiple
    # remote (cut) edges: src published at a boundary slot, consumed by dst's
    # partition through boundary tiles.
    bslot_of_src: np.ndarray  # (num_boundary,) int64 global vertex publishing
    out_slot: np.ndarray  # (P, Omax) int32 boundary slot per published vertex
    out_local: np.ndarray  # (P, Omax) int32 local index of published vertex
    n_out: np.ndarray  # (P,) int32
    btiles_rc: np.ndarray  # (P, Tb, 2) int32 (boundary_block, col_block)
    n_btiles: np.ndarray  # (P,) int32
    re_edge_id: np.ndarray  # (Rp_total,) int64 template edge ids (cut edges)
    re_part: np.ndarray  # (Rp_total,) int32 destination partition
    re_flat: np.ndarray  # (Rp_total,) int64 flat index into (Tb*B*B) per part
    # lazily computed: is each fill map duplicate-free (no parallel edges
    # sharing a tile slot)?  If so the batched fill can use vectorized
    # assignment instead of the much slower combining ``ufunc.at``.
    _le_unique: Optional[bool] = None
    _re_unique: Optional[bool] = None

    @property
    def t_max(self) -> int:
        return self.tiles_rc.shape[1]

    @property
    def tb_max(self) -> int:
        return self.btiles_rc.shape[1]

    @property
    def o_max(self) -> int:
        return self.out_slot.shape[1]

    @property
    def boundary_nnz(self) -> int:
        """Boundary vertices actually published per superstep — the real
        cut size the comm cost model should see, as opposed to the padded
        ``num_boundary`` buffer length."""
        return int(self.n_out.sum())

    # ------------------------------------------------------------------ fill
    # Parallel edges between the same (src, dst) land in the same tile slot;
    # they must be COMBINED with the semiring add (min for tropical / sum for
    # arithmetic), never overwritten — the zero value selects the op.
    def fill_local(self, weights: np.ndarray, zero: float = INF) -> np.ndarray:
        """Edge weights (E,) -> local tile values (P, T, B, B)."""
        B = self.block_size
        vals = np.full((self.n_parts, self.t_max * B * B), zero, np.float32)
        op = np.minimum if zero == INF else np.add
        op.at(vals, (self.le_part, self.le_flat), weights[self.le_edge_id])
        return vals.reshape(self.n_parts, self.t_max, B, B)

    def fill_boundary(self, weights: np.ndarray, zero: float = INF) -> np.ndarray:
        """Edge weights (E,) -> boundary tile values (P, Tb, B, B)."""
        B = self.block_size
        vals = np.full((self.n_parts, self.tb_max * B * B), zero, np.float32)
        op = np.minimum if zero == INF else np.add
        op.at(vals, (self.re_part, self.re_flat), weights[self.re_edge_id])
        return vals.reshape(self.n_parts, self.tb_max, B, B)

    # ------------------------------------------------------- batched staging
    # One flat scatter for ALL instances at once — replaces the per-instance
    # fill_local + np.stack Python loop in the temporal drivers (the edge ->
    # tile-slot map is instance-invariant, so the instance axis broadcasts).
    @staticmethod
    def _part_filter(
        parts: Tuple[int, int], part: np.ndarray, flat: np.ndarray,
        edge_id: np.ndarray,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Restrict a fill map to the half-open partition range ``parts``,
        rebasing partition indices to the range — the shard-local staging
        hook (``repro.cluster.staging``): a process fills ONLY the tile
        slots of partitions it owns into a (I, hi-lo, ...) buffer."""
        lo, hi = parts
        m = (part >= lo) & (part < hi)
        return part[m] - lo, flat[m], edge_id[m]

    def _fill_batch(
        self, weights: np.ndarray, zero: float, part: np.ndarray,
        flat: np.ndarray, edge_id: np.ndarray, t_count: int,
        out: Optional[np.ndarray], slots_unique: bool,
        parts: Optional[Tuple[int, int]] = None,
    ) -> np.ndarray:
        B = self.block_size
        I = weights.shape[0]
        if parts is not None:
            part, flat, edge_id = self._part_filter(parts, part, flat,
                                                    edge_id)
            P = parts[1] - parts[0]
        else:
            P = self.n_parts
        per_inst = P * t_count * B * B
        if out is None:
            vals = np.full(I * per_inst, zero, np.float32)
        else:
            # pre-staged buffer (prefetch chunk): fill in place, no 2nd copy
            assert out.shape == (I, P, t_count, B, B), out.shape
            assert out.dtype == np.float32 and out.flags.c_contiguous
            vals = out.reshape(-1)
            vals[...] = zero
        slot = part.astype(np.int64) * (t_count * B * B) + flat
        idx = (np.arange(I, dtype=np.int64)[:, None] * per_inst + slot[None, :])
        if slots_unique:
            # no parallel edges share a slot: semiring combining is a
            # no-op, and vectorized assignment is ~6x faster than ufunc.at
            vals[idx.ravel()] = weights[:, edge_id].ravel()
        else:
            op = np.minimum if zero == INF else np.add
            op.at(vals, idx.ravel(), weights[:, edge_id].ravel())
        return vals.reshape(I, P, t_count, B, B)

    def _slot_key(self, part: np.ndarray, flat: np.ndarray, t_count: int):
        return part.astype(np.int64) * (t_count * self.block_size ** 2) + flat

    def _local_slots_unique(self) -> bool:
        """Is the local fill map duplicate-free (lazily probed once)?"""
        if self._le_unique is None:
            key = self._slot_key(self.le_part, self.le_flat, self.t_max)
            self._le_unique = bool(len(np.unique(key)) == len(key))
        return self._le_unique

    def _boundary_slots_unique(self) -> bool:
        if self._re_unique is None:
            key = self._slot_key(self.re_part, self.re_flat, self.tb_max)
            self._re_unique = bool(len(np.unique(key)) == len(key))
        return self._re_unique

    def fill_local_batch(
        self, weights: np.ndarray, zero: float = INF,
        out: Optional[np.ndarray] = None,
        parts: Optional[Tuple[int, int]] = None,
    ) -> np.ndarray:
        """Instance edge weights (I, E) -> local tiles (I, P, T, B, B).

        ``out``: optional pre-staged (I, P, T, B, B) float32 buffer filled
        in place (see ``alloc_batch_buffers``); avoids the allocation per
        call when the prefetcher stages chunk buffers.  ``parts``: fill
        only the half-open partition range (shard-local staging) — the
        result's partition axis is ``hi - lo``."""
        return self._fill_batch(
            weights, zero, self.le_part, self.le_flat, self.le_edge_id,
            self.t_max, out, self._local_slots_unique(), parts=parts,
        )

    def fill_boundary_batch(
        self, weights: np.ndarray, zero: float = INF,
        out: Optional[np.ndarray] = None,
        parts: Optional[Tuple[int, int]] = None,
    ) -> np.ndarray:
        """Instance edge weights (I, E) -> boundary tiles (I, P, Tb, B, B).

        ``out``/``parts``: as in ``fill_local_batch``."""
        return self._fill_batch(
            weights, zero, self.re_part, self.re_flat, self.re_edge_id,
            self.tb_max, out, self._boundary_slots_unique(), parts=parts,
        )

    def alloc_batch_buffers(
        self, max_instances: int, *,
        bucket: Optional[int] = None, bbucket: Optional[int] = None,
        parts: Optional[Tuple[int, int]] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Allocate one reusable (local, boundary) fill-buffer pair sized
        for ``max_instances`` — the unit of the prefetcher's buffer ring.

        ``bucket``/``bbucket`` size the tile axes for the sparse layout's
        padded power-of-two buckets instead of the dense ``t_max``/
        ``tb_max`` — a ``bucket/t_max`` staging-memory reduction.
        ``parts`` sizes the partition axis to a shard-local range."""
        B = self.block_size
        P = self.n_parts if parts is None else parts[1] - parts[0]
        return (
            np.empty((max_instances, P, bucket or self.t_max,
                      B, B), np.float32),
            np.empty((max_instances, P, bbucket or self.tb_max,
                      B, B), np.float32),
        )

    # ------------------------------------------------------- sparse staging
    # A tile is ACTIVE for an instance iff at least one edge mapping into it
    # carries a weight != the semiring zero.  Inactive tiles contribute
    # exact semiring zeros to the SpMV (min with +inf / sum with 0.0), so
    # packing only active tiles is result-identical to the dense layout —
    # bitwise for min-plus, where min is order-exact.
    def _active_tiles(
        self, w: np.ndarray, zero: float, part: np.ndarray,
        flat: np.ndarray, edge_id: np.ndarray, t_count: int,
        parts: Optional[Tuple[int, int]] = None,
    ) -> np.ndarray:
        """(I, E) weights -> (I, P, t_count) bool active-tile mask."""
        B2 = self.block_size * self.block_size
        I = w.shape[0]
        if parts is not None:
            part, flat, edge_id = self._part_filter(parts, part, flat,
                                                    edge_id)
            P = parts[1] - parts[0]
        else:
            P = self.n_parts
        act = np.zeros((I, P * t_count), bool)
        if len(edge_id):
            tile_key = part.astype(np.int64) * t_count + flat // B2  # (L,)
            live = w[:, edge_id] != zero  # (I, L)
            ii, ll = np.nonzero(live)
            act[ii, tile_key[ll]] = True
        return act.reshape(I, P, t_count)

    def pack_tile_index(
        self, act: np.ndarray, rc: np.ndarray, *,
        bucket: Optional[int] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Active-tile mask (I, P, T) -> packed index (rows, cols, nnz, slot).

        ``slot[i, p, t]`` is the packed position of template tile ``t``
        (valid where ``act``), assigned in template order so the packed
        subset keeps the col-major contiguous-output-runs invariant the
        Pallas kernel needs.  Shared by the sparse fill below and the GoFS
        delta-chain reconstruction (repro.gofs.store), which must agree
        slot-for-slot for delta reads to be bitwise-identical."""
        I, P, t_count = act.shape
        nnz = act.sum(-1, dtype=np.int32)  # (I, P)
        max_nnz = int(nnz.max()) if nnz.size else 0
        K = int(bucket) if bucket is not None else pow2_bucket(max_nnz)
        assert K >= max_nnz, \
            f"bucket {K} < max active tiles {max_nnz} (stale tile map?)"
        slot = np.cumsum(act, axis=-1, dtype=np.int64) - 1  # valid where act
        rows = np.full((I, P, K), -1, np.int32)
        cols = np.full((I, P, K), -1, np.int32)
        ii, pp, tt = np.nonzero(act)
        ss = slot[ii, pp, tt]
        rows[ii, pp, ss] = rc[pp, tt, 0]
        cols[ii, pp, ss] = rc[pp, tt, 1]
        return rows, cols, nnz, slot

    def pack_payload_tiles(
        self, ref: np.ndarray, payloads: np.ndarray, rc: np.ndarray,
        zero: float, *, bucket: Optional[int] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Reconstruct a packed batch from a delta-encoded tile chain.

        ``ref`` (I, P, T) int32 indexes each active template-tile slot into
        the deduplicated ``payloads`` (U, B, B) pool (-1 = inactive); the
        gather is a RAM copy, so a payload shared by many instances is
        decoded from the store only once.  Returns (vals, rows, cols, nnz)
        exactly as ``fill_local_batch_sparse`` would for the full weights.
        """
        B = self.block_size
        act = ref >= 0
        rows, cols, nnz, slot = self.pack_tile_index(act, rc, bucket=bucket)
        I, P, K = rows.shape
        vals = np.full((I, P, K, B, B), zero, np.float32)
        ii, pp, tt = np.nonzero(act)
        ss = slot[ii, pp, tt]
        vals[ii, pp, ss] = payloads[ref[ii, pp, tt]]
        return vals, rows, cols, nnz

    def _fill_batch_sparse(
        self, w: np.ndarray, zero: float, part: np.ndarray,
        flat: np.ndarray, edge_id: np.ndarray, t_count: int,
        rc: np.ndarray, bucket: Optional[int], out: Optional[np.ndarray],
        slots_unique: bool, act: Optional[np.ndarray],
        parts: Optional[Tuple[int, int]] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Packed-tile fill.  Returns (vals (I, P, K, B, B), rows (I, P, K),
        cols (I, P, K), nnz (I, P))."""
        B = self.block_size
        B2 = B * B
        I, P = w.shape[0], self.n_parts
        if parts is not None:
            P = parts[1] - parts[0]
            rc = rc[parts[0]:parts[1]]
            if act is not None and act.shape[1] == self.n_parts:
                act = act[:, parts[0]:parts[1]]
            elif act is None:
                act = self._active_tiles(w, zero, part, flat, edge_id,
                                         t_count, parts=parts)
            part, flat, edge_id = self._part_filter(parts, part, flat,
                                                    edge_id)
        if act is None:
            act = self._active_tiles(w, zero, part, flat, edge_id, t_count)
        assert act.shape == (I, P, t_count), act.shape
        rows, cols, nnz, slot = self.pack_tile_index(act, rc, bucket=bucket)
        K = rows.shape[2]
        if out is None:
            vals = np.full(I * P * K * B2, zero, np.float32)
        else:
            assert out.shape == (I, P, K, B, B), (out.shape, K)
            assert out.dtype == np.float32 and out.flags.c_contiguous
            vals = out.reshape(-1)
            vals[...] = zero
        if len(edge_id):
            tile_key = part.astype(np.int64) * t_count + flat // B2  # (L,)
            within = flat % B2
            keep = act.reshape(I, P * t_count)[:, tile_key]  # (I, L) bool
            # gather destinations/values only at the KEPT (instance, edge)
            # pairs — no full (I, L) weight/offset temporaries beyond the
            # boolean mask itself
            ki, kl = np.nonzero(keep)
            pslot = slot.reshape(I, P * t_count)[ki, tile_key[kl]]
            didx = ((ki * np.int64(P) + part[kl]) * K + pslot) * B2 \
                + within[kl]
            dvals = w[ki, edge_id[kl]]
            if slots_unique:
                vals[didx] = dvals
            else:
                op = np.minimum if zero == INF else np.add
                op.at(vals, didx, dvals)
        return vals.reshape(I, P, K, B, B), rows, cols, nnz

    def fill_local_batch_sparse(
        self, weights: np.ndarray, zero: float = INF, *,
        bucket: Optional[int] = None, out: Optional[np.ndarray] = None,
        act: Optional[np.ndarray] = None,
        parts: Optional[Tuple[int, int]] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Instance edge weights (I, E) -> packed local tiles.

        Returns (vals (I, P, K, B, B), rows (I, P, K), cols (I, P, K),
        nnz (I, P)) with K = ``bucket`` or the pow2 bucket of the batch's
        max active-tile count.  ``act``: precomputed (I, P, T) active-tile
        mask (e.g. a GoFS-recorded per-pack tile map); ``out``: pre-staged
        buffer as in ``fill_local_batch``; ``parts``: shard-local
        partition range, as in ``fill_local_batch``."""
        return self._fill_batch_sparse(
            np.asarray(weights, np.float32), zero, self.le_part,
            self.le_flat, self.le_edge_id, self.t_max, self.tiles_rc,
            bucket, out, self._local_slots_unique(), act, parts=parts,
        )

    def fill_boundary_batch_sparse(
        self, weights: np.ndarray, zero: float = INF, *,
        bucket: Optional[int] = None, out: Optional[np.ndarray] = None,
        act: Optional[np.ndarray] = None,
        parts: Optional[Tuple[int, int]] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Instance edge weights (I, E) -> packed boundary tiles (see
        ``fill_local_batch_sparse``)."""
        return self._fill_batch_sparse(
            np.asarray(weights, np.float32), zero, self.re_part,
            self.re_flat, self.re_edge_id, self.tb_max, self.btiles_rc,
            bucket, out, self._boundary_slots_unique(), act, parts=parts,
        )

    def active_tile_maps(
        self, weights: np.ndarray, zero: float = INF
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(I, E) weights -> ((I, P, T), (I, P, Tb)) bool active-tile maps
        — the per-pack record GoFS deployment persists next to the
        attribute slices (``repro.gofs.layout``)."""
        w = np.asarray(weights, np.float32)
        if w.ndim == 1:
            w = w[None]
        return (
            self._active_tiles(w, zero, self.le_part, self.le_flat,
                               self.le_edge_id, self.t_max),
            self._active_tiles(w, zero, self.re_part, self.re_flat,
                               self.re_edge_id, self.tb_max),
        )

    def sparse_buckets(
        self, weights: np.ndarray, zero: float = INF
    ) -> Tuple[int, int]:
        """Pow2 (local, boundary) tile buckets for a weight batch — the
        shape every chunk of the batch should share (one jit entry)."""
        w = np.asarray(weights, np.float32)
        if w.ndim == 1:
            w = w[None]
        la = self._active_tiles(w, zero, self.le_part, self.le_flat,
                                self.le_edge_id, self.t_max)
        ba = self._active_tiles(w, zero, self.re_part, self.re_flat,
                                self.re_edge_id, self.tb_max)
        lmax = int(la.sum(-1).max()) if la.size else 0
        bmax = int(ba.sum(-1).max()) if ba.size else 0
        return pow2_bucket(lmax), pow2_bucket(bmax)

    def stage_sparse(
        self, weights: np.ndarray, zero: float = INF, *,
        bucket: Optional[int] = None, bbucket: Optional[int] = None,
        act_local: Optional[np.ndarray] = None,
        act_boundary: Optional[np.ndarray] = None,
        parts: Optional[Tuple[int, int]] = None,
    ) -> SparseBlocked:
        """(I, E) edge weights -> :class:`SparseBlocked` packed batch.

        ``parts=(lo, hi)`` stages only that partition range (shard-local
        cluster staging) — tiles then carry a ``hi - lo`` partition axis.
        """
        w = np.asarray(weights, np.float32)
        if w.ndim == 1:
            w = w[None]
        tiles, rows, cols, nnz = self.fill_local_batch_sparse(
            w, zero=zero, bucket=bucket, act=act_local, parts=parts,
        )
        btiles, brows, bcols, bnnz = self.fill_boundary_batch_sparse(
            w, zero=zero, bucket=bbucket, act=act_boundary, parts=parts,
        )
        return SparseBlocked(
            block_size=self.block_size,
            tiles=tiles, btiles=btiles,
            rows=rows, cols=cols, brows=brows, bcols=bcols,
            nnz=nnz, bnnz=bnnz,
            total_tiles=int(self.n_tiles.sum()),
            total_btiles=int(self.n_btiles.sum()),
        )

    # ------------------------------------------------------------- vertex io
    def scatter_vertex(self, values: np.ndarray, pad: float) -> np.ndarray:
        """Global (V,) vertex values -> padded per-partition (P, Vp)."""
        out = np.full((self.n_parts, self.vp), pad, np.float32)
        out[self.part_of, self.local_of] = values
        return out

    def gather_vertex(self, padded: np.ndarray) -> np.ndarray:
        """Padded per-partition (P, Vp) -> global (V,) vertex values."""
        return np.asarray(padded)[self.part_of, self.local_of]


def build_blocked(
    template: GraphTemplate,
    assign: np.ndarray,
    block_size: int = 128,
    *,
    vertex_order: Optional[np.ndarray] = None,
) -> BlockedGraph:
    """Compute the static blocked structure from template + partitioning.

    ``vertex_order``: optional (V,) permutation controlling local numbering
    within each partition (bin-major subgraph order from gofs.layout slots in
    here; default = ascending global id).
    """
    B = block_size
    V = template.num_vertices
    P = int(assign.max()) + 1 if len(assign) else 1
    src, dst = template.src, template.dst

    # --- local numbering, grouped by partition in the given order ----------
    order = vertex_order if vertex_order is not None else np.arange(V)
    part_of = assign.astype(np.int32)
    local_of = np.zeros(V, np.int32)
    counts = np.zeros(P, np.int64)
    globals_per_part: List[List[int]] = [[] for _ in range(P)]
    for v in order:
        p = part_of[v]
        local_of[v] = counts[p]
        counts[p] += 1
        globals_per_part[p].append(int(v))
    vp = int(-(-max(1, counts.max()) // B) * B)
    global_of = np.full((P, vp), -1, np.int64)
    for p in range(P):
        g = globals_per_part[p]
        global_of[p, : len(g)] = g

    # --- local edges -> tiles ----------------------------------------------
    local_mask = part_of[src] == part_of[dst]
    le = np.nonzero(local_mask)[0]
    le_p = part_of[src[le]]
    li, lj = local_of[src[le]], local_of[dst[le]]  # row = src, col = dst
    rb, cb = li // B, lj // B
    ri, cj = li % B, lj % B
    # unique tiles ordered (part, col_block, row_block): col-major order is
    # what the Pallas kernel's sequential-grid output accumulation needs.
    nvb = vp // B
    tile_key = (le_p.astype(np.int64) * nvb + cb) * nvb + rb
    uniq, tile_idx = np.unique(tile_key, return_inverse=True)
    t_part = uniq // (nvb * nvb)
    t_cb = (uniq // nvb) % nvb
    t_rb = uniq % nvb
    n_tiles = np.bincount(t_part.astype(np.int64), minlength=P).astype(np.int32)
    t_max = int(max(1, n_tiles.max()))
    tiles_rc = np.full((P, t_max, 2), -1, np.int32)
    # index of each unique tile within its partition
    tile_local = np.zeros(len(uniq), np.int64)
    c = np.zeros(P, np.int64)
    for i in range(len(uniq)):
        p = int(t_part[i])
        tile_local[i] = c[p]
        tiles_rc[p, c[p]] = (t_rb[i], t_cb[i])
        c[p] += 1
    le_flat = tile_local[tile_idx] * B * B + ri.astype(np.int64) * B + cj
    le_edge_id = le.astype(np.int64)
    le_part = le_p.astype(np.int32)

    # --- boundary slots ------------------------------------------------------
    cut = np.nonzero(~local_mask)[0]
    # publishers: unique cut-edge sources (each owned by exactly one part)
    pub = np.unique(src[cut]) if len(cut) else np.array([], np.int64)
    nb = int(-(-max(1, len(pub)) // B) * B)
    bslot = np.full(nb, -1, np.int64)
    bslot[: len(pub)] = pub
    slot_of_vertex = {int(v): s for s, v in enumerate(pub)}

    # per-partition publish maps
    n_out = np.zeros(P, np.int32)
    outs: List[List[Tuple[int, int]]] = [[] for _ in range(P)]
    for s, v in enumerate(pub):
        p = int(part_of[v])
        outs[p].append((s, int(local_of[v])))
    for p in range(P):
        n_out[p] = len(outs[p])
    o_max = int(max(1, n_out.max()))
    out_slot = np.zeros((P, o_max), np.int32)
    out_local = np.zeros((P, o_max), np.int32)
    for p in range(P):
        for i, (s, l) in enumerate(outs[p]):
            out_slot[p, i] = s
            out_local[p, i] = l

    # --- boundary tiles: (boundary block) x (local dst block) ---------------
    if len(cut):
        re_p = part_of[dst[cut]]
        bi = np.array([slot_of_vertex[int(v)] for v in src[cut]], np.int64)
        bj = local_of[dst[cut]].astype(np.int64)
        brb, bcb = bi // B, bj // B
        bri, bcj = bi % B, bj % B
        nbb = nb // B
        bkey = (re_p.astype(np.int64) * nvb + bcb) * nbb + brb
        buniq, btile_idx = np.unique(bkey, return_inverse=True)
        bt_part = buniq // (nbb * nvb)
        bt_cb = (buniq // nbb) % nvb
        bt_rb = buniq % nbb
        n_btiles = np.bincount(bt_part.astype(np.int64), minlength=P).astype(np.int32)
        tb_max = int(max(1, n_btiles.max()))
        btiles_rc = np.full((P, tb_max, 2), -1, np.int32)
        btile_local = np.zeros(len(buniq), np.int64)
        c = np.zeros(P, np.int64)
        for i in range(len(buniq)):
            p = int(bt_part[i])
            btile_local[i] = c[p]
            btiles_rc[p, c[p]] = (bt_rb[i], bt_cb[i])
            c[p] += 1
        re_flat = btile_local[btile_idx] * B * B + bri * B + bcj
        re_edge_id = cut.astype(np.int64)
        re_part = re_p.astype(np.int32)
    else:
        n_btiles = np.zeros(P, np.int32)
        tb_max = 1
        btiles_rc = np.full((P, 1, 2), -1, np.int32)
        re_flat = np.array([], np.int64)
        re_edge_id = np.array([], np.int64)
        re_part = np.array([], np.int32)

    return BlockedGraph(
        block_size=B,
        n_parts=P,
        part_of=part_of,
        local_of=local_of,
        global_of=global_of,
        vp=vp,
        tiles_rc=tiles_rc,
        n_tiles=n_tiles,
        le_edge_id=le_edge_id,
        le_part=le_part,
        le_flat=le_flat,
        num_boundary=nb,
        bslot_of_src=bslot,
        out_slot=out_slot,
        out_local=out_local,
        n_out=n_out,
        btiles_rc=btiles_rc,
        n_btiles=n_btiles,
        re_edge_id=re_edge_id,
        re_part=re_part,
        re_flat=re_flat,
    )
