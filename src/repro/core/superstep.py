"""Sub-graph-centric BSP supersteps on blocked graphs (TPU-native Gopher).

The engine realizes the paper's superstep semantics as linear algebra
(DESIGN.md §2):

* one *superstep* = (optional) local convergence inside each partition
  followed by ONE boundary exchange;
* *sub-graph-centric* mode iterates the local semiring SpMV to fixpoint
  before exchanging (the paper's "do much local work per message" trade) —
  valid for idempotent semirings (SSSP, reachability, components);
* *vertex-centric* mode does exactly one local sweep per superstep — the
  Pregel baseline the paper compares against.  Same code path, one knob.

Both a stacked single-process path (partitions on a leading axis, used by
CPU tests/benchmarks) and an SPMD path (partitions sharded over a mesh axis
inside ``shard_map``, used by the dry-run and production launch) share the
kernel-level step functions; only the :class:`repro.core.comm.CommBackend`
reduction differs.

The boundary exchange is a dense (num_boundary,) buffer combined with the
semiring's add — O(cut vertices) collective bytes per superstep, the
blocked analogue of Gopher's message-count win.  HOW those bytes move is
pluggable (``repro.core.comm``): a psum/pmin all-reduce (default), a
``ppermute`` ring for DCI-bound multi-pod topologies, or a host-side
gather for mesh-free CPU clusters — same drivers, same algorithms.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.core.blocked import BlockedGraph
from repro.core.comm import (  # noqa: F401  (re-exported: historical home)
    Comm,
    CommBackend,
    DenseAllReduce,
    HostGather,
    RingExchange,
    make_comm,
)
from repro.core.semiring import MIN_PLUS, PLUS_MUL, Semiring
from repro.kernels.semiring_spmm.ops import spmv_blocked
from repro.kernels.semiring_superstep.ops import fused_step

#: Engine kernel modes: ``"off"`` is the pure-jnp oracle, ``"spmv"`` the
#: per-stage blocked SpMV Pallas kernel, ``"fused"`` the single-call
#: superstep kernel (sweep + semiring combine + halt vote in one
#: ``pallas_call``, ``kernels/semiring_superstep``).  Plain bools keep
#: their historical meaning (``False`` -> off, ``True`` -> spmv).
KERNEL_MODES = ("off", "spmv", "fused")


def kernel_mode(use_pallas) -> Tuple[str, Any]:
    """Normalize a ``use_pallas`` value to ``(mode, interpret)``.

    ``use_pallas`` is the historical knob name and still accepts bools;
    it now also accepts a mode string from :data:`KERNEL_MODES` or a
    ``(mode, interpret)`` tuple for callers (tests, the engine) that
    force interpret mode explicitly.  ``interpret=None`` defers to the
    cached backend probe in ``kernels/semiring_spmm/ops.py``.
    """
    interpret = None
    if isinstance(use_pallas, tuple):
        use_pallas, interpret = use_pallas
    if use_pallas is False or use_pallas is None:
        return "off", interpret
    if use_pallas is True:
        return "spmv", interpret
    if use_pallas not in KERNEL_MODES:
        raise ValueError(
            f"unknown kernel mode {use_pallas!r}: pick from {KERNEL_MODES}")
    return use_pallas, interpret


@dataclass
class DeviceGraph:
    """Device-resident blocked structure+values, leading partition axis."""

    block_size: int
    num_boundary: int
    rows: jax.Array  # (P, T) int32
    cols: jax.Array  # (P, T) int32
    tiles: jax.Array  # (P, T, B, B) float32 — per-instance values
    brows: jax.Array  # (P, Tb) int32 (boundary block index)
    bcols: jax.Array  # (P, Tb) int32 (local dst block index)
    btiles: jax.Array  # (P, Tb, B, B) float32 — per-instance values
    out_slot: jax.Array  # (P, O) int32
    out_local: jax.Array  # (P, O) int32
    out_mask: jax.Array  # (P, O) bool
    vmask: jax.Array  # (P, Vp) bool valid-vertex mask

    @property
    def n_parts(self) -> int:
        return self.rows.shape[0]

    @property
    def vp(self) -> int:
        return self.vmask.shape[1]


def device_graph(
    bg: BlockedGraph,
    local_vals: np.ndarray,  # (P, T, B, B) from bg.fill_local
    boundary_vals: np.ndarray,  # (P, Tb, B, B) from bg.fill_boundary
) -> DeviceGraph:
    P, O = bg.out_slot.shape
    out_mask = np.arange(O)[None, :] < bg.n_out[:, None]
    vmask = bg.global_of >= 0
    return DeviceGraph(
        block_size=bg.block_size,
        num_boundary=bg.num_boundary,
        rows=jnp.asarray(bg.tiles_rc[:, :, 0]),
        cols=jnp.asarray(bg.tiles_rc[:, :, 1]),
        tiles=jnp.asarray(local_vals),
        brows=jnp.asarray(bg.btiles_rc[:, :, 0]),
        bcols=jnp.asarray(bg.btiles_rc[:, :, 1]),
        btiles=jnp.asarray(boundary_vals),
        out_slot=jnp.asarray(bg.out_slot),
        out_local=jnp.asarray(bg.out_local),
        out_mask=jnp.asarray(out_mask),
        vmask=jnp.asarray(vmask),
    )


# ---------------------------------------------------------------------------
# Step primitives
# ---------------------------------------------------------------------------

def _blocks(x: jax.Array, dg: DeviceGraph) -> jax.Array:
    """(P, Vp) state -> (P, NVB, B) block view for the fused kernel."""
    return x.reshape(x.shape[0], -1, dg.block_size)


def _fused_sweep_vote(
    x: jax.Array, dg: DeviceGraph, sr: Semiring, interpret,
) -> Tuple[jax.Array, jax.Array]:
    """One fused sweep: x' = add(x, A^T x) plus the per-partition halt
    vote vs the pre-sweep state, all inside one ``pallas_call``."""
    xs = _blocks(x, dg)
    xo, changed = fused_step(dg.tiles, dg.rows, dg.cols, xs, xs, xs,
                             _blocks(dg.vmask, dg), sr, interpret=interpret)
    return xo.reshape(x.shape), changed


def _fused_consume_vote(
    x: jax.Array, boundary: jax.Array, dg: DeviceGraph, sr: Semiring,
    x_ref: jax.Array, interpret, combine: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Fused boundary consume: x' = add(x, R^T boundary), voting against
    ``x_ref`` (the superstep start) in-kernel."""
    xs = _blocks(x, dg)
    comb = xs if combine else sr.full(xs.shape, xs.dtype)
    xo, changed = fused_step(
        dg.btiles, dg.brows, dg.bcols,
        boundary.reshape(1, -1, dg.block_size), comb, _blocks(x_ref, dg),
        _blocks(dg.vmask, dg), sr, interpret=interpret)
    return xo.reshape(x.shape), changed


def _local_sweep(
    x: jax.Array, dg: DeviceGraph, sr: Semiring, use_pallas
) -> jax.Array:
    """One relaxation sweep of every partition: x' = add(x, A^T x)."""
    mode, interpret = kernel_mode(use_pallas)
    if mode == "fused":
        return _fused_sweep_vote(x, dg, sr, interpret)[0]

    def one(tiles, rows, cols, xp):
        y = spmv_blocked(tiles, rows, cols, xp, sr,
                         use_pallas=mode == "spmv", interpret=interpret)
        return sr.add(xp, y)

    return jax.vmap(one)(dg.tiles, dg.rows, dg.cols, x)


def _spmv_only(
    x: jax.Array, dg: DeviceGraph, sr: Semiring, use_pallas
) -> jax.Array:
    """Plain y = A^T x per partition (no combine with x) — PageRank path."""
    mode, interpret = kernel_mode(use_pallas)
    if mode == "fused":
        # add(zero, y) == y and untouched blocks stay sr.zero — the
        # fused kernel degenerates to the plain SpMV (vote ignored)
        xs = _blocks(x, dg)
        xo, _ = fused_step(dg.tiles, dg.rows, dg.cols, xs,
                           sr.full(xs.shape, xs.dtype), xs,
                           _blocks(dg.vmask, dg), sr, interpret=interpret)
        return xo.reshape(x.shape)

    def one(tiles, rows, cols, xp):
        return spmv_blocked(tiles, rows, cols, xp, sr,
                            use_pallas=mode == "spmv", interpret=interpret)

    return jax.vmap(one)(dg.tiles, dg.rows, dg.cols, x)


def _local_converge(
    x: jax.Array, dg: DeviceGraph, sr: Semiring, use_pallas,
    max_sweeps: int,
) -> Tuple[jax.Array, jax.Array]:
    """Sweep to local fixpoint (idempotent sr).  Returns (x, n_sweeps)."""
    mode, interpret = kernel_mode(use_pallas)

    def cond(carry):
        _, changed, it = carry
        return jnp.logical_and(changed, it < max_sweeps)

    def body(carry):
        xc, _, it = carry
        if mode == "fused":
            # the kernel's per-partition vote is ready-made: the loop
            # folds P scalars instead of re-reading two (P, Vp) states
            xn, chv = _fused_sweep_vote(xc, dg, sr, interpret)
            changed = jnp.max(chv) > 0
        else:
            xn = _local_sweep(xc, dg, sr, use_pallas)
            changed = jnp.any(jnp.where(dg.vmask, xn != xc, False))
        return xn, changed, it + 1

    x, _, sweeps = jax.lax.while_loop(
        cond, body, (x, jnp.asarray(True), jnp.asarray(0, jnp.int32))
    )
    return x, sweeps


def _publish(x: jax.Array, dg: DeviceGraph, sr: Semiring,
             comm: CommBackend) -> jax.Array:
    """Scatter owned boundary-vertex values into the global boundary buffer
    and combine across partitions.  Returns (NB,)."""

    def one(xp, slots, locals_, mask):
        vals = jnp.where(mask, xp[locals_], jnp.asarray(sr.zero, xp.dtype))
        buf = sr.full((dg.num_boundary,), xp.dtype)
        return sr.scatter_add(buf, slots, vals)

    buf = jax.vmap(one)(x, dg.out_slot, dg.out_local, dg.out_mask)
    return comm.combine_boundary(buf, sr)


def _consume(
    x: jax.Array, boundary: jax.Array, dg: DeviceGraph, sr: Semiring,
    use_pallas, combine: bool = True,
) -> jax.Array:
    """Apply incoming cut edges: y = R^T boundary; x' = add(x, y)."""
    mode, interpret = kernel_mode(use_pallas)
    if mode == "fused":
        return _fused_consume_vote(x, boundary, dg, sr, x, interpret,
                                   combine=combine)[0]
    nob = dg.vp // dg.block_size

    def one(btiles, brows, bcols, xp):
        y = spmv_blocked(
            btiles, brows, bcols, boundary, sr,
            n_out_blocks=nob, use_pallas=mode == "spmv", interpret=interpret,
        )
        return sr.add(xp, y) if combine else y

    return jax.vmap(one, in_axes=(0, 0, 0, 0))(dg.btiles, dg.brows, dg.bcols, x)


def make_spmd_superstep(mesh, sr: Semiring = MIN_PLUS, *,
                        use_pallas=False,
                        comm="dense"):
    """One BSP superstep as an explicit shard_map program: partitions are
    sharded one-per-device over ALL mesh axes; the boundary exchange is one
    combine of the (num_boundary,) buffer through the selected
    ``repro.core.comm`` backend (``"dense"`` pmin/psum all-reduce or
    ``"ring"`` collective-permute ring).

    This is the production lowering — letting XLA auto-shard the stacked
    (P, NB) publish buffer instead materializes an all-gather of P x NB
    bytes per superstep (measured 995 MB/device on the TR-full cell vs
    3.9 MB here; EXPERIMENTS.md §Perf).
    """
    from jax.sharding import PartitionSpec as P

    axes = tuple(mesh.axis_names)
    comm = make_comm(comm, mesh=mesh, model_axes=axes)

    def superstep_with_nb(nb: int):
        def run(x, rows, cols, tiles, brows, bcols, btiles,
                out_slot, out_local, out_mask, vmask):
            def local_fn(x_l, rows_l, cols_l, tiles_l, brows_l, bcols_l,
                         btiles_l, out_slot_l, out_local_l, out_mask_l,
                         vmask_l):
                d = DeviceGraph(
                    block_size=tiles_l.shape[-1], num_boundary=nb,
                    rows=rows_l, cols=cols_l, tiles=tiles_l,
                    brows=brows_l, bcols=bcols_l, btiles=btiles_l,
                    out_slot=out_slot_l, out_local=out_local_l,
                    out_mask=out_mask_l, vmask=vmask_l,
                )
                x1 = _local_sweep(x_l, d, sr, use_pallas)
                boundary = _publish(x1, d, sr, comm)
                return _consume(x1, boundary, d, sr, use_pallas)

            def lead(a):
                return P(axes, *([None] * (a.ndim - 1)))

            args = (x, rows, cols, tiles, brows, bcols, btiles,
                    out_slot, out_local, out_mask, vmask)
            fn = shard_map(
                local_fn, mesh=mesh,
                in_specs=tuple(lead(a) for a in args),
                out_specs=lead(x),
                check_vma=False,
            )
            return fn(*args)

        return run

    return superstep_with_nb


# ---------------------------------------------------------------------------
# BSP drivers
# ---------------------------------------------------------------------------

def bsp_fixpoint(
    x0: jax.Array,  # (P, Vp) initial vertex values
    dg: DeviceGraph,
    sr: Semiring = MIN_PLUS,
    *,
    comm: CommBackend = DenseAllReduce(),
    subgraph_centric: bool = True,
    max_supersteps: int = 64,
    max_local_sweeps: int = 1024,
    use_pallas=False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Run BSP supersteps until global fixpoint (idempotent semirings).

    Returns (x, stats) with stats = {supersteps, local_sweeps}.
    ``subgraph_centric=False`` gives the vertex-centric (Pregel) baseline:
    exactly one local sweep per superstep.
    """
    assert sr.idempotent, "bsp_fixpoint needs an idempotent semiring"
    sweeps_cap = max_local_sweeps if subgraph_centric else 1
    mode, interpret = kernel_mode(use_pallas)

    def cond(carry):
        _, changed, ss, _ = carry
        return jnp.logical_and(changed, ss < max_supersteps)

    def body(carry):
        x0_step, _, ss, lsw = carry
        x, s = _local_converge(x0_step, dg, sr, use_pallas, sweeps_cap)
        boundary = _publish(x, dg, sr, comm)
        # vote-to-halt compares against the superstep START: in
        # vertex-centric mode the single local sweep can progress even when
        # the boundary exchange is quiet.
        if mode == "fused":
            # the consume kernel emits the vote: the while_loop consumes
            # a (P, 1) scalar fold, never re-reading the full states
            xn, chv = _fused_consume_vote(x, boundary, dg, sr, x0_step,
                                          interpret)
            changed = jnp.max(chv) > 0
        else:
            xn = _consume(x, boundary, dg, sr, use_pallas)
            changed = jnp.any(jnp.where(dg.vmask, xn != x0_step, False))
        changed = comm.any_changed(changed)
        return xn, changed, ss + 1, lsw + s

    x, _, supersteps, local_sweeps = jax.lax.while_loop(
        cond, body,
        (x0, jnp.asarray(True), jnp.asarray(0, jnp.int32),
         jnp.asarray(0, jnp.int32)),
    )
    return x, {"supersteps": supersteps, "local_sweeps": local_sweeps}


def pagerank_step(
    rank: jax.Array,  # (P, Vp)
    dg: DeviceGraph,  # tiles already hold 1/out_degree weights
    comm: CommBackend,
    *,
    damping: float = 0.85,
    num_vertices: int,
    use_pallas=False,
) -> jax.Array:
    """One PageRank superstep: contribution SpMV + boundary exchange."""
    contrib = _spmv_only(rank, dg, PLUS_MUL, use_pallas)
    boundary = _publish(rank, dg, PLUS_MUL, comm)
    contrib = contrib + _consume(
        jnp.zeros_like(rank), boundary, dg, PLUS_MUL, use_pallas, combine=False
    )
    base = (1.0 - damping) / num_vertices
    out = jnp.where(dg.vmask, base + damping * contrib, 0.0)
    return out


def pagerank_run(
    dg: DeviceGraph,
    comm: CommBackend = DenseAllReduce(),
    *,
    damping: float = 0.85,
    num_vertices: int,
    iters: int = 30,
    tol: float = 0.0,
    use_pallas=False,
) -> Tuple[jax.Array, jax.Array]:
    """PageRank to ``iters`` supersteps (or L1 tolerance).  Returns
    (rank (P, Vp), supersteps)."""
    P, Vp = dg.vmask.shape
    r0 = jnp.where(dg.vmask, 1.0 / num_vertices, 0.0)

    def cond(carry):
        _, delta, it = carry
        return jnp.logical_and(delta > tol, it < iters)

    def body(carry):
        r, _, it = carry
        rn = pagerank_step(
            r, dg, comm, damping=damping, num_vertices=num_vertices,
            use_pallas=use_pallas,
        )
        delta = comm.sum_scalar(jnp.sum(jnp.abs(rn - r)))
        return rn, delta, it + 1

    r, _, it = jax.lax.while_loop(
        cond, body, (r0, jnp.asarray(jnp.inf), jnp.asarray(0, jnp.int32))
    )
    return r, it
