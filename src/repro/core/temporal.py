"""Temporal parallelism on the mesh (paper §IV-B orchestration, DESIGN §2).

The *independent* and *eventually dependent* patterns expose concurrency
ACROSS graph instances; on the production mesh this maps instances onto the
``data`` axis while graph partitions stay on ``model`` — both forms of the
paper's parallelism at once:

    tiles  (I, P, T, B, B)   I sharded over data, P sharded over model
    ranks  (I, P, Vp)        same

Each device holds I/|data| instances x P/|model| partitions; the spatial
boundary exchange is a psum over ``model`` ONLY (instances never talk), and
the eventually-dependent Merge is a final reduction over ``data``.

PageRank (fixed iteration count) is the paper's independent-pattern
workload; ``pagerank_temporal`` runs every instance's PageRank
concurrently and optionally merges (mean rank across instances — the
"PageRank stability over time" analysis the paper cites).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocked import BlockedGraph
from repro.core.semiring import PLUS_MUL
from repro.core.superstep import Comm, DeviceGraph, _consume, _publish, _spmv_only


def _pagerank_iters_local(
    tiles, btiles, struct: Dict[str, jax.Array], comm: Comm, *,
    damping: float, num_vertices: int, iters: int, block_size: int,
    num_boundary: int,
):
    """Fixed-iteration PageRank for ONE instance's local partition shard.

    tiles: (P_l, T, B, B); struct holds rows/cols/brows/bcols/out_*/vmask.
    Fixed iteration count keeps every instance's loop in lockstep, so the
    model-axis collectives stay congruent under the data-axis sharding.
    """
    dg = DeviceGraph(
        block_size=block_size, num_boundary=num_boundary,
        rows=struct["rows"], cols=struct["cols"], tiles=tiles,
        brows=struct["brows"], bcols=struct["bcols"], btiles=btiles,
        out_slot=struct["out_slot"], out_local=struct["out_local"],
        out_mask=struct["out_mask"], vmask=struct["vmask"],
    )
    r0 = jnp.where(dg.vmask, 1.0 / num_vertices, 0.0)
    base = (1.0 - damping) / num_vertices

    def body(r, _):
        contrib = _spmv_only(r, dg, PLUS_MUL, False)
        boundary = _publish(r, dg, PLUS_MUL, comm)
        contrib = contrib + _consume(
            jnp.zeros_like(r), boundary, dg, PLUS_MUL, False, combine=False
        )
        return jnp.where(dg.vmask, base + damping * contrib, 0.0), None

    r, _ = jax.lax.scan(body, r0, None, length=iters)
    return r


def make_temporal_pagerank(
    mesh,
    *,
    block_size: int,
    num_boundary: int,
    num_vertices: int,
    damping: float = 0.85,
    iters: int = 30,
    data_axis: str = "data",
    model_axes: Tuple[str, ...] = ("model",),
    merge: bool = True,
):
    """Build the jittable temporal-parallel PageRank.

    Inputs (global shapes): tiles (I, P, T, B, B), btiles (I, P, Tb, B, B),
    struct arrays (P, ...).  Returns ranks (I, P, Vp) and, when ``merge``,
    the across-instance mean rank (P, Vp) — the eventually-dependent Merge
    as one reduction over the data axis.
    """
    from jax.sharding import PartitionSpec as P_

    comm = Comm(axis_name=model_axes)
    maxes = model_axes if len(model_axes) > 1 else model_axes[0]

    def local_fn(tiles_l, btiles_l, rows, cols, brows, bcols,
                 out_slot, out_local, out_mask, vmask):
        struct = {
            "rows": rows, "cols": cols, "brows": brows, "bcols": bcols,
            "out_slot": out_slot, "out_local": out_local,
            "out_mask": out_mask, "vmask": vmask,
        }
        run = functools.partial(
            _pagerank_iters_local, struct=struct, comm=comm,
            damping=damping, num_vertices=num_vertices, iters=iters,
            block_size=block_size, num_boundary=num_boundary,
        )
        ranks = jax.vmap(run)(tiles_l, btiles_l)  # over local instances
        if not merge:
            return ranks, jnp.zeros_like(ranks[0])
        # eventually-dependent Merge: mean over ALL instances (data axis)
        part = jnp.sum(ranks, axis=0)
        total = jax.lax.psum(part, data_axis)
        n_inst = jax.lax.psum(jnp.asarray(ranks.shape[0], jnp.float32),
                              data_axis)
        return ranks, total / n_inst

    def spec(*axes):
        return P_(*axes)

    fn = jax.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            spec(data_axis, maxes, None, None, None),  # tiles
            spec(data_axis, maxes, None, None, None),  # btiles
            spec(maxes, None), spec(maxes, None),      # rows, cols
            spec(maxes, None), spec(maxes, None),      # brows, bcols
            spec(maxes, None), spec(maxes, None),      # out_slot, out_local
            spec(maxes, None), spec(maxes, None),      # out_mask, vmask
        ),
        out_specs=(
            spec(data_axis, maxes, None),
            spec(maxes, None),  # merged (P_l, Vp): replicated over data
        ),
        check_vma=False,
    )
    return fn


def pagerank_temporal(
    bg: BlockedGraph,
    src: np.ndarray,
    instance_active: np.ndarray,  # (I, E)
    mesh,
    *,
    num_vertices: int,
    damping: float = 0.85,
    iters: int = 30,
    data_axis: str = "data",
    model_axes: Tuple[str, ...] = ("model",),
) -> Tuple[np.ndarray, np.ndarray]:
    """Host wrapper: fill per-instance tiles, run all instances concurrently
    on the mesh.  Returns (ranks (I, V), merged mean rank (V,))."""
    from repro.core.algorithms.pagerank import edge_weights_for_instance

    I = instance_active.shape[0]
    lt, bt = [], []
    for i in range(I):
        w = edge_weights_for_instance(src, instance_active[i], num_vertices)
        lt.append(bg.fill_local(w, zero=0.0))
        bt.append(bg.fill_boundary(w, zero=0.0))
    tiles = jnp.asarray(np.stack(lt))
    btiles = jnp.asarray(np.stack(bt))
    out_mask = np.arange(bg.o_max)[None, :] < bg.n_out[:, None]
    fn = make_temporal_pagerank(
        mesh, block_size=bg.block_size, num_boundary=bg.num_boundary,
        num_vertices=num_vertices, damping=damping, iters=iters,
        data_axis=data_axis, model_axes=model_axes,
    )
    with mesh:
        ranks, merged = jax.jit(fn)(
            tiles, btiles,
            jnp.asarray(bg.tiles_rc[:, :, 0]), jnp.asarray(bg.tiles_rc[:, :, 1]),
            jnp.asarray(bg.btiles_rc[:, :, 0]), jnp.asarray(bg.btiles_rc[:, :, 1]),
            jnp.asarray(bg.out_slot), jnp.asarray(bg.out_local),
            jnp.asarray(out_mask), jnp.asarray(bg.global_of >= 0),
        )
    ranks_v = np.stack([bg.gather_vertex(np.asarray(ranks[i])) for i in range(I)])
    merged_v = bg.gather_vertex(np.asarray(merged))
    return ranks_v, merged_v
