"""Temporal parallelism on the mesh (paper §IV-B orchestration, DESIGN §2).

The *independent* and *eventually dependent* patterns expose concurrency
ACROSS graph instances; on the production mesh this maps instances onto the
``data`` axis while graph partitions stay on ``model`` — both forms of the
paper's parallelism at once:

    tiles  (I, P, T, B, B)   I sharded over data, P sharded over model
    ranks  (I, P, Vp)        same

Each device holds I/|data| instances x P/|model| partitions; the spatial
boundary exchange runs over ``model`` ONLY (instances never talk), through
whichever ``repro.core.comm`` backend the deployment picks (dense psum
all-reduce by default, a collective-permute ring for multi-pod DCI), and
the eventually-dependent Merge is a final reduction over ``data``.

This module provides the shape-polymorphic ``shard_map`` builder
(``make_temporal_runner``) used by the dry-run to lower temporal cells from
abstract shapes alone.  Concrete executions go through
``repro.core.engine.TemporalEngine``, which generalizes the same lowering
to every semiring program (SSSP, components, N-hop — not just PageRank)
and adds batched instance staging; ``pagerank_temporal`` below is the
engine-backed host wrapper kept for the paper's independent-pattern
workload.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.core.blocked import BlockedGraph
from repro.core.comm import make_comm
from repro.core.superstep import DeviceGraph, pagerank_step


def make_temporal_runner(
    mesh,
    run_one: Callable[[jax.Array, jax.Array, Dict[str, jax.Array]], jax.Array],
    *,
    data_axis: str = "data",
    model_axes: Tuple[str, ...] = ("model",),
    merge: bool = True,
):
    """Lower a per-instance local program onto the temporal-parallel mesh.

    ``run_one(tiles_l (P_l, T, B, B), btiles_l, struct)`` computes one
    instance's final vertex state (P_l, Vp) on the local partition shard
    (collectives over ``model_axes`` only — typically a ``repro.core.comm``
    backend bound to those axes, so the same runner lowers to a dense
    all-reduce or a collective-permute ring depending on the closure's
    ``comm`` choice).  The returned jittable fn takes
    the global (I, P, ...) tensors, shards instances over ``data_axis`` and
    partitions over ``model_axes``, vmaps ``run_one`` over the local
    instances, and (when ``merge``) folds the across-instance mean as one
    reduction over the data axis — the eventually-dependent Merge.
    """
    from jax.sharding import PartitionSpec as P_

    maxes = model_axes if len(model_axes) > 1 else model_axes[0]

    def local_fn(tiles_l, btiles_l, rows, cols, brows, bcols,
                 out_slot, out_local, out_mask, vmask):
        struct = {
            "rows": rows, "cols": cols, "brows": brows, "bcols": bcols,
            "out_slot": out_slot, "out_local": out_local,
            "out_mask": out_mask, "vmask": vmask,
        }
        states = jax.vmap(lambda t, b: run_one(t, b, struct))(
            tiles_l, btiles_l
        )  # over local instances
        if not merge:
            return states, jnp.zeros_like(states[0])
        # eventually-dependent Merge: mean over ALL instances (data axis)
        part = jnp.sum(states, axis=0)
        total = jax.lax.psum(part, data_axis)
        n_inst = jax.lax.psum(jnp.asarray(states.shape[0], jnp.float32),
                              data_axis)
        return states, total / n_inst

    def spec(*axes):
        return P_(*axes)

    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            spec(data_axis, maxes, None, None, None),  # tiles
            spec(data_axis, maxes, None, None, None),  # btiles
            spec(maxes, None), spec(maxes, None),      # rows, cols
            spec(maxes, None), spec(maxes, None),      # brows, bcols
            spec(maxes, None), spec(maxes, None),      # out_slot, out_local
            spec(maxes, None), spec(maxes, None),      # out_mask, vmask
        ),
        out_specs=(
            spec(data_axis, maxes, None),
            spec(maxes, None),  # merged (P_l, Vp): replicated over data
        ),
        check_vma=False,
    )


def make_temporal_pagerank(
    mesh,
    *,
    block_size: int,
    num_boundary: int,
    num_vertices: int,
    damping: float = 0.85,
    iters: int = 30,
    data_axis: str = "data",
    model_axes: Tuple[str, ...] = ("model",),
    merge: bool = True,
    comm="dense",
):
    """Build the jittable temporal-parallel PageRank (the paper's
    independent-pattern workload) on top of ``make_temporal_runner``.

    Inputs (global shapes): tiles (I, P, T, B, B), btiles (I, P, Tb, B, B),
    struct arrays (P, ...).  Returns ranks (I, P, Vp) and, when ``merge``,
    the across-instance mean rank (P, Vp).  Fixed iteration count keeps
    every instance's loop in lockstep, so the model-axis collectives stay
    congruent under the data-axis sharding.  ``comm`` picks the boundary
    exchange backend (``"dense"`` or ``"ring"``; see ``repro.core.comm``).
    """
    comm = make_comm(comm, mesh=mesh, model_axes=model_axes)

    def run_one(tiles, btiles, struct):
        dg = DeviceGraph(
            block_size=block_size, num_boundary=num_boundary,
            rows=struct["rows"], cols=struct["cols"], tiles=tiles,
            brows=struct["brows"], bcols=struct["bcols"], btiles=btiles,
            out_slot=struct["out_slot"], out_local=struct["out_local"],
            out_mask=struct["out_mask"], vmask=struct["vmask"],
        )
        r0 = jnp.where(dg.vmask, 1.0 / num_vertices, 0.0)

        def body(r, _):
            r = pagerank_step(
                r, dg, comm, damping=damping, num_vertices=num_vertices,
            )
            return r, None

        r, _ = jax.lax.scan(body, r0, None, length=iters)
        return r

    return make_temporal_runner(
        mesh, run_one, data_axis=data_axis, model_axes=model_axes,
        merge=merge,
    )


def pagerank_temporal(
    bg: BlockedGraph,
    src: np.ndarray,
    instance_active: np.ndarray,  # (I, E)
    mesh,
    *,
    num_vertices: int,
    damping: float = 0.85,
    iters: int = 30,
    data_axis: str = "data",
    model_axes: Tuple[str, ...] = ("model",),
    comm="dense",
) -> Tuple[np.ndarray, np.ndarray]:
    """Host wrapper: batched-stage per-instance tiles, run all instances
    concurrently on the mesh through the TemporalEngine.  ``comm`` selects
    the boundary exchange backend.  Returns (ranks (I, V), merged mean
    rank (V,))."""
    from repro.core.algorithms.pagerank import edge_weights_for_instances
    from repro.core.engine import TemporalEngine, pagerank_program

    w = edge_weights_for_instances(src, instance_active, num_vertices)
    eng = TemporalEngine(
        bg, mesh=mesh, data_axis=data_axis, model_axes=model_axes, comm=comm,
    )
    res = eng.run(
        pagerank_program(num_vertices, damping=damping, iters=iters),
        w, pattern="eventually", merge="mean",
    )
    return res.values, res.merged
