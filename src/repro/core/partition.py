"""Graph partitioning + subgraph discovery (paper §IV-A, §V-A).

* ``partition_graph``     — BFS-grown balanced edge-cut partitioner (the
  paper uses METIS-style "balance vertices, minimize remote edges").
* ``discover_subgraphs``  — maximal connected components via LOCAL edges
  within each partition: the paper's unit of computation.
* ``Partition``           — per-host view: local subgraphs, local/remote
  edges, boundary-vertex tables used by Gopher's message exchange.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.graph import GraphTemplate


def partition_graph(template: GraphTemplate, n_parts: int, seed: int = 0) -> np.ndarray:
    """Greedy BFS-grown partitioning: balanced vertices, low edge cut.

    Returns (V,) int32 partition assignment.
    """
    V = template.num_vertices
    if n_parts == 1:
        return np.zeros(V, np.int32)
    indptr, indices = template.undirected_adjacency()
    target = -(-V // n_parts)
    assign = np.full(V, -1, np.int32)
    rng = np.random.default_rng(seed)
    # order seeds by degree (high-degree first makes growth contiguous)
    order = np.argsort(-(indptr[1:] - indptr[:-1]), kind="stable")
    cur_part = 0
    cur_size = 0
    from collections import deque

    frontier: deque = deque()
    oi = 0
    while True:
        if not frontier:
            while oi < V and assign[order[oi]] >= 0:
                oi += 1
            if oi >= V:
                break
            frontier.append(order[oi])
        u = frontier.popleft()
        if assign[u] >= 0:
            continue
        assign[u] = cur_part
        cur_size += 1
        if cur_size >= target:
            cur_part = min(cur_part + 1, n_parts - 1)
            cur_size = 0
            frontier.clear()
            continue
        for w in indices[indptr[u]:indptr[u + 1]]:
            if assign[w] < 0:
                frontier.append(int(w))
    return assign


def discover_subgraphs(
    template: GraphTemplate, assign: np.ndarray
) -> np.ndarray:
    """Union-find over LOCAL edges only -> (V,) int64 global subgraph ids.

    A subgraph is a maximal set of vertices connected through edges whose
    endpoints share a partition (paper §IV-A).
    """
    V = template.num_vertices
    parent = np.arange(V, dtype=np.int64)

    def find(x: int) -> int:
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:
            parent[x], x = root, parent[x]
        return root

    src, dst = template.src, template.dst
    local = assign[src] == assign[dst]
    for u, v in zip(src[local], dst[local]):
        ru, rv = find(int(u)), find(int(v))
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    roots = np.array([find(int(i)) for i in range(V)], np.int64)
    # compact ids, stable by root
    _, sg_ids = np.unique(roots, return_inverse=True)
    return sg_ids


@dataclass
class Partition:
    """Host-local view of one partition of the template."""

    pid: int
    vertices: np.ndarray  # (Vp,) global vertex ids in this partition
    local_src: np.ndarray  # (Lp,) indices into template edge list (local edges)
    remote_src: np.ndarray  # (Rp,) indices into template edge list (remote out-edges)
    remote_in: np.ndarray  # (Rin,) template edge ids whose dst is here, src remote
    subgraph_of: np.ndarray  # (Vp,) global subgraph id per local vertex
    subgraph_ids: np.ndarray  # unique global subgraph ids in this partition
    # vertex id -> local index
    global_to_local: Dict[int, int] = field(default_factory=dict)

    @property
    def num_vertices(self) -> int:
        return len(self.vertices)

    def subgraph_sizes(self) -> np.ndarray:
        _, counts = np.unique(self.subgraph_of, return_counts=True)
        return counts


def build_partitions(
    template: GraphTemplate, assign: np.ndarray, sg_ids: np.ndarray
) -> List[Partition]:
    n_parts = int(assign.max()) + 1 if len(assign) else 1
    src, dst = template.src, template.dst
    e_part = assign[src]  # edges live with their source (paper: directed)
    local_mask = assign[src] == assign[dst]
    parts: List[Partition] = []
    for p in range(n_parts):
        vmask = assign == p
        verts = np.nonzero(vmask)[0]
        emask = e_part == p
        local_e = np.nonzero(emask & local_mask)[0]
        remote_e = np.nonzero(emask & ~local_mask)[0]
        remote_in = np.nonzero((assign[dst] == p) & ~local_mask)[0]
        parts.append(
            Partition(
                pid=p,
                vertices=verts,
                local_src=local_e,
                remote_src=remote_e,
                remote_in=remote_in,
                subgraph_of=sg_ids[verts],
                subgraph_ids=np.unique(sg_ids[verts]),
                global_to_local={int(v): i for i, v in enumerate(verts)},
            )
        )
    return parts


def edge_cut(template: GraphTemplate, assign: np.ndarray) -> int:
    return int(np.sum(assign[template.src] != assign[template.dst]))


def bin_pack_subgraphs(
    sizes: np.ndarray, ids: np.ndarray, n_bins: int
) -> List[np.ndarray]:
    """Paper §V-D: pack subgraphs into ``n_bins`` bins balancing total
    vertices per bin (greedy largest-first).  Returns list of id arrays,
    bin-major order."""
    order = np.argsort(-sizes, kind="stable")
    loads = np.zeros(n_bins, np.int64)
    bins: List[List[int]] = [[] for _ in range(n_bins)]
    for i in order:
        b = int(np.argmin(loads))
        bins[b].append(int(ids[i]))
        loads[b] += int(sizes[i])
    return [np.array(b, np.int64) for b in bins]
