"""Host-side subgraph topology — the paper's unit of computation (§IV-A).

``SubgraphTopology`` is the time-invariant part handed to the user's
``Compute`` together with per-instance attribute values.  Edges are split
into *local* (both endpoints in this subgraph — available for shared-memory
algorithms like Dijkstra/DFS, the paper's key reuse) and *remote* (crossing
to another subgraph, possibly in another partition — these define where
``SendToSubgraph`` messages flow).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

from repro.core.graph import GraphTemplate


@dataclass
class SubgraphTopology:
    sgid: int  # global subgraph id
    pid: int  # owning partition
    vertices: np.ndarray  # (n,) global vertex ids
    # local edges, endpoints as LOCAL indices into ``vertices``
    local_src: np.ndarray  # (m,) int32
    local_dst: np.ndarray  # (m,) int32
    local_edge_id: np.ndarray  # (m,) int64 template edge ids
    # remote out-edges: local src index, destination (global vertex, sgid)
    remote_src: np.ndarray  # (r,) int32 local index
    remote_dst_vertex: np.ndarray  # (r,) int64 global vertex id
    remote_dst_sgid: np.ndarray  # (r,) int64
    remote_edge_id: np.ndarray  # (r,) int64
    global_to_local: Dict[int, int] = field(default_factory=dict)

    @property
    def num_vertices(self) -> int:
        return len(self.vertices)

    @property
    def num_local_edges(self) -> int:
        return len(self.local_src)

    def local_adjacency(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """CSR (indptr, indices, edge_ids) over local DIRECTED edges."""
        n = self.num_vertices
        order = np.argsort(self.local_src, kind="stable")
        s = self.local_src[order]
        indptr = np.zeros(n + 1, np.int64)
        np.add.at(indptr, s + 1, 1)
        indptr = np.cumsum(indptr)
        return indptr, self.local_dst[order], self.local_edge_id[order]

    def remote_by_src(self) -> Dict[int, List[int]]:
        """local src index -> list of remote-edge row indices."""
        out: Dict[int, List[int]] = {}
        for i, s in enumerate(self.remote_src):
            out.setdefault(int(s), []).append(i)
        return out


def build_subgraphs(
    template: GraphTemplate, assign: np.ndarray, sg_ids: np.ndarray
) -> Dict[int, SubgraphTopology]:
    """All subgraph topologies, keyed by global subgraph id."""
    src, dst = template.src, template.dst
    sg_src = sg_ids[src]
    sg_dst = sg_ids[dst]
    part_of_sg: Dict[int, int] = {}
    verts_of: Dict[int, List[int]] = {}
    for v in range(template.num_vertices):
        g = int(sg_ids[v])
        verts_of.setdefault(g, []).append(v)
        part_of_sg[g] = int(assign[v])

    out: Dict[int, SubgraphTopology] = {}
    local_map: Dict[int, Dict[int, int]] = {}
    for g, vs in verts_of.items():
        va = np.array(vs, np.int64)
        g2l = {int(v): i for i, v in enumerate(va)}
        local_map[g] = g2l
        out[g] = SubgraphTopology(
            sgid=g, pid=part_of_sg[g], vertices=va,
            local_src=np.array([], np.int32), local_dst=np.array([], np.int32),
            local_edge_id=np.array([], np.int64),
            remote_src=np.array([], np.int32),
            remote_dst_vertex=np.array([], np.int64),
            remote_dst_sgid=np.array([], np.int64),
            remote_edge_id=np.array([], np.int64),
            global_to_local=g2l,
        )

    # local edges: same subgraph (implies same partition by construction)
    same = sg_src == sg_dst
    le = np.nonzero(same)[0]
    re = np.nonzero(~same)[0]
    by_sg_local: Dict[int, List[int]] = {}
    for e in le:
        by_sg_local.setdefault(int(sg_src[e]), []).append(int(e))
    for g, es in by_sg_local.items():
        ea = np.array(es, np.int64)
        g2l = local_map[g]
        out[g].local_src = np.array([g2l[int(v)] for v in src[ea]], np.int32)
        out[g].local_dst = np.array([g2l[int(v)] for v in dst[ea]], np.int32)
        out[g].local_edge_id = ea
    by_sg_remote: Dict[int, List[int]] = {}
    for e in re:
        by_sg_remote.setdefault(int(sg_src[e]), []).append(int(e))
    for g, es in by_sg_remote.items():
        ea = np.array(es, np.int64)
        g2l = local_map[g]
        out[g].remote_src = np.array([g2l[int(v)] for v in src[ea]], np.int32)
        out[g].remote_dst_vertex = dst[ea].astype(np.int64)
        out[g].remote_dst_sgid = sg_ids[dst[ea]].astype(np.int64)
        out[g].remote_edge_id = ea
    return out
