"""Pluggable communication backends for the boundary exchange.

The paper's iBSP supersteps hinge on ONE collective: merging boundary
vertex state across partitions (§IV-B).  How that merge moves bytes is a
deployment decision, not an algorithm decision — GoFFish itself targets a
commodity Ethernet cluster (§V) while this repro's production lowering
targets a TPU mesh — so the engine treats it as a pluggable
:class:`CommBackend`:

==================  ========================================================
backend             boundary combine
==================  ========================================================
``DenseAllReduce``  one ``lax.psum``/``pmin`` of the (num_boundary,) buffer
                    over the mesh axis — XLA's tree/ring all-reduce, the
                    default on a single pod (lowest latency per superstep)
``RingExchange``    a ``lax.ppermute`` ring over the mesh axis: each device
                    circulates its semiring-partial buffer in P-1
                    neighbor-to-neighbor hops, folding with the semiring
                    add at every hop.  Every transfer is point-to-point, so
                    on multi-pod DCI (or any bandwidth-asymmetric topology)
                    no hop crosses the slow links more than once per
                    superstep — the regime where a ring beats the
                    all-reduce tree.  Two variants: ``circulate`` (v1)
                    moves the FULL (NB,) buffer on every hop —
                    ``(n-1) * NB`` bytes per device; ``rs_ag`` (v2,
                    backend name ``"ring-rs"``) runs a chunked
                    reduce-scatter followed by an all-gather, moving
                    ``2 * (n-1)/n * NB`` bytes per device — the
                    bandwidth-optimal schedule, ~2x less traffic for
                    large rings at the cost of twice the hop count
``HostGather``      mesh-free: the (P, num_boundary) per-partition buffers
                    are combined on the HOST (numpy semiring fold behind
                    ``jax.pure_callback``), so the same
                    ``SemiringProgram`` runs on CPU clusters with no
                    ``shard_map``/mesh at all — the paper's §V commodity
                    cluster deployment
==================  ========================================================

Exactness contract (enforced by ``tests/test_comm_backends.py``): min-plus
combines are **bitwise identical** across all three backends (min is exact
in floats regardless of order); plus-mul (PageRank) is bitwise in stacked
and host modes (same left-fold association) while the mesh ring
**reassociates** the sum — one differently-ordered float add chain per
device, equal to the all-reduce up to low-order bits.

Backends are frozen dataclasses bound to a placement by :func:`make_comm`
(``axis_name=None`` = stacked: all partitions live on one device's leading
axis; otherwise the leading axis is the per-device shard inside
``shard_map``).  Analytic per-superstep byte costs for each backend live in
``repro.dist.collectives.boundary_exchange_bytes``; measured HLO volumes in
``collective_bytes_by_kind``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.semiring import Semiring

COMM_BACKENDS = ("dense", "ring", "ring-rs", "host")

AxisName = Optional[Union[str, Tuple[str, ...]]]


def _axes(axis_name: AxisName) -> Tuple[str, ...]:
    if axis_name is None:
        return ()
    return (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)


def _stack_fold(buf: jax.Array, sr: Semiring) -> jax.Array:
    """Left-fold the leading (local partition) axis with the semiring add.
    Fixed association 0..P-1 — every backend shares it, which is what makes
    stacked-mode results bitwise comparable across backends."""
    if buf.shape[0] == 1:
        return buf[0]
    return functools.reduce(sr.add, [buf[i] for i in range(buf.shape[0])])


@dataclass(frozen=True)
class CommBackend:
    """Cross-partition combination protocol for one BSP superstep.

    ``combine_boundary`` merges the per-partition (P_local, NB) boundary
    buffers into the globally combined (NB,) buffer every partition
    consumes; ``any_changed`` globalizes the vote-to-halt flag;
    ``sum_scalar`` globalizes scalar reductions (PageRank's L1 delta).
    """

    axis_name: AxisName = None

    name: str = "abstract"

    def combine_boundary(self, buf: jax.Array, sr: Semiring) -> jax.Array:
        """buf: (P_local, NB) -> (NB,) combined over ALL partitions."""
        raise NotImplementedError

    def any_changed(self, flag: jax.Array) -> jax.Array:
        """Global OR of the per-shard convergence flag."""
        raise NotImplementedError

    def sum_scalar(self, x: jax.Array) -> jax.Array:
        """Global sum of a per-shard scalar (tolerance checks)."""
        raise NotImplementedError

    def bind_sync(self, axes: Tuple[str, ...]) -> "CommBackend":
        """Bind extra mesh axes the halt vote must synchronize over.

        The engine calls this when OTHER mesh axes run data-dependent
        superstep loops concurrently (instances sharded over ``data``).
        Backends whose collectives rendezvous globally (the ppermute ring:
        XLA schedules one collective-permute across ALL devices, not per
        replica group) must equalize while-loop trip counts across those
        axes or the permutes deadlock; extra supersteps on already
        converged shards are idempotent no-ops, so results are unchanged.
        Group-scoped backends (dense all-reduce) ignore this.
        """
        return self


@dataclass(frozen=True)
class DenseAllReduce(CommBackend):
    """Dense all-reduce of the boundary buffer (the default backend).

    Stacked mode folds the partition axis on one device; mesh mode adds one
    ``lax.pmin``/``psum`` over ``axis_name`` — O(num_boundary) collective
    bytes per superstep, lowered by XLA to its tuned all-reduce.

    >>> import jax.numpy as jnp
    >>> import numpy as np
    >>> from repro.core.semiring import MIN_PLUS
    >>> buf = jnp.asarray([[0., 7., jnp.inf],
    ...                    [jnp.inf, 2., 5.]])  # 2 partitions, 3 boundary
    >>> np.asarray(DenseAllReduce().combine_boundary(buf, MIN_PLUS))
    array([0., 2., 5.], dtype=float32)
    """

    name: str = "dense"

    def combine_boundary(self, buf: jax.Array, sr: Semiring) -> jax.Array:
        out = _stack_fold(buf, sr)
        if self.axis_name is not None:
            if sr.name == "plus_mul":
                out = jax.lax.psum(out, self.axis_name)
            else:
                out = jax.lax.pmin(out, self.axis_name)
        return out

    def any_changed(self, flag: jax.Array) -> jax.Array:
        if self.axis_name is not None:
            flag = jax.lax.pmax(flag.astype(jnp.int32), self.axis_name) > 0
        return flag

    def sum_scalar(self, x: jax.Array) -> jax.Array:
        if self.axis_name is not None:
            x = jax.lax.psum(x, self.axis_name)
        return x


@dataclass(frozen=True)
class RingExchange(CommBackend):
    """Collective-permute ring over the mesh axis (multi-pod DCI regime).

    Each device folds its local partitions, then circulates the partial
    (NB,) buffer around a ``lax.ppermute`` ring for ``n - 1`` hops,
    combining with the semiring add at every hop; after the last hop every
    device holds the full combination.  All traffic is neighbor-to-neighbor
    point-to-point — on bandwidth-asymmetric topologies (pods joined by
    DCI) each slow link carries exactly one (NB,) buffer per hop instead of
    the all-reduce tree's cross-section traffic.

    ``axis_sizes`` pins the static ring length per axis (``make_comm``
    derives it from the mesh).  In stacked mode (``axis_name=None``) there
    is no ring to walk — the backend degenerates to the same partition-axis
    left fold as :class:`DenseAllReduce`, bitwise identical.

    ``variant`` picks the hop schedule.  ``"circulate"`` (v1, backend name
    ``"ring"``) ships the whole (NB,) partial on each of the ``n - 1``
    hops: ``(n - 1) * NB`` bytes leave every device per superstep.
    ``"rs_ag"`` (v2, backend name ``"ring-rs"``) is the bandwidth-optimal
    two-phase schedule: the buffer is split into ``n`` chunks, a
    reduce-scatter walks ``n - 1`` hops combining ONE chunk per hop (after
    which device ``i`` owns the fully combined chunk ``(i + 1) % n``), and
    an all-gather walks ``n - 1`` more hops broadcasting the owned chunks —
    ``2 * (n - 1) / n * NB`` bytes per device, ~2x less than circulate for
    large ``n``, at twice the latency-bound hop count.  Per-superstep costs
    for both are modeled in
    ``repro.dist.collectives.boundary_exchange_bytes``.

    Min-plus ring results are bitwise equal to the all-reduce (min is
    order-exact, both variants); plus-mul results are REASSOCIATED — each
    device (circulate) or each chunk (rs_ag) folds the same addends in its
    own ring order, so expect low-order float bit differences vs
    ``DenseAllReduce`` on a mesh (see ``tests/test_comm_backends.py``
    tolerances).

    >>> import jax.numpy as jnp
    >>> import numpy as np
    >>> from repro.core.semiring import MIN_PLUS
    >>> buf = jnp.asarray([[0., 7., jnp.inf],
    ...                    [jnp.inf, 2., 5.]])  # 2 partitions, 3 boundary
    >>> np.asarray(RingExchange().combine_boundary(buf, MIN_PLUS))
    array([0., 2., 5.], dtype=float32)
    >>> np.asarray(RingExchange(name="ring-rs", variant="rs_ag")
    ...            .combine_boundary(buf, MIN_PLUS))  # stacked: same fold
    array([0., 2., 5.], dtype=float32)
    """

    name: str = "ring"
    axis_sizes: Tuple[int, ...] = ()
    variant: str = "circulate"  # "circulate" (v1) | "rs_ag" (v2)
    # extra axes the halt vote synchronizes over (see CommBackend.bind_sync)
    sync_axes: Tuple[str, ...] = ()

    def __post_init__(self):
        assert len(_axes(self.axis_name)) == len(self.axis_sizes), \
            "RingExchange needs one static axis size per mesh axis " \
            "(use make_comm to derive them from the mesh)"
        assert self.variant in ("circulate", "rs_ag"), \
            f"unknown ring variant {self.variant!r}"

    def bind_sync(self, axes: Tuple[str, ...]) -> "RingExchange":
        import dataclasses

        return dataclasses.replace(self, sync_axes=tuple(axes))

    def _ring(self, x: jax.Array, combine) -> jax.Array:
        """Fold ``x`` over every mesh axis with P-1 neighbor hops each."""
        for ax, n in zip(_axes(self.axis_name), self.axis_sizes):
            if n == 1:
                continue
            perm = [(i, (i + 1) % n) for i in range(n)]
            send = x
            for _ in range(n - 1):
                send = jax.lax.ppermute(send, ax, perm)
                x = combine(x, send)
        return x

    def _ring_rs_ag(self, x: jax.Array, sr: Semiring) -> jax.Array:
        """Chunked reduce-scatter + all-gather over every mesh axis.

        Phase 1 (reduce-scatter): the (NB,) buffer is padded with the
        semiring zero to a multiple of ``n`` and split into ``n`` chunks;
        on hop ``s`` each device forwards its running partial and combines
        the received partial with its LOCAL copy of that chunk, so after
        ``n - 1`` hops device ``i`` owns the fully combined chunk
        ``(i + 1) % n`` (folded in device order ``c, c+1, ..`` for chunk
        ``c`` — one fixed association per chunk).  Phase 2 (all-gather):
        the owned chunks circulate ``n - 1`` more hops, each device
        scattering arrivals back into place.  Each hop moves ``NB / n``
        elements instead of circulate's full ``NB``.
        """
        for ax, n in zip(_axes(self.axis_name), self.axis_sizes):
            if n == 1:
                continue
            nb = x.shape[0]
            pad = (-nb) % n
            xp = jnp.pad(x, (0, pad), constant_values=sr.zero) if pad else x
            chunks = xp.reshape(n, -1)
            idx = jax.lax.axis_index(ax)
            perm = [(i, (i + 1) % n) for i in range(n)]

            def take(c):
                return jax.lax.dynamic_index_in_dim(chunks, c, keepdims=False)

            # reduce-scatter: after n-1 hops device i owns chunk (i+1) % n
            send = take(idx)
            for s in range(n - 1):
                recv = jax.lax.ppermute(send, ax, perm)
                send = sr.add(recv, take(jnp.mod(idx - 1 - s, n)))
            # all-gather: broadcast the owned chunks around the same ring
            out = chunks.at[jnp.mod(idx + 1, n)].set(send)
            g = send
            for s in range(n - 1):
                g = jax.lax.ppermute(g, ax, perm)
                out = out.at[jnp.mod(idx - s, n)].set(g)
            x = out.reshape(-1)[:nb]
        return x

    def combine_boundary(self, buf: jax.Array, sr: Semiring) -> jax.Array:
        out = _stack_fold(buf, sr)
        if self.axis_name is not None:
            if self.variant == "rs_ag":
                out = self._ring_rs_ag(out, sr)
            else:
                out = self._ring(out, sr.add)
        return out

    def any_changed(self, flag: jax.Array) -> jax.Array:
        if self.axis_name is None:
            return flag
        # control stays a group-scoped all-reduce: the ring is for the
        # O(num_boundary) payload, but walking P-1 hops to reduce a 4-byte
        # vote would double the latency-bound permute chain per superstep.
        # ``sync_axes`` folds in too — equalizing trip counts with
        # concurrent data-sharded loops so the globally scheduled permutes
        # cannot deadlock (see bind_sync).
        axes = _axes(self.axis_name) + tuple(self.sync_axes)
        return jax.lax.pmax(flag.astype(jnp.int32), axes) > 0

    def sum_scalar(self, x: jax.Array) -> jax.Array:
        if self.axis_name is None:
            return x
        # scalar control reduction: all-reduce, same rationale as the vote
        return jax.lax.psum(x, self.axis_name)


def _host_fold_min(buf) -> np.ndarray:
    b = np.asarray(buf)
    out = b[0]
    for i in range(1, b.shape[0]):
        out = np.minimum(out, b[i])
    return out


def _host_fold_sum(buf) -> np.ndarray:
    b = np.asarray(buf)
    out = b[0]
    for i in range(1, b.shape[0]):
        out = out + b[i]
    return out


@dataclass(frozen=True)
class HostGather(CommBackend):
    """Mesh-free backend: combine boundary buffers on the host.

    The (P, NB) publish buffer crosses to host memory once per superstep
    (``jax.pure_callback``), is folded there with a numpy semiring
    left-fold in the SAME 0..P-1 association as the stacked device fold
    (bitwise-identical results), and the combined (NB,) buffer returns to
    the device.  No mesh, no ``shard_map``, no XLA collectives — the
    paper's §V commodity-cluster deployment shape, where the exchange is a
    host-side gather over Ethernet rather than an accelerator collective.
    On a real multi-host CPU cluster the fold site is where the MPI-style
    gather slots in; single-process it demonstrates (and tests) the
    mesh-free execution path.

    Host-gather is inherently stacked: it requires all per-partition
    buffers in one process, so ``make_comm`` rejects it when a mesh is
    given.

    >>> import jax.numpy as jnp
    >>> import numpy as np
    >>> from repro.core.semiring import MIN_PLUS, PLUS_MUL
    >>> buf = jnp.asarray([[0., 7., jnp.inf],
    ...                    [jnp.inf, 2., 5.]])  # 2 partitions, 3 boundary
    >>> np.asarray(HostGather().combine_boundary(buf, MIN_PLUS))
    array([0., 2., 5.], dtype=float32)
    >>> np.asarray(HostGather().combine_boundary(
    ...     jnp.asarray([[1., 2.], [3., 4.]]), PLUS_MUL))
    array([4., 6.], dtype=float32)
    """

    name: str = "host"

    def combine_boundary(self, buf: jax.Array, sr: Semiring) -> jax.Array:
        fold = _host_fold_sum if sr.name == "plus_mul" else _host_fold_min
        return jax.pure_callback(
            fold, jax.ShapeDtypeStruct(buf.shape[1:], buf.dtype), buf
        )

    def any_changed(self, flag: jax.Array) -> jax.Array:
        return flag  # stacked: the flag already covers every partition

    def sum_scalar(self, x: jax.Array) -> jax.Array:
        return x


# Backwards-compatible name: the original hardcoded ``Comm`` WAS the dense
# all-reduce; existing call sites (dryrun, benches) keep working.
Comm = DenseAllReduce


def make_comm(
    backend: Union[str, CommBackend] = "dense",
    *,
    mesh=None,
    model_axes: Tuple[str, ...] = ("model",),
) -> CommBackend:
    """Bind a backend name (or pre-built instance) to a placement.

    ``mesh=None`` binds the stacked form (``axis_name=None``); with a mesh
    the backend combines over ``model_axes`` (``RingExchange`` additionally
    captures the static per-axis ring lengths from the mesh shape).
    Pre-built instances pass through, but their binding is VALIDATED
    against the placement — an unbound backend inside ``shard_map`` would
    silently fold only the local shard and never cross devices.

    >>> make_comm("dense").name
    'dense'
    >>> make_comm("ring").axis_name is None   # stacked: fold, no ring
    True
    >>> make_comm("ring-rs").variant      # v2: reduce-scatter + all-gather
    'rs_ag'
    >>> make_comm("host").name
    'host'
    >>> make_comm("nope")
    Traceback (most recent call last):
        ...
    ValueError: unknown comm backend 'nope'; pick from ('dense', 'ring', 'ring-rs', 'host')
    """
    axes = tuple(model_axes)
    if isinstance(backend, CommBackend):
        if mesh is None:
            if backend.axis_name is not None:
                raise ValueError(
                    f"comm backend {backend.name!r} is bound to mesh axes "
                    f"{backend.axis_name!r} but no mesh was given"
                )
            return backend
        if isinstance(backend, HostGather):
            raise ValueError(
                "HostGather is mesh-free (it folds all partition buffers in "
                "one host process); use 'dense' or 'ring' on a mesh"
            )
        bound = _axes(backend.axis_name)
        if not bound:
            raise ValueError(
                f"comm backend {backend.name!r} is unbound (axis_name=None) "
                f"but the engine runs on a mesh over {axes!r}: inside "
                f"shard_map it would combine only the local shard — pass "
                f"the backend NAME to bind it, or bind axis_name yourself"
            )
        missing = [a for a in bound if a not in mesh.axis_names]
        if missing:
            raise ValueError(
                f"comm backend {backend.name!r} is bound to {bound!r} but "
                f"the mesh only has axes {tuple(mesh.axis_names)!r}"
            )
        if isinstance(backend, RingExchange):
            want = tuple(int(mesh.shape[a]) for a in bound)
            if backend.axis_sizes != want:
                raise ValueError(
                    f"RingExchange axis_sizes {backend.axis_sizes!r} do not "
                    f"match the mesh shape {want!r} over {bound!r}"
                )
        return backend
    axis_name = None if mesh is None else axes
    if backend == "dense":
        return DenseAllReduce(axis_name=axis_name)
    if backend in ("ring", "ring-rs"):
        variant = "rs_ag" if backend == "ring-rs" else "circulate"
        if mesh is None:
            return RingExchange(name=backend, axis_name=None, variant=variant)
        sizes = tuple(int(mesh.shape[a]) for a in axes)
        return RingExchange(
            name=backend, axis_name=axis_name, axis_sizes=sizes,
            variant=variant,
        )
    if backend == "host":
        if mesh is not None:
            raise ValueError(
                "HostGather is mesh-free (it folds all partition buffers in "
                "one host process); use 'dense' or 'ring' on a mesh"
            )
        return HostGather()
    raise ValueError(
        f"unknown comm backend {backend!r}; pick from {COMM_BACKENDS}"
    )
