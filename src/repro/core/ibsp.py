"""Iterative BSP (iBSP) — the paper's programming abstraction (§IV-B),
reproduced faithfully at the host level.

The user implements::

    def compute(ctx: ComputeContext) -> None: ...
    def merge(ctx: MergeContext) -> None: ...   # eventually-dependent only

``ComputeContext`` carries the SubgraphInstance view (topology + projected
attribute values for the current graph instance), the ``timestep`` (graph
instance index) and ``superstep`` numbers, the incoming messages, and the
paper's messaging API:

    SendToSubgraph(sgid, msg)             — superstep messaging (BSP)
    SendToNextTimeStep(msg)               — same subgraph, next instance
    SendToSubgraphInNextTimeStep(sgid, m) — other subgraph, next instance
    SendMessageToMerge(msg)               — fold into the Merge step
    VoteToHalt()

Execution patterns (§III-C): ``sequential`` runs timesteps in order with
inter-timestep message handoff; ``independent`` runs each instance's BSP in
isolation (thread pool across timesteps — temporal concurrency);
``eventually`` is independent + a final Merge BSP over the collected merge
messages.

Messages in a superstep are delivered in *bulk* before the next superstep
(BSP semantics): ordering inside a superstep carries no meaning.  A BSP
timestep terminates when every subgraph voted to halt and no messages are
in flight.  The engine tracks superstep counts and message volumes — the
quantities the paper's evaluation reasons about.

Comm topology: this host engine's exchange IS the host-gather shape — all
per-subgraph messages meet in one process's inboxes between supersteps,
exactly GoFFish's §V commodity-cluster deployment.  The blocked engine
exposes the same choice as the ``HostGather`` backend in
``repro.core.comm`` (beside the device-collective ``DenseAllReduce`` /
``RingExchange`` backends), so a ``SemiringProgram`` can run with
``run_ibsp``-style host combining without leaving the blocked/TPU path.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.subgraph import SubgraphTopology


@dataclass
class SubgraphInstance:
    """Topology + instance attribute values, as handed to Compute."""

    topology: SubgraphTopology
    timestep: int
    timestamp: float
    # projected attribute values, LOCAL order (topology.vertices order /
    # local edge order and remote edge order for edge attrs)
    vertex_values: Dict[str, np.ndarray] = field(default_factory=dict)
    local_edge_values: Dict[str, np.ndarray] = field(default_factory=dict)
    remote_edge_values: Dict[str, np.ndarray] = field(default_factory=dict)

    @property
    def sgid(self) -> int:
        return self.topology.sgid


class ComputeContext:
    def __init__(self, engine: "_TimestepBSP", sgi: SubgraphInstance,
                 superstep: int, messages: List[Any]):
        self.subgraph = sgi
        self.timestep = sgi.timestep
        self.superstep = superstep
        self.messages = messages
        self._engine = engine
        self._halted = False

    # ---- paper messaging API ------------------------------------------
    def send_to_subgraph(self, sgid: int, msg: Any) -> None:
        self._engine.post_superstep_msg(int(sgid), msg)

    def send_to_next_timestep(self, msg: Any) -> None:
        self._engine.post_timestep_msg(self.subgraph.sgid, msg)

    def send_to_subgraph_in_next_timestep(self, sgid: int, msg: Any) -> None:
        self._engine.post_timestep_msg(int(sgid), msg)

    def send_message_to_merge(self, msg: Any) -> None:
        self._engine.post_merge_msg(msg)

    def vote_to_halt(self) -> None:
        self._halted = True


class MergeContext:
    def __init__(self, messages: List[Any]):
        self.messages = messages
        self.result: Any = None

    def emit(self, result: Any) -> None:
        self.result = result


@dataclass
class BSPStats:
    supersteps: int = 0
    compute_calls: int = 0
    superstep_messages: int = 0
    timestep_messages: int = 0
    merge_messages: int = 0

    def merge_from(self, other: "BSPStats") -> None:
        self.supersteps += other.supersteps
        self.compute_calls += other.compute_calls
        self.superstep_messages += other.superstep_messages
        self.timestep_messages += other.timestep_messages
        self.merge_messages += other.merge_messages


class InstanceProvider:
    """Data-access protocol the engine pulls subgraph instances through.

    Implementations: ``repro.gofs.store.GoFSStore`` (slice-backed, cached)
    and ``repro.core.ibsp.InMemoryProvider``.
    """

    def subgraph_ids(self) -> Sequence[int]:
        raise NotImplementedError

    def num_timesteps(self) -> int:
        raise NotImplementedError

    def get_instance(self, t_idx: int, sgid: int) -> SubgraphInstance:
        raise NotImplementedError


class InMemoryProvider(InstanceProvider):
    """Adapter over (TimeSeriesGraph, subgraph topologies)."""

    def __init__(self, tsg, subgraphs: Dict[int, SubgraphTopology],
                 vertex_attrs: Sequence[str] = (),
                 edge_attrs: Sequence[str] = ()):
        self.tsg = tsg
        self.subgraphs = subgraphs
        self.vertex_attrs = tuple(vertex_attrs)
        self.edge_attrs = tuple(edge_attrs)

    def subgraph_ids(self):
        return sorted(self.subgraphs)

    def num_timesteps(self) -> int:
        return len(self.tsg)

    def get_instance(self, t_idx: int, sgid: int) -> SubgraphInstance:
        topo = self.subgraphs[sgid]
        inst = self.tsg.instances[t_idx]
        vv = {
            a: self.tsg.vertex_values(t_idx, a)[topo.vertices]
            for a in self.vertex_attrs
        }
        lev, rev = {}, {}
        for a in self.edge_attrs:
            full = self.tsg.edge_values(t_idx, a)
            lev[a] = full[topo.local_edge_id]
            rev[a] = full[topo.remote_edge_id]
        return SubgraphInstance(
            topology=topo, timestep=t_idx, timestamp=inst.timestamp,
            vertex_values=vv, local_edge_values=lev, remote_edge_values=rev,
        )


class _TimestepBSP:
    """One BSP timestep over one graph instance."""

    def __init__(self, provider: InstanceProvider, t_idx: int,
                 compute: Callable[[ComputeContext], None],
                 inbox: Dict[int, List[Any]],
                 merge_sink: List[Any],
                 pool: Optional[ThreadPoolExecutor],
                 max_supersteps: int = 10_000):
        self.provider = provider
        self.t_idx = t_idx
        self.compute = compute
        self.inbox = dict(inbox)  # sgid -> messages for superstep 1
        self.merge_sink = merge_sink
        self.pool = pool
        self.max_supersteps = max_supersteps
        self.stats = BSPStats()
        self._lock = threading.Lock()
        self._next_super: Dict[int, List[Any]] = defaultdict(list)
        self._next_timestep: Dict[int, List[Any]] = defaultdict(list)

    # message sinks (thread-safe: Compute may run in a pool)
    def post_superstep_msg(self, sgid: int, msg: Any) -> None:
        with self._lock:
            self._next_super[sgid].append(msg)
            self.stats.superstep_messages += 1

    def post_timestep_msg(self, sgid: int, msg: Any) -> None:
        with self._lock:
            self._next_timestep[sgid].append(msg)
            self.stats.timestep_messages += 1

    def post_merge_msg(self, msg: Any) -> None:
        with self._lock:
            self.merge_sink.append(msg)
            self.stats.merge_messages += 1

    def run(self) -> Dict[int, List[Any]]:
        """Run supersteps to quiescence; returns next-timestep inbox."""
        sgids = list(self.provider.subgraph_ids())
        active = {g: True for g in sgids}  # all active in superstep 1
        current: Dict[int, List[Any]] = {g: self.inbox.get(g, []) for g in sgids}
        superstep = 1
        while superstep <= self.max_supersteps:
            run_set = [g for g in sgids if active[g] or current.get(g)]
            if not run_set:
                break
            self.stats.supersteps += 1

            def run_one(g):
                sgi = self.provider.get_instance(self.t_idx, g)
                ctx = ComputeContext(self, sgi, superstep, current.get(g, []))
                self.compute(ctx)
                return g, ctx._halted

            if self.pool is not None:
                results = list(self.pool.map(run_one, run_set))
            else:
                results = [run_one(g) for g in run_set]
            self.stats.compute_calls += len(run_set)
            for g, halted in results:
                active[g] = not halted
            with self._lock:
                current = {g: msgs for g, msgs in self._next_super.items()}
                self._next_super = defaultdict(list)
            superstep += 1
        return dict(self._next_timestep)


@dataclass
class IBSPResult:
    merge_result: Any
    merge_messages: List[Any]
    stats: BSPStats
    per_timestep_stats: List[BSPStats]


def run_ibsp(
    provider: InstanceProvider,
    compute: Callable[[ComputeContext], None],
    *,
    pattern: str = "sequential",  # sequential | independent | eventually
    merge: Optional[Callable[[MergeContext], None]] = None,
    initial_messages: Optional[Dict[int, List[Any]]] = None,
    workers: int = 0,  # >0: thread pool over subgraphs (and instances when
    #                     the pattern allows temporal concurrency)
    max_supersteps: int = 10_000,
) -> IBSPResult:
    """Execute an iBSP application over the collection (paper §IV-B)."""
    assert pattern in ("sequential", "independent", "eventually")
    n_t = provider.num_timesteps()
    merge_sink: List[Any] = []
    total = BSPStats()
    per_ts: List[BSPStats] = []
    pool = ThreadPoolExecutor(max_workers=workers) if workers > 0 else None
    try:
        if pattern == "sequential":
            inbox = dict(initial_messages or {})
            for t in range(n_t):
                bsp = _TimestepBSP(provider, t, compute, inbox, merge_sink,
                                   pool, max_supersteps)
                inbox = bsp.run()
                per_ts.append(bsp.stats)
                total.merge_from(bsp.stats)
        else:
            # temporal concurrency: each instance's BSP is independent
            def run_t(t):
                # application inputs are visible to every timestep's
                # superstep 1 (paper §IV-B: no notion of a previous instance)
                inbox = dict(initial_messages or {})
                bsp = _TimestepBSP(provider, t, compute, inbox, merge_sink,
                                   None, max_supersteps)
                bsp.run()
                return bsp.stats

            if pool is not None:
                stats_list = list(pool.map(run_t, range(n_t)))
            else:
                stats_list = [run_t(t) for t in range(n_t)]
            for s in stats_list:
                per_ts.append(s)
                total.merge_from(s)
    finally:
        if pool is not None:
            pool.shutdown()

    merge_result = None
    if pattern == "eventually" and merge is not None:
        mctx = MergeContext(list(merge_sink))
        merge(mctx)
        merge_result = mctx.result
    return IBSPResult(
        merge_result=merge_result,
        merge_messages=list(merge_sink),
        stats=total,
        per_timestep_stats=per_ts,
    )
