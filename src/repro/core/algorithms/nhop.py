"""N-hop latency (paper §VI-A): eventually dependent pattern.

Spec (identical across host / blocked / oracle): per instance, compute
  hops[v] = unweighted shortest-path distance from the source,
  lat[v]  = min-latency distance from the source (independent relaxation),
then histogram ``lat`` over vertices with ``hops == N``.  Per-instance
histograms are folded into a composite in the Merge step (fork-join).

Host path: per-subgraph Bellman-Ford through the iBSP engine, merging via
``SendMessageToMerge``.  Blocked path: two min-plus fixpoints per instance.
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, Tuple

import numpy as np

from repro.core.blocked import BlockedGraph
from repro.core.ibsp import ComputeContext, InstanceProvider, MergeContext, run_ibsp
from repro.core.semiring import INF
from repro.gopher.registry import REQUIRED, register_analytic

LATENCY_ATTR = "latency"

DEFAULT_BINS = np.array([0, 10, 20, 50, 100, 200, 500, 1000, np.inf])


def histogram(latencies: np.ndarray, bins: np.ndarray = DEFAULT_BINS) -> np.ndarray:
    h, _ = np.histogram(latencies[np.isfinite(latencies)], bins=bins)
    return h


# --------------------------------------------------------------------------
# Host implementation (iBSP, eventually dependent)
# --------------------------------------------------------------------------

def make_compute(source_vertex: int, n_hops: int, bins: np.ndarray = DEFAULT_BINS):
    """Compute carrying independent (hops, lat) relaxations per vertex.
    Cross-subgraph frontier messages: (vertex, hops, lat)."""
    state: Dict[Tuple[int, int], Dict[str, np.ndarray]] = {}

    def compute(ctx: ComputeContext) -> None:
        topo = ctx.subgraph.topology
        key = (ctx.timestep, topo.sgid)
        n = topo.num_vertices
        lat_l = ctx.subgraph.local_edge_values[LATENCY_ATTR]
        lat_r = ctx.subgraph.remote_edge_values[LATENCY_ATTR]

        if ctx.superstep == 1:
            st = {"hops": np.full(n, INF), "lat": np.full(n, INF)}
            state[key] = st
            frontier = set()
            if source_vertex in topo.global_to_local:
                li = topo.global_to_local[source_vertex]
                st["hops"][li] = 0
                st["lat"][li] = 0.0
                frontier.add(li)
        else:
            st = state[key]
            frontier = set()
            for v_global, h, d in ctx.messages:
                li = topo.global_to_local[int(v_global)]
                if h < st["hops"][li]:
                    st["hops"][li] = h
                    frontier.add(li)
                if d < st["lat"][li]:
                    st["lat"][li] = d
                    frontier.add(li)

        # local relaxation to fixpoint (both quantities independently)
        indptr, indices, eids = topo.local_adjacency()
        eid_to_w = {int(e): float(w) for e, w in zip(topo.local_edge_id, lat_l)}
        work = list(frontier)
        touched = set(frontier)
        while work:
            u = work.pop()
            hu, du = st["hops"][u], st["lat"][u]
            for k in range(indptr[u], indptr[u + 1]):
                v = int(indices[k])
                w = eid_to_w[int(eids[k])]
                improved = False
                if hu + 1 < st["hops"][v]:
                    st["hops"][v] = hu + 1
                    improved = True
                if du + w < st["lat"][v]:
                    st["lat"][v] = du + w
                    improved = True
                if improved:
                    work.append(v)
                    touched.add(v)
        # remote expansion: publish improved boundary values
        for i in range(len(topo.remote_src)):
            s = int(topo.remote_src[i])
            if s in touched or ctx.superstep == 1:
                if np.isfinite(st["hops"][s]) or np.isfinite(st["lat"][s]):
                    ctx.send_to_subgraph(
                        int(topo.remote_dst_sgid[i]),
                        (int(topo.remote_dst_vertex[i]), st["hops"][s] + 1,
                         st["lat"][s] + float(lat_r[i])),
                    )
        # merge reporting: last message per (timestep, sgid) wins
        mask = st["hops"] == n_hops
        ctx.send_message_to_merge(
            (ctx.timestep, topo.sgid, ctx.superstep,
             histogram(st["lat"][mask], bins))
        )
        ctx.vote_to_halt()

    return compute


def merge_histograms(mctx: MergeContext) -> None:
    """Keep each (timestep, sgid)'s LAST histogram, sum the composite."""
    latest: Dict[Tuple[int, int], Tuple[int, np.ndarray]] = {}
    for t, g, s, h in mctx.messages:
        cur = latest.get((t, g))
        if cur is None or s > cur[0]:
            latest[(t, g)] = (s, h)
    total = None
    per_t: Dict[int, np.ndarray] = {}
    for (t, g), (_, h) in latest.items():
        per_t[t] = per_t.get(t, 0) + h
        total = h if total is None else total + h
    mctx.emit({"composite": total, "per_timestep": per_t})


def run_host(
    provider: InstanceProvider,
    source_vertex: int,
    n_hops: int = 6,
    *,
    bins: np.ndarray = DEFAULT_BINS,
    workers: int = 0,
) -> Tuple[Dict[str, Any], Any]:
    compute = make_compute(source_vertex, n_hops, bins)
    res = run_ibsp(
        provider, compute, pattern="eventually", merge=merge_histograms,
        workers=workers,
    )
    return res.merge_result, res


# --------------------------------------------------------------------------
# Blocked TPU implementation: registered Gopher analytic (composite)
# --------------------------------------------------------------------------

@register_analytic(
    "nhop",
    pattern="eventually",
    attr=LATENCY_ATTR,
    zero_fill=INF,
    params={"source": REQUIRED, "n_hops": 6, "bins": DEFAULT_BINS},
    kind="composite",
    source_axis="source",
    describe="N-hop latency histogram: eventually dependent — concurrent "
             "per-instance min-latency fixpoints + host-side Merge",
)
def _nhop_execute(ctx, *, source, n_hops, bins):
    """Composite executor: the hop-count fixpoint runs ONCE over unit
    weights (topology is instance-invariant, staged via the shared ones
    batch), the per-instance min-latency fixpoints run under the plan's
    pattern over the shared latency batch, and the Merge folds histograms
    on the host.

    ``source`` may be a sequence of Q vertices: both fixpoints run once
    on the engine's query axis and ``composite``/``histograms`` gain a
    leading (Q,) dim, each row bitwise identical to that scalar-source
    run."""
    from repro.core.engine import min_plus_program, source_init, sources_init

    multi = isinstance(source, (list, tuple, np.ndarray))
    bins = np.asarray(bins, np.float64)
    init = sources_init(source) if multi else source_init(source)
    prog = min_plus_program("nhop", init=init)
    # unweighted hop distance: one instance of all-ones weights
    hops_res = ctx.run(prog, pattern="independent", staged=ctx.staged_ones())
    # min-latency distance per instance, then host-side Merge (histograms)
    lat = ctx.run(prog, pattern=ctx.plan.pattern, staged=ctx.staged())
    if not multi:
        mask = hops_res.values[0] == n_hops
        hists = np.stack([
            histogram(lat.values[i][mask], bins)
            for i in range(lat.values.shape[0])
        ])
        return {"composite": hists.sum(0), "histograms": hists,
                "__engine__": lat}
    # query axis: values are ([Q,] I, V) — fold the Merge per source
    hists = np.stack([
        np.stack([
            histogram(lat.values[q, i][hops_res.values[q, 0] == n_hops], bins)
            for i in range(lat.values.shape[1])
        ])
        for q in range(lat.values.shape[0])
    ])
    return {"composite": hists.sum(1), "histograms": hists,
            "__engine__": lat}


def run_blocked(
    bg: BlockedGraph,
    instance_latency: np.ndarray,  # (I, E)
    source_vertex: int,
    n_hops: int = 6,
    *,
    bins: np.ndarray = DEFAULT_BINS,
    mesh=None,
    use_pallas: bool = False,
    comm="dense",
) -> Tuple[np.ndarray, np.ndarray]:
    """Deprecated: use the Gopher session API —
    ``GopherSession.from_blocked(bg, weights={"latency": w}).run(
    session.plan("nhop", source=..., n_hops=...))`` (``repro.gopher``).
    Pins the legacy knobs; results are identical to the session path.

    Returns (composite histogram, per-instance histograms (I, nbins))."""
    warnings.warn(
        "nhop.run_blocked is deprecated; use repro.gopher.GopherSession "
        "(session.run(session.plan('nhop', source=..., n_hops=...)))",
        DeprecationWarning, stacklevel=2,
    )
    from repro.gopher import GopherSession

    sess = GopherSession.from_blocked(
        bg, weights={LATENCY_ATTR: instance_latency},
        mesh=mesh, use_pallas=use_pallas,
    )
    res = sess.run(sess.plan(
        "nhop", source=source_vertex, n_hops=n_hops, bins=bins,
        layout="dense", comm=comm, staging="sync",
    ))
    return res.output["composite"], res.output["histograms"]


# --------------------------------------------------------------------------
# numpy oracle
# --------------------------------------------------------------------------

def oracle(
    src: np.ndarray, dst: np.ndarray, latency: np.ndarray,
    num_vertices: int, source_vertex: int, n_hops: int = 6,
    bins: np.ndarray = DEFAULT_BINS,
) -> np.ndarray:
    hops = np.full(num_vertices, INF)
    lat = np.full(num_vertices, INF)
    hops[source_vertex] = 0
    lat[source_vertex] = 0.0
    for arr, w in ((hops, np.ones(len(src))), (lat, latency)):
        changed = True
        while changed:
            new = arr.copy()
            np.minimum.at(new, dst, arr[src] + w)
            changed = bool(np.any(new < arr))
            arr[:] = new
    return histogram(lat[hops == n_hops], bins)
