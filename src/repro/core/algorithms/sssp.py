"""Temporal SSSP (paper §VI-A/C): sequentially dependent pattern.

Each timestep runs SSSP on its instance's edge weights (latency); distances
are *incrementally aggregated* between instances — the previous timestep's
distances seed the next (a vertex can only improve as new conditions are
observed), matching the paper's iBSP SSSP.

Two implementations share semantics:

* ``compute``          — faithful host Compute: Dijkstra inside the subgraph
  (the paper's shared-memory-algorithm reuse), boundary relaxations via
  ``SendToSubgraph``, seed handoff via ``SendToNextTimeStep``.
* the registered ``"sssp"`` analytic — TPU path through the Gopher
  session API (``repro.gopher``): min-plus ``bsp_fixpoint`` per timestep,
  scanned over instances carrying the distance vector.  ``run_blocked``
  remains as a deprecated thin wrapper over the session.
"""
from __future__ import annotations

import heapq
import warnings
from typing import Any, Dict, List, Tuple

import numpy as np

from repro.core.blocked import BlockedGraph
from repro.core.ibsp import ComputeContext, InstanceProvider, run_ibsp
from repro.core.semiring import INF
from repro.gopher.registry import REQUIRED, register_analytic

WEIGHT_ATTR = "latency"


# --------------------------------------------------------------------------
# Faithful host implementation (Compute + Dijkstra per subgraph)
# --------------------------------------------------------------------------

def _dijkstra_local(
    topo, weights: np.ndarray, dist: np.ndarray, seeds: List[int]
) -> Tuple[np.ndarray, List[Tuple[int, float]]]:
    """Multi-source Dijkstra over LOCAL edges from ``seeds`` (local idx).

    Returns (updated dist, relaxations over remote edges as
    (remote_edge_row, new_distance))."""
    indptr, indices, eids = topo.local_adjacency()
    # weights are in local-edge order (topo.local_edge_id order)
    eid_to_w = {int(e): float(w) for e, w in zip(topo.local_edge_id, weights)}
    heap = [(dist[s], int(s)) for s in seeds]
    heapq.heapify(heap)
    while heap:
        d, u = heapq.heappop(heap)
        if d > dist[u]:
            continue
        for k in range(indptr[u], indptr[u + 1]):
            v = int(indices[k])
            w = eid_to_w[int(eids[k])]
            nd = d + w
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(heap, (nd, v))
    return dist


def make_compute(source_vertex: int):
    """Compute closure for the sequentially dependent SSSP.

    Per-subgraph state (distances) is carried across supersteps in
    ``ctx.subgraph`` scope via an external dict keyed by (sgid) — the engine
    re-loads instances each superstep, so state lives here (the paper's
    subgraph state survives within a timestep's BSP).
    """
    state: Dict[int, np.ndarray] = {}
    result: Dict[int, np.ndarray] = {}

    def compute(ctx: ComputeContext) -> None:
        topo = ctx.subgraph.topology
        n = topo.num_vertices
        weights = ctx.subgraph.local_edge_values[WEIGHT_ATTR]
        rweights = ctx.subgraph.remote_edge_values[WEIGHT_ATTR]

        if ctx.superstep == 1:
            # seed: previous timestep's result (sequential handoff through
            # the per-subgraph state dict — in-process equivalent of the
            # paper's SendToNextTimeStep carrying end state) or inf
            if ctx.timestep == 0:
                dist = np.full(n, INF)
            else:
                dist = state.get(topo.sgid, np.full(n, INF)).copy()
            if source_vertex in topo.global_to_local:
                dist[topo.global_to_local[source_vertex]] = 0.0
            seeds = [i for i in range(n) if np.isfinite(dist[i])]
        else:
            dist = state[topo.sgid]
            seeds = []
            for v_global, d in ctx.messages:  # boundary relaxations
                li = topo.global_to_local[int(v_global)]
                if d < dist[li]:
                    dist[li] = d
                    seeds.append(li)

        if seeds:
            dist = _dijkstra_local(topo, weights, dist, seeds)
            # relax remote edges; message the owning subgraph
            for i in range(len(topo.remote_src)):
                s = int(topo.remote_src[i])
                nd = dist[s] + float(rweights[i])
                if np.isfinite(nd):
                    ctx.send_to_subgraph(
                        int(topo.remote_dst_sgid[i]),
                        (int(topo.remote_dst_vertex[i]), nd),
                    )
        state[topo.sgid] = dist
        result[topo.sgid] = dist
        ctx.vote_to_halt()

    compute.state = state
    compute.result = result
    return compute


def run_host(
    provider: InstanceProvider,
    source_vertex: int,
    *,
    workers: int = 0,
) -> Tuple[Dict[int, np.ndarray], Any]:
    """Faithful sequentially-dependent temporal SSSP.  Returns
    ({sgid: final distances (local order)}, IBSPResult)."""
    compute = make_compute(source_vertex)
    res = run_ibsp(provider, compute, pattern="sequential", workers=workers)
    return compute.result, res


# --------------------------------------------------------------------------
# Blocked TPU implementation: registered Gopher analytic
# --------------------------------------------------------------------------

def _postprocess(ctx, res, **_params):
    return {"final": res.final}


@register_analytic(
    "sssp",
    pattern="sequential",
    attr=WEIGHT_ATTR,
    zero_fill=INF,
    params={"source": REQUIRED, "subgraph_centric": True,
            "max_supersteps": 64},
    postprocess=_postprocess,
    source_axis="source",
    describe="temporal SSSP: sequentially dependent min-plus fixpoint, "
             "distances carried between timesteps",
)
def _sssp_program(ctx, *, source, subgraph_centric, max_supersteps):
    """Program factory for the ``"sssp"`` analytic: min-plus fixpoint
    seeded at ``source``; the sequential pattern carries distances
    across the instance axis (incremental aggregation).

    ``source`` may be a sequence of Q vertices: the seeds stack on the
    engine's query axis and all Q runs execute as one vectorized pass,
    with ``final`` gaining a leading (Q,) dim — bitwise identical to Q
    scalar-source runs (GopherService batches concurrent requests here).
    """
    from repro.core.engine import min_plus_program, source_init, sources_init

    if isinstance(source, (list, tuple, np.ndarray)):
        init = sources_init(source)
    else:
        init = source_init(source)
    return min_plus_program(
        "sssp", init=init,
        subgraph_centric=subgraph_centric, max_supersteps=max_supersteps,
    )


def run_blocked(
    bg: BlockedGraph,
    instance_weights: np.ndarray,  # (I, E) per-instance edge latency
    source_vertex: int,
    *,
    subgraph_centric: bool = True,
    mesh=None,
    use_pallas: bool = False,
    max_supersteps: int = 64,
    comm="dense",
) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
    """Deprecated: use the Gopher session API —
    ``GopherSession.from_blocked(bg, weights={"latency": w}).run(
    session.plan("sssp", source=...))`` (``repro.gopher``).  This wrapper
    pins the legacy knobs (dense layout, sync staging) and returns
    (final distances (V,), stats per timestep), bitwise identical to the
    session path.
    """
    warnings.warn(
        "sssp.run_blocked is deprecated; use repro.gopher.GopherSession "
        "(session.run(session.plan('sssp', source=...)))",
        DeprecationWarning, stacklevel=2,
    )
    from repro.gopher import GopherSession

    sess = GopherSession.from_blocked(
        bg, weights={WEIGHT_ATTR: instance_weights},
        mesh=mesh, use_pallas=use_pallas,
    )
    res = sess.run(sess.plan(
        "sssp", source=source_vertex, subgraph_centric=subgraph_centric,
        max_supersteps=max_supersteps,
        layout="dense", comm=comm, staging="sync",
    ))
    return res.output["final"], res.engine.stats


# --------------------------------------------------------------------------
# numpy oracle (Bellman-Ford over the full graph, incremental across time)
# --------------------------------------------------------------------------

def oracle(
    src: np.ndarray, dst: np.ndarray, instance_weights: np.ndarray,
    num_vertices: int, source_vertex: int,
) -> np.ndarray:
    dist = np.full(num_vertices, INF)
    dist[source_vertex] = 0.0
    for t in range(instance_weights.shape[0]):
        w = instance_weights[t]
        changed = True
        while changed:
            relaxed = dist[src] + w
            new = dist.copy()
            np.minimum.at(new, dst, relaxed)
            changed = bool(np.any(new < dist))
            dist = new
    return dist
