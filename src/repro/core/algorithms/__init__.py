from repro.core.algorithms import sssp, pagerank, nhop, components, tracking

__all__ = ["sssp", "pagerank", "nhop", "components", "tracking"]
