"""Vehicle tracking (paper Algorithm 1): sequentially dependent traversal.

The graph template is a road network; each instance's vertex attribute
``plates`` holds the license IDs seen at that intersection during the
window.  Starting from an initial location, each timestep traces the
vehicle spatially (bounded-depth search across subgraphs via superstep
messages) until the trail goes cold in that instance, then hands the last
known location to the next timestep (``SendToNextTimeStep``).

Host path: faithful Alg. 1 — DFS per subgraph, remote handoff messages,
(vertex, timestamp) carried between timesteps.  Blocked path: per timestep,
a masked min-plus wavefront from the previous sighting restricted to
vertices observing the plate.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.blocked import BlockedGraph
from repro.core.ibsp import ComputeContext, InstanceProvider
from repro.core.semiring import INF

PLATE_ATTR = "plate"  # int vertex attribute: vehicle id seen (-1 = none)


def make_compute(plate: int, initial_vertex: int, search_depth: int = 4):
    """Alg. 1 Compute.  Messages within a timestep: (vertex, depth_left).
    Messages across timesteps (via state dict): last sighting vertex."""
    state: Dict[str, Any] = {"last_seen": initial_vertex, "trace": []}

    def compute(ctx: ComputeContext) -> None:
        topo = ctx.subgraph.topology
        plates = ctx.subgraph.vertex_values[PLATE_ATTR]

        if ctx.superstep == 1:
            roots: List[Tuple[int, int]] = []
            v = state["last_seen"]
            if v is not None and int(v) in topo.global_to_local:
                roots.append((topo.global_to_local[int(v)], search_depth))
        else:
            roots = [
                (topo.global_to_local[int(v)], int(d))
                for v, d in ctx.messages
                if int(v) in topo.global_to_local
            ]

        if not roots:
            ctx.vote_to_halt()
            return

        # DFS on the subgraph from the roots (paper line 17)
        indptr, indices, _ = topo.local_adjacency()
        best: Optional[int] = None
        seen_depth: Dict[int, int] = {}
        stack = list(roots)
        while stack:
            u, depth = stack.pop()
            if seen_depth.get(u, -1) >= depth:
                continue
            seen_depth[u] = depth
            if int(plates[u]) == plate:
                g = int(topo.vertices[u])
                if best is None or g < best:
                    best = g
            if depth > 0:
                for k in range(indptr[u], indptr[u + 1]):
                    stack.append((int(indices[k]), depth - 1))
        # remote handoff (paper lines 18-21)
        remote_by_src = topo.remote_by_src()
        for u, depth in seen_depth.items():
            if depth > 0:
                for i in remote_by_src.get(u, []):
                    ctx.send_to_subgraph(
                        int(topo.remote_dst_sgid[i]),
                        (int(topo.remote_dst_vertex[i]), depth - 1),
                    )
        if best is not None:
            # found in this instance: remember (monotone min for determinism)
            cur = state.get("found_at")
            state["found_at"] = best if cur is None else min(cur, best)
        ctx.vote_to_halt()

    def on_timestep_end(t_idx: int) -> None:
        found = state.pop("found_at", None)
        if found is not None:
            state["last_seen"] = found
            state["trace"].append((t_idx, found))

    compute.state = state
    compute.on_timestep_end = on_timestep_end
    return compute


def run_host(
    provider: InstanceProvider,
    plate: int,
    initial_vertex: int,
    *,
    search_depth: int = 4,
    workers: int = 0,
) -> Tuple[List[Tuple[int, int]], Any]:
    """Returns (trace [(timestep, vertex), ...], IBSPResult)."""
    compute = make_compute(plate, initial_vertex, search_depth)
    # sequential pattern with an end-of-timestep hook: run timesteps one by
    # one so the state handoff (Alg. 1 lines 22-27) lands between instances.
    from repro.core.ibsp import BSPStats, IBSPResult, _TimestepBSP

    total = BSPStats()
    per_ts = []
    for t in range(provider.num_timesteps()):
        bsp = _TimestepBSP(provider, t, compute, {}, [], None)
        bsp.run()
        compute.on_timestep_end(t)
        per_ts.append(bsp.stats)
        total.merge_from(bsp.stats)
    return compute.state["trace"], IBSPResult(None, [], total, per_ts)


# --------------------------------------------------------------------------
# Blocked TPU implementation
# --------------------------------------------------------------------------

def run_blocked(
    bg: BlockedGraph,
    instance_plates: np.ndarray,  # (I, V) int
    plate: int,
    initial_vertex: int,
    *,
    search_depth: int = 4,
    mesh=None,
    use_pallas: bool = False,
    comm="dense",
) -> List[Tuple[int, int]]:
    """Masked wavefront tracker through the unified temporal engine.

    The sequential dependence is data-dependent on the host (the next
    timestep's seed is the argmin sighting, a host-side decision), so each
    timestep is one engine probe: a min-plus hop fixpoint from the last
    sighting over the instance-invariant topology (tiles staged ONCE, the
    jitted runner cached across timesteps).  ``comm`` selects the boundary
    exchange backend (min-plus: bitwise identical across backends).
    Returns [(timestep, vertex)].
    """
    from repro.core.engine import TemporalEngine, min_plus_program, source_init

    I, V = instance_plates.shape
    E = len(bg.le_edge_id) + len(bg.re_edge_id)  # every edge local xor cut
    eng = TemporalEngine(bg, mesh=mesh, use_pallas=use_pallas, comm=comm)
    tiles, btiles = eng.stage(np.ones((1, E), np.float32), INF)
    prog = min_plus_program("tracking_hops")
    trace: List[Tuple[int, int]] = []
    last = initial_vertex
    for t in range(I):
        hv = eng.run(
            prog, tiles=tiles, btiles=btiles,
            x0=source_init(last)(bg), pattern="independent",
        ).values[0]
        cand = np.nonzero(
            (hv <= search_depth) & (instance_plates[t] == plate)
        )[0]
        if len(cand):
            last = int(cand.min())
            trace.append((t, last))
    return trace
