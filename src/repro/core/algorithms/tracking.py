"""Vehicle tracking (paper Algorithm 1): sequentially dependent traversal.

The graph template is a road network; each instance's vertex attribute
``plates`` holds the license IDs seen at that intersection during the
window.  Starting from an initial location, each timestep traces the
vehicle spatially (bounded-depth search across subgraphs via superstep
messages) until the trail goes cold in that instance, then hands the last
known location to the next timestep (``SendToNextTimeStep``).

Host path: faithful Alg. 1 — DFS per subgraph, remote handoff messages,
(vertex, timestamp) carried between timesteps.  Blocked path: per timestep,
a masked min-plus wavefront from the previous sighting restricted to
vertices observing the plate.
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.blocked import BlockedGraph
from repro.core.ibsp import ComputeContext, InstanceProvider
from repro.core.semiring import INF
from repro.gopher.registry import REQUIRED, register_analytic

PLATE_ATTR = "plate"  # int vertex attribute: vehicle id seen (-1 = none)


def make_compute(plate: int, initial_vertex: int, search_depth: int = 4):
    """Alg. 1 Compute.  Messages within a timestep: (vertex, depth_left).
    Messages across timesteps (via state dict): last sighting vertex."""
    state: Dict[str, Any] = {"last_seen": initial_vertex, "trace": []}

    def compute(ctx: ComputeContext) -> None:
        topo = ctx.subgraph.topology
        plates = ctx.subgraph.vertex_values[PLATE_ATTR]

        if ctx.superstep == 1:
            roots: List[Tuple[int, int]] = []
            v = state["last_seen"]
            if v is not None and int(v) in topo.global_to_local:
                roots.append((topo.global_to_local[int(v)], search_depth))
        else:
            roots = [
                (topo.global_to_local[int(v)], int(d))
                for v, d in ctx.messages
                if int(v) in topo.global_to_local
            ]

        if not roots:
            ctx.vote_to_halt()
            return

        # DFS on the subgraph from the roots (paper line 17)
        indptr, indices, _ = topo.local_adjacency()
        best: Optional[int] = None
        seen_depth: Dict[int, int] = {}
        stack = list(roots)
        while stack:
            u, depth = stack.pop()
            if seen_depth.get(u, -1) >= depth:
                continue
            seen_depth[u] = depth
            if int(plates[u]) == plate:
                g = int(topo.vertices[u])
                if best is None or g < best:
                    best = g
            if depth > 0:
                for k in range(indptr[u], indptr[u + 1]):
                    stack.append((int(indices[k]), depth - 1))
        # remote handoff (paper lines 18-21)
        remote_by_src = topo.remote_by_src()
        for u, depth in seen_depth.items():
            if depth > 0:
                for i in remote_by_src.get(u, []):
                    ctx.send_to_subgraph(
                        int(topo.remote_dst_sgid[i]),
                        (int(topo.remote_dst_vertex[i]), depth - 1),
                    )
        if best is not None:
            # found in this instance: remember (monotone min for determinism)
            cur = state.get("found_at")
            state["found_at"] = best if cur is None else min(cur, best)
        ctx.vote_to_halt()

    def on_timestep_end(t_idx: int) -> None:
        found = state.pop("found_at", None)
        if found is not None:
            state["last_seen"] = found
            state["trace"].append((t_idx, found))

    compute.state = state
    compute.on_timestep_end = on_timestep_end
    return compute


def run_host(
    provider: InstanceProvider,
    plate: int,
    initial_vertex: int,
    *,
    search_depth: int = 4,
    workers: int = 0,
) -> Tuple[List[Tuple[int, int]], Any]:
    """Returns (trace [(timestep, vertex), ...], IBSPResult)."""
    compute = make_compute(plate, initial_vertex, search_depth)
    # sequential pattern with an end-of-timestep hook: run timesteps one by
    # one so the state handoff (Alg. 1 lines 22-27) lands between instances.
    from repro.core.ibsp import BSPStats, IBSPResult, _TimestepBSP

    total = BSPStats()
    per_ts = []
    for t in range(provider.num_timesteps()):
        bsp = _TimestepBSP(provider, t, compute, {}, [], None)
        bsp.run()
        compute.on_timestep_end(t)
        per_ts.append(bsp.stats)
        total.merge_from(bsp.stats)
    return compute.state["trace"], IBSPResult(None, [], total, per_ts)


# --------------------------------------------------------------------------
# Blocked TPU implementation: registered Gopher analytic (composite)
# --------------------------------------------------------------------------

@register_analytic(
    "tracking",
    pattern="sequential",
    attr="__ones__",  # probes traverse topology, not attribute values
    zero_fill=INF,
    params={"plate": REQUIRED, "initial_vertex": REQUIRED,
            "search_depth": 4},
    kind="composite",
    describe="vehicle tracking (Alg. 1): all candidate sighting wavefronts "
             "as one multi-source pass, per-timestep handoff on the host",
)
def _tracking_execute(ctx, *, plate, initial_vertex, search_depth):
    """Composite executor: the sequential dependence is data-dependent on
    the host (the next timestep's seed is the argmin sighting), but every
    seed a probe can ever start from is known up front — the initial
    vertex plus each vertex that observes the plate in SOME timestep
    (a timestep's sighting is always drawn from that set).  So instead of
    one host-driven engine probe per timestep, ALL candidate wavefronts
    run as one multi-source pass on the engine's query axis over the
    instance-invariant unit-weight topology (staged once via the shared
    ones batch), and the per-timestep trace reduces to numpy lookups into
    the (Q, V) hop matrix — same trace, one engine dispatch."""
    from repro.core.engine import min_plus_program, sources_init

    staged = ctx.staged_ones()
    plates = np.asarray(ctx.vertex_attr(PLATE_ATTR))
    sighted = np.unique(np.nonzero(plates == plate)[1]) \
        if plates.size else np.empty(0, np.int64)
    srcs = np.unique(np.concatenate(
        [np.asarray([int(initial_vertex)], np.int64),
         sighted.astype(np.int64)]
    ))
    prog = min_plus_program("tracking_hops", init=sources_init(srcs))
    hv = ctx.run(prog, pattern="independent", staged=staged).values[:, 0]
    row = {int(v): q for q, v in enumerate(srcs)}  # source vertex -> row
    trace: List[Tuple[int, int]] = []
    last = int(initial_vertex)
    for t in range(plates.shape[0]):
        cand = np.nonzero(
            (hv[row[last]] <= search_depth) & (plates[t] == plate)
        )[0]
        if len(cand):
            last = int(cand.min())
            trace.append((t, last))
    return {"trace": trace}


def run_blocked(
    bg: BlockedGraph,
    instance_plates: np.ndarray,  # (I, V) int
    plate: int,
    initial_vertex: int,
    *,
    search_depth: int = 4,
    mesh=None,
    use_pallas: bool = False,
    comm="dense",
) -> List[Tuple[int, int]]:
    """Deprecated: use the Gopher session API —
    ``GopherSession.from_blocked(bg, vertex_attrs={"plate": p}).run(
    session.plan("tracking", plate=..., initial_vertex=...))``
    (``repro.gopher``).  Returns [(timestep, vertex)], identical to the
    session path."""
    warnings.warn(
        "tracking.run_blocked is deprecated; use repro.gopher."
        "GopherSession (session.run(session.plan('tracking', ...)))",
        DeprecationWarning, stacklevel=2,
    )
    from repro.gopher import GopherSession

    sess = GopherSession.from_blocked(
        bg, vertex_attrs={PLATE_ATTR: instance_plates},
        mesh=mesh, use_pallas=use_pallas,
    )
    res = sess.run(sess.plan(
        "tracking", plate=plate, initial_vertex=initial_vertex,
        search_depth=search_depth,
        layout="dense", comm=comm, staging="sync",
    ))
    return res.output["trace"]
