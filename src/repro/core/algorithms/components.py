"""Connected components per instance (independent pattern) — the classic
label-propagation workload; exercises min-plus with 0/inf weights.

Used by tests as a structural invariant check (components of the blocked
path must match union-find on the host) and by the benchmark suite as a
second independent-pattern application beside PageRank.
"""
from __future__ import annotations

import warnings

import numpy as np

from repro.core.blocked import BlockedGraph
from repro.core.semiring import INF
from repro.gopher.registry import register_analytic


def symmetrized_blocked(
    bg: BlockedGraph, src: np.ndarray, dst: np.ndarray
) -> BlockedGraph:
    """Blocked structure over the doubled (undirected) edge list, same
    partitioning — labels propagate both ways through min-plus."""
    from repro.core.blocked import build_blocked
    from repro.core.graph import GraphTemplate

    tmpl2 = GraphTemplate(
        num_vertices=len(bg.part_of),
        src=np.concatenate([src, dst]),
        dst=np.concatenate([dst, src]),
    )
    return build_blocked(tmpl2, bg.part_of, bg.block_size)


def _components_weights(session, raw: np.ndarray) -> np.ndarray:
    """Staging transform: (I, E) activity -> (I, 2E) min-plus weights over
    the symmetrized (doubled) edge list — 0 on active edges (labels pass
    freely both ways), INF elsewhere."""
    w = np.where(np.asarray(raw) > 0, 0.0, INF).astype(np.float32)
    return np.concatenate([w, w], axis=1)  # both orientations


def _postprocess(ctx, res, **_params):
    return {"labels": res.values.astype(np.int64)}


@register_analytic(
    "components",
    pattern="independent",
    attr="active",
    zero_fill=INF,
    graph="symmetrized",
    params={"max_supersteps": 256},
    weights=_components_weights,
    postprocess=_postprocess,
    describe="connected components per instance: min-label propagation "
             "over the symmetrized active edges",
)
def _components_program(ctx, *, max_supersteps):
    """Program factory for the ``"components"`` analytic."""
    from repro.core.engine import label_init, min_plus_program

    return min_plus_program(
        "components", init=label_init(), max_supersteps=max_supersteps,
    )


def _session_labels(bg, src, dst, instance_active, mesh, use_pallas, comm):
    from repro.gopher import GopherSession

    sess = GopherSession.from_blocked(
        bg, weights={"active": instance_active}, src=src, dst=dst,
        mesh=mesh, use_pallas=use_pallas,
    )
    res = sess.run(sess.plan(
        "components", layout="dense", comm=comm, staging="sync",
    ))
    return res.output["labels"]


def run_blocked_temporal(
    bg: BlockedGraph,
    src: np.ndarray,
    dst: np.ndarray,
    instance_active: np.ndarray,  # (I, E) 0/1 per instance
    *,
    mesh=None,
    use_pallas: bool = False,
    comm="dense",
) -> np.ndarray:
    """Deprecated: use the Gopher session API —
    ``GopherSession.from_blocked(bg, weights={"active": a}, src=src,
    dst=dst).run(session.plan("components"))`` (``repro.gopher``).
    Returns (I, V) int64 labels, identical to the session path."""
    warnings.warn(
        "components.run_blocked_temporal is deprecated; use repro.gopher."
        "GopherSession (session.run(session.plan('components')))",
        DeprecationWarning, stacklevel=2,
    )
    return _session_labels(bg, src, dst, instance_active, mesh, use_pallas,
                           comm)


def run_blocked(
    bg: BlockedGraph,
    src: np.ndarray,
    dst: np.ndarray,
    active: np.ndarray,  # (E,) 0/1 — edges active in this instance
    *,
    mesh=None,
    use_pallas: bool = False,
    comm="dense",
) -> np.ndarray:
    """Deprecated single-instance form of ``run_blocked_temporal`` (same
    session path).  Returns (V,) component labels (min vertex id in
    component)."""
    warnings.warn(
        "components.run_blocked is deprecated; use repro.gopher."
        "GopherSession (session.run(session.plan('components')))",
        DeprecationWarning, stacklevel=2,
    )
    labels = _session_labels(
        bg, src, dst, np.asarray(active)[None], mesh, use_pallas, comm,
    )
    return labels[0]


def oracle(
    src: np.ndarray, dst: np.ndarray, active: np.ndarray, num_vertices: int
) -> np.ndarray:
    """Union-find oracle; labels = min vertex id per component."""
    parent = np.arange(num_vertices)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v, a in zip(src, dst, active):
        if a > 0:
            ru, rv = find(int(u)), find(int(v))
            if ru != rv:
                parent[max(ru, rv)] = min(ru, rv)
    return np.array([find(int(i)) for i in range(num_vertices)], np.int64)
