"""Connected components per instance (independent pattern) — the classic
label-propagation workload; exercises min-plus with 0/inf weights.

Used by tests as a structural invariant check (components of the blocked
path must match union-find on the host) and by the benchmark suite as a
second independent-pattern application beside PageRank.
"""
from __future__ import annotations

import numpy as np

from repro.core.blocked import BlockedGraph
from repro.core.semiring import INF


def symmetrized_blocked(
    bg: BlockedGraph, src: np.ndarray, dst: np.ndarray
) -> BlockedGraph:
    """Blocked structure over the doubled (undirected) edge list, same
    partitioning — labels propagate both ways through min-plus."""
    from repro.core.blocked import build_blocked
    from repro.core.graph import GraphTemplate

    tmpl2 = GraphTemplate(
        num_vertices=len(bg.part_of),
        src=np.concatenate([src, dst]),
        dst=np.concatenate([dst, src]),
    )
    return build_blocked(tmpl2, bg.part_of, bg.block_size)


def run_blocked_temporal(
    bg: BlockedGraph,
    src: np.ndarray,
    dst: np.ndarray,
    instance_active: np.ndarray,  # (I, E) 0/1 per instance
    *,
    mesh=None,
    use_pallas: bool = False,
    comm="dense",
) -> np.ndarray:
    """Components of EVERY instance (independent pattern) through the
    unified temporal engine.  ``comm`` selects the boundary exchange
    backend (min-plus: bitwise identical across backends).  Returns
    (I, V) int64 labels."""
    from repro.core.engine import TemporalEngine, label_init, min_plus_program

    bg2 = symmetrized_blocked(bg, src, dst)
    w = np.where(instance_active > 0, 0.0, INF).astype(np.float32)
    w2 = np.concatenate([w, w], axis=1)  # both orientations
    eng = TemporalEngine(bg2, mesh=mesh, use_pallas=use_pallas, comm=comm)
    prog = min_plus_program(
        "components", init=label_init(), max_supersteps=256,
    )
    res = eng.run(prog, w2, pattern="independent")
    return res.values.astype(np.int64)


def run_blocked(
    bg: BlockedGraph,
    src: np.ndarray,
    dst: np.ndarray,
    active: np.ndarray,  # (E,) 0/1 — edges active in this instance
    *,
    mesh=None,
    use_pallas: bool = False,
    comm="dense",
) -> np.ndarray:
    """Min-label propagation over UNDIRECTED active edges of one instance.
    Returns (V,) component labels (min vertex id in component)."""
    labels = run_blocked_temporal(
        bg, src, dst, np.asarray(active)[None], mesh=mesh,
        use_pallas=use_pallas, comm=comm,
    )
    return labels[0]


def oracle(
    src: np.ndarray, dst: np.ndarray, active: np.ndarray, num_vertices: int
) -> np.ndarray:
    """Union-find oracle; labels = min vertex id per component."""
    parent = np.arange(num_vertices)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v, a in zip(src, dst, active):
        if a > 0:
            ru, rv = find(int(u)), find(int(v))
            if ru != rv:
                parent[max(ru, rv)] = min(ru, rv)
    return np.array([find(int(i)) for i in range(num_vertices)], np.int64)
