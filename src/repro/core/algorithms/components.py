"""Connected components per instance (independent pattern) — the classic
label-propagation workload; exercises min-plus with 0/inf weights.

Used by tests as a structural invariant check (components of the blocked
path must match union-find on the host) and by the benchmark suite as a
second independent-pattern application beside PageRank.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core.blocked import BlockedGraph
from repro.core.semiring import INF, MIN_PLUS
from repro.core.superstep import Comm, bsp_fixpoint, device_graph


def run_blocked(
    bg: BlockedGraph,
    src: np.ndarray,
    dst: np.ndarray,
    active: np.ndarray,  # (E,) 0/1 — edges active in this instance
    *,
    comm: Comm = Comm(),
    use_pallas: bool = False,
) -> np.ndarray:
    """Min-label propagation over UNDIRECTED active edges.  Returns (V,)
    component labels (min vertex id in component)."""
    V = len(bg.part_of)
    # symmetrize: propagate labels both ways
    w = np.where(active > 0, 0.0, INF).astype(np.float32)
    # build a temporary blocked graph over the symmetrized edge set by
    # filling both orientations: run on a doubled edge list
    from repro.core.graph import GraphTemplate
    from repro.core.blocked import build_blocked

    tmpl2 = GraphTemplate(
        num_vertices=V,
        src=np.concatenate([src, dst]),
        dst=np.concatenate([dst, src]),
    )
    bg2 = build_blocked(tmpl2, bg.part_of, bg.block_size)
    w2 = np.concatenate([w, w])
    dg = device_graph(bg2, bg2.fill_local(w2), bg2.fill_boundary(w2))
    labels0 = np.arange(V, dtype=np.float32)
    x0 = jnp.asarray(bg2.scatter_vertex(labels0, INF))
    x, _ = bsp_fixpoint(x0, dg, MIN_PLUS, comm=comm, use_pallas=use_pallas,
                        max_supersteps=256)
    return bg2.gather_vertex(np.asarray(x)).astype(np.int64)


def oracle(
    src: np.ndarray, dst: np.ndarray, active: np.ndarray, num_vertices: int
) -> np.ndarray:
    """Union-find oracle; labels = min vertex id per component."""
    parent = np.arange(num_vertices)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v, a in zip(src, dst, active):
        if a > 0:
            ru, rv = find(int(u)), find(int(v))
            if ru != rv:
                parent[max(ru, rv)] = min(ru, rv)
    return np.array([find(int(i)) for i in range(num_vertices)], np.int64)
