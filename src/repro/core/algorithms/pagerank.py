"""PageRank per instance (paper §VI-A): independent pattern.

Each graph instance is ranked independently, considering only edges *active*
in that instance (``isExists``-style activity flag / observed in a trace).
The host path runs the vertex-value iteration through the iBSP engine
(independent pattern — temporal concurrency across instances); the blocked
path runs plus-mul supersteps, instances vmapped/sharded over the mesh
``data`` axis.

Specification (both paths + oracle): power iteration of
    r' = (1-d)/N + d * A_w^T r,   A_w[u,v] = active(u,v)/outdeg_active(u)
without dangling-mass redistribution, ``iters`` fixed steps.
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, Tuple

import numpy as np

from repro.core.blocked import BlockedGraph
from repro.core.ibsp import ComputeContext, InstanceProvider, run_ibsp
from repro.gopher.registry import register_analytic

ACTIVE_ATTR = "active"


def edge_weights_for_instance(
    src: np.ndarray, active: np.ndarray, num_vertices: int
) -> np.ndarray:
    """w(u, v) = active / outdeg_active(u)."""
    deg = np.zeros(num_vertices, np.float64)
    np.add.at(deg, src, active.astype(np.float64))
    w = np.where(deg[src] > 0, active / np.maximum(deg[src], 1e-30), 0.0)
    return w.astype(np.float32)


def edge_weights_for_instances(
    src: np.ndarray, active: np.ndarray, num_vertices: int
) -> np.ndarray:
    """Vectorized over the instance axis: (I, E) activity -> (I, E) weights
    (one bincount scatter for the whole collection, no per-instance loop)."""
    I = active.shape[0]
    deg = np.zeros((I, num_vertices), np.float64)
    np.add.at(deg, (np.arange(I)[:, None], src[None, :]),
              active.astype(np.float64))
    d = deg[:, src]
    w = np.where(d > 0, active / np.maximum(d, 1e-30), 0.0)
    return w.astype(np.float32)


# --------------------------------------------------------------------------
# Faithful host implementation through the iBSP engine
# --------------------------------------------------------------------------

def make_compute(num_vertices: int, damping: float = 0.85, iters: int = 30):
    """Vertex-value PageRank as an iBSP Compute (independent pattern).

    Superstep k computes iteration k; boundary contributions move through
    SendToSubgraph messages; results are reported to merge.
    """
    results: Dict[Tuple[int, int], np.ndarray] = {}  # (timestep, sgid) -> r
    state: Dict[Tuple[int, int], Dict[str, np.ndarray]] = {}

    def compute(ctx: ComputeContext) -> None:
        topo = ctx.subgraph.topology
        key = (ctx.timestep, topo.sgid)
        n = topo.num_vertices
        active_l = ctx.subgraph.local_edge_values[ACTIVE_ATTR]
        active_r = ctx.subgraph.remote_edge_values[ACTIVE_ATTR]
        deg = ctx.subgraph.vertex_values["outdeg_active"]  # precomputed (n,)

        if ctx.superstep == 1:
            r = np.full(n, 1.0 / num_vertices, np.float64)
            state[key] = {"r": r}
        st = state[key]
        r = st["r"]

        # contributions: local edges + incoming boundary messages
        contrib = np.zeros(n, np.float64)
        share = np.where(deg > 0, r / np.maximum(deg, 1e-30), 0.0)
        np.add.at(contrib, topo.local_dst, share[topo.local_src] * active_l)
        for v_global, c in ctx.messages:
            contrib[topo.global_to_local[int(v_global)]] += c

        if ctx.superstep > 1:
            r = (1.0 - damping) / num_vertices + damping * contrib
            st["r"] = r
            share = np.where(deg > 0, r / np.maximum(deg, 1e-30), 0.0)

        if ctx.superstep <= iters:
            # publish shares over remote edges for the NEXT superstep
            for i in range(len(topo.remote_src)):
                if active_r[i] > 0:
                    s = int(topo.remote_src[i])
                    ctx.send_to_subgraph(
                        int(topo.remote_dst_sgid[i]),
                        (int(topo.remote_dst_vertex[i]), share[s] * active_r[i]),
                    )
        else:
            results[key] = r.copy()
            ctx.send_message_to_merge((ctx.timestep, topo.sgid, r.copy()))
            ctx.vote_to_halt()

    compute.results = results
    return compute


def run_host(
    provider: InstanceProvider,
    num_vertices: int,
    *,
    damping: float = 0.85,
    iters: int = 30,
    workers: int = 0,
) -> Tuple[Dict[Tuple[int, int], np.ndarray], Any]:
    compute = make_compute(num_vertices, damping, iters)
    res = run_ibsp(provider, compute, pattern="independent", workers=workers)
    return compute.results, res


# --------------------------------------------------------------------------
# Blocked TPU implementation: registered Gopher analytic
# --------------------------------------------------------------------------

def _pagerank_weights(session, raw: np.ndarray) -> np.ndarray:
    """Staging transform: (I, E) activity -> outdegree-normalized edge
    weights (named so the shared-staging key distinguishes it from the
    raw attribute)."""
    assert session.src is not None, \
        "pagerank derives weights from topology: pass src= to from_blocked"
    return edge_weights_for_instances(
        session.src, np.asarray(raw), len(session.bg.part_of)
    )


def _postprocess(ctx, res, **_params):
    return {"ranks": res.values}


@register_analytic(
    "pagerank",
    pattern="independent",
    attr=ACTIVE_ATTR,
    zero_fill=0.0,
    params={"damping": 0.85, "iters": 30},
    weights=_pagerank_weights,
    # outdegree normalization reads one instance's activity row at a
    # time — safe to apply chunk-wise on the prefetcher thread
    rowwise=True,
    postprocess=_postprocess,
    describe="per-instance PageRank over active edges: independent "
             "pattern, fixed-count plus-mul iteration",
)
def _pagerank_program(ctx, *, damping, iters):
    """Program factory for the ``"pagerank"`` analytic."""
    from repro.core.engine import pagerank_program

    return pagerank_program(ctx.num_vertices, damping=damping, iters=iters)


def run_blocked(
    bg: BlockedGraph,
    src: np.ndarray,  # (E,) template edge sources (for outdeg weights)
    instance_active: np.ndarray,  # (I, E) 0/1 activity per instance
    *,
    num_vertices: int,
    damping: float = 0.85,
    iters: int = 30,
    mesh=None,
    use_pallas: bool = False,
    comm="dense",
) -> Tuple[np.ndarray, np.ndarray]:
    """Deprecated: use the Gopher session API —
    ``GopherSession.from_blocked(bg, weights={"active": a}, src=src).run(
    session.plan("pagerank", iters=...))`` (``repro.gopher``).  Pins the
    legacy knobs (dense layout, sync staging); results are identical to
    the session path.  Returns (ranks (I, V), supersteps (I,))."""
    warnings.warn(
        "pagerank.run_blocked is deprecated; use repro.gopher."
        "GopherSession (session.run(session.plan('pagerank', ...)))",
        DeprecationWarning, stacklevel=2,
    )
    from repro.gopher import GopherSession

    assert num_vertices == len(bg.part_of), \
        "num_vertices must match the blocked template"
    sess = GopherSession.from_blocked(
        bg, weights={ACTIVE_ATTR: instance_active}, src=src,
        mesh=mesh, use_pallas=use_pallas,
    )
    res = sess.run(sess.plan(
        "pagerank", damping=damping, iters=iters,
        layout="dense", comm=comm, staging="sync",
    ))
    return res.output["ranks"], res.engine.stats["supersteps"]


# --------------------------------------------------------------------------
# numpy oracle
# --------------------------------------------------------------------------

def oracle(
    src: np.ndarray, dst: np.ndarray, active: np.ndarray,
    num_vertices: int, damping: float = 0.85, iters: int = 30,
) -> np.ndarray:
    w = edge_weights_for_instance(src, active, num_vertices).astype(np.float64)
    r = np.full(num_vertices, 1.0 / num_vertices, np.float64)
    for _ in range(iters):
        contrib = np.zeros(num_vertices, np.float64)
        np.add.at(contrib, dst, r[src] * w)
        r = (1.0 - damping) / num_vertices + damping * contrib
    return r
