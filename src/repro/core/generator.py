"""Synthetic time-series graph generator shaped like the paper's TR dataset
(§VI-A): small-world topology with power-law-ish subgraph size spread, 7
vertex + 7 edge attributes of mixed types, per-instance values.

Deterministic in (config.seed): the same config always yields the same
collection — the data-pipeline determinism contract extended to graphs.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.configs.base import GraphConfig
from repro.core.graph import (
    AttributeDef,
    GraphInstance,
    GraphTemplate,
    TimeSeriesGraph,
)

VERTEX_ATTRS = (
    AttributeDef("plate", "int32", default=-1),
    AttributeDef("obs_count", "int32", default=0),
    AttributeDef("outdeg_active", "float32", default=0.0),
    AttributeDef("ip_class", "int32", constant=3),
    AttributeDef("is_router", "int32", default=0),
    AttributeDef("load", "float32", default=0.0),
    AttributeDef("uptime", "float32", default=1.0),
)

EDGE_ATTRS = (
    AttributeDef("latency", "float32", default=1.0),
    AttributeDef("bandwidth", "float32", default=100.0),
    AttributeDef("active", "float32", default=1.0),
    AttributeDef("loss", "float32", default=0.0),
    AttributeDef("hops_seen", "int32", default=0),
    AttributeDef("mtu", "int32", constant=1500),
    AttributeDef("jitter", "float32", default=0.0),
)


def generate_template(cfg: GraphConfig) -> GraphTemplate:
    """Hub-and-spoke small-world digraph: preferential attachment backbone
    (gives the inverse subgraph-size/count correlation of Fig. 5) + random
    long-range links."""
    rng = np.random.default_rng(cfg.seed)
    V = cfg.num_vertices
    E = int(V * cfg.avg_degree)
    # preferential-attachment-ish: new vertex links to ~zipf earlier vertex
    tail = rng.integers(1, V, size=E)
    zipf_like = np.minimum(
        (tail * rng.random(E) ** 2.5).astype(np.int64), tail - 1
    )
    src = np.concatenate([tail, zipf_like[: E // 4]])
    dst = np.concatenate([zipf_like, tail[: E // 4]])
    # dedupe + drop self loops
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src * V + dst
    _, idx = np.unique(key, return_index=True)
    src, dst = src[np.sort(idx)], dst[np.sort(idx)]
    return GraphTemplate(
        num_vertices=V,
        src=src.astype(np.int64),
        dst=dst.astype(np.int64),
        vertex_attrs=VERTEX_ATTRS,
        edge_attrs=EDGE_ATTRS,
        name=cfg.name,
    )


def generate_instances(
    cfg: GraphConfig, template: GraphTemplate, *, num_plates: int = 32
) -> List[GraphInstance]:
    """Per-instance values; diurnal latency pattern + random vehicle walk."""
    rng = np.random.default_rng(cfg.seed + 1)
    V, E = template.num_vertices, template.num_edges
    out: List[GraphInstance] = []
    # vehicles do random walks on the graph; plate i at some vertex per t
    plate_pos = rng.integers(0, V, size=num_plates)
    indptr, indices = template.undirected_adjacency()
    for t in range(cfg.num_instances):
        phase = 2 * np.pi * t / max(cfg.num_instances, 1)
        lat = (
            50.0
            + 30.0 * np.sin(phase)
            + rng.gamma(2.0, 10.0, size=E)
        ).astype(np.float32)
        active = (rng.random(E) < 0.8).astype(np.float32)
        plates = np.full(V, -1, np.int32)
        for i in range(num_plates):
            v = int(plate_pos[i])
            plates[v] = i
            deg = indptr[v + 1] - indptr[v]
            if deg > 0:
                plate_pos[i] = int(indices[indptr[v] + rng.integers(0, deg)])
        deg_active = np.zeros(V, np.float32)
        np.add.at(deg_active, template.src, active)
        out.append(
            GraphInstance(
                timestamp=float(t * 7200),
                duration=7200.0,
                vertex_values={
                    "plate": plates,
                    "obs_count": rng.poisson(2.0, V).astype(np.int32),
                    "outdeg_active": deg_active,
                    "is_router": (rng.random(V) < 0.1).astype(np.int32),
                    "load": rng.random(V).astype(np.float32),
                    "uptime": np.minimum(
                        1.0, rng.random(V) + 0.5
                    ).astype(np.float32),
                },
                edge_values={
                    "latency": lat,
                    "bandwidth": rng.gamma(3.0, 30.0, size=E).astype(np.float32),
                    "active": active,
                    "loss": (rng.random(E) * 0.05).astype(np.float32),
                    "hops_seen": rng.poisson(1.0, E).astype(np.int32),
                    "jitter": rng.gamma(1.0, 2.0, size=E).astype(np.float32),
                },
            )
        )
    return out


def generate_collection(cfg: GraphConfig, **kw) -> TimeSeriesGraph:
    template = generate_template(cfg)
    return TimeSeriesGraph(template, generate_instances(cfg, template, **kw))
