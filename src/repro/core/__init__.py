"""GoFFish core: time-series graph model, partitioning, blocked layout,
sub-graph-centric iBSP engines (host-faithful + TPU-blocked), algorithms."""
from repro.core.graph import (
    AttributeDef,
    GraphInstance,
    GraphTemplate,
    TimeSeriesGraph,
)
from repro.core.ibsp import (
    ComputeContext,
    IBSPResult,
    InMemoryProvider,
    InstanceProvider,
    MergeContext,
    SubgraphInstance,
    run_ibsp,
)
from repro.core.partition import (
    build_partitions,
    discover_subgraphs,
    edge_cut,
    partition_graph,
)
from repro.core.semiring import MIN_PLUS, PLUS_MUL, Semiring
from repro.core.subgraph import SubgraphTopology, build_subgraphs
from repro.core.superstep import Comm, DeviceGraph, bsp_fixpoint, device_graph

__all__ = [
    "AttributeDef", "GraphInstance", "GraphTemplate", "TimeSeriesGraph",
    "ComputeContext", "IBSPResult", "InMemoryProvider", "InstanceProvider",
    "MergeContext", "SubgraphInstance", "run_ibsp",
    "build_partitions", "discover_subgraphs", "edge_cut", "partition_graph",
    "MIN_PLUS", "PLUS_MUL", "Semiring",
    "SubgraphTopology", "build_subgraphs",
    "Comm", "DeviceGraph", "bsp_fixpoint", "device_graph",
]
