"""Time-series graph model (paper §III-A).

Γ = ⟨Ĝ, G⟩: a *template* Ĝ = (V̂, Ê) holding the slow-changing topology and
the attribute *schemas*, and a time-ordered list of *instances* gᵗ holding
attribute *values* for every vertex/edge at time window t.  |Vᵗ| = |V̂| and
|Eᵗ| = |Ê| for all t; the special ``isExists`` attribute simulates slow
appearance/disappearance of vertices/edges.

Host-side representation is flat numpy (CSR-ish edge list); the TPU-facing
blocked representation lives in ``repro.core.blocked``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

IS_EXISTS = "isExists"


@dataclass(frozen=True)
class AttributeDef:
    """Typed attribute schema entry (paper: typed name-value pairs)."""

    name: str
    dtype: str = "float32"
    default: Optional[float] = None  # template-level default (overridable)
    constant: Optional[float] = None  # template-level constant (not overridable)

    def fill_value(self) -> float:
        if self.constant is not None:
            return self.constant
        if self.default is not None:
            return self.default
        return 0.0


@dataclass
class GraphTemplate:
    """Ĝ: topology + attribute schemas.  Edges are directed (src -> dst)."""

    num_vertices: int
    src: np.ndarray  # (E,) int64 source vertex ids
    dst: np.ndarray  # (E,) int64 destination vertex ids
    vertex_attrs: Tuple[AttributeDef, ...] = ()
    edge_attrs: Tuple[AttributeDef, ...] = ()
    name: str = "graph"

    @property
    def num_edges(self) -> int:
        return len(self.src)

    def __post_init__(self):
        assert self.src.shape == self.dst.shape
        if self.num_edges:
            assert int(self.src.max()) < self.num_vertices
            assert int(self.dst.max()) < self.num_vertices

    def vertex_attr(self, name: str) -> AttributeDef:
        for a in self.vertex_attrs:
            if a.name == name:
                return a
        raise KeyError(name)

    def edge_attr(self, name: str) -> AttributeDef:
        for a in self.edge_attrs:
            if a.name == name:
                return a
        raise KeyError(name)

    def out_degree(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.num_vertices)

    def undirected_adjacency(self) -> "csr_like":
        """(indptr, indices) over the symmetrized edge set (for partitioning
        and subgraph discovery, which the paper defines on connectivity)."""
        s = np.concatenate([self.src, self.dst])
        d = np.concatenate([self.dst, self.src])
        order = np.argsort(s, kind="stable")
        s, d = s[order], d[order]
        indptr = np.zeros(self.num_vertices + 1, np.int64)
        np.add.at(indptr, s + 1, 1)
        indptr = np.cumsum(indptr)
        return indptr, d


@dataclass
class GraphInstance:
    """gᵗ: attribute values for one time window [t_start, t_end)."""

    timestamp: float
    duration: float
    vertex_values: Dict[str, np.ndarray] = field(default_factory=dict)  # (V,)
    edge_values: Dict[str, np.ndarray] = field(default_factory=dict)  # (E,)

    @property
    def t_end(self) -> float:
        return self.timestamp + self.duration


class TimeSeriesGraph:
    """Γ: template + time-ordered instances (in-memory collection).

    The GoFS store (repro.gofs) persists/loads the same logical model; this
    class is the programming-model-facing view with value inheritance
    (instance value > template default > template constant).
    """

    def __init__(self, template: GraphTemplate, instances: Sequence[GraphInstance]):
        self.template = template
        self.instances = sorted(instances, key=lambda g: g.timestamp)
        ts = [g.timestamp for g in self.instances]
        assert ts == sorted(ts)

    def __len__(self) -> int:
        return len(self.instances)

    def vertex_values(self, t_idx: int, name: str) -> np.ndarray:
        """Instance value with template default/constant inheritance."""
        a = self.template.vertex_attr(name)
        inst = self.instances[t_idx]
        if a.constant is None and name in inst.vertex_values:
            return inst.vertex_values[name]
        return np.full(self.template.num_vertices, a.fill_value(),
                       np.dtype(a.dtype))

    def edge_values(self, t_idx: int, name: str) -> np.ndarray:
        a = self.template.edge_attr(name)
        inst = self.instances[t_idx]
        if a.constant is None and name in inst.edge_values:
            return inst.edge_values[name]
        return np.full(self.template.num_edges, a.fill_value(), np.dtype(a.dtype))

    def time_range(self) -> Tuple[float, float]:
        return self.instances[0].timestamp, self.instances[-1].t_end

    def filter_time(self, t_start: float, t_end: float) -> List[int]:
        """Indices of instances overlapping [t_start, t_end) (paper §V-B)."""
        return [
            i for i, g in enumerate(self.instances)
            if g.timestamp < t_end and g.t_end > t_start
        ]
