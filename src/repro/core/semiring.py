"""Semirings for blocked graph linear algebra.

The paper's sub-graph-centric ``Compute`` runs irregular shared-memory
algorithms (Dijkstra, DFS) inside each subgraph.  The TPU adaptation
(DESIGN.md §2) re-expresses those traversals as iterated *semiring SpMV*
over dense adjacency tiles:

* SSSP / temporal traversal  ->  (min, +)  with identity +inf
* reachability / frontier    ->  (or, and) realized as (min, +) on 0/inf
* connected components       ->  (min, min-label propagate)
* PageRank / centrality      ->  (+, x)    with identity 0

``idempotent`` marks semirings where applying the same relaxation twice is
harmless — those support the paper's subgraph-centric *local convergence*
inside one superstep (Gopher's key trade: more local work per message).
Non-idempotent semirings (PageRank) take exactly one SpMV per superstep.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Semiring:
    name: str
    zero: float  # identity of ``add`` (annihilator of ``mul``)
    one: float  # identity of ``mul``
    idempotent: bool

    # y = add-reduce_i mul(x_i, w_i)
    def mul(self, x: jax.Array, w: jax.Array) -> jax.Array:
        raise NotImplementedError

    def add_reduce(self, x: jax.Array, axis: int) -> jax.Array:
        raise NotImplementedError

    def add(self, a: jax.Array, b: jax.Array) -> jax.Array:
        raise NotImplementedError

    def scatter_add(self, y: jax.Array, idx: jax.Array, vals: jax.Array) -> jax.Array:
        """y[idx] <- add(y[idx], vals) with duplicate indices combined."""
        raise NotImplementedError

    def segment_reduce(
        self, vals: jax.Array, segment_ids: jax.Array, num_segments: int
    ) -> jax.Array:
        """add-reduce ``vals`` rows into ``num_segments`` buckets; empty
        segments hold the semiring zero.  The packed-tile SpMV fallback
        folds each output block's active-tile partials through this."""
        raise NotImplementedError

    def full(self, shape, dtype=jnp.float32) -> jax.Array:
        return jnp.full(shape, self.zero, dtype)


class _MinPlus(Semiring):
    def mul(self, x, w):
        return x + w

    def add_reduce(self, x, axis):
        return jnp.min(x, axis=axis)

    def add(self, a, b):
        return jnp.minimum(a, b)

    def scatter_add(self, y, idx, vals):
        return y.at[idx].min(vals)

    def segment_reduce(self, vals, segment_ids, num_segments):
        return jax.ops.segment_min(vals, segment_ids,
                                   num_segments=num_segments)


class _PlusMul(Semiring):
    def mul(self, x, w):
        return x * w

    def add_reduce(self, x, axis):
        return jnp.sum(x, axis=axis)

    def add(self, a, b):
        return a + b

    def scatter_add(self, y, idx, vals):
        return y.at[idx].add(vals)

    def segment_reduce(self, vals, segment_ids, num_segments):
        return jax.ops.segment_sum(vals, segment_ids,
                                   num_segments=num_segments)


INF = float(np.inf)

MIN_PLUS = _MinPlus("min_plus", zero=INF, one=0.0, idempotent=True)
PLUS_MUL = _PlusMul("plus_mul", zero=0.0, one=1.0, idempotent=False)

# Label propagation (connected components, reachability) IS min-plus with
# 0/inf edge weights: label + 0 flows, label + inf is blocked.  No separate
# semiring needed.

SEMIRINGS = {s.name: s for s in (MIN_PLUS, PLUS_MUL)}
