"""Resumable long-running analytics: periodic engine-state snapshots.

A collection-scale analytic pass can outlive a worker lease.  This module
makes the pass restartable without changing its result: the instance axis
is consumed in fixed spans, and after every ``every`` spans the run's
engine state — the pattern carry (converged state seeding the next span),
the accumulated per-instance values, the superstep counters, and the
staging cursor — is snapshotted through the SAME atomic-rename/retention
machinery training checkpoints use (:mod:`repro.train.checkpoint`):

* a crash mid-save never corrupts the previous snapshot (tmp dir + fsync
  + rename; ``list_steps`` skips uncommitted dirs);
* retention keeps the newest K snapshots;
* a resumed run re-executes only the spans past the cursor, seeded from
  the snapshotted carry — and because chunking a pattern scan is exact
  (each instance sees the identical seed and staged tiles), the resumed
  result is **bitwise identical** to the uninterrupted run.

Snapshots carry a *run fingerprint* (analytic, params, pattern, span
size, collection length).  Resuming against a snapshot from a different
run raises :class:`ResumeMismatch` instead of silently blending state.

Multi-process runs snapshot from process 0 only: engine results are
already globally gathered on every process (identical bytes), and the
fingerprint pins the process count so a resumed run re-shards the same
way.  ``GopherSession.run(plan, checkpoint_dir=..., resume=True)`` is the
user-facing entry (:mod:`repro.gopher.session`).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.train import checkpoint as _ckpt


class ResumeMismatch(RuntimeError):
    """A resume attempted against a snapshot of a DIFFERENT run (analytic,
    params, pattern, chunking, or collection length changed)."""


class AnalyticCheckpointer:
    """Atomic snapshots of one analytic run's engine state.

    Thin wrapper over :mod:`repro.train.checkpoint`: ``save`` commits the
    state dict under ``step_<cursor>`` with the run fingerprint in the
    manifest; ``latest`` loads the newest COMMITTED snapshot (torn tmp
    dirs are invisible) and verifies the fingerprint.

    >>> import numpy as np, tempfile
    >>> d = tempfile.mkdtemp()
    >>> ck = AnalyticCheckpointer(d)
    >>> fp = {"analytic": "sssp", "chunk": 2}
    >>> _ = ck.save(2, {"final": np.zeros(3, np.float32)}, fp)
    >>> state, cursor = ck.latest(fp)
    >>> cursor, state["final"].shape
    (2, (3,))
    >>> try:
    ...     ck.latest({"analytic": "pagerank", "chunk": 2})
    ... except ResumeMismatch:
    ...     print("different run refused")
    different run refused
    """

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep

    def save(self, cursor: int, state: Dict[str, np.ndarray],
             fingerprint: Dict[str, Any]) -> str:
        """Atomically commit ``state`` at staging cursor ``cursor``."""
        return _ckpt.save(
            self.ckpt_dir, cursor, state, keep=self.keep,
            extra_meta={"fingerprint": _canon(fingerprint)},
        )

    def latest(
        self, fingerprint: Optional[Dict[str, Any]] = None,
    ) -> Optional[Tuple[Dict[str, np.ndarray], int]]:
        """Newest committed ``(state, cursor)``; ``None`` when the
        directory holds no committed snapshot.  Raises
        :class:`ResumeMismatch` when the stored fingerprint differs from
        ``fingerprint`` — resuming a different run would blend state."""
        steps = _ckpt.list_steps(self.ckpt_dir)
        if not steps:
            return None
        d = os.path.join(self.ckpt_dir, f"step_{steps[-1]:08d}")
        with open(os.path.join(d, _ckpt.MANIFEST)) as f:
            manifest = json.load(f)
        if fingerprint is not None:
            got = manifest.get("extra", {}).get("fingerprint")
            want = _canon(fingerprint)
            if got != want:
                raise ResumeMismatch(
                    f"checkpoint in {self.ckpt_dir} belongs to a different "
                    f"run: {got!r} != {want!r}")
        state = {
            name: np.load(os.path.join(d, meta["file"]))
            for name, meta in manifest["leaves"].items()
        }
        return state, int(manifest["step"])


def _canon(fp: Dict[str, Any]) -> Dict[str, Any]:
    """JSON round-trip so saved and in-memory fingerprints compare equal
    (tuples become lists, ints stay ints)."""
    return json.loads(json.dumps(fp, sort_keys=True))


def run_fingerprint(plan, num_instances: int, chunk: int,
                    num_processes: int = 1) -> Dict[str, Any]:
    """What must match for a snapshot to seed this run."""
    from repro.gopher.session import _freeze_value

    return {
        "analytic": plan.analytic,
        "params": repr(_freeze_value(plan.param_dict)),
        "pattern": plan.pattern,
        "merge": plan.merge,
        "warm": bool(plan.warm.value),
        "num_instances": int(num_instances),
        "chunk": int(chunk),
        "num_processes": int(num_processes),
    }


class ResumableRun:
    """One checkpointed analytic pass over a session's collection.

    Executes ``plan`` span by span through the session's engine (the
    spans chain exactly like the engine's own chunked scan, so the
    combined result is bitwise-identical to ``session.run(plan)``),
    snapshotting after every ``every`` spans and after the final one.
    ``run(resume=True)`` skips the spans a prior snapshot already
    covered.

    Patterns: ``sequential`` (the carry IS the pattern), ``independent``
    (cold spans are trivially exact; warm plans chain the seed across
    spans under the same monotone contract as ``RunSpec.warm_start``),
    and ``eventually`` without an on-device merge.  Composite analytics
    and ``merge="mean"`` plans have no single resumable engine pass.
    """

    def __init__(self, session, plan, *, checkpoint_dir: str,
                 every: int = 1, keep: int = 3,
                 chunk_instances: Optional[int] = None):
        from repro.gopher.registry import get_analytic

        self.session = session
        self.plan = plan
        self.analytic = get_analytic(plan.analytic)
        assert not self.analytic.composite, \
            f"{plan.analytic!r} is composite: no single engine pass to " \
            f"checkpoint"
        assert plan.pattern in ("sequential", "independent") or (
            plan.pattern == "eventually" and plan.merge is None), \
            f"pattern {plan.pattern!r}/merge {plan.merge!r} has no exact " \
            f"span decomposition"
        self.every = max(1, int(every))
        self.checkpointer = AnalyticCheckpointer(checkpoint_dir, keep=keep)
        w = session._staged_weights(self.analytic)
        self.weights = w if w.ndim > 1 else w[None]
        I = self.weights.shape[0]
        self.chunk = int(chunk_instances or max(1, -(-I // 4)))
        self.spans = [(s, min(s + self.chunk, I))
                      for s in range(0, I, self.chunk)]
        rt = getattr(session, "cluster", None)
        self.runtime = rt if (rt is not None and rt.is_distributed) else None
        self.fingerprint = run_fingerprint(
            plan, I, self.chunk,
            self.runtime.num_processes if self.runtime else 1)

    def run(self, resume: bool = False):
        """Execute (or finish) the pass; returns the session-level
        :class:`~repro.gopher.session.AnalyticResult` over the FULL
        collection."""
        from repro.core.engine import EngineResult, RunSpec
        from repro.gopher.session import PlanContext, _StagingCache

        sess, plan, a = self.session, self.plan, self.analytic
        cache = sess._staging_cache if sess._staging_cache is not None \
            else _StagingCache()
        ctx = PlanContext(sess, plan, a, cache)
        program = a.make_program(ctx, **plan.param_dict)
        engine = sess._engine(plan.graph, plan.comm.value,
                              plan.kernel.value)
        warm = bool(plan.warm.value) and program.kind == "fixpoint"
        zero = float(a.zero_fill)

        cursor = 0
        vals, sss, lsws = [], [], []
        carry: Optional[np.ndarray] = None  # gathered (V,) / (Q, V) final
        if resume:
            got = self.checkpointer.latest(self.fingerprint)
            if got is not None:
                state, cursor = got
                carry = state["final"]
                vals, sss = [state["values"]], [state["supersteps"]]
                lsws = [state["local_sweeps"]]

        done = sum(1 for _, e in self.spans if e <= cursor)
        for s, e in self.spans:
            if e <= cursor:
                continue
            assert s >= cursor, \
                f"snapshot cursor {cursor} misaligned with span ({s}, {e})"
            chained = plan.pattern == "sequential" or warm
            if carry is not None and chained:
                spec = RunSpec(program, plan.pattern,
                               x0=engine.resume_seed(carry, pad=zero),
                               warm_start=warm)
            else:
                spec = RunSpec(program, plan.pattern, warm_start=warm)
            res = engine.run_many([spec], self.weights[s:e],
                                  staging="sync")[0]
            carry = np.asarray(res.final)
            vals.append(np.asarray(res.values))
            sss.append(np.asarray(res.stats["supersteps"]))
            lsws.append(np.asarray(res.stats["local_sweeps"]))
            cursor = e
            done += 1
            if done % self.every == 0 or cursor == self.spans[-1][1]:
                self._snapshot(cursor, carry, vals, sss, lsws)

        assert carry is not None, "empty collection"
        bg = engine.bg
        combined = EngineResult(
            pattern=plan.pattern,
            values=_cat(vals, axis=-2),
            final=carry,
            merged=None,
            stats={"supersteps": _cat(sss, axis=-1),
                   "local_sweeps": _cat(lsws, axis=-1)},
            occupancy=None,
            warm_start=warm,
            n_sources=carry.shape[0] if carry.ndim == 2 else None,
            _n_published=int(bg.n_out.sum()),
            _n_parts=bg.n_parts,
            _num_vertices=len(bg.part_of),
        )
        return sess._wrap(plan, a, combined, cache)

    def _snapshot(self, cursor, carry, vals, sss, lsws) -> None:
        """Commit the run state at ``cursor``.  Every process holds the
        identical gathered state, so process 0 writes for everyone; the
        barrier keeps a fast peer from racing ahead and snapshotting a
        LATER cursor into the same directory out of order."""
        if self.runtime is None or self.runtime.process_id == 0:
            self.checkpointer.save(cursor, {
                "final": np.asarray(carry),
                "values": _cat(vals, axis=-2),
                "supersteps": _cat(sss, axis=-1),
                "local_sweeps": _cat(lsws, axis=-1),
            }, self.fingerprint)
        if self.runtime is not None:
            self.runtime.barrier(f"ckpt/{cursor}")


def _cat(parts, axis: int) -> np.ndarray:
    return parts[0] if len(parts) == 1 else np.concatenate(parts, axis=axis)
