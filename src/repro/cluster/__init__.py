"""Multi-process GoFFish cluster runtime (paper §V deployment shape).

The paper's GoFFish runs on a commodity cluster: every worker hosts its
own GoFS partition slices and Gopher computes where the data lives.  This
package is that deployment layer for the blocked engine:

* :mod:`repro.cluster.runtime` — process bootstrap (``jax.distributed``
  when available, single-process no-op fallback) plus the rank-ordered
  TCP exchange every cross-process primitive rides on.
* :mod:`repro.cluster.staging` — shard-local staging: each process's
  :class:`~repro.gofs.prefetch.SlicePrefetcher` stages only its OWN
  partition shard of the collection (~1/num_processes of the bytes),
  with a cross-process consistency check on chunk boundaries.
* :mod:`repro.cluster.gather` — :class:`ClusterGather`, the real
  inter-process boundary exchange behind the ``_host_fold_*`` seam of
  ``repro.core.comm`` (bitwise-identical to the single-process fold).
* :mod:`repro.cluster.checkpoint` — periodic snapshots of long analytic
  runs (atomic-rename machinery from ``repro.train.checkpoint``) so a
  preempted worker resumes mid-collection bitwise-identically.
"""
from repro.cluster.checkpoint import AnalyticCheckpointer, ResumableRun
from repro.cluster.gather import ClusterGather
from repro.cluster.runtime import ClusterRuntime, init_cluster
from repro.cluster.staging import shard_staged_bytes, shard_stream

__all__ = [
    "AnalyticCheckpointer",
    "ClusterGather",
    "ClusterRuntime",
    "ResumableRun",
    "init_cluster",
    "shard_staged_bytes",
    "shard_stream",
]
