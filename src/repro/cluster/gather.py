"""Real inter-process boundary exchange behind the ``HostGather`` seam.

``repro.core.comm.HostGather`` folds the stacked (P, NB) publish buffer
on the host with a fixed-association left fold (``_host_fold_*``) — the
module has always documented that fold site as "where the MPI-style
gather slots in on a real multi-host cluster".  :class:`ClusterGather`
is that gather: each process folds ONLY its own partition shard's
(P_local, NB) buffer rows; the callback allgathers the shards over the
:class:`~repro.cluster.runtime.TcpExchange` in process-id order,
concatenates them back into the full (P, NB) buffer, and applies the
IDENTICAL ``_host_fold_*`` left fold on every host.

Because :meth:`ClusterRuntime.partition_shard` assigns contiguous
partition ranges in process-id order, the concatenation reconstructs the
exact single-process buffer — so the distributed combine is
**bitwise-identical** to the single-process fold, for min-plus AND
plus-mul (same 0..P-1 association, same IEEE f32 adds).

The halt vote (``any_changed``) becomes a cross-process OR: every
process's ``while_loop`` then runs the same superstep count — which is
both what makes the reported ``supersteps`` stats match the
single-process run and what keeps the per-superstep exchange
deadlock-free (no process exits the loop while others still expect its
buffers).  ``local_sweeps`` stays a per-process statistic: a shard
holding fewer partitions locally converges in fewer sweeps, and the
extra sweeps the single-process run performs on already-converged
partitions are idempotent no-ops — values are unaffected.

``sum_scalar`` (only the standalone ``pagerank_run`` tolerance driver
uses it; the engine's PageRank is fixed-iteration) sums the per-process
partials in rank order — associated differently than the single-process
``jnp.sum`` over all partitions, so tolerance-triggered halts may differ
in low-order bits there.  The engine paths the parity suite gates never
touch it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import (CommBackend, HostGather, _host_fold_min,
                             _host_fold_sum)
from repro.core.semiring import Semiring
from repro.cluster.runtime import ClusterRuntime


@dataclass(frozen=True)
class ClusterGather(CommBackend):
    """Inter-process boundary combine (see module docstring).

    Degrades exactly to :class:`~repro.core.comm.HostGather` when the
    runtime is single-process — same callback, same fold, zero network.

    >>> import numpy as np, jax.numpy as jnp
    >>> from repro.core.semiring import MIN_PLUS
    >>> from repro.cluster.runtime import ClusterRuntime
    >>> cg = ClusterGather(runtime=ClusterRuntime(0, 1))
    >>> buf = jnp.asarray([[0., 7., jnp.inf], [jnp.inf, 2., 5.]])
    >>> np.asarray(cg.combine_boundary(buf, MIN_PLUS))
    array([0., 2., 5.], dtype=float32)
    """

    name: str = "cluster"
    runtime: Optional[ClusterRuntime] = None

    def __post_init__(self):
        assert self.runtime is not None, "ClusterGather needs a runtime"
        assert self.axis_name is None, \
            "ClusterGather is mesh-free (stacked per-process shards)"

    def combine_boundary(self, buf: jax.Array, sr: Semiring) -> jax.Array:
        fold = _host_fold_sum if sr.name == "plus_mul" else _host_fold_min
        rt = self.runtime

        def exchange_fold(b) -> np.ndarray:
            full = rt.allgather_concat(
                np.asarray(b), axis=0, tag=f"combine/{sr.name}")
            return fold(full)

        return jax.pure_callback(
            exchange_fold,
            jax.ShapeDtypeStruct(buf.shape[1:], buf.dtype), buf,
        )

    def any_changed(self, flag: jax.Array) -> jax.Array:
        if not self.runtime.is_distributed:
            return flag
        rt = self.runtime

        def vote(f) -> np.ndarray:
            return np.asarray(rt.all_reduce_or(bool(f), tag="vote"))

        return jax.pure_callback(
            vote, jax.ShapeDtypeStruct((), jnp.bool_), flag)

    def sum_scalar(self, x: jax.Array) -> jax.Array:
        if not self.runtime.is_distributed:
            return x
        rt = self.runtime

        def ssum(v) -> np.ndarray:
            parts = rt.allgather("sum", np.asarray(v))
            out = parts[0]
            for p in parts[1:]:
                out = out + p
            return out

        return jax.pure_callback(
            ssum, jax.ShapeDtypeStruct(x.shape, x.dtype), x)


def cluster_comm(runtime: Optional[ClusterRuntime]) -> CommBackend:
    """The comm backend a cluster-placed engine should default to: the
    inter-process gather when distributed, plain ``HostGather`` (same
    fold, no exchange) single-process."""
    if runtime is not None and runtime.is_distributed:
        return ClusterGather(runtime=runtime)
    return HostGather()
