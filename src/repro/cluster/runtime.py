"""Process bootstrap + rank-ordered exchange for the cluster runtime.

The paper's GoFFish deployment (§V) is N commodity workers, each owning
one GoFS shard, coordinated over Ethernet.  This module is that shape for
the blocked engine:

* :func:`init_cluster` boots one process of an N-process run.  With
  ``num_processes == 1`` (the default when no coordinator is configured)
  it returns a no-op single-process runtime — every cluster-aware call
  site degrades to today's behavior, so the whole subsystem is inert
  unless explicitly launched.  Multi-process, it optionally initializes
  ``jax.distributed`` (coordinator address, process id/count — the real
  accelerator-cluster control plane) and always stands up the
  :class:`TcpExchange` the host-lane primitives ride on.
* :class:`TcpExchange` is a root-relayed, rank-ordered allgather over
  TCP: every process contributes one tagged payload per operation, the
  root (process 0) collects them in PROCESS-ID order and broadcasts the
  full list back.  Rank order is the load-bearing property — the
  boundary-fold seam (:class:`repro.cluster.gather.ClusterGather`)
  concatenates the per-process partition buffers in this order, which
  is exactly what makes the distributed fold bitwise-identical to the
  single-process ``_host_fold_*`` left fold.
* Operations are SEQUENCED: process k's i-th operation pairs with every
  other process's i-th operation, and the root verifies all N tags
  match before combining — a divergent schedule (one process staging a
  different chunk, or running a different analytic order) fails fast
  with the mismatching tags instead of silently folding unrelated
  buffers.  This is the cross-process consistency check the staging
  layer leans on at chunk boundaries.

The exchange moves ``2 * payload`` bytes per worker per op (up to root,
full list back) — the same O(num_boundary) per-superstep cost the
``HostGather`` byte model already charges for a host-side exchange.
"""
from __future__ import annotations

import os
import pickle
import socket
import struct
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

#: Environment knobs the worker entrypoint (``launch/cluster_graph.py``)
#: sets for each spawned process.
ENV_COORDINATOR = "GOFFISH_COORDINATOR"
ENV_NUM_PROCESSES = "GOFFISH_NUM_PROCESSES"
ENV_PROCESS_ID = "GOFFISH_PROCESS_ID"
ENV_TRANSPORT = "GOFFISH_TRANSPORT"

_LEN = struct.Struct("<Q")


def _send_frame(sock: socket.socket, obj: Any) -> None:
    data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(_LEN.pack(len(data)) + data)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("cluster exchange peer closed")
        buf.extend(chunk)
    return bytes(buf)


def _recv_frame(sock: socket.socket) -> Any:
    (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    return pickle.loads(_recv_exact(sock, n))


class ExchangeError(RuntimeError):
    """A cross-process schedule divergence (mismatched operation tags) or
    a dead peer.  Fail-fast by design: a divergent schedule would
    otherwise fold unrelated boundary buffers."""


class TcpExchange:
    """Root-relayed rank-ordered allgather among N processes.

    Process 0 listens; workers connect and identify themselves by
    process id.  Every :meth:`allgather` is one sequenced operation:
    all N processes must call it with the SAME tag, in the same order —
    the root verifies and relays, so results arrive in process-id order
    on every participant.
    """

    def __init__(self, process_id: int, num_processes: int, *,
                 timeout: float = 120.0):
        assert 0 <= process_id < num_processes
        self.process_id = process_id
        self.num_processes = num_processes
        self.timeout = timeout
        self._seq = 0
        self._lock = threading.Lock()
        self._closed = False
        self._listener: Optional[socket.socket] = None
        # root: sockets to workers 1..N-1 (index pid); worker: socket to root
        self._peers: Dict[int, socket.socket] = {}
        self._root_sock: Optional[socket.socket] = None

    # ------------------------------------------------------------ wiring
    @classmethod
    def listen(cls, port: int, num_processes: int, *, host: str = "",
               timeout: float = 120.0) -> "TcpExchange":
        """Process 0: bind, accept the N-1 workers, return the exchange."""
        ex = cls(0, num_processes, timeout=timeout)
        srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        srv.bind((host or "0.0.0.0", port))
        srv.listen(num_processes)
        srv.settimeout(timeout)
        ex._listener = srv
        for _ in range(num_processes - 1):
            conn, _addr = srv.accept()
            conn.settimeout(timeout)
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            hello = _recv_frame(conn)
            if not (isinstance(hello, tuple) and hello[0] == "hello"):
                raise ExchangeError(f"bad hello frame: {hello!r}")
            pid = int(hello[1])
            if pid in ex._peers or not (1 <= pid < num_processes):
                raise ExchangeError(f"duplicate/invalid worker id {pid}")
            ex._peers[pid] = conn
        return ex

    @classmethod
    def connect(cls, host: str, port: int, process_id: int,
                num_processes: int, *, timeout: float = 120.0,
                retry_for: float = 30.0) -> "TcpExchange":
        """Worker: dial the root (retrying while it boots) and say hello."""
        import time

        ex = cls(process_id, num_processes, timeout=timeout)
        deadline = time.monotonic() + retry_for
        while True:
            try:
                sock = socket.create_connection((host, port), timeout=timeout)
                break
            except OSError:
                if time.monotonic() >= deadline:
                    raise
                time.sleep(0.05)
        sock.settimeout(timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        _send_frame(sock, ("hello", process_id))
        ex._root_sock = sock
        return ex

    # --------------------------------------------------------- operations
    def allgather(self, tag: str, payload: Any) -> List[Any]:
        """All N processes contribute ``payload``; everyone receives the
        N payloads in process-id order.  Tags must match across processes
        (verified at the root) — the consistency check."""
        with self._lock:
            if self._closed:
                raise ExchangeError("exchange is closed")
            seq = self._seq
            self._seq += 1
            if self.process_id == 0:
                return self._root_gather(seq, tag, payload)
            return self._worker_gather(seq, tag, payload)

    def _root_gather(self, seq: int, tag: str, payload: Any) -> List[Any]:
        parts: List[Any] = [None] * self.num_processes
        parts[0] = payload
        tags = {0: tag}
        for pid in range(1, self.num_processes):
            frame = self._checked(_recv_frame(self._peers[pid]))
            fseq, ftag, fpayload = frame
            if fseq != seq:
                self._fail(f"process {pid} is at op {fseq}, root at {seq}")
            tags[pid] = ftag
            parts[pid] = fpayload
        if len(set(tags.values())) != 1:
            self._fail(f"divergent op tags at seq {seq}: {tags}")
        reply = ("ok", seq, parts)
        for pid in range(1, self.num_processes):
            _send_frame(self._peers[pid], reply)
        return parts

    def _worker_gather(self, seq: int, tag: str, payload: Any) -> List[Any]:
        _send_frame(self._root_sock, (seq, tag, payload))
        reply = self._checked(_recv_frame(self._root_sock))
        status, rseq, parts = reply
        if rseq != seq:
            raise ExchangeError(f"reply for op {rseq}, expected {seq}")
        return parts

    def _checked(self, frame: Any) -> Any:
        if isinstance(frame, tuple) and frame and frame[0] == "error":
            raise ExchangeError(frame[1])
        return frame

    def _fail(self, msg: str) -> None:
        err = ("error", msg)
        for sock in self._peers.values():
            try:
                _send_frame(sock, err)
            except OSError:
                pass
        raise ExchangeError(msg)

    def barrier(self, tag: str = "barrier") -> None:
        self.allgather(tag, None)

    # -------------------------------------------------------------- close
    def close(self) -> None:
        self._closed = True
        for sock in list(self._peers.values()):
            try:
                sock.close()
            except OSError:
                pass
        self._peers.clear()
        if self._root_sock is not None:
            try:
                self._root_sock.close()
            except OSError:
                pass
            self._root_sock = None
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
            self._listener = None


def shard_range(n_parts: int, process_id: int,
                num_processes: int) -> Tuple[int, int]:
    """The contiguous half-open partition range process ``process_id``
    owns out of ``n_parts`` partitions over ``num_processes`` processes.

    Contiguity in process-id order is what lets the gather seam
    re-assemble the global (P, NB) publish buffer by plain concatenation
    — the fold association (0..P-1) is then identical to the
    single-process stacked fold, hence bitwise-equal results.  Remainder
    partitions go to the lowest-id processes.

    >>> [shard_range(7, pid, 3) for pid in range(3)]
    [(0, 3), (3, 5), (5, 7)]
    """
    assert n_parts >= num_processes, \
        f"{n_parts} partitions cannot shard over {num_processes} processes"
    base, rem = divmod(n_parts, num_processes)
    lo = process_id * base + min(process_id, rem)
    hi = lo + base + (1 if process_id < rem else 0)
    return lo, hi


class ClusterRuntime:
    """One process's view of the N-process GoFFish cluster.

    ``num_processes == 1`` (no exchange) is the inert single-process
    fallback: every primitive is a local no-op, ``partition_shard``
    returns the full range, and nothing touches the network — engines
    and sessions can hold a runtime unconditionally.

    >>> rt = ClusterRuntime(0, 1)
    >>> rt.is_distributed
    False
    >>> rt.partition_shard(4)
    (0, 4)
    >>> rt.all_reduce_or(False)
    False
    """

    def __init__(self, process_id: int = 0, num_processes: int = 1,
                 exchange: Optional[TcpExchange] = None,
                 jax_initialized: bool = False):
        assert 0 <= process_id < num_processes
        assert (num_processes == 1) == (exchange is None), \
            "multi-process runtimes need an exchange; single-process none"
        self.process_id = int(process_id)
        self.num_processes = int(num_processes)
        self.exchange = exchange
        self.jax_initialized = jax_initialized

    @property
    def is_distributed(self) -> bool:
        return self.num_processes > 1

    # --------------------------------------------------- shard assignment
    def partition_shard(self, n_parts: int,
                        process_id: Optional[int] = None) -> Tuple[int, int]:
        """The contiguous half-open partition range this process owns.

        Contiguity in process-id order is what lets the gather seam
        re-assemble the global (P, NB) publish buffer by plain
        concatenation — the fold association (0..P-1) is then identical
        to the single-process stacked fold, hence bitwise-equal results.
        Remainder partitions go to the lowest-id processes.
        """
        pid = self.process_id if process_id is None else process_id
        return shard_range(n_parts, pid, self.num_processes)

    def shard_of_partition(self, part: int, n_parts: int) -> int:
        """Inverse map: which process owns partition ``part``."""
        for pid in range(self.num_processes):
            lo, hi = self.partition_shard(n_parts, pid)
            if lo <= part < hi:
                return pid
        raise ValueError(part)

    # ----------------------------------------------------- host exchange
    def allgather(self, tag: str, payload: Any) -> List[Any]:
        """Rank-ordered allgather (single-process: the 1-element list)."""
        if self.exchange is None:
            return [payload]
        return self.exchange.allgather(tag, payload)

    def allgather_concat(self, arr: np.ndarray, *, axis: int = 0,
                         tag: str = "concat") -> np.ndarray:
        """Concatenate per-process arrays along ``axis`` in rank order."""
        arr = np.asarray(arr)
        parts = self.allgather(tag, arr)
        if len(parts) == 1:
            return arr
        return np.concatenate(parts, axis=axis)

    def all_reduce_or(self, flag, *, tag: str = "or") -> bool:
        """Cross-process OR (the global vote-to-halt)."""
        if self.exchange is None:
            return bool(flag)
        return any(bool(f) for f in self.allgather(tag, bool(flag)))

    def check_consistent(self, tag: str, digest: Any) -> None:
        """Assert all processes present an identical ``digest`` for this
        sequenced point (chunk boundaries, plan fingerprints).  The op
        tag already catches schedule divergence; the digest catches
        same-schedule/different-data divergence (e.g. two processes
        staging differently sized chunks)."""
        views = self.allgather(tag, digest)
        if any(v != digest for v in views):
            raise ExchangeError(
                f"cluster divergence at {tag!r}: {views!r}")

    def barrier(self, tag: str = "barrier") -> None:
        if self.exchange is not None:
            self.exchange.barrier(tag)

    def close(self) -> None:
        if self.exchange is not None:
            self.exchange.close()

    def __enter__(self) -> "ClusterRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _parse_hostport(addr: str) -> Tuple[str, int]:
    host, _, port = addr.rpartition(":")
    return host or "127.0.0.1", int(port)


def init_cluster(
    coordinator: Optional[str] = None,
    num_processes: Optional[int] = None,
    process_id: Optional[int] = None,
    *,
    transport: Optional[str] = None,
    timeout: float = 120.0,
) -> ClusterRuntime:
    """Boot this process's cluster runtime.

    Parameters default from the ``GOFFISH_*`` environment (what
    ``launch/cluster_graph.py`` sets for each spawned worker); with no
    configuration at all this is the single-process no-op fallback.

    ``transport``:

    * ``"tcp"`` — stand up only the :class:`TcpExchange` (the forced-host
      lane: CPU clusters, tests, CI).
    * ``"jax"`` — additionally initialize ``jax.distributed`` against
      ``coordinator`` (real accelerator clusters: gives every process its
      global process index and binds local devices).  The host-lane
      exchange still rides the TCP port ``coordinator.port + 1``.
    * ``None``/``"auto"`` — ``"jax"`` when JAX exposes a distributed
      client, falling back to ``"tcp"`` if its initialization fails
      (e.g. CPU-only wheels without cross-process support).
    """
    coordinator = coordinator or os.environ.get(ENV_COORDINATOR)
    if num_processes is None:
        num_processes = int(os.environ.get(ENV_NUM_PROCESSES, "1"))
    if process_id is None:
        process_id = int(os.environ.get(ENV_PROCESS_ID, "0"))
    transport = transport or os.environ.get(ENV_TRANSPORT) or "auto"
    if num_processes <= 1:
        return ClusterRuntime(0, 1)
    assert coordinator, "multi-process runs need a coordinator host:port"
    host, port = _parse_hostport(coordinator)

    jax_ok = False
    if transport in ("jax", "auto"):
        try:
            import jax

            jax.distributed.initialize(
                coordinator_address=coordinator,
                num_processes=num_processes,
                process_id=process_id,
            )
            jax_ok = True
        except Exception:
            if transport == "jax":
                raise
    # the host-lane exchange always exists: the boundary fold, the halt
    # vote, and the staging consistency checks ride it even when
    # jax.distributed is up (they are host-side numpy operations)
    ex_port = port + 1 if jax_ok else port
    if process_id == 0:
        ex = TcpExchange.listen(ex_port, num_processes, timeout=timeout)
    else:
        ex = TcpExchange.connect(host, ex_port, process_id, num_processes,
                                 timeout=timeout, retry_for=timeout)
    rt = ClusterRuntime(process_id, num_processes, ex, jax_initialized=jax_ok)
    rt.barrier("init")
    return rt
