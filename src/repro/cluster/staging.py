"""Shard-local staging: each process stages only the partitions it owns.

Single-process staging materializes the WHOLE collection on every host —
(I, P, T, B, B) tiles spanning all P partitions.  On a cluster that is
both wasted RAM and wasted store traffic: the engine shard on process r
only ever consumes the rows of its own partition range.  This module
stages exactly that range:

* :func:`shard_stream` wraps a :class:`~repro.gofs.prefetch
  .SlicePrefetcher` whose chunks hold a ``(count, P_local, ...)``
  partition axis.  The underlying read touches the owned partitions'
  GoFS slice files plus the peers' remote-edge halo
  (``GoFSStore.edge_attr_rows(parts=..., halo=True)`` — incoming cut
  edges are recorded at their SOURCE partition) and the fills scatter
  only the owned partitions' tile slots
  (``BlockedGraph.fill_*_batch(parts=...)``) — staged bytes per host
  drop to the shard fraction (~1/num_processes for an even split).
* Every chunk boundary is a **cross-process consistency check**: as the
  consumer pulls a chunk, the processes exchange the chunk's (start,
  count, layout) digest through the sequenced runtime exchange and fail
  fast on divergence (two processes streaming different spans would
  otherwise combine boundary buffers from different timesteps — a
  silent-corruption class this check turns into an error).  The check
  runs on the CONSUMER thread, never the prefetch pool, so its exchange
  operations interleave deterministically with the engine's
  per-superstep exchanges.
* :func:`shard_staged_bytes` is the accounting hook the CI lane and the
  ``cluster_scaling`` bench row assert on: bytes materialized for a
  chunk (tile tensors + sparse index arrays).
"""
from __future__ import annotations

from typing import Callable, Iterator, Optional

import numpy as np

from repro.cluster.runtime import ClusterRuntime
from repro.gofs.prefetch import SlicePrefetcher, StagedChunk


def shard_staged_bytes(chunk: StagedChunk) -> int:
    """Bytes materialized for one staged chunk (tiles + index arrays)."""
    total = chunk.tiles.nbytes + chunk.btiles.nbytes
    for a in (chunk.rows, chunk.cols, chunk.brows, chunk.bcols):
        if a is not None:
            total += a.nbytes
    return total


class ShardStream:
    """A consistency-checked iterable of shard-local staged chunks.

    Iterates the wrapped prefetcher, verifying every chunk's span digest
    across processes before handing it to the engine, and accumulating
    :attr:`staged_bytes` (the per-host staging cost the scaling
    acceptance compares against the single-process total).  Supports the
    same ``with``/``close`` lifecycle as the prefetcher.
    """

    def __init__(self, prefetcher: SlicePrefetcher,
                 runtime: Optional[ClusterRuntime]):
        self.prefetcher = prefetcher
        self.runtime = runtime
        self.staged_bytes = 0
        self.chunks = 0

    def __iter__(self) -> Iterator[StagedChunk]:
        for ch in self.prefetcher:
            if self.runtime is not None and self.runtime.is_distributed:
                self.runtime.check_consistent(
                    f"chunk/{self.chunks}",
                    (int(ch.start), int(ch.count),
                     "sparse" if ch.is_sparse else "dense"),
                )
            self.staged_bytes += shard_staged_bytes(ch)
            self.chunks += 1
            yield ch

    def close(self) -> None:
        self.prefetcher.close()

    def __enter__(self) -> "ShardStream":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def shard_stream(
    store,
    bg,
    name: str,
    runtime: Optional[ClusterRuntime],
    *,
    zero: float = np.inf,
    prefetch_depth: int = 2,
    chunk_instances: Optional[int] = None,
    num_workers: int = 1,
    inflight: Optional[int] = None,
    layout: str = "dense",
    transform: Optional[Callable[[np.ndarray], np.ndarray]] = None,
) -> ShardStream:
    """Stream an edge attribute staged for THIS process's partition shard.

    The shard-local counterpart of ``GoFSStore.load_blocked_stream``:
    chunks carry a ``(count, P_local, ...)`` partition axis covering
    ``runtime.partition_shard(bg.n_parts)``, reads touch only the owned
    partitions' slice files, and chunk boundaries are consistency-checked
    across processes (see module docstring).  With a single-process
    runtime (or ``runtime=None``) the shard is the full partition range
    and no exchange happens — the stream is then byte-for-byte what
    ``load_blocked_stream`` stages, just with the accounting wrapper.

    Delta tile chains and deployment-recorded buckets describe the FULL
    collection, so the shard path always stages from the value slices;
    sparse chunks bucket themselves per chunk (jit shapes are per-process
    anyway — shards never exchange tile tensors).
    """
    assert layout in ("dense", "sparse"), layout
    rt = runtime if runtime is not None else ClusterRuntime(0, 1)
    lo, hi = rt.partition_shard(bg.n_parts)
    parts = (lo, hi)
    owned = range(lo, hi)
    chunk = int(chunk_instances or store.ipack)

    def stage_shard_chunk(s: int, e: int) -> StagedChunk:
        n = e - s
        if transform is None:
            # halo=True: the owned partitions' BOUNDARY tiles scatter cut
            # edges *incoming* from peer shards, recorded in the peers'
            # remote slices — read just that sliver on top of the owned
            # bulk
            w = store.edge_attr_rows(name, range(s, e), parts=owned,
                                     fill=zero, halo=True)
        else:
            # weights transforms may be structural over the WHOLE row
            # (PageRank normalizes each edge by its source's global
            # outdegree) — a shard-read row would feed them fill values
            # and silently change the weights.  Read full rows for the
            # transform; the fills below still scatter only the owned
            # partitions' tile slots, so the *materialized* per-host
            # bytes (the metric the scaling acceptance asserts) stay
            # shard-local.
            w = store.edge_attr_rows(name, range(s, e))
            w = np.asarray(transform(w), np.float32)
            assert w.shape[0] == n, (w.shape, n)
        if layout == "sparse":
            tiles, rows, cols, nnz = bg.fill_local_batch_sparse(
                w, zero=zero, parts=parts)
            btiles, brows, bcols, bnnz = bg.fill_boundary_batch_sparse(
                w, zero=zero, parts=parts)
            return StagedChunk(
                start=s, count=n, tiles=tiles, btiles=btiles,
                rows=rows, cols=cols, brows=brows, bcols=bcols,
                nnz=nnz, bnnz=bnnz,
            )
        lt_buf, bt_buf = bg.alloc_batch_buffers(n, parts=parts)
        tiles = bg.fill_local_batch(w, zero=zero, out=lt_buf, parts=parts)
        btiles = bg.fill_boundary_batch(w, zero=zero, out=bt_buf,
                                        parts=parts)
        return StagedChunk(start=s, count=n, tiles=tiles, btiles=btiles)

    pf = SlicePrefetcher(
        bg, None, store.num_timesteps(), zero=zero,
        prefetch_depth=prefetch_depth, chunk_instances=chunk,
        num_workers=num_workers, inflight=inflight, layout=layout,
        stage_fn=stage_shard_chunk,
    )
    return ShardStream(pf, rt)
