"""Version compatibility shims (single import point, no jax state touched).

``shard_map`` moved from ``jax.experimental.shard_map`` (kwarg
``check_rep``) to ``jax.shard_map`` (kwarg ``check_vma``); callers use the
new-style signature and this shim translates for older jax.

``pallas_compiler_params`` papers over the ``TPUCompilerParams`` ->
``CompilerParams`` rename in ``jax.experimental.pallas.tpu``.
"""
from __future__ import annotations

import jax


def pallas_compiler_params(**kwargs):
    """TPU pallas_call compiler_params across the class rename."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:  # older jax naming
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )
