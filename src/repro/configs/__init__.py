"""Config registry: ``get_config(arch_id)`` + the assigned shape grid."""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import (
    ArchConfig,
    GraphConfig,
    GraphShapeConfig,
    LM_SHAPES,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
)

# arch id -> module name
_ARCH_MODULES = {
    "mistral-large-123b": "mistral_large_123b",
    "glm4-9b": "glm4_9b",
    "minitron-4b": "minitron_4b",
    "starcoder2-7b": "starcoder2_7b",
    "dbrx-132b": "dbrx_132b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "paligemma-3b": "paligemma_3b",
    "whisper-medium": "whisper_medium",
    "hymba-1.5b": "hymba_1_5b",
    "xlstm-1.3b": "xlstm_1_3b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def get_graph_config(name: str = "small") -> GraphConfig:
    mod = importlib.import_module("repro.configs.goffish_tr")
    return {"full": mod.TR_FULL, "small": mod.TR_SMALL, "tiny": mod.TR_TINY}[name]


def shape_by_name(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs, with reason when skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: long_500k skipped (DESIGN.md §3)"
    if shape.kind == "decode" and not cfg.has_decoder:
        return False, "encoder-only arch: no decode step"
    return True, ""


def all_cells() -> List[Tuple[str, str, bool, str]]:
    """(arch_id, shape_name, applicable, reason) for the 40-cell grid."""
    out = []
    for aid in ARCH_IDS:
        cfg = get_config(aid)
        for s in LM_SHAPES:
            ok, why = cell_applicable(cfg, s)
            out.append((aid, s.name, ok, why))
    return out


__all__ = [
    "ArchConfig",
    "GraphConfig",
    "GraphShapeConfig",
    "MoEConfig",
    "SSMConfig",
    "ShapeConfig",
    "LM_SHAPES",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "ARCH_IDS",
    "get_config",
    "get_graph_config",
    "shape_by_name",
    "cell_applicable",
    "all_cells",
]
