"""hymba-1.5b [hybrid] — arXiv:2411.13676.  Parallel attention + Mamba heads.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Each layer runs attention heads and SSM (Mamba) heads in PARALLEL on the same
input and fuses their (normalized) outputs.  Most layers use sliding-window
attention; 128 learnable meta tokens are prepended.  Sub-quadratic ->
long_500k applies.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1_600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5_504,
    vocab_size=32_001,
    rope_theta=10_000.0,
    sliding_window=1_024,
    mlp_activation="swiglu",
    norm="rmsnorm",
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    hybrid_ssm_heads=25,
    meta_tokens=128,
    supports_long_context=True,
)
