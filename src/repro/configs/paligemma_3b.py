"""paligemma-3b [vlm] — arXiv:2407.07726.  SigLIP vision tower + Gemma-2B LM.

Backbone (assigned): 18L d_model=2048 8H (GQA kv=1, i.e. MQA) d_ff=16384
vocab=257216.  Gemma uses head_dim=256, GeGLU, RMSNorm, tied embeddings.

The SigLIP frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings of shape (batch, num_image_patches, d_model)
which are prepended to the text sequence (prefix-LM style).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2_048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab_size=257_216,
    rope_theta=10_000.0,
    mlp_activation="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
    num_image_patches=256,  # 224px / 14px patches -> 16x16
    supports_long_context=False,
)
