"""mistral-large-123b [dense] — hf:mistralai/Mistral-Large-Instruct-2407.

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12_288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab_size=32_768,
    rope_theta=1_000_000.0,
    mlp_activation="swiglu",
    norm="rmsnorm",
    supports_long_context=False,  # pure full attention -> long_500k skipped
)
