"""starcoder2-7b [dense] — arXiv:2402.19173.  GQA, RoPE, 4K sliding window.

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
StarCoder2 uses non-gated GELU MLP (d_ff = 4·d_model) and LayerNorm.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4_608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18_432,
    vocab_size=49_152,
    rope_theta=100_000.0,
    sliding_window=4_096,
    mlp_activation="gelu",
    norm="layernorm",
    # Sliding-window attention is sub-quadratic in principle, but the
    # assignment classes starcoder2 with the full-attention archs for
    # long_500k (window 4096 ≪ 524288 makes the cell degenerate): skipped.
    supports_long_context=False,
)
