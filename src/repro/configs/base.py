"""Architecture + shape configuration for the repro framework.

One ``ArchConfig`` covers every assigned family (dense / moe / vlm / audio /
hybrid / ssm).  Family-specific knobs default to inert values so a config file
only states what its architecture actually uses.

Shapes are global (pre-sharding).  ``train_*`` shapes lower ``train_step``;
``prefill_*`` lower the prefill half of ``serve_step``; ``decode_*`` /
``long_*`` lower the single-new-token decode step against a KV cache of
``seq_len``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ShapeConfig:
    """A single input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


# The four LM shapes shared by all 10 assigned architectures.
TRAIN_4K = ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode")

LM_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # Every Nth layer is MoE (1 = all layers, as in dbrx / llama4-maverick-ish).
    moe_every: int = 1
    # llama4-style always-on shared expert alongside routed experts.
    shared_expert: bool = False


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_width: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class ArchConfig:
    """Complete architecture description.

    ``family`` is one of: dense | moe | vlm | audio | hybrid | ssm.
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # Attention details
    head_dim: int = 0  # 0 -> d_model // num_heads
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0  # glm4 rotates only half the head dim
    pos_embed: str = "rope"  # rope | sinusoidal | none
    sliding_window: int = 0  # 0 = full attention
    attn_logit_softcap: float = 0.0
    max_seq_len: int = 524_288

    # Activation / norm
    mlp_activation: str = "swiglu"  # swiglu | geglu | gelu | relu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # Family extensions
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)

    # [audio] enc-dec: encoder depth/width; frontend is a stub that provides
    # precomputed frame embeddings of shape (batch, num_frames, d_model).
    encoder_layers: int = 0
    encoder_seq_len: int = 1_500  # whisper: 30s @ 50Hz after conv stub

    # [vlm]: stub vision tower provides (batch, num_patches, d_model) patch
    # embeddings prepended to the text sequence.
    num_image_patches: int = 0

    # [hybrid] hymba: attention and SSM heads run in parallel in each layer;
    # meta tokens are learnable prefix tokens.
    hybrid_ssm_heads: int = 0
    meta_tokens: int = 0

    # [ssm] xlstm: pattern of block kinds, e.g. ("m","m","s","m",...) cycled.
    xlstm_slstm_every: int = 0  # every Nth block is sLSTM; 0 = pure mLSTM

    # Whether full-attention makes long_500k inapplicable (sub-quadratic archs
    # override to True).
    supports_long_context: bool = False
    # Encoder-only / enc-dec handling of decode shapes.
    has_decoder: bool = True

    # Training defaults
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"  # none | dots | full

    # Unroll layer scans (dry-run cost fitting: cost_analysis counts scan
    # bodies once, so the fit compiles small UNROLLED configs and
    # extrapolates body-per-unit x units).
    scan_unroll: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % self.num_kv_heads == 0, (
            f"{self.name}: num_heads {self.num_heads} not divisible by "
            f"num_kv_heads {self.num_kv_heads}"
        )

    # ---- derived quantities -------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Embedding/head table rows, padded to 128 for TP divisibility
        (whisper 51865 and hymba 32001 are not 16-divisible).  Logits beyond
        ``vocab_size`` are masked in the loss/sampling paths."""
        return -(-self.vocab_size // 128) * 128

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.moe.num_experts > 0

    def with_overrides(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---- scan-unit scaling (dry-run cost fit) -----------------------------
    def scan_units(self) -> int:
        """Trips of the outer layer scan (what cost extrapolation counts):
        dense/vlm/hybrid = layers; moe = layer groups; ssm = superblocks;
        audio = decoder layers (encoder scales 1:1 alongside)."""
        if self.is_moe:
            return self.num_layers // self.moe.moe_every
        if self.family == "ssm" and self.xlstm_slstm_every:
            return self.num_layers // self.xlstm_slstm_every
        return self.num_layers

    def with_units(self, k: int) -> "ArchConfig":
        """Config with exactly ``k`` outer-scan units (same structure)."""
        kw = {}
        if self.is_moe:
            kw["num_layers"] = k * self.moe.moe_every
        elif self.family == "ssm" and self.xlstm_slstm_every:
            kw["num_layers"] = k * self.xlstm_slstm_every
        else:
            kw["num_layers"] = k
        if self.encoder_layers:
            kw["encoder_layers"] = k
        return self.with_overrides(**kw)

    def reduced(self) -> "ArchConfig":
        """A tiny config of the same family for CPU smoke tests."""
        kw = dict(
            num_layers=max(2, min(4, self.num_layers)),
            d_model=128,
            num_heads=4,
            num_kv_heads=max(1, min(2, self.num_kv_heads)),
            head_dim=32,
            d_ff=0 if self.d_ff == 0 else 256,
            vocab_size=512,
            max_seq_len=512,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq_len=32 if self.encoder_layers else self.encoder_seq_len,
            num_image_patches=16 if self.num_image_patches else 0,
            hybrid_ssm_heads=2 if self.hybrid_ssm_heads else 0,
            meta_tokens=4 if self.meta_tokens else 0,
            sliding_window=64 if self.sliding_window else 0,
            remat="none",
        )
        if self.is_moe:
            kw["moe"] = MoEConfig(
                num_experts=4,
                top_k=min(2, self.moe.top_k),
                capacity_factor=self.moe.capacity_factor,
                moe_every=self.moe.moe_every,
            )
        if self.family in ("ssm", "hybrid"):
            kw["ssm"] = SSMConfig(state_dim=8, conv_width=4, expand=2)
        return self.with_overrides(**kw)

    # Parameter count (analytic; excludes biases which we do not use except
    # where an arch requires them).  Used for 6·N·D roofline cross-checks.
    def param_count(self) -> int:
        d, h = self.d_model, self.head_dim
        attn = d * (self.num_heads * h) + 2 * d * (self.num_kv_heads * h) + (self.num_heads * h) * d
        if self.mlp_activation in ("swiglu", "geglu"):
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.is_moe:
            dense_every = self.moe.moe_every
            n_moe = self.num_layers // dense_every
            n_dense = self.num_layers - n_moe
            router = d * self.moe.num_experts
            n_ffn = self.moe.num_experts + (1 if self.moe.shared_expert else 0)
            per_layer_moe = attn + n_ffn * mlp + router + 2 * d
            per_layer_dense = attn + mlp + 2 * d
            body = n_moe * per_layer_moe + n_dense * per_layer_dense
        elif self.family == "ssm":
            body = self.num_layers * self._xlstm_block_params()
        elif self.family == "hybrid":
            ssm_inner = self.ssm.expand * d
            ssm = (
                d * ssm_inner * 2
                + ssm_inner * self.ssm.conv_width
                + ssm_inner * (self.ssm.state_dim * 2 + self._dt_rank() + 1)
                + self._dt_rank() * ssm_inner
                + ssm_inner * d
            )
            body = self.num_layers * (attn + ssm + mlp + 3 * d)
        else:
            body = self.num_layers * (attn + mlp + 2 * d)
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        enc = 0
        if self.encoder_layers:
            enc_attn = 4 * d * d
            enc = self.encoder_layers * (enc_attn + mlp + 2 * d)
            # decoder cross-attention adds one more attn block per layer
            body += self.num_layers * enc_attn
        return body + emb + head + enc + d

    def _dt_rank(self) -> int:
        return self.ssm.dt_rank or -(-self.d_model // 16)

    def _xlstm_block_params(self) -> int:
        d = self.d_model
        # mLSTM block: up-proj 2x, qkv on inner dim, gates, down-proj (xLSTM paper pf=2)
        inner = 2 * d
        m = d * inner * 2 + 3 * inner * inner // max(1, self.num_heads) + 3 * inner + inner * d
        return m + 2 * d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        mlp = 3 * d * self.d_ff
        inactive = (self.moe.num_experts - self.moe.top_k) * mlp
        n_moe = self.num_layers // self.moe.moe_every
        return self.param_count() - n_moe * inactive


@dataclass(frozen=True)
class GraphShapeConfig:
    """Shape cell for the GoFFish graph workloads (the paper's own kind)."""

    name: str
    num_vertices: int
    num_edges: int
    num_instances: int
    block_size: int = 128
    pattern: str = "sequential"  # independent | eventually | sequential


@dataclass(frozen=True)
class GraphConfig:
    """Configuration of a time-series graph collection (paper §III/§VI)."""

    name: str
    num_vertices: int
    avg_degree: float
    num_instances: int
    num_partitions: int
    block_size: int = 128
    # GoFS layout knobs (paper §V-B..E)
    instances_per_slice: int = 20  # temporal packing (i1/i20)
    bins_per_partition: int = 20  # subgraph bin packing (s20/s40)
    cache_slots: int = 14  # LRU slice cache (c0/c14)
    seed: int = 0
