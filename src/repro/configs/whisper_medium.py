"""whisper-medium [audio] — arXiv:2212.04356.  Encoder-decoder transformer.

Assigned backbone: 24L d_model=1024 16H (kv=16, i.e. MHA) d_ff=4096
vocab=51865.  24 encoder + 24 decoder layers, GELU MLP, LayerNorm,
sinusoidal positions (no RoPE).

The conv1d audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (batch, 1500, d_model) — 30 s of audio
at 50 Hz after the two stride-2 convs.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    d_model=1_024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4_096,
    vocab_size=51_865,
    pos_embed="sinusoidal",
    mlp_activation="gelu",
    norm="layernorm",
    encoder_layers=24,
    encoder_seq_len=1_500,
    supports_long_context=False,
)
