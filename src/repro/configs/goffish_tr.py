"""GoFFish TR dataset analogue (paper §VI-A) + reduced variants.

The paper's TR collection: internet traceroute graph, 19.4M vertices, 22.8M
edges, 146 instances over 12 days (2 h windows), partitioned over 12 hosts.
We define a scaled family of synthetic small-world collections with the same
shape characteristics (power-law-ish subgraph size distribution, ~1.17
edges/vertex, 7 vertex + 7 edge attributes) for CPU-runnable benchmarks, and
the full-size spec for the dry-run.
"""
from repro.configs.base import GraphConfig

# Full-size spec (dry-run / documentation only on this container).
TR_FULL = GraphConfig(
    name="goffish-tr-full",
    num_vertices=19_442_778,
    avg_degree=1.172,
    num_instances=146,
    num_partitions=256,  # one per mesh device on the single-pod mesh
    block_size=128,
    instances_per_slice=20,
    bins_per_partition=20,
    cache_slots=14,
)

# CPU-scale replica preserving the distributional shape (for benchmarks).
TR_SMALL = GraphConfig(
    name="goffish-tr-small",
    num_vertices=16_384,
    avg_degree=2.0,
    num_instances=48,
    num_partitions=8,
    block_size=64,
    instances_per_slice=20,
    bins_per_partition=20,
    cache_slots=14,
)

# Tiny config for tests.
TR_TINY = GraphConfig(
    name="goffish-tr-tiny",
    num_vertices=512,
    avg_degree=3.0,
    num_instances=6,
    num_partitions=4,
    block_size=32,
    instances_per_slice=2,
    bins_per_partition=2,
    cache_slots=4,
)

CONFIG = TR_SMALL
