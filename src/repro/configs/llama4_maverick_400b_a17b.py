"""llama4-maverick-400b-a17b [moe] — hf:meta-llama/Llama-4 family.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1
with an always-on shared expert (17B active of ~400B total).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5_120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8_192,
    vocab_size=202_048,
    rope_theta=500_000.0,
    mlp_activation="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        capacity_factor=1.25,
        moe_every=2,  # maverick interleaves MoE / dense layers -> ~400B total
        shared_expert=True,
    ),
    supports_long_context=False,
)
