"""xlstm-1.3b [ssm] — arXiv:2405.04517.  sLSTM + mLSTM blocks (xLSTM[7:1]).

48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304.  d_ff=0 per the assignment:
feed-forward capacity lives inside the mLSTM (projection factor 2) and sLSTM
(gated FFN, pf=4/3) blocks.  Every 8th block is sLSTM (7:1 ratio).
Recurrent, O(1) state per token -> long_500k applies.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2_048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50_304,
    pos_embed="none",  # recurrence carries position
    mlp_activation="swiglu",
    norm="layernorm",
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    xlstm_slstm_every=8,
    supports_long_context=True,
)
