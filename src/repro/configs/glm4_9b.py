"""glm4-9b [dense] — hf:THUDM/glm-4-9b.  RoPE (half-dim rotary), GQA.

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4_096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13_696,
    vocab_size=151_552,
    rope_theta=10_000.0,
    rope_fraction=0.5,  # GLM rotary applies to half of each head dim
    mlp_activation="swiglu",
    norm="rmsnorm",
    supports_long_context=False,
)
