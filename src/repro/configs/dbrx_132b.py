"""dbrx-132b [moe] — hf:databricks/dbrx-base.  Fine-grained MoE 16e top-4.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6_144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10_752,
    vocab_size=100_352,
    rope_theta=500_000.0,
    mlp_activation="swiglu",
    norm="layernorm",
    moe=MoEConfig(num_experts=16, top_k=4, capacity_factor=1.25, moe_every=1),
    supports_long_context=False,
)
