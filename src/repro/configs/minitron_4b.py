"""minitron-4b [dense] — arXiv:2407.14679 (pruned Nemotron-4).

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
Nemotron family uses squared-ReLU MLP (non-gated) and LayerNorm.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3_072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9_216,
    vocab_size=256_000,
    rope_theta=10_000.0,
    mlp_activation="relu2",
    norm="layernorm",
    tie_embeddings=False,
    supports_long_context=False,
)
