"""Fused BSP-superstep stage as ONE Pallas TPU kernel.

Grid ``(P, T)``: partition-major walk over every partition's col-sorted
packed tile list.  Per grid step the kernel

* double-buffers the next tile's HBM->VMEM copy against the current
  tile's compute (manual ``make_async_copy`` ping-pong over a 2-slot
  VMEM scratch, chained across the partition boundary);
* accumulates the blocked SpMV partial for the current output run into a
  VMEM-resident ``y`` accumulator (tiles are col-sorted, so each output
  block is one contiguous run — same invariant as
  ``kernels/semiring_spmm``);
* at the end of a run, combines the run's ``y`` into the VMEM-resident
  output state: ``x_out[c] = sr.add(x_comb[c], y)`` — the semiring
  combine that used to be a separate XLA op;
* at the last tile of a partition, writes the per-partition halt vote
  ``changed[p] = any(vmask & (x_out != x_ref))`` into SMEM — the
  vote-to-halt reduction that used to re-read both full states in XLA.

The x/y vertex state for partition ``p`` (``x_in``/``x_comb``/``x_ref``/
``x_out`` rows plus the run accumulator) stays VMEM-resident across the
whole ``T``-step walk; only tiles stream from HBM.  Padding tiles
(``cols < 0``, always sorted last) skip compute under ``pl.when`` but
keep the DMA chain uniform.

Semantics per partition (min-plus shown):

    y      = A_p^T x_in
    x_out  = min(x_comb, y)          (untouched blocks keep x_comb)
    changed[p] = any(vmask_p & (x_out_p != x_ref_p))

``interpret=True`` runs the same kernel under the Pallas interpreter —
the CI-provable parity tier used by the CPU test suite.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params
from repro.core.semiring import INF

_ZEROS = {"min_plus": INF, "plus_mul": 0.0, "max_plus": -INF}


def _fused_kernel(
    # scalar prefetch (SMEM)
    rows_ref,  # (P, T) int32, pad rows clamped to 0
    cols_ref,  # (P, T) int32, -1 = pad (sorted last)
    # inputs
    tiles_hbm,  # (P, T, B, B) — stays in HBM, manually DMA'd
    x_in_ref,  # (1, NVBin, B) VMEM block (partition row or shared buffer)
    x_comb_ref,  # (1, NVB, B) VMEM block
    x_ref_ref,  # (1, NVB, B) VMEM block
    vmask_ref,  # (1, NVB, B) VMEM block, 0/1 float
    # outputs
    x_out_ref,  # (1, NVB, B) VMEM block — revisited across the t-walk
    changed_ref,  # (P, 1) int32 SMEM (whole array)
    # scratch
    y_ref,  # (1, B) VMEM run accumulator
    tbuf,  # (2, B, B) VMEM tile ping-pong
    sems,  # DMA semaphores, one per slot
    *,
    sr_name: str,
    n_t: int,
    total: int,
):
    zero = _ZEROS[sr_name]
    p = pl.program_id(0)
    t = pl.program_id(1)
    g = p * n_t + t
    slot = jax.lax.rem(g, 2)
    nslot = jax.lax.rem(g + 1, 2)

    # ---- double-buffered tile DMA: warm up, then overlap t+1 with t ----
    @pl.when(g == 0)
    def _():
        pltpu.make_async_copy(
            tiles_hbm.at[0, 0], tbuf.at[0], sems.at[0]).start()

    @pl.when(g + 1 < total)
    def _():
        g1 = g + 1
        pltpu.make_async_copy(
            tiles_hbm.at[g1 // n_t, jax.lax.rem(g1, n_t)],
            tbuf.at[nslot], sems.at[nslot]).start()

    # ---- superstep baseline: untouched blocks must carry x_comb ----
    @pl.when(t == 0)
    def _():
        x_out_ref[...] = x_comb_ref[...]

    c = cols_ref[p, t]
    valid = c >= 0
    cprev = cols_ref[p, jnp.maximum(t - 1, 0)]
    cnext = cols_ref[p, jnp.minimum(t + 1, n_t - 1)]
    first = jnp.logical_and(valid, jnp.logical_or(t == 0, cprev != c))
    last = jnp.logical_and(valid, jnp.logical_or(t == n_t - 1, cnext != c))

    pltpu.make_async_copy(
        tiles_hbm.at[p, t], tbuf.at[slot], sems.at[slot]).wait()

    @pl.when(first)
    def _():
        y_ref[...] = jnp.full_like(y_ref, zero)

    @pl.when(valid)
    def _():
        r = rows_ref[p, t]
        xb = x_in_ref[0, r, :]
        w = tbuf[slot]
        if sr_name == "plus_mul":
            y_ref[0, :] = y_ref[0, :] + jnp.dot(
                xb, w, preferred_element_type=jnp.float32)
        else:
            # broadcast-add + min-reduce on the VPU (idempotent: exact)
            y_ref[0, :] = jnp.minimum(
                y_ref[0, :], jnp.min(xb[:, None] + w, axis=0))

    @pl.when(last)
    def _():
        base = x_comb_ref[0, c, :]
        if sr_name == "plus_mul":
            x_out_ref[0, c, :] = base + y_ref[0, :]
        else:
            x_out_ref[0, c, :] = jnp.minimum(base, y_ref[0, :])

    # ---- halt vote: one VMEM-resident compare per partition ----
    @pl.when(t == n_t - 1)
    def _():
        diff = jnp.logical_and(vmask_ref[...] != 0.0,
                               x_out_ref[...] != x_ref_ref[...])
        changed_ref[p, 0] = jnp.any(diff).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("sr_name", "interpret"))
def fused_step_pallas(
    tiles: jax.Array,  # (P, T, B, B) float32
    rows: jax.Array,  # (P, T) int32, -1 = pad
    cols: jax.Array,  # (P, T) int32, -1 = pad (sorted last)
    x_in: jax.Array,  # (Pin, NVBin, B); Pin in {P, 1}
    x_comb: jax.Array,  # (P, NVB, B)
    x_ref: jax.Array,  # (P, NVB, B)
    vmask: jax.Array,  # (P, NVB, B) float32 0/1
    *,
    sr_name: str = "min_plus",
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Returns ``(x_out (P, NVB, B), changed (P, 1) int32)``."""
    P, T, B, _ = tiles.shape
    nvb = x_comb.shape[1]
    nvb_in = x_in.shape[1]
    shared_xin = x_in.shape[0] == 1

    def xin_map(p, t, r, c):
        del t, r, c
        return (0, 0, 0) if shared_xin else (p, 0, 0)

    def part_row(p, t, r, c):
        del t, r, c
        return (p, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(P, T),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),  # tiles stay in HBM
            pl.BlockSpec((1, nvb_in, B), xin_map),
            pl.BlockSpec((1, nvb, B), part_row),
            pl.BlockSpec((1, nvb, B), part_row),
            pl.BlockSpec((1, nvb, B), part_row),
        ],
        out_specs=[
            pl.BlockSpec((1, nvb, B), part_row),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, B), jnp.float32),
            pltpu.VMEM((2, B, B), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    kernel = functools.partial(
        _fused_kernel, sr_name=sr_name, n_t=T, total=P * T)
    x_out, changed = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((P, nvb, B), x_comb.dtype),
            jax.ShapeDtypeStruct((P, 1), jnp.int32),
        ],
        # the t-walk accumulates into revisited VMEM blocks and the DMA
        # chain crosses the partition boundary: both grid dims sequential
        compiler_params=pallas_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(jnp.maximum(rows, 0), cols, tiles, x_in, x_comb, x_ref, vmask)
    return x_out, changed
