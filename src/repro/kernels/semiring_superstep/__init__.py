"""Fused-superstep Pallas kernel: sweep + semiring combine + halt vote.

One ``pallas_call`` executes the local compute of a BSP superstep stage
for EVERY partition: the blocked SpMV walk over the (col-sorted, packed)
tile list, the semiring combine into the output state, and the
vote-to-halt comparison against the superstep-start state.  See
``kernel.py`` for the grid layout and the manual double-buffered tile
DMA, ``ref.py`` for the jnp oracle the kernel is bitwise-tested against
(min-plus), and ``ops.py`` for the dispatching wrapper used by
``repro.core.superstep``.
"""
from repro.kernels.semiring_superstep.ops import fused_step  # noqa: F401
from repro.kernels.semiring_superstep.ref import fused_step_ref  # noqa: F401
