"""jnp oracle for the fused superstep step (parity tier for the kernel).

Computes, for every partition at once,

    y      = A_p^T x_in        (blocked SpMV over the packed tile list)
    x_out  = sr.add(x_comb, y)  with untouched blocks left at x_comb
    changed[p] = any(vmask_p & (x_out_p != x_ref_p))

which is exactly what ``kernel.fused_step_pallas`` fuses into one
``pallas_call``.  The min-plus path is bitwise-identical to the kernel:
``min`` is exactly associative/commutative, and per-tile partials combine
in an order-insensitive way.  The plus-mul path reassociates the per-tile
dot accumulation (segment-sum here vs sequential walk in the kernel) —
callers compare it with a float tolerance.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.semiring import Semiring
from repro.kernels.semiring_spmm.ref import spmv_blocked_ref


def fused_step_ref(
    tiles: jax.Array,  # (P, T, B, B)
    rows: jax.Array,  # (P, T) int32, -1 = pad
    cols: jax.Array,  # (P, T) int32, -1 = pad
    x_in: jax.Array,  # (Pin, NVBin, B) — Pin == P, or 1 (shared boundary)
    x_comb: jax.Array,  # (P, NVB, B) combine baseline (superstep state)
    x_ref: jax.Array,  # (P, NVB, B) halt-vote reference (superstep start)
    vmask: jax.Array,  # (P, NVB, B) valid-vertex mask (bool or 0/1 float)
    sr: Semiring,
) -> Tuple[jax.Array, jax.Array]:
    """Returns ``(x_out (P, NVB, B), changed (P, 1) int32)``."""
    P, _, nvb, B = (tiles.shape[0], tiles.shape[1],
                    x_comb.shape[1], x_comb.shape[2])

    def one(tiles_p, rows_p, cols_p, xin_p, xcomb_p):
        y = spmv_blocked_ref(tiles_p, rows_p, cols_p,
                             xin_p.reshape(-1), sr, n_out_blocks=nvb)
        # untouched output blocks carry sr.zero out of the SpMV, and
        # add(x, zero) == x — the baseline survives untouched blocks
        return sr.add(xcomb_p.reshape(-1), y).reshape(nvb, B)

    xin_axis = None if x_in.shape[0] == 1 else 0
    xin = x_in[0] if x_in.shape[0] == 1 else x_in
    x_out = jax.vmap(one, in_axes=(0, 0, 0, xin_axis, 0))(
        tiles, rows, cols, xin, x_comb)
    live = vmask != 0 if vmask.dtype != jnp.bool_ else vmask
    diff = jnp.logical_and(live, x_out != x_ref)
    changed = jnp.any(diff.reshape(P, -1), axis=1)
    return x_out, changed.astype(jnp.int32)[:, None]
