"""Dispatching wrapper for the fused superstep stage.

``fused_step`` mirrors ``repro.kernels.semiring_spmm.ops.spmv_blocked``:
one entry point that routes to the Pallas kernel (``use_pallas=True``)
or the jnp oracle, with ``interpret`` resolved through the same cached
backend probe the SpMV kernel uses (resolved once per process, never in
the hot dispatch loop).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.semiring import Semiring
from repro.kernels.semiring_spmm.ops import default_interpret
from repro.kernels.semiring_superstep.kernel import fused_step_pallas
from repro.kernels.semiring_superstep.ref import fused_step_ref


def fused_step(
    tiles: jax.Array,
    rows: jax.Array,
    cols: jax.Array,
    x_in: jax.Array,
    x_comb: jax.Array,
    x_ref: jax.Array,
    vmask: jax.Array,
    sr: Semiring,
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """One fused sweep/consume stage.  Returns ``(x_out, changed)``.

    ``vmask`` may be bool; the kernel consumes a 0/1 float32 mask.
    """
    mask = vmask.astype(jnp.float32) if vmask.dtype != jnp.float32 \
        else vmask
    if not use_pallas:
        return fused_step_ref(tiles, rows, cols, x_in, x_comb, x_ref,
                              mask, sr)
    if interpret is None:
        interpret = default_interpret()
    return fused_step_pallas(tiles, rows, cols, x_in, x_comb, x_ref,
                             mask, sr_name=sr.name, interpret=interpret)
