"""Dispatch wrapper: (B, S, H/K, d) GQA layout -> flash attention.

GQA is handled by reshaping queries into (B*K, G*Sq, d) groups? No — K/V
heads are broadcast: we expand KV to the query head count once (cheap next
to the O(S²) attention work at prefill shapes) and flatten (B, H) into the
grid dimension.  On-TPU this is the Pallas path; off-TPU (or ``use_pallas=
False``) it falls back to the chunked-softmax jnp path in
``repro.models.attention`` — the same math, XLA-fused.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import mha_ref


def _expand_kv(k: jax.Array, H: int) -> jax.Array:
    B, S, K, d = k.shape
    return jnp.repeat(k, H // K, axis=2)


def flash_attention(
    q: jax.Array,  # (B, Sq, H, d)
    k: jax.Array,  # (B, Skv, K, d)
    v: jax.Array,  # (B, Skv, K, d)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    use_pallas: bool = False,
    interpret: bool | None = None,
    bq: int = 128,
    bk: int = 128,
) -> jax.Array:
    B, Sq, H, d = q.shape
    kf, vf = _expand_kv(k, H), _expand_kv(v, H)
    if not use_pallas:
        return mha_ref(q, kf, vf, causal=causal, q_offset=q_offset,
                       window=window)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, d)
    kt = kf.transpose(0, 2, 1, 3).reshape(B * H, kf.shape[1], d)
    vt = vf.transpose(0, 2, 1, 3).reshape(B * H, vf.shape[1], d)
    o = flash_attention_pallas(
        qt, kt, vt, causal=causal, window=window, q_offset=q_offset,
        bq=bq, bk=bk, interpret=interpret,
    )
    return o.reshape(B, H, Sq, d).transpose(0, 2, 1, 3)
