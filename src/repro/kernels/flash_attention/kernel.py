"""Pallas TPU flash attention (prefill): tiled online-softmax.

Grid = (batch*heads, q_blocks, kv_blocks); the kv dimension is innermost and
sequential, so the fp32 accumulators (acc, m, l) live in VMEM scratch and
persist across kv steps of one q block.  Causal + sliding-window masking is
applied from absolute positions; fully-masked kv blocks are skipped via
``pl.when`` (upper-triangle blocks cost nothing but the grid step).

Block sizes default to (128, 128): q tile (128, d) + k/v tiles (128, d) +
(128,128) logits in fp32 ≈ 3·128·d·4 + 64 KiB — comfortably inside VMEM for
d ≤ 256, MXU-aligned on both matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, bq: int, bk: int, causal: bool, window: int, q_offset: int, scale: float,
):
    j = pl.program_id(2)
    nk = pl.num_programs(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    qpos0 = i * bq + q_offset
    # skip kv blocks entirely above the causal diagonal / below the window
    needed = True
    if causal:
        needed = j * bk <= qpos0 + bq - 1
        if window:
            needed = jnp.logical_and(needed, (j + 1) * bk - 1 > qpos0 - window)

    @pl.when(needed)
    def _():
        q = q_ref[0]  # (bq, d)
        k = k_ref[0]  # (bk, d)
        v = v_ref[0]  # (bk, d)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (bq, bk)
        if causal:
            qpos = qpos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            ok = kpos <= qpos
            if window:
                ok = jnp.logical_and(ok, kpos > qpos - window)
            logits = jnp.where(ok, logits, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        p = jnp.exp(logits - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "bq", "bk", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (BH, Sq, d)
    k: jax.Array,  # (BH, Skv, d)
    v: jax.Array,  # (BH, Skv, d)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    BH, Sq, d = q.shape
    Skv = k.shape[1]
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    scale = 1.0 / float(d) ** 0.5
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, window=window,
        q_offset=q_offset, scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, Sq // bq, Skv // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
