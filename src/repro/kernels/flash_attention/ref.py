"""Pure-jnp oracle for tiled causal (flash) attention.

Materializes the full (Sq, Skv) logits — fine at test scale; the chunked
online-softmax path in ``repro.models.attention`` is the production jnp
path and is itself validated against this oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_ref(
    q: jax.Array,  # (B, Sq, H, d)
    k: jax.Array,  # (B, Skv, H, d)   (GQA pre-expanded by ops.py)
    v: jax.Array,  # (B, Skv, H, d)
    *,
    causal: bool = True,
    q_offset: int = 0,  # absolute position of q[0] (decode: cache length)
    window: int = 0,  # sliding window; 0 = unbounded
) -> jax.Array:
    B, Sq, H, d = q.shape
    Skv = k.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= kpos <= qpos
        if window:
            ok &= kpos > qpos - window
    logits = jnp.where(ok[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
    return out.astype(q.dtype)
