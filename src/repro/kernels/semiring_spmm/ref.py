"""Pure-jnp oracle for the blocked semiring SpMV — a segment-reduce over
the (packed) tile list.

y[cb*B + j] = add-reduce over tiles t with col(t)==cb, over i of
              mul(x[row(t)*B + i], tiles[t, i, j])

The tile axis may be the dense template list (every tile slot of the
partition) or a block-sparse packed list (only the instance's active
tiles, pow2-bucket padded — ``repro.core.blocked.SparseBlocked``): the
oracle only ever walks the tiles it is given, folding each output block's
partials with the semiring's segment reduce.  Padding tiles carry
(rows, cols) == -1 and values == semiring zero; they are routed to an
overflow segment that is sliced off, so the oracle is safe for any fill
value.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.semiring import Semiring


def spmv_blocked_ref(
    tiles: jax.Array,  # (T, B, B) float32, padded with sr.zero
    rows: jax.Array,  # (T,) int32 source block index, -1 = padding
    cols: jax.Array,  # (T,) int32 destination block index, -1 = padding
    x: jax.Array,  # (n_vblocks * B,) float32
    sr: Semiring,
    n_out_blocks: int | None = None,
) -> jax.Array:
    T, B, _ = tiles.shape
    nvb = x.shape[0] // B
    nob = n_out_blocks if n_out_blocks is not None else nvb
    xb = x.reshape(nvb, B)[jnp.maximum(rows, 0)]  # (T, B)
    prod = sr.mul(xb[:, :, None], tiles)  # (T, B, B)
    part = sr.add_reduce(prod, 1)  # (T, B) per-tile output-block partial
    # segment-reduce the partials by output block; padding tiles fold into
    # an overflow segment (nob) that never reaches the caller, and blocks
    # with no tiles come back as the semiring zero (segment identity).
    seg = jnp.where(cols >= 0, cols, nob)
    y = sr.segment_reduce(part, seg, nob + 1)[:nob]
    return y.reshape(-1)
