"""Pure-jnp oracle for the blocked semiring SpMV.

y[cb*B + j] = add-reduce over tiles t with col(t)==cb, over i of
              mul(x[row(t)*B + i], tiles[t, i, j])

Padding tiles carry (rows, cols) == -1 and values == semiring zero; they are
masked out explicitly so the oracle is safe for any fill value.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.semiring import Semiring


def spmv_blocked_ref(
    tiles: jax.Array,  # (T, B, B) float32, padded with sr.zero
    rows: jax.Array,  # (T,) int32 source block index, -1 = padding
    cols: jax.Array,  # (T,) int32 destination block index, -1 = padding
    x: jax.Array,  # (n_vblocks * B,) float32
    sr: Semiring,
    n_out_blocks: int | None = None,
) -> jax.Array:
    T, B, _ = tiles.shape
    nvb = x.shape[0] // B
    nob = n_out_blocks if n_out_blocks is not None else nvb
    xb = x.reshape(nvb, B)[jnp.maximum(rows, 0)]  # (T, B)
    prod = sr.mul(xb[:, :, None], tiles)  # (T, B, B)
    part = sr.add_reduce(prod, 1)  # (T, B)
    part = jnp.where((cols >= 0)[:, None], part,
                     jnp.asarray(sr.zero, prod.dtype))
    y = sr.full((nob, B), prod.dtype)
    y = sr.scatter_add(y, jnp.maximum(cols, 0), part)
    return y.reshape(-1)
