"""jit'd dispatch wrapper for the blocked semiring SpMV.

``spmv_blocked(... , use_pallas=...)`` picks the Pallas kernel on TPU (or in
interpret mode when forced) and the pure-jnp oracle otherwise.  Both paths
take identical arguments and produce identical results — the oracle is the
reference the kernel sweep tests assert against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.semiring import Semiring
from repro.kernels.semiring_spmm.kernel import spmv_blocked_pallas
from repro.kernels.semiring_spmm.ref import spmv_blocked_ref


def spmv_blocked(
    tiles: jax.Array,  # (T, B, B) — dense template or packed active tiles
    rows: jax.Array,  # (T,)
    cols: jax.Array,  # (T,)
    x: jax.Array,  # (nvb * B,)
    sr: Semiring,
    *,
    n_out_blocks: int | None = None,
    use_pallas: bool = False,
    interpret: bool | None = None,
    nnz: jax.Array | None = None,  # valid-tile count of a packed list
) -> jax.Array:
    """``nnz`` (block-sparse packed lists only) lets the Pallas kernel skip
    the compute of pow2-bucket padding steps; the jnp oracle's segment
    reduce already routes padding to a dropped overflow segment, so it
    ignores ``nnz``."""
    nob = n_out_blocks if n_out_blocks is not None else x.shape[0] // tiles.shape[1]
    if use_pallas:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return spmv_blocked_pallas(
            tiles, rows, cols, x,
            sr_name=sr.name, n_out_blocks=nob, interpret=interpret, nnz=nnz,
        )
    return spmv_blocked_ref(tiles, rows, cols, x, sr, n_out_blocks=nob)
