"""jit'd dispatch wrapper for the blocked semiring SpMV.

``spmv_blocked(... , use_pallas=...)`` picks the Pallas kernel on TPU (or in
interpret mode when forced) and the pure-jnp oracle otherwise.  Both paths
take identical arguments and produce identical results — the oracle is the
reference the kernel sweep tests assert against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.semiring import Semiring
from repro.kernels.semiring_spmm.kernel import spmv_blocked_pallas
from repro.kernels.semiring_spmm.ref import spmv_blocked_ref

# Backend probe cache: ``jax.default_backend()`` walks the initialized
# backend registry, which is not free on the dispatch path that every
# sweep of every superstep goes through.  The backend cannot change
# within a process, so resolve it once on first use (not at import —
# importing this module must never initialize jax device state; the
# multi-device subprocess harnesses set XLA_FLAGS first and import
# later).  Tests and the engine can still force interpret mode per call.
_DEFAULT_INTERPRET: bool | None = None


def resolved_backend() -> str:
    """The jax platform this process dispatches to, probed once."""
    global _DEFAULT_INTERPRET
    backend = jax.default_backend()
    if _DEFAULT_INTERPRET is None:
        _DEFAULT_INTERPRET = backend != "tpu"
    return backend


def default_interpret() -> bool:
    """Whether Pallas kernels should run interpreted (cached probe)."""
    global _DEFAULT_INTERPRET
    if _DEFAULT_INTERPRET is None:
        _DEFAULT_INTERPRET = jax.default_backend() != "tpu"
    return _DEFAULT_INTERPRET


def spmv_blocked(
    tiles: jax.Array,  # (T, B, B) — dense template or packed active tiles
    rows: jax.Array,  # (T,)
    cols: jax.Array,  # (T,)
    x: jax.Array,  # (nvb * B,)
    sr: Semiring,
    *,
    n_out_blocks: int | None = None,
    use_pallas: bool = False,
    interpret: bool | None = None,
    nnz: jax.Array | None = None,  # valid-tile count of a packed list
) -> jax.Array:
    """``nnz`` (block-sparse packed lists only) lets the Pallas kernel skip
    the compute of pow2-bucket padding steps; the jnp oracle's segment
    reduce already routes padding to a dropped overflow segment, so it
    ignores ``nnz``."""
    nob = n_out_blocks if n_out_blocks is not None else x.shape[0] // tiles.shape[1]
    if use_pallas:
        if interpret is None:
            interpret = default_interpret()
        return spmv_blocked_pallas(
            tiles, rows, cols, x,
            sr_name=sr.name, n_out_blocks=nob, interpret=interpret, nnz=nnz,
        )
    return spmv_blocked_ref(tiles, rows, cols, x, sr, n_out_blocks=nob)
