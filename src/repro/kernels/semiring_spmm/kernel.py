"""Pallas TPU kernel: blocked semiring SpMV (the paper's compute hot-spot,
TPU-adapted per DESIGN.md §2).

One grid step processes one (B x B) adjacency tile resident in VMEM.  Tiles
are pre-sorted by destination (column) block — ``repro.core.blocked``
guarantees this — so the sequential TPU grid revisits each output block in a
contiguous run and the kernel can initialize it on first touch and combine
in place afterwards (classic scalar-prefetch block-sparse pattern).

Padding tiles (cols == -1 in the caller) are redirected to a dummy output
block at index ``n_out_blocks`` which is sliced off afterwards; they sort
last, preserving the contiguous-runs invariant.

* plus_mul  — the (1,B)x(B,B) product runs on the MXU.
* min_plus  — broadcast-add + min-reduce on the VPU (no MXU analogue of a
  tropical matmul; B=128 keeps lanes full).

VMEM footprint per step: tile (B*B*4) + x block (B*4) + y block (B*4)
≈ 64 KiB at B=128 — far under the ~16 MiB/core VMEM budget, so the implicit
pipeline can run multi-buffered with room to spare.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params


def _spmv_kernel(rows, cols, tile_ref, x_ref, y_ref, *, sr_name: str, zero: float):
    t = pl.program_id(0)
    first = jnp.logical_or(t == 0, cols[t] != cols[jnp.maximum(t - 1, 0)])

    @pl.when(first)
    def _():
        y_ref[...] = jnp.full_like(y_ref, zero)

    xb = x_ref[0]  # (B,)
    w = tile_ref[0]  # (B, B)
    if sr_name == "plus_mul":
        part = jnp.dot(xb, w, preferred_element_type=jnp.float32)
        y_ref[0, :] = y_ref[0, :] + part
    else:  # min_plus
        part = jnp.min(xb[:, None] + w, axis=0)
        y_ref[0, :] = jnp.minimum(y_ref[0, :], part)


@functools.partial(
    jax.jit, static_argnames=("sr_name", "n_out_blocks", "interpret")
)
def spmv_blocked_pallas(
    tiles: jax.Array,  # (T, B, B) float32, padding tiles filled with sr zero
    rows: jax.Array,  # (T,) int32, -1 = padding
    cols: jax.Array,  # (T,) int32, sorted ascending among valid, -1 = padding
    x: jax.Array,  # (nvb * B,) float32
    *,
    sr_name: str,
    n_out_blocks: int,
    interpret: bool = True,
) -> jax.Array:
    T, B, _ = tiles.shape
    nvb = x.shape[0] // B
    zero = 0.0 if sr_name == "plus_mul" else float(jnp.inf)

    rows_c = jnp.maximum(rows, 0)  # padding reads block 0, contributes zero
    cols_c = jnp.where(cols < 0, n_out_blocks, cols)  # padding -> dummy block

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, B, B), lambda t, r, c: (t, 0, 0)),
            pl.BlockSpec((1, B), lambda t, r, c: (r[t], 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda t, r, c: (c[t], 0)),
    )
    kernel = functools.partial(_spmv_kernel, sr_name=sr_name, zero=zero)
    y = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out_blocks + 1, B), jnp.float32),
        interpret=interpret,
        compiler_params=pallas_compiler_params(
            dimension_semantics=("arbitrary",),  # sequential grid: accumulation
        ),
    )(rows_c, cols_c, tiles, x.reshape(nvb, B))
    y = y[:n_out_blocks]
    # blocks never touched by a valid tile hold uninitialized memory
    touched = jnp.zeros((n_out_blocks + 1,), jnp.bool_).at[cols_c].set(True)
    return jnp.where(touched[:n_out_blocks, None], y, zero).reshape(-1)
