"""Pallas TPU kernel: blocked semiring SpMV (the paper's compute hot-spot,
TPU-adapted per DESIGN.md §2).

One grid step processes one (B x B) adjacency tile resident in VMEM.  The
tile list may be the dense template list or a block-sparse *packed*
active-tile list (``repro.core.blocked.SparseBlocked``): either way, tiles
are pre-sorted by destination (column) block — ``repro.core.blocked``
guarantees this for the template order, and a packed subset preserves
it — so the sequential TPU grid revisits each output block in a contiguous
run and the kernel can initialize it on first touch and combine in place
afterwards (classic scalar-prefetch block-sparse pattern).

Padding tiles (cols == -1 in the caller) are redirected to a dummy output
block at index ``n_out_blocks`` which is sliced off afterwards; they sort
last, preserving the contiguous-runs invariant.  When the caller passes
the packed list's valid-tile count (``nnz``, a scalar-prefetch value), the
kernel additionally skips the VPU/MXU work of every padding step — the
pow2-bucket padding then costs only its (pipelined) DMAs, so the walk is
effectively over the active-tile list alone.

* plus_mul  — the (1,B)x(B,B) product runs on the MXU.
* min_plus  — broadcast-add + min-reduce on the VPU (no MXU analogue of a
  tropical matmul; B=128 keeps lanes full).

VMEM footprint per step: tile (B*B*4) + x block (B*4) + y block (B*4)
≈ 64 KiB at B=128 — far under the ~16 MiB/core VMEM budget, so the implicit
pipeline can run multi-buffered with room to spare.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params


def _spmv_body(t, cols, tile_ref, x_ref, y_ref, *, sr_name: str, zero: float):
    first = jnp.logical_or(t == 0, cols[t] != cols[jnp.maximum(t - 1, 0)])

    @pl.when(first)
    def _():
        y_ref[...] = jnp.full_like(y_ref, zero)

    xb = x_ref[0]  # (B,)
    w = tile_ref[0]  # (B, B)
    if sr_name == "plus_mul":
        part = jnp.dot(xb, w, preferred_element_type=jnp.float32)
        y_ref[0, :] = y_ref[0, :] + part
    else:  # min_plus
        part = jnp.min(xb[:, None] + w, axis=0)
        y_ref[0, :] = jnp.minimum(y_ref[0, :], part)


def _spmv_kernel(rows, cols, tile_ref, x_ref, y_ref, *, sr_name: str,
                 zero: float):
    _spmv_body(pl.program_id(0), cols, tile_ref, x_ref, y_ref,
               sr_name=sr_name, zero=zero)


def _spmv_kernel_nnz(rows, cols, nnz, tile_ref, x_ref, y_ref, *,
                     sr_name: str, zero: float):
    t = pl.program_id(0)

    # packed active-tile walk: steps past the valid count are pure padding
    # (pow2 bucket) — skip their compute entirely; their (clamped) DMAs
    # overlap the pipeline and their dummy output block is sliced off.
    @pl.when(t < nnz[0])
    def _():
        _spmv_body(t, cols, tile_ref, x_ref, y_ref, sr_name=sr_name,
                   zero=zero)


@functools.partial(
    jax.jit, static_argnames=("sr_name", "n_out_blocks", "interpret")
)
def spmv_blocked_pallas(
    tiles: jax.Array,  # (T, B, B) float32, padding tiles filled with sr zero
    rows: jax.Array,  # (T,) int32, -1 = padding
    cols: jax.Array,  # (T,) int32, sorted ascending among valid, -1 = padding
    x: jax.Array,  # (nvb * B,) float32
    *,
    sr_name: str,
    n_out_blocks: int,
    interpret: bool = True,
    nnz: jax.Array | None = None,  # () or (1,) int32 valid-tile count
) -> jax.Array:
    T, B, _ = tiles.shape
    nvb = x.shape[0] // B
    zero = 0.0 if sr_name == "plus_mul" else float(jnp.inf)

    rows_c = jnp.maximum(rows, 0)  # padding reads block 0, contributes zero
    cols_c = jnp.where(cols < 0, n_out_blocks, cols)  # padding -> dummy block

    if nnz is None:
        n_prefetch = 2
        prefetch = (rows_c, cols_c)
        kernel = functools.partial(_spmv_kernel, sr_name=sr_name, zero=zero)
        tile_spec = pl.BlockSpec((1, B, B), lambda t, r, c: (t, 0, 0))
        x_spec = pl.BlockSpec((1, B), lambda t, r, c: (r[t], 0))
        out_spec = pl.BlockSpec((1, B), lambda t, r, c: (c[t], 0))
    else:
        n_prefetch = 3
        prefetch = (rows_c, cols_c, jnp.asarray(nnz, jnp.int32).reshape(1))
        kernel = functools.partial(_spmv_kernel_nnz, sr_name=sr_name,
                                   zero=zero)
        tile_spec = pl.BlockSpec((1, B, B), lambda t, r, c, n: (t, 0, 0))
        x_spec = pl.BlockSpec((1, B), lambda t, r, c, n: (r[t], 0))
        out_spec = pl.BlockSpec((1, B), lambda t, r, c, n: (c[t], 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_prefetch,
        grid=(T,),
        in_specs=[tile_spec, x_spec],
        out_specs=out_spec,
    )
    y = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out_blocks + 1, B), jnp.float32),
        interpret=interpret,
        compiler_params=pallas_compiler_params(
            dimension_semantics=("arbitrary",),  # sequential grid: accumulation
        ),
    )(*prefetch, tiles, x.reshape(nvb, B))
    y = y[:n_out_blocks]
    # blocks never touched by a valid tile hold uninitialized memory
    if nnz is None:
        touched = jnp.zeros((n_out_blocks + 1,), jnp.bool_).at[cols_c].set(True)
    else:
        valid = jnp.arange(T) < jnp.asarray(nnz, jnp.int32).reshape(())
        touched = jnp.zeros((n_out_blocks + 1,), jnp.bool_).at[
            jnp.where(valid, cols_c, n_out_blocks)
        ].set(True)
    return jnp.where(touched[:n_out_blocks, None], y, zero).reshape(-1)
