from repro.kernels.semiring_spmm.ops import spmv_blocked

__all__ = ["spmv_blocked"]
