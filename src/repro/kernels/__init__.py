"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships three files: ``kernel.py`` (pl.pallas_call + BlockSpec
VMEM tiling), ``ops.py`` (jit'd dispatch wrapper), ``ref.py`` (pure-jnp
oracle).  All kernels are validated in interpret mode against their oracle
by ``tests/test_kernels.py`` shape/dtype sweeps.

* ``semiring_spmm``     — blocked min-plus / plus-mul SpMV: the paper's
  subgraph-centric Compute hot-spot, TPU-adapted (DESIGN.md §2).
* ``flash_attention``   — tiled online-softmax prefill attention.
* ``decode_attention``  — single-token GQA attention over long KV caches.
"""
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.semiring_spmm.ops import spmv_blocked

__all__ = ["decode_attention", "flash_attention", "spmv_blocked"]
