"""Pallas TPU decode attention: one query token against a long KV cache.

Decode is memory-bound — the entire cost is streaming the KV cache through
VMEM once.  Grid = (B*K, kv_blocks); the (G, d) query tile for one KV head
group stays resident while (bk, d) K/V tiles stream; online softmax
accumulates in VMEM scratch.  GQA folds the G = H/K queries of a KV head
into the left matmul dimension so each KV byte is used G times (arithmetic
intensity ~G instead of ~1 — the GQA decode win).

For a 32k cache at bk=512 that is 64 sequential steps per (B,K) — long
enough for the implicit DMA pipeline to hide HBM latency.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_compiler_params

NEG_INF = -1e30


def _decode_kernel(
    len_ref,  # scalar prefetch: (B,) lengths
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, bk: int, G: int, n_b: int, window: int, scale: float,
):
    bkh = pl.program_id(0)  # fused (batch, kv-head) index
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    # lengths are pre-expanded to (B*K,) by the wrapper
    length = len_ref[bkh]

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    lo = j * bk
    needed = lo < length
    if window:
        needed = jnp.logical_and(needed, (j + 1) * bk - 1 > length - 1 - window)

    @pl.when(needed)
    def _():
        q = q_ref[0]  # (G, d)
        k = k_ref[0]  # (bk, d)
        v = v_ref[0]  # (bk, d)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (G, bk)
        pos = lo + jax.lax.broadcasted_iota(jnp.int32, (G, bk), 1)
        ok = pos < length
        if window:
            ok = jnp.logical_and(ok, pos > length - 1 - window)
        logits = jnp.where(ok, logits, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        p = jnp.exp(logits - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "bk", "interpret")
)
def decode_attention_pallas(
    q: jax.Array,  # (BK, G, d)
    k: jax.Array,  # (BK, S, d)
    v: jax.Array,  # (BK, S, d)
    lengths: jax.Array,  # (BK,) int32
    *,
    window: int = 0,
    bk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    BK, G, d = q.shape
    S = k.shape[1]
    bk = min(bk, S)
    assert S % bk == 0
    scale = 1.0 / float(d) ** 0.5
    kernel = functools.partial(
        _decode_kernel, bk=bk, G=G, n_b=BK, window=window, scale=scale,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BK, S // bk),
        in_specs=[
            pl.BlockSpec((1, G, d), lambda b, j, L: (b, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, L: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, L: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, d), lambda b, j, L: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, d), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BK, G, d), q.dtype),
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k, v)
