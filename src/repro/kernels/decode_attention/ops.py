"""Dispatch wrapper for single-token GQA decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_ref


def decode_attention(
    q: jax.Array,  # (B, H, d)
    k: jax.Array,  # (B, S, K, d)
    v: jax.Array,  # (B, S, K, d)
    lengths: jax.Array,  # (B,)
    *,
    window: int = 0,
    use_pallas: bool = False,
    interpret: bool | None = None,
    bk: int = 512,
) -> jax.Array:
    B, H, d = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    if not use_pallas:
        return decode_ref(q, k, v, lengths, window=window)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qg = q.reshape(B, K, G, d).reshape(B * K, G, d)
    kt = k.transpose(0, 2, 1, 3).reshape(B * K, S, d)
    vt = v.transpose(0, 2, 1, 3).reshape(B * K, S, d)
    lens = jnp.repeat(lengths, K)
    o = decode_attention_pallas(
        qg, kt, vt, lens, window=window, bk=bk, interpret=interpret
    )
    return o.reshape(B, K, G, d).reshape(B, H, d)
