"""Pure-jnp oracle for single-token GQA decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_ref(
    q: jax.Array,  # (B, H, d) one new token per sequence
    k: jax.Array,  # (B, S, K, d) cache
    v: jax.Array,  # (B, S, K, d)
    lengths: jax.Array,  # (B,) valid cache entries
    *,
    window: int = 0,  # sliding window over absolute positions; 0 = unbounded
) -> jax.Array:
    B, H, d = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    qg = q.reshape(B, K, G, d)
    logits = jnp.einsum(
        "bkgd,bskd->bkgs", qg, k, preferred_element_type=jnp.float32
    ) * scale
    pos = jnp.arange(S)[None]  # (1, S)
    ok = pos < lengths[:, None]
    if window:
        ok &= pos > (lengths[:, None] - 1 - window)
    logits = jnp.where(ok[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v)
    return out.reshape(B, H, d).astype(q.dtype)
