"""AdamW with global-norm clipping, cosine schedule, and configurable
optimizer-state dtype (bf16 moments for 100B+ archs — halves HBM at ~zero
quality cost at these scales).  Pure-JAX (no optax dependency)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"  # moments dtype ("bfloat16" for 100B+)


def lr_at(step: jax.Array, oc: OptConfig) -> jax.Array:
    warm = oc.lr * (step + 1) / max(oc.warmup_steps, 1)
    prog = jnp.clip(
        (step - oc.warmup_steps) / max(oc.total_steps - oc.warmup_steps, 1), 0, 1
    )
    cos = oc.lr * (oc.min_lr_frac + (1 - oc.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < oc.warmup_steps, warm, cos)


def init_opt_state(params: Params, oc: OptConfig) -> Dict[str, Any]:
    dt = jnp.dtype(oc.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def _is_matrix(p: jax.Array) -> bool:
    return p.ndim >= 2


def adamw_update(
    params: Params, grads: Params, state: Dict[str, Any], oc: OptConfig
) -> Tuple[Params, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step.  Returns (params, state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(step, oc)
    b1, b2 = oc.b1, oc.b2
    bc1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
    bc2 = 1 - b2 ** (step.astype(jnp.float32) + 1)
    sdt = jnp.dtype(oc.state_dtype)

    def upd(p, g, m, n):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        n32 = b2 * n.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m32 / bc1
        nhat = n32 / bc2
        delta = mhat / (jnp.sqrt(nhat) + oc.eps)
        if _is_matrix(p):  # decoupled weight decay on matrices only
            delta = delta + oc.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m32.astype(sdt), n32.astype(sdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["mu"])
    flat_n = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_m, flat_n)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_n = treedef.unflatten([o[2] for o in out])
    new_state = {"mu": new_m, "nu": new_n, "step": step + 1}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
