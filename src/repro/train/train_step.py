"""Train-step factory: value_and_grad over the model forward, optional
gradient accumulation (microbatching), optional cross-pod gradient
compression, NaN-guarded optimizer update (bad steps are skipped, not
applied — the fault-tolerance contract is "a poisoned batch never corrupts
the weights")."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import CPU_RUNTIME, Runtime
from repro.models import forward_train
from repro.train.optimizer import OptConfig, adamw_update, global_norm

Params = Any


def _split_batch(batch: Dict[str, jax.Array], k: int) -> Dict[str, jax.Array]:
    return {n: x.reshape((k, x.shape[0] // k) + x.shape[1:]) for n, x in batch.items()}


def make_train_step(
    cfg,
    runtime: Runtime = CPU_RUNTIME,
    oc: OptConfig = OptConfig(),
    *,
    accum_steps: int = 1,
    compressor=None,  # repro.dist.compression.Compressor or None
    cast_params_once: bool = False,  # §Perf: bf16-before-gather FSDP
):
    """Returns train_step(params, opt_state, [comp_state,] batch) -> ...

    ``cast_params_once`` casts matrix parameters to the compute dtype ONCE
    at step start, so XLA's per-layer FSDP all-gathers move bf16 instead of
    the f32 masters (halves the dominant collective in large dense train
    cells — EXPERIMENTS.md §Perf).  Vectors (norm scales etc.) stay f32.
    The bf16 copies are PINNED to the same sharding as the masters with
    with_sharding_constraint — without it XLA places the convert after its
    all-gather and the bytes saving evaporates (§Perf, refuted-then-fixed).
    """
    cast_shardings = None
    if cast_params_once and runtime.mesh is not None:
        from repro.dist.sharding import shardings_for_schema
        from repro.models import model_schema

        cast_shardings = shardings_for_schema(
            model_schema(cfg), runtime.rules, runtime.mesh
        )

    def loss_fn(params, mb):
        if cast_params_once:
            dt = jnp.dtype(cfg.dtype)

            def cast(p, sh):
                if p.ndim < 2:
                    return p
                c = p.astype(dt)
                if sh is not None:
                    c = jax.lax.with_sharding_constraint(c, sh)
                return c

            if cast_shardings is not None:
                params = jax.tree.map(cast, params, cast_shardings)
            else:
                params = jax.tree.map(lambda p: cast(p, None), params)
        loss, metrics = forward_train(params, mb, cfg, runtime)
        return loss, metrics

    def grads_of(params, batch):
        if accum_steps == 1:
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            return grads, metrics

        mbs = _split_batch(batch, accum_steps)

        def acc_fn(carry, mb):
            g_acc, m_acc = carry
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb
            )
            g_acc = jax.tree.map(jnp.add, g_acc, grads)
            m_acc = jax.tree.map(jnp.add, m_acc, metrics)
            return (g_acc, m_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        m0 = {"loss": 0.0, "ce": 0.0, "aux": 0.0}
        m0 = jax.tree.map(jnp.float32, m0)
        (grads, metrics), _ = jax.lax.scan(
            lambda c, mb: acc_fn(c, mb), (g0, m0), mbs
        )
        inv = 1.0 / accum_steps
        grads = jax.tree.map(lambda g: g * inv, grads)
        metrics = jax.tree.map(lambda m: m * inv, metrics)
        return grads, metrics

    def train_step(params, opt_state, batch, comp_state=None):
        grads, metrics = grads_of(params, batch)
        extra = {}
        if compressor is not None:
            grads, comp_state, cm = compressor.apply(grads, comp_state, runtime)
            extra.update(cm)
        new_params, new_opt, om = adamw_update(params, grads, opt_state, oc)
        # NaN guard: skip the update when the gradient norm is non-finite.
        good = jnp.isfinite(om["grad_norm"])
        new_params = jax.tree.map(
            lambda n, o: jnp.where(good, n, o), new_params, params
        )
        new_opt = jax.tree.map(
            lambda n, o: jnp.where(good, n, o), new_opt, opt_state
        )
        metrics = {**metrics, **om, **extra, "skipped": (~good).astype(jnp.float32)}
        out = (new_params, new_opt, metrics)
        return out + ((comp_state,) if compressor is not None else ())

    return train_step


def jit_train_step(cfg, runtime, oc, param_shardings=None, **kw):
    """jit with donated params/opt-state and explicit shardings (dry-run and
    production entry point)."""
    step = make_train_step(cfg, runtime, oc, **kw)
    return jax.jit(step, donate_argnums=(0, 1))
