"""Serving steps: jit'd prefill + single-token decode, and a host-side
generate loop (greedy / temperature sampling) for the examples."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import CPU_RUNTIME, Runtime
from repro.models import decode_step, init_serve_cache, prefill

Params = Any


def make_prefill_step(cfg, runtime: Runtime = CPU_RUNTIME):
    def fn(params, batch):
        return prefill(params, batch, cfg, runtime)

    return jax.jit(fn)


def make_decode_step(cfg, runtime: Runtime = CPU_RUNTIME):
    def fn(params, batch):
        logits, cache = decode_step(params, batch, cfg, runtime)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, logits, cache

    # donate the cache: decode must be in-place at production sizes
    return jax.jit(fn, donate_argnums=())


def generate(
    params: Params,
    prompt_tokens: jax.Array,  # (B, S)
    cfg,
    runtime: Runtime = CPU_RUNTIME,
    *,
    max_new_tokens: int = 16,
    max_len: Optional[int] = None,
    extra_inputs: Optional[Dict[str, jax.Array]] = None,
    temperature: float = 0.0,
    rng: Optional[jax.Array] = None,
) -> jax.Array:
    """Greedy/temperature generation.  Returns (B, max_new_tokens)."""
    B, S = prompt_tokens.shape
    max_len = max_len or (S + max_new_tokens + 8)
    cache = init_serve_cache(cfg, B, max_len)
    batch = {"tokens": prompt_tokens, "cache": cache, **(extra_inputs or {})}
    pf = make_prefill_step(cfg, runtime)
    dc = make_decode_step(cfg, runtime)
    logits, cache = pf(params, batch)
    offset = cfg.meta_tokens + (cfg.num_image_patches if cfg.family == "vlm" else 0)

    def sample(lg, key):
        if temperature <= 0.0:
            return jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
        return jax.random.categorical(key, lg[:, -1] / temperature).astype(jnp.int32)

    rng = rng if rng is not None else jax.random.key(0)
    toks = []
    tok = sample(logits, rng)
    toks.append(tok)
    for i in range(max_new_tokens - 1):
        rng, k = jax.random.split(rng)
        pos = jnp.full((B,), S + i + offset, jnp.int32)
        nxt, logits, cache = dc(
            params, {"tokens": tok[:, None], "pos": pos, "cache": cache}
        )
        if temperature <= 0.0:
            tok = nxt
        else:
            tok = sample(logits, k)
        toks.append(tok)
    return jnp.stack(toks, axis=1)
