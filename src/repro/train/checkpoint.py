"""GoFS-style sharded checkpointing.

The layout deliberately mirrors the paper's GoFS slice design (§V): each
parameter leaf is a *slice file*, a *manifest* (metadata slice) indexes the
tree structure / shapes / dtypes / step, and commits are atomic (write to a
temp dir, fsync, rename).  Restore is mesh-shape agnostic: leaves are stored
with their full logical shapes, so a checkpoint written on N hosts restores
onto M (elastic scaling) — resharding happens at the jit boundary.

Fault-tolerance contract:
  * a crash mid-save never corrupts the previous checkpoint (atomic rename);
  * ``restore_latest`` skips incomplete step dirs (no manifest = not
    committed);
  * retention keeps the newest K checkpoints;
  * ``async_save`` snapshots to host RAM synchronously (cheap) and writes to
    disk on a background thread so the train loop is not I/O-bound.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Params = Any

MANIFEST = "manifest.json"


def _flatten_with_paths(tree: Params) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out.append((name, leaf))
    return out


def _treedef_skeleton(tree: Params) -> Any:
    return jax.tree.map(lambda _: None, tree)


def save(
    ckpt_dir: str,
    step: int,
    state: Dict[str, Params],
    *,
    keep: int = 3,
    extra_meta: Optional[Dict[str, Any]] = None,
) -> str:
    """Synchronous atomic save.  ``state`` is an arbitrary pytree dict."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _flatten_with_paths(state)
    manifest = {"step": step, "leaves": {}, "extra": extra_meta or {}}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        fn = name.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][name] = {
            "file": fn,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _apply_retention(ckpt_dir, keep)
    return final


def _apply_retention(ckpt_dir: str, keep: int) -> None:
    steps = sorted(list_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, MANIFEST)):
                out.append(int(d[len("step_"):]))
    return sorted(out)


def restore(
    ckpt_dir: str,
    like: Dict[str, Params],
    step: Optional[int] = None,
) -> Tuple[Dict[str, Params], int]:
    """Restore into the structure of ``like`` (shapes/dtypes validated).

    ``like`` may contain ShapeDtypeStructs (abstract restore) or arrays.
    """
    steps = list_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints in {ckpt_dir}")
    step = steps[-1] if step is None else step
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    names = [n for n, _ in _flatten_with_paths(like)]
    missing = [n for n in names if n not in manifest["leaves"]]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
    arrays = []
    for name, leaf in _flatten_with_paths(like):
        meta = manifest["leaves"][name]
        arr = np.load(os.path.join(d, meta["file"]))
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{name}: shape {arr.shape} != expected {want_shape}")
        arrays.append(arr.astype(leaf.dtype))
    treedef = jax.tree.structure(like)
    return jax.tree.unflatten(treedef, arrays), step


class AsyncCheckpointer:
    """Snapshot-on-host, write-in-background checkpointer."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def save(self, step: int, state: Dict[str, Params], **kw) -> None:
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            try:
                save(self.ckpt_dir, step, snapshot, keep=self.keep, **kw)
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
