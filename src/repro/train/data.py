"""Deterministic, seekable data pipeline.

``SyntheticLMDataset`` generates token batches from a counter-based RNG
(Philox): batch ``i`` is a pure function of (seed, i), so resuming training
at step N reproduces the exact stream with O(1) seek — the property the
checkpoint/restart contract needs.  ``PackedShardDataset`` reads GoFS-style
packed token shards from disk with a prefetch thread (double buffering, the
disk analogue of the paper's slice cache).
"""
from __future__ import annotations

import json
import os
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np


class SyntheticLMDataset:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.Philox(key=self.seed, counter=step))
        # markov-ish stream so the loss is learnable, not pure noise
        base = rng.integers(
            0, self.vocab_size, size=(self.global_batch, self.seq_len + 1),
            dtype=np.int32,
        )
        tokens = base[:, :-1]
        labels = base[:, 1:].copy()
        # make ~50% of next-tokens predictable: label = (token * 7 + 1) % V
        mask = rng.random((self.global_batch, self.seq_len)) < 0.5
        labels[mask] = (tokens[mask].astype(np.int64) * 7 + 1).astype(np.int32) % self.vocab_size
        return {"tokens": tokens, "labels": labels}

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        i = step
        while True:
            yield self.batch_at(i)
            i += 1


def write_packed_shards(
    out_dir: str, tokens: np.ndarray, *, shard_tokens: int = 1 << 20
) -> None:
    """Pack a flat token stream into GoFS-like shard slices + manifest."""
    os.makedirs(out_dir, exist_ok=True)
    n = len(tokens)
    shards = []
    for i, start in enumerate(range(0, n, shard_tokens)):
        fn = f"shard_{i:05d}.npy"
        np.save(os.path.join(out_dir, fn), tokens[start : start + shard_tokens])
        shards.append({"file": fn, "start": start, "len": min(shard_tokens, n - start)})
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump({"total_tokens": n, "shards": shards}, f)


class PackedShardDataset:
    """Sequential reader over packed shards with background prefetch."""

    def __init__(self, shard_dir: str, seq_len: int, global_batch: int,
                 prefetch: int = 2):
        with open(os.path.join(shard_dir, "manifest.json")) as f:
            self.manifest = json.load(f)
        self.shard_dir = shard_dir
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.prefetch = prefetch
        self.tokens_per_batch = seq_len * global_batch

    def _read_span(self, start: int, length: int) -> np.ndarray:
        out = np.empty(length, np.int32)
        filled = 0
        for sh in self.manifest["shards"]:
            s0, s1 = sh["start"], sh["start"] + sh["len"]
            lo = max(start, s0)
            hi = min(start + length, s1)
            if lo < hi:
                arr = np.load(os.path.join(self.shard_dir, sh["file"]),
                              mmap_mode="r")
                out[lo - start : hi - start] = arr[lo - s0 : hi - s0]
                filled += hi - lo
        assert filled == length, "span out of range"
        return out

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        span = self.tokens_per_batch + self.global_batch  # +1 label per row
        start = (step * span) % max(self.manifest["total_tokens"] - span, 1)
        flat = self._read_span(start, span)
        rows = flat[: self.global_batch * (self.seq_len + 1)].reshape(
            self.global_batch, self.seq_len + 1
        )
        return {"tokens": rows[:, :-1].copy(), "labels": rows[:, 1:].copy()}

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def producer():
            i = step
            while not stop.is_set():
                q.put(self.batch_at(i))
                i += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
