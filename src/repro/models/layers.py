"""Parameter schemas + common layers (norms, MLPs, RoPE, positions).

Everything is functional pure-JAX: a module is (schema, apply).  The schema
is the single source of truth for parameter shapes, init, and *logical* axis
names; ``repro.dist.sharding`` maps logical axes onto mesh axes.  Layer
stacks store parameters with a leading ``layers`` axis and run under
``lax.scan`` so HLO size is O(1) in depth.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any  # nested dict of arrays
Schema = Any  # nested dict of ParamDef


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | embed | deep
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(key: jax.Array, d: ParamDef, dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "embed":
        return (jax.random.normal(key, d.shape) * 0.02 * d.scale).astype(dtype)
    # fan-in scaled normal
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    std = d.scale / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
    return (jax.random.normal(key, d.shape) * std).astype(dtype)


def init_params(key: jax.Array, schema: Schema, dtype=jnp.float32) -> Params:
    """Deterministic init: each leaf key is folded from its path."""

    def go(key, node, path):
        if isinstance(node, ParamDef):
            k = key
            for p in path:
                k = jax.random.fold_in(k, hash(p) % (2**31))
            return _init_leaf(k, node, dtype)
        return {name: go(key, child, path + (name,)) for name, child in node.items()}

    return go(key, schema, ())


def schema_axes(schema: Schema) -> Params:
    """Tree of logical-axis tuples mirroring the param tree."""
    if isinstance(schema, ParamDef):
        return schema.axes
    return {k: schema_axes(v) for k, v in schema.items()}


def schema_shapes(schema: Schema, dtype=jnp.float32) -> Params:
    if isinstance(schema, ParamDef):
        return jax.ShapeDtypeStruct(schema.shape, dtype)
    return {k: schema_shapes(v, dtype) for k, v in schema.items()}


def stacked(schema: Schema, n: int) -> Schema:
    """Prepend a ``layers`` axis of size n to every leaf (for lax.scan)."""
    if isinstance(schema, ParamDef):
        return dataclasses.replace(
            schema, shape=(n,) + schema.shape, axes=("layers",) + schema.axes
        )
    return {k: stacked(v, n) for k, v in schema.items()}


def init_stacked(key: jax.Array, schema: Schema, n: int, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_params(k, schema, dtype))(keys)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def norm_schema(cfg) -> Schema:
    d = cfg.d_model
    sch = {"scale": ParamDef((d,), ("embed",), "ones")}
    if cfg.norm == "layernorm":
        sch["bias"] = ParamDef((d,), ("embed",), "zeros")
    return sch


def apply_norm(p: Params, x: jax.Array, cfg) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(x, -1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), -1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x), -1, keepdims=True)
        y = x * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(dt)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def _gated(act_name: str) -> bool:
    return act_name in ("swiglu", "geglu")


def _act(act_name: str, x: jax.Array) -> jax.Array:
    if act_name in ("swiglu", "silu"):
        return jax.nn.silu(x)
    if act_name in ("geglu", "gelu"):
        return jax.nn.gelu(x)
    if act_name == "relu":
        return jax.nn.relu(x)
    if act_name == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(act_name)


def mlp_schema(cfg, d_ff: Optional[int] = None) -> Schema:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    wi_cols = 2 * f if _gated(cfg.mlp_activation) else f
    return {
        "wi": ParamDef((d, wi_cols), ("embed", "ffn")),
        "wo": ParamDef((f, d), ("ffn", "embed"), scale=1.0),
    }


def apply_mlp(p: Params, x: jax.Array, cfg) -> jax.Array:
    h = x @ p["wi"].astype(x.dtype)
    if _gated(cfg.mlp_activation):
        gate, up = jnp.split(h, 2, axis=-1)
        h = _act(cfg.mlp_activation, gate) * up
    else:
        h = _act(cfg.mlp_activation, h)
    return h @ p["wo"].astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embedding (with partial-dim rotation, GLM-style)
# --------------------------------------------------------------------------

def rope_frequencies(cfg) -> jax.Array:
    rot = int(cfg.head_dim * cfg.rope_fraction)
    rot -= rot % 2
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jax.Array, positions: jax.Array, cfg) -> jax.Array:
    """x: (..., S, n_heads, head_dim); positions: (..., S)."""
    if cfg.pos_embed != "rope":
        return x
    freqs = rope_frequencies(cfg)  # (rot/2,)
    rot = 2 * freqs.shape[0]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, rot/2)
    sin = jnp.sin(angles)[..., :, None, :]  # (..., S, 1, rot/2)
    cos = jnp.cos(angles)[..., :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2 :]
    y1 = (x1 * cos - x2 * sin).astype(x.dtype)
    y2 = (x2 * cos + x1 * sin).astype(x.dtype)
    return jnp.concatenate([y1, y2, xp], axis=-1)


def sinusoidal_positions(max_len: int, d: int) -> jax.Array:
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros((max_len, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle[:, : (d + 1) // 2]))
    return pe
