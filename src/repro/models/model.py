"""Family dispatcher: one API over dense / moe / vlm / audio / hybrid / ssm.

Public surface:
  model_schema(cfg)                  -> param schema (single source of truth)
  init_model_params(key, cfg, dtype) -> concrete params
  abstract_params(cfg, dtype)        -> ShapeDtypeStruct tree (dry-run)
  forward_train(params, batch, cfg, runtime) -> (loss, metrics)
  prefill(params, batch, cfg, runtime)       -> (logits_last, cache)
  decode_step(params, batch, cfg, runtime)   -> (logits, new_cache)
  init_serve_cache(cfg, batch, max_len)      -> family-appropriate cache
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import CPU_RUNTIME, Runtime, embed_lookup, lm_head_loss, lm_head_logits
from repro.models import encdec, hybrid, transformer, xlstm
from repro.models.layers import apply_norm, init_params, schema_axes, schema_shapes

Params = Any


def model_schema(cfg) -> Any:
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.decoder_schema(cfg)
    if cfg.family == "audio":
        return encdec.encdec_schema(cfg)
    if cfg.family == "hybrid":
        return hybrid.hymba_schema(cfg)
    if cfg.family == "ssm":
        return xlstm.xlstm_schema(cfg)
    raise ValueError(cfg.family)


def init_model_params(key: jax.Array, cfg, dtype=jnp.float32) -> Params:
    return init_params(key, model_schema(cfg), dtype)


def abstract_params(cfg, dtype=jnp.float32) -> Params:
    return schema_shapes(model_schema(cfg), dtype)


def logical_axes(cfg) -> Params:
    return schema_axes(model_schema(cfg))


def _head_weight(params: Params, cfg) -> jax.Array:
    return params["embed"] if cfg.tie_embeddings else params["head"]


def _positions(B: int, S: int, offset: int = 0) -> jax.Array:
    return jnp.broadcast_to(jnp.arange(S)[None] + offset, (B, S))


# --------------------------------------------------------------------------
# Training forward
# --------------------------------------------------------------------------

def forward_train(
    params: Params, batch: Dict[str, jax.Array], cfg, runtime: Runtime = CPU_RUNTIME
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    dt = jnp.dtype(cfg.dtype)
    tokens, labels = batch["tokens"], batch["labels"]
    B, S_txt = tokens.shape
    aux = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe"):
        x = embed_lookup(params["embed"], tokens, runtime).astype(dt)
        pos = _positions(B, S_txt)
        x, _, aux = transformer.apply_stack(
            params["groups"], x, cfg, runtime, positions=pos, mode="train"
        )
        strip = 0

    elif cfg.family == "vlm":
        patches = batch["patches"].astype(dt)  # (B, P, d) stub SigLIP output
        P_img = patches.shape[1]
        xt = embed_lookup(params["embed"], tokens, runtime).astype(dt)
        xt = xt * jnp.sqrt(cfg.d_model).astype(dt)  # gemma embedding scale
        x = jnp.concatenate([patches, xt], axis=1)
        pos = _positions(B, x.shape[1])
        x, _, aux = transformer.apply_stack(
            params["groups"], x, cfg, runtime, positions=pos, mode="train",
            prefix_len=P_img,
        )
        strip = P_img

    elif cfg.family == "audio":
        frames = batch["frames"].astype(dt)
        enc_out = encdec.encode(params, frames, cfg, runtime)
        cross_kv = encdec.cross_kv_all_layers(params, enc_out, cfg)
        pos = _positions(B, S_txt)
        x = encdec.decoder_embed(params, tokens, pos, cfg, runtime).astype(dt)
        x, _, aux = encdec.decode_stack(
            params, x, cfg, runtime, positions=pos, cross_kv=cross_kv, mode="train"
        )
        strip = 0

    elif cfg.family == "hybrid":
        xt = embed_lookup(params["embed"], tokens, runtime).astype(dt)
        M = cfg.meta_tokens
        meta = jnp.broadcast_to(params["meta"].astype(dt)[None], (B, M, cfg.d_model))
        x = jnp.concatenate([meta, xt], axis=1)
        pos = _positions(B, x.shape[1])
        x, _ = hybrid.apply_hymba_stack(
            params["layers"], x, cfg, runtime, positions=pos, mode="train"
        )
        strip = M

    elif cfg.family == "ssm":
        x = embed_lookup(params["embed"], tokens, runtime).astype(dt)
        x, _ = xlstm.apply_xlstm_stack(
            params["supers"], x, cfg, runtime, mode="train"
        )
        strip = 0
    else:
        raise ValueError(cfg.family)

    x = apply_norm(params["ln_f"], x[:, strip:], cfg)
    loss_ce = lm_head_loss(x, _head_weight(params, cfg), labels, runtime,
                           valid_vocab=cfg.vocab_size)
    loss = loss_ce + cfg.moe.aux_loss_weight * aux
    return loss, {"loss": loss, "ce": loss_ce, "aux": aux}


# --------------------------------------------------------------------------
# Serving: prefill + single-token decode
# --------------------------------------------------------------------------

def init_serve_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    if cfg.family in ("dense", "moe"):
        return transformer.init_cache(cfg, batch, max_len, dtype)
    if cfg.family == "vlm":
        return transformer.init_cache(cfg, batch, max_len + cfg.num_image_patches, dtype)
    if cfg.family == "audio":
        return encdec.init_encdec_cache(cfg, batch, max_len, dtype)
    if cfg.family == "hybrid":
        return hybrid.init_hymba_cache(cfg, batch, max_len, dtype)
    if cfg.family == "ssm":
        return xlstm.init_xlstm_state(cfg, batch)
    raise ValueError(cfg.family)


def prefill(
    params: Params, batch: Dict[str, Any], cfg, runtime: Runtime = CPU_RUNTIME
) -> Tuple[jax.Array, Any]:
    """Fill the cache from a prompt.  batch: tokens (B, S) [+ patches/frames],
    cache (pre-initialized).  Returns (last-token logits, cache)."""
    dt = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    cache = batch["cache"]
    B, S_txt = tokens.shape

    if cfg.family in ("dense", "moe"):
        x = embed_lookup(params["embed"], tokens, runtime).astype(dt)
        pos = _positions(B, S_txt)
        x, cache, _ = transformer.apply_stack(
            params["groups"], x, cfg, runtime, positions=pos, mode="prefill",
            cache=cache,
        )
    elif cfg.family == "vlm":
        patches = batch["patches"].astype(dt)
        P_img = patches.shape[1]
        xt = embed_lookup(params["embed"], tokens, runtime).astype(dt)
        xt = xt * jnp.sqrt(cfg.d_model).astype(dt)
        x = jnp.concatenate([patches, xt], axis=1)
        pos = _positions(B, x.shape[1])
        x, cache, _ = transformer.apply_stack(
            params["groups"], x, cfg, runtime, positions=pos, mode="prefill",
            cache=cache, prefix_len=P_img,
        )
    elif cfg.family == "audio":
        enc_out = encdec.encode(params, batch["frames"].astype(dt), cfg, runtime)
        cross_kv = encdec.cross_kv_all_layers(params, enc_out, cfg)
        cross_kv = jax.tree.map(lambda a: a.astype(jnp.bfloat16), cross_kv)
        pos = _positions(B, S_txt)
        x = encdec.decoder_embed(params, tokens, pos, cfg, runtime).astype(dt)
        x, self_cache, _ = encdec.decode_stack(
            params, x, cfg, runtime, positions=pos, cross_kv=cross_kv,
            mode="prefill", cache=batch["cache"]["self"],
        )
        cache = {"self": self_cache, "cross": cross_kv}
    elif cfg.family == "hybrid":
        xt = embed_lookup(params["embed"], tokens, runtime).astype(dt)
        M = cfg.meta_tokens
        meta = jnp.broadcast_to(params["meta"].astype(dt)[None], (B, M, cfg.d_model))
        x = jnp.concatenate([meta, xt], axis=1)
        pos = _positions(B, x.shape[1])
        x, cache = hybrid.apply_hymba_stack(
            params["layers"], x, cfg, runtime, positions=pos, mode="prefill",
            cache=cache,
        )
    elif cfg.family == "ssm":
        x = embed_lookup(params["embed"], tokens, runtime).astype(dt)
        x, cache = xlstm.apply_xlstm_stack(
            params["supers"], x, cfg, runtime, mode="prefill", state=cache
        )
    else:
        raise ValueError(cfg.family)

    x_last = apply_norm(params["ln_f"], x[:, -1:], cfg)
    logits = lm_head_logits(x_last, _head_weight(params, cfg), runtime,
                             valid_vocab=cfg.vocab_size)
    return logits, cache


def decode_step(
    params: Params, batch: Dict[str, Any], cfg, runtime: Runtime = CPU_RUNTIME
) -> Tuple[jax.Array, Any]:
    """One new token against the cache.  batch: tokens (B,1), pos (B,), cache."""
    dt = jnp.dtype(cfg.dtype)
    tokens, cache = batch["tokens"], batch["cache"]
    B = tokens.shape[0]
    pos = batch["pos"][:, None]  # (B,1) absolute position of the new token

    if cfg.family in ("dense", "moe", "vlm"):
        x = embed_lookup(params["embed"], tokens, runtime).astype(dt)
        if cfg.family == "vlm":
            x = x * jnp.sqrt(cfg.d_model).astype(dt)
        prefix = cfg.num_image_patches if cfg.family == "vlm" else 0
        x, cache, _ = transformer.apply_stack(
            params["groups"], x, cfg, runtime, positions=pos, mode="decode",
            cache=cache, prefix_len=prefix,
        )
    elif cfg.family == "audio":
        x = encdec.decoder_embed(params, tokens, pos, cfg, runtime).astype(dt)
        x, self_cache, _ = encdec.decode_stack(
            params, x, cfg, runtime, positions=pos, cross_kv=cache["cross"],
            mode="decode", cache=cache["self"],
        )
        cache = {"self": self_cache, "cross": cache["cross"]}
    elif cfg.family == "hybrid":
        x = embed_lookup(params["embed"], tokens, runtime).astype(dt)
        x, cache = hybrid.apply_hymba_stack(
            params["layers"], x, cfg, runtime, positions=pos, mode="decode",
            cache=cache,
        )
    elif cfg.family == "ssm":
        x = embed_lookup(params["embed"], tokens, runtime).astype(dt)
        x, cache = xlstm.apply_xlstm_stack(
            params["supers"], x, cfg, runtime, mode="decode", state=cache
        )
    else:
        raise ValueError(cfg.family)

    x = apply_norm(params["ln_f"], x, cfg)
    logits = lm_head_logits(x, _head_weight(params, cfg), runtime,
                             valid_vocab=cfg.vocab_size)
    return logits, cache
