"""Mixture-of-Experts layer: top-k routing, capacity-bounded sort/scatter
dispatch, expert parallelism via shard_map all-to-all, switch-style aux loss.

Two execution paths share the same parameters and routing math:

* ``moe_apply_local``  — single-device (or data-parallel-replicated-experts)
  grouped compute.  Used in CPU smoke tests and as the oracle for the EP path.
* ``moe_apply_ep``     — expert parallelism: tokens are sequence-sharded over
  the TP mesh axis, redistributed to the devices owning their experts with an
  ``all_to_all``, processed by the local expert group, and sent back.  This is
  the deployment path inside the jitted step (shard_map region).

Token overflow beyond ``capacity_factor`` is dropped (contributes only the
residual/shared-expert path), matching switch/dbrx semantics.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.layers import ParamDef, _act, _gated

Params = Any


def moe_schema(cfg) -> Dict[str, ParamDef]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    wi_cols = 2 * f if _gated(cfg.mlp_activation) else f
    # Expert weights shard over the EP axis only ("experts" -> model); the
    # within-expert dims use "expert_inner" (-> None) so one PartitionSpec
    # never maps two dims to the same mesh axis.
    sch = {
        "router": ParamDef((d, e), ("embed", "experts_r"), scale=0.1),
        "wi": ParamDef((e, d, wi_cols), ("experts", "embed", "expert_inner")),
        "wo": ParamDef((e, f, d), ("experts", "expert_inner", "embed")),
    }
    if cfg.moe.shared_expert:
        sch["shared_wi"] = ParamDef((d, wi_cols), ("embed", "ffn"))
        sch["shared_wo"] = ParamDef((f, d), ("ffn", "embed"))
    return sch


def _route(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (T, d) -> (topk_gate (T,k) fp32, topk_idx (T,k) int32, gates (T,E))."""
    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)  # (T, E)
    top_g, top_i = jax.lax.top_k(gates, cfg.moe.top_k)
    top_g = top_g / jnp.maximum(jnp.sum(top_g, -1, keepdims=True), 1e-9)
    return top_g, top_i.astype(jnp.int32), gates


def _aux_stats(gates: jax.Array, top_i: jax.Array, num_experts: int):
    """(density, frac) for the switch load-balance loss; kept separate so
    the EP path can pmean each BEFORE the product (exact global loss)."""
    density = jnp.mean(gates, axis=0)  # (E,)
    onehot = jax.nn.one_hot(top_i[:, 0], num_experts, dtype=jnp.float32)
    frac = jnp.mean(onehot, axis=0)
    return density, frac


def _aux_loss(gates: jax.Array, top_i: jax.Array, num_experts: int) -> jax.Array:
    """Switch-transformer load-balance loss."""
    density, frac = _aux_stats(gates, top_i, num_experts)
    return num_experts * jnp.sum(density * frac)


def _dispatch(
    x: jax.Array, top_g: jax.Array, top_i: jax.Array, num_experts: int, capacity: int
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Sort-free scatter dispatch.  x:(T,d) -> buffer (E, C, d).

    Returns (buffer, slot (T,k), keep (T,k) fp32, flat order info for combine).
    """
    T, k = top_i.shape
    # position of (t, j) within its expert = count of same-expert assignments
    # with smaller flat index; computed via cumsum over one-hot.
    flat_e = top_i.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot  # exclusive cumsum
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]  # (T*k,)
    keep = (slot < capacity).astype(x.dtype)
    slot = jnp.minimum(slot, capacity - 1)
    tok = jnp.repeat(jnp.arange(T), k)
    buf = jnp.zeros((num_experts, capacity, x.shape[-1]), x.dtype)
    buf = buf.at[flat_e, slot].add(x[tok] * keep[:, None])
    return buf, slot.reshape(T, k), keep.reshape(T, k), tok


def _expert_ffn(wi: jax.Array, wo: jax.Array, buf: jax.Array, cfg) -> jax.Array:
    """buf: (E, C, d) -> (E, C, d) through each expert's MLP."""
    h = jnp.einsum("ecd,edf->ecf", buf, wi.astype(buf.dtype))
    if _gated(cfg.mlp_activation):
        gate, up = jnp.split(h, 2, axis=-1)
        h = _act(cfg.mlp_activation, gate) * up
    else:
        h = _act(cfg.mlp_activation, h)
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(buf.dtype))


def _combine(
    buf_out: jax.Array, top_g: jax.Array, top_i: jax.Array,
    slot: jax.Array, keep: jax.Array, T: int,
) -> jax.Array:
    """Gather expert outputs back to token order, weighted by gates."""
    k = top_i.shape[1]
    flat_e = top_i.reshape(-1)
    flat_s = slot.reshape(-1)
    picked = buf_out[flat_e, flat_s]  # (T*k, d)
    w = (top_g * keep.astype(top_g.dtype)).reshape(-1, 1).astype(picked.dtype)
    picked = picked * w
    return jnp.sum(picked.reshape(T, k, -1), axis=1)


def _capacity(tokens: int, cfg) -> int:
    c = int(tokens * cfg.moe.top_k * cfg.moe.capacity_factor / cfg.moe.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8, floor at 8


def moe_apply_local(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """(B, S, d) -> (B, S, d), aux loss.  No expert parallelism."""
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    top_g, top_i, gates = _route(p, xt, cfg)
    aux = _aux_loss(gates, top_i, cfg.moe.num_experts)
    C = _capacity(B * S, cfg)
    buf, slot, keep, _ = _dispatch(xt, top_g, top_i, cfg.moe.num_experts, C)
    buf = _expert_ffn(p["wi"], p["wo"], buf, cfg)
    out = _combine(buf, top_g, top_i, slot, keep, B * S)
    if cfg.moe.shared_expert:
        h = xt @ p["shared_wi"].astype(xt.dtype)
        g, u = jnp.split(h, 2, axis=-1)
        out = out + (_act(cfg.mlp_activation, g) * u) @ p["shared_wo"].astype(xt.dtype)
    return out.reshape(B, S, d), aux


def moe_apply_ep(
    p: Params, x: jax.Array, cfg, mesh, *,
    dp_axes: Tuple[str, ...], tp_axis: str,
) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE: shard_map region inside the jitted step.

    x is (B, S, d) global; inside the region each device sees its
    (B/dp, S/tp, d) block.  Experts are sharded over ``tp_axis``.
    """
    E = cfg.moe.num_experts
    tp = mesh.shape[tp_axis]
    assert E % tp == 0, f"experts {E} must divide over tp={tp}"
    e_local = E // tp

    def local_fn(xl, router, wi_l, wo_l, *shared):
        # xl: (Bl, Sl, d); wi_l: (e_local, d, F2); experts sharded over tp.
        Bl, Sl, d = xl.shape
        T = Bl * Sl
        xt = xl.reshape(T, d)
        pr = {"router": router}
        top_g, top_i, gates = _route(pr, xt, cfg)
        density, frac = _aux_stats(gates, top_i, E)
        axes_all = (tp_axis,) + tuple(dp_axes)
        density = jax.lax.pmean(density, axes_all)
        frac = jax.lax.pmean(frac, axes_all)
        aux = E * jnp.sum(density * frac)  # exact global load-balance loss
        C = _capacity(T, cfg)
        buf, slot, keep, _ = _dispatch(xt, top_g, top_i, E, C)  # (E, C, d)
        # redistribute: split E across tp peers, exchange
        buf = buf.reshape(tp, e_local, C, d)
        buf = jax.lax.all_to_all(buf, tp_axis, 0, 0, tiled=False)  # (tp, e_local, C, d)
        buf = buf.transpose(1, 0, 2, 3).reshape(e_local, tp * C, d)
        out = _expert_ffn(wi_l, wo_l, buf, cfg)  # (e_local, tp*C, d)
        out = out.reshape(e_local, tp, C, d).transpose(1, 0, 2, 3)  # (tp, e_local, C, d)
        out = jax.lax.all_to_all(out, tp_axis, 0, 0, tiled=False)
        out = out.reshape(E, C, d)
        y = _combine(out, top_g, top_i, slot, keep, T)
        if shared:
            swi, swo = shared
            h = xt @ swi.astype(xt.dtype)
            g, u = jnp.split(h, 2, axis=-1)
            y = y + (_act(cfg.mlp_activation, g) * u) @ swo.astype(xt.dtype)
        return y.reshape(Bl, Sl, d), aux

    B_, S_, _ = x.shape
    ndp = 1
    for a in dp_axes:
        ndp *= mesh.shape[a]
    batch_axes = dp_axes if len(dp_axes) != 1 else dp_axes[0]
    batch_ok = dp_axes and B_ % max(ndp, 1) == 0 and B_ >= ndp
    seq_ok = S_ % tp == 0 and S_ >= tp  # decode: S=1 stays unsharded
    x_spec = P(batch_axes if batch_ok else None, tp_axis if seq_ok else None, None)
    shared_args = ()
    shared_specs = ()
    if cfg.moe.shared_expert:
        shared_args = (p["shared_wi"], p["shared_wo"])
        shared_specs = (P(None, None), P(None, None))
    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), P(tp_axis, None, None), P(tp_axis, None, None))
        + shared_specs,
        out_specs=(x_spec, P()),
        check_vma=False,
    )
    return fn(x, p["router"], p["wi"], p["wo"], *shared_args)


def moe_apply(
    p: Params, x: jax.Array, cfg, runtime=None
) -> Tuple[jax.Array, jax.Array]:
    """Dispatcher: EP path when a mesh runtime is provided, local otherwise."""
    if runtime is not None and runtime.mesh is not None and runtime.ep_enabled(cfg):
        return moe_apply_ep(
            p, x, cfg, runtime.mesh,
            dp_axes=runtime.dp_axes, tp_axis=runtime.tp_axis,
        )
    return moe_apply_local(p, x, cfg)
