"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential) with exponential gating + stabilizers.

mLSTM training/prefill uses the stabilized *chunkwise* form (GLA-style):
intra-chunk quadratic attention with cumulative log-gate decays, inter-chunk
(hd × hd) recurrent matrix state — O(S·c) work, O(hd²) state.  Decode is the
O(1) recurrent step.  sLSTM is inherently sequential (``lax.scan`` over time,
recurrent input precomputed in parallel outside the scan).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef, apply_norm

Params = Any


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def mlstm_schema(cfg) -> Dict[str, ParamDef]:
    d = cfg.d_model
    di = cfg.ssm.expand * d  # projection factor 2
    h = cfg.num_heads
    hd = di // h
    w = cfg.ssm.conv_width
    return {
        "up": ParamDef((d, 2 * di), ("embed", "ssm_inner")),
        "conv_w": ParamDef((w, di), (None, "ssm_inner"), scale=0.5),
        "conv_b": ParamDef((di,), ("ssm_inner",), "zeros"),
        # head count (4) is below the TP degree; shard the per-head output
        # dim instead ("ssm_head" -> model), heads replicated.
        "wq": ParamDef((h, hd, hd), (None, None, "ssm_head")),
        "wk": ParamDef((h, hd, hd), (None, None, "ssm_head")),
        "wv": ParamDef((h, hd, hd), (None, None, "ssm_head")),
        "w_gates": ParamDef((di, 2 * h), ("ssm_inner", None), scale=0.1),
        "b_gates": ParamDef((2 * h,), (None,), "zeros"),
        "gn_scale": ParamDef((di,), ("ssm_inner",), "ones"),
        "down": ParamDef((di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(w, b, x, state):
    W = w.shape[0]
    pad = state if state is not None else jnp.zeros(
        (x.shape[0], W - 1, x.shape[2]), x.dtype
    )
    xp = jnp.concatenate([pad.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    return y + b.astype(x.dtype), xp[:, -(W - 1):]


def _mlstm_chunk(q, k, v, ig, fg, state, chunk: int):
    """Stabilized chunkwise mLSTM.

    q,k,v: (B, H, S, hd); ig,fg: (B, H, S) raw gate pre-activations (fp32).
    state: dict(c (B,H,hd,hd), n (B,H,hd), m (B,H)) or None.
    Returns (out (B,H,S,hd), new_state).
    """
    B, H, S, hd = q.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        ig = jnp.pad(ig, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
        fg = jnp.pad(fg, ((0, 0), (0, 0), (0, pad)))
    S_p = S + pad
    nc = S_p // c

    def to_chunks(x):
        return x.reshape(x.shape[:2] + (nc, c) + x.shape[3:]).transpose(
            (2, 0, 1, 3) + tuple(range(4, x.ndim + 1))
        )

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)  # (nc,B,H,c,hd)
    igc, fgc = to_chunks(ig), to_chunks(fg)  # (nc,B,H,c)

    if state is None:
        c0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state["c"], state["n"], state["m"]

    scale = 1.0 / jnp.sqrt(hd)

    def step(carry, xs):
        C, N, M = carry
        qb, kb, vb, ib, fb = xs  # (B,H,c,hd) / (B,H,c)
        logf = jax.nn.log_sigmoid(fb)  # (B,H,c)
        F = jnp.cumsum(logf, axis=-1)  # inclusive cumsum of log forget
        # per-query stabilizer: m_i = max(F_i + M, cummax_{j<=i}(i_j + F_i - F_j))
        b_j = ib - F  # (B,H,c)
        cummax_b = jax.lax.associative_scan(jnp.maximum, b_j, axis=-1)
        m_i = jnp.maximum(F + M[..., None], F + cummax_b)  # (B,H,c)
        # inter-chunk contribution (q carries the 1/sqrt(hd) scale, as intra)
        w_prev = jnp.exp(F + M[..., None] - m_i)  # (B,H,c)
        inter = jnp.einsum("bhcd,bhde->bhce", qb, C) * (w_prev * scale)[..., None]
        n_inter = jnp.einsum("bhcd,bhd->bhc", qb, N) * w_prev * scale
        # intra-chunk: D_ij = exp(F_i - F_j + i_j - m_i), j <= i
        Dlog = F[..., :, None] - F[..., None, :] + ib[..., None, :] - m_i[..., :, None]
        mask = jnp.tril(jnp.ones((c, c), bool))
        D = jnp.where(mask, jnp.exp(Dlog), 0.0)  # (B,H,c,c)
        Sij = jnp.einsum("bhid,bhjd->bhij", qb, kb) * scale * D
        intra = jnp.einsum("bhij,bhjd->bhid", Sij, vb)
        n_intra = jnp.einsum("bhij->bhi", Sij * 0.0) + jnp.einsum(
            "bhid,bhjd,bhij->bhi", qb, kb, D
        ) * scale
        h_num = inter + intra
        n_tot = n_inter + n_intra
        denom = jnp.maximum(jnp.abs(n_tot), jnp.exp(-m_i))
        out = h_num / denom[..., None]
        # new state at chunk end
        F_end = F[..., -1:]
        m_state = jnp.maximum(
            F_end[..., 0] + M, jnp.max(ib + F_end - F, axis=-1)
        )  # (B,H)
        w_c = jnp.exp(F_end[..., 0] + M - m_state)
        w_j = jnp.exp(F_end - F + ib - m_state[..., None])  # (B,H,c)
        C_new = C * w_c[..., None, None] + jnp.einsum(
            "bhjd,bhje,bhj->bhde", kb, vb, w_j
        )
        N_new = N * w_c[..., None] + jnp.einsum("bhjd,bhj->bhd", kb, w_j)
        return (C_new, N_new, m_state), out

    (Cf, Nf, Mf), outs = jax.lax.scan(step, (c0, n0, m0), (qc, kc, vc, igc, fgc))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, S_p, hd)[:, :, :S]
    return out, {"c": Cf, "n": Nf, "m": Mf}


def mlstm_apply(
    p: Params, x: jax.Array, cfg, *, state=None, chunk: int = 256
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, d = x.shape
    di = cfg.ssm.expand * d
    H = cfg.num_heads
    hd = di // H
    dt = x.dtype
    up = x @ p["up"].astype(dt)
    xm, z = jnp.split(up, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    xc, new_conv = _causal_conv(p["conv_w"], p["conv_b"], xm, conv_state)
    xc = jax.nn.silu(xc)
    xch = xc.reshape(B, S, H, hd).transpose(0, 2, 1, 3).astype(jnp.float32)
    xmh = xm.reshape(B, S, H, hd).transpose(0, 2, 1, 3).astype(jnp.float32)
    q = jnp.einsum("bhsd,hde->bhse", xch, p["wq"].astype(jnp.float32))
    k = jnp.einsum("bhsd,hde->bhse", xch, p["wk"].astype(jnp.float32))
    v = jnp.einsum("bhsd,hde->bhse", xmh, p["wv"].astype(jnp.float32))
    gates = xc @ p["w_gates"].astype(dt) + p["b_gates"].astype(dt)
    ig, fg = jnp.split(gates.astype(jnp.float32), 2, axis=-1)  # (B,S,H)
    ig = ig.transpose(0, 2, 1)
    fg = fg.transpose(0, 2, 1) + 3.0  # bias toward remembering
    ssm_state = (
        {k_: state[k_] for k_ in ("c", "n", "m")} if state is not None else None
    )
    h, new_ssm = _mlstm_chunk(q, k, v, ig, fg, ssm_state, chunk)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, di)
    # per-head group norm
    hh = h.reshape(B, S, H, hd)
    ms = jnp.mean(jnp.square(hh), -1, keepdims=True)
    hh = hh * jax.lax.rsqrt(ms + 1e-6)
    h = hh.reshape(B, S, di) * p["gn_scale"].astype(jnp.float32)
    h = h.astype(dt) * jax.nn.silu(z)
    out = h @ p["down"].astype(dt)
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv.astype(state["conv"].dtype), **new_ssm}
    return out, new_state


def init_mlstm_state(cfg, batch: int):
    d = cfg.d_model
    di = cfg.ssm.expand * d
    H = cfg.num_heads
    hd = di // H
    return {
        "conv": jnp.zeros((batch, cfg.ssm.conv_width - 1, di), jnp.bfloat16),
        "c": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def slstm_schema(cfg) -> Dict[str, ParamDef]:
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    f = -(-int(d * 4 / 3) // 64) * 64  # gated FFN pf=4/3, 64-aligned (TP)
    return {
        "w_in": ParamDef((d, 4 * d), ("embed", None)),
        "b_in": ParamDef((4 * d,), (None,), "zeros"),
        "r": ParamDef((h, hd, 4 * hd), (None, None, "ssm_head"), scale=0.5),
        "gn_scale": ParamDef((d,), ("embed",), "ones"),
        "ffn_wi": ParamDef((d, 2 * f), ("embed", "ffn")),
        "ffn_wo": ParamDef((f, d), ("ffn", "embed")),
    }


def slstm_apply(
    p: Params, x: jax.Array, cfg, *, state=None
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: (B, S, d).  state: dict(h, c, n, m) each (B, d)."""
    B, S, d = x.shape
    H = cfg.num_heads
    hd = d // H
    dt = x.dtype
    zx = (x @ p["w_in"].astype(dt) + p["b_in"].astype(dt)).astype(jnp.float32)
    if state is None:
        h0 = jnp.zeros((B, d), jnp.float32)
        c0 = jnp.zeros((B, d), jnp.float32)
        n0 = jnp.ones((B, d), jnp.float32)
        m0 = jnp.zeros((B, d), jnp.float32)
    else:
        h0, c0, n0, m0 = (state[k].astype(jnp.float32) for k in ("h", "c", "n", "m"))
    r = p["r"].astype(jnp.float32)

    def step(carry, zx_t):
        h, cc, n, m = carry  # (B, d)
        hh = h.reshape(B, H, hd)
        rec = jnp.einsum("bhd,hde->bhe", hh, r)  # (B, H, 4*hd)
        # per-head recurrence feeds the 4 gates: regroup (H, 4, hd) -> (4, d)
        rec4 = rec.reshape(B, H, 4, hd).transpose(0, 2, 1, 3).reshape(B, 4 * d)
        zz = zx_t + rec4
        zt, it, ft, ot = jnp.split(zz, 4, axis=-1)
        zt = jnp.tanh(zt)
        m_new = jnp.maximum(jax.nn.log_sigmoid(ft) + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(jax.nn.log_sigmoid(ft) + m - m_new)
        c_new = f_p * cc + i_p * zt
        n_new = f_p * n + i_p
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    zx_seq = zx.reshape(B, S, 4 * d).transpose(1, 0, 2)  # (S, B, 4d)
    (hf, cf, nf, mf), hs = jax.lax.scan(step, (h0, c0, n0, m0), zx_seq)
    hs = hs.transpose(1, 0, 2)  # (B, S, d)
    # group norm per head
    hh = hs.reshape(B, S, H, hd)
    msq = jnp.mean(jnp.square(hh), -1, keepdims=True)
    hs = (hh * jax.lax.rsqrt(msq + 1e-6)).reshape(B, S, d)
    hs = (hs * p["gn_scale"].astype(jnp.float32)).astype(dt)
    # gated FFN (pf 4/3)
    u = hs @ p["ffn_wi"].astype(dt)
    g, uu = jnp.split(u, 2, axis=-1)
    out = (jax.nn.gelu(g) * uu) @ p["ffn_wo"].astype(dt)
    new_state = None
    if state is not None:
        new_state = {"h": hf, "c": cf, "n": nf, "m": mf}
    return out, new_state


def init_slstm_state(cfg, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"h": z, "c": z, "n": jnp.ones((batch, d), jnp.float32), "m": z}


# --------------------------------------------------------------------------
# Full xLSTM stack: superblocks of (m_per mLSTM + 1 sLSTM), scanned
# --------------------------------------------------------------------------

def _super_structure(cfg) -> Tuple[int, int]:
    """(n_super, mlstm_per_super).  48L @ 7:1 -> 6 superblocks of 7m+1s."""
    every = cfg.xlstm_slstm_every or cfg.num_layers + 1
    n_super = max(1, cfg.num_layers // every)
    m_per = cfg.num_layers // n_super - 1
    return n_super, m_per


def xlstm_schema(cfg) -> Dict:
    from repro.models.layers import ParamDef, norm_schema, stacked

    n_super, m_per = _super_structure(cfg)
    mblock = {"ln": norm_schema(cfg), "core": mlstm_schema(cfg)}
    sblock = {"ln": norm_schema(cfg), "core": slstm_schema(cfg)}
    return {
        "embed": ParamDef((cfg.vocab_padded, cfg.d_model), ("vocab", "embed"), "embed"),
        "supers": stacked(
            {"mlstm": stacked(mblock, m_per), "slstm": sblock}, n_super
        ),
        "ln_f": norm_schema(cfg),
        "head": ParamDef((cfg.vocab_padded, cfg.d_model), ("vocab", "embed")),
    }


def apply_xlstm_stack(
    supers: Params, x: jax.Array, cfg, runtime, *, mode: str = "train", state=None
) -> Tuple[jax.Array, Optional[Params]]:
    """state: {"mlstm": stacked (n_super, m_per, ...), "slstm": (n_super, ...)}"""

    def mblock_fn(xc, xs):
        mp, mstate = xs
        h = apply_norm(mp["ln"], xc, cfg)
        y, new_state = mlstm_apply(mp["core"], h, cfg, state=mstate)
        return xc + y, new_state

    def sblock_fn(xc, sp, sstate):
        h = apply_norm(sp["ln"], xc, cfg)
        y, new_state = slstm_apply(sp["core"], h, cfg, state=sstate)
        return xc + y, new_state

    def super_fn(xc, xs):
        gp, gstate = xs
        mstate = None if gstate is None else gstate["mlstm"]
        remat = mode == "train" and cfg.remat != "none"
        mfn = jax.checkpoint(mblock_fn) if remat else mblock_fn
        # unroll: m_per <= 7 blocks; keeps cost_analysis exact for the
        # dry-run two-point fit (nested scan bodies are counted once)
        xc, new_m = jax.lax.scan(mfn, xc, (gp["mlstm"], mstate), unroll=True)
        sfn = jax.checkpoint(sblock_fn) if remat else sblock_fn
        xc, new_s = sfn(xc, gp["slstm"], None if gstate is None else gstate["slstm"])
        if gstate is None:
            return xc, None
        return xc, {"mlstm": new_m, "slstm": new_s}

    x, new_state = jax.lax.scan(super_fn, x, (supers, state),
                                unroll=cfg.scan_unroll)
    return x, new_state


def init_xlstm_state(cfg, batch: int):
    n_super, m_per = _super_structure(cfg)

    def rep(tree, n):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), tree)

    return {
        "mlstm": rep(rep(init_mlstm_state(cfg, batch), m_per), n_super),
        "slstm": rep(init_slstm_state(cfg, batch), n_super),
    }
