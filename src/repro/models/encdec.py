"""Whisper-style encoder-decoder backbone.

The audio frontend (two stride-2 convs over mel spectrogram) is a STUB per
the assignment: inputs are precomputed frame embeddings (B, 1500, d_model).
Encoder: bidirectional self-attention layers.  Decoder: causal self-attention
+ cross-attention to encoder output.  Sinusoidal positions on both sides.

Serving: ``prefill`` runs the encoder once, precomputes per-layer cross K/V,
and fills the decoder self-attention cache; ``decode_step`` is a single-token
decoder step re-using both.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.layers import (
    ParamDef,
    apply_norm,
    norm_schema,
    sinusoidal_positions,
    stacked,
)
from repro.models.transformer import apply_stack, group_schema, init_cache

Params = Any


def encdec_schema(cfg) -> Dict:
    return {
        "enc_groups": stacked(group_schema(cfg, cross=False), cfg.encoder_layers),
        "enc_ln_f": norm_schema(cfg),
        "embed": ParamDef((cfg.vocab_padded, cfg.d_model), ("vocab", "embed"), "embed"),
        "dec_groups": stacked(group_schema(cfg, cross=True), cfg.num_layers),
        "ln_f": norm_schema(cfg),
        "head": ParamDef((cfg.vocab_padded, cfg.d_model), ("vocab", "embed")),
    }


def encode(params: Params, frames: jax.Array, cfg, runtime) -> jax.Array:
    """frames: (B, F, d) stub frame embeddings -> encoder hidden states."""
    B, F, d = frames.shape
    pe = sinusoidal_positions(F, d).astype(frames.dtype)
    x = frames + pe[None]
    pos = jnp.broadcast_to(jnp.arange(F)[None], (B, F))
    x, _, _ = apply_stack(
        params["enc_groups"], x, cfg, runtime,
        positions=pos, mode="train", causal=False,
    )
    return apply_norm(params["enc_ln_f"], x, cfg)


def cross_kv_all_layers(params: Params, enc_out: jax.Array, cfg):
    """Precompute cross-attention K/V for every decoder layer (stacked)."""
    xattn = params["dec_groups"]["dense"]["xattn"]  # leading (L, ...)
    return jax.vmap(lambda p: attn.make_cross_kv(p, enc_out, cfg))(xattn)


def sinusoidal_at(positions: jax.Array, d: int) -> jax.Array:
    """PE rows computed directly from (B, S) positions (no table)."""
    pos = positions.astype(jnp.float32)[..., None]  # (B,S,1)
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, None, :]
    angle = pos / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros(positions.shape + (d,), jnp.float32)
    pe = pe.at[..., 0::2].set(jnp.sin(angle))
    pe = pe.at[..., 1::2].set(jnp.cos(angle[..., : d // 2]))
    return pe


def decoder_embed(params: Params, tokens: jax.Array, positions: jax.Array, cfg, runtime):
    from repro.dist.sharding import embed_lookup

    x = embed_lookup(params["embed"], tokens, runtime)
    return x + sinusoidal_at(positions, cfg.d_model).astype(x.dtype)


def decode_stack(
    params: Params, x: jax.Array, cfg, runtime, *,
    positions: jax.Array, cross_kv, mode: str, cache=None,
):
    # cross_kv leaves are (L, B, Se, H, hd); wrap to match group structure
    cross_tree = {"dense": cross_kv}
    return apply_stack(
        params["dec_groups"], x, cfg, runtime,
        positions=positions, mode=mode, causal=True,
        cache=cache, cross_kv=cross_tree,
    )


def init_encdec_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    self_cache = init_cache(cfg, batch, max_len, dtype)
    H, hd = cfg.num_heads, cfg.head_dim
    L, Se = cfg.num_layers, cfg.encoder_seq_len
    cross = (
        jnp.zeros((L, batch, Se, H, hd), dtype),
        jnp.zeros((L, batch, Se, H, hd), dtype),
    )
    return {"self": self_cache, "cross": cross}
