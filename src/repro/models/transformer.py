"""Decoder-only transformer stack (dense / MoE / VLM backbone).

Layers are stored stacked (leading ``layers`` axis) and executed under
``lax.scan`` so HLO size and compile time are O(1) in depth.  MoE archs with
``moe_every > 1`` scan over *groups* of (moe_every-1 dense + 1 MoE) layers so
the scan body stays homogeneous.  Remat policy wraps the scan body.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.layers import (
    ParamDef,
    apply_mlp,
    apply_norm,
    init_params,
    init_stacked,
    mlp_schema,
    norm_schema,
    stacked,
)

Params = Any


def layer_schema(cfg, *, kind: str = "dense", cross: bool = False) -> Dict:
    sch = {
        "ln1": norm_schema(cfg),
        "attn": attn.attn_schema(cfg),
        "ln2": norm_schema(cfg),
    }
    if kind == "moe":
        sch["moe"] = moe_mod.moe_schema(cfg)
    else:
        sch["mlp"] = mlp_schema(cfg)
    if cross:
        sch["ln_x"] = norm_schema(cfg)
        sch["xattn"] = attn.attn_schema(cfg, cross=True)
    return sch


def _group_structure(cfg) -> Tuple[int, int, bool]:
    """(n_groups, dense_per_group, has_moe)."""
    if cfg.is_moe:
        ge = cfg.moe.moe_every
        assert cfg.num_layers % ge == 0
        return cfg.num_layers // ge, ge - 1, True
    return cfg.num_layers, 1, False


def group_schema(cfg, *, cross: bool = False) -> Dict:
    n_groups, n_dense, has_moe = _group_structure(cfg)
    if not has_moe:
        return {"dense": layer_schema(cfg, kind="dense", cross=cross)}
    sch = {"moe": layer_schema(cfg, kind="moe", cross=cross)}
    if n_dense:
        sch["dense"] = stacked(layer_schema(cfg, kind="dense", cross=cross), n_dense)
    return sch


def decoder_schema(cfg, *, cross: bool = False) -> Dict:
    n_groups, _, _ = _group_structure(cfg)
    sch = {
        "embed": ParamDef((cfg.vocab_padded, cfg.d_model), ("vocab", "embed"), "embed"),
        "groups": stacked(group_schema(cfg, cross=cross), n_groups),
        "ln_f": norm_schema(cfg),
    }
    if not cfg.tie_embeddings:
        sch["head"] = ParamDef((cfg.vocab_padded, cfg.d_model), ("vocab", "embed"))
    if cfg.meta_tokens:
        sch["meta"] = ParamDef((cfg.meta_tokens, cfg.d_model), (None, "embed"), "embed")
    return sch


def apply_layer(
    p: Params,
    x: jax.Array,
    cfg,
    runtime,
    *,
    kind: str,
    positions: jax.Array,
    causal: bool = True,
    window: Optional[jax.Array] = None,
    prefix_len: int | jax.Array = 0,
    layer_cache=None,
    cross_kv=None,
) -> Tuple[jax.Array, Any, jax.Array]:
    """One transformer layer.  Returns (x, new_cache, aux)."""
    x = runtime.activation(x)
    h = apply_norm(p["ln1"], x, cfg)
    # Pin the POST-norm bf16 output to the residual sharding so the SP->TP
    # boundary gathers bf16 h, not the f32 norm intermediate (2x bytes);
    # replicating positions (tiny) lets every device build its attention-
    # mask slice locally instead of all-gathering O(B*H*S*chunk) pred masks.
    # NOTE (§Perf, refuted): a full explicit SP->TP all-gather of h here
    # REGRESSES 2x on GQA models — XLA's choice (gather the small K/V
    # heads) moves fewer bytes than replicating h, and the replicate
    # constraint adds a gradient all-reduce on the way back.
    h = runtime.activation(h)
    if runtime.mesh is not None:
        positions = runtime.shard(positions, runtime.batch_axes, None)
    a, new_cache = attn.apply_attention(
        p["attn"], h, cfg,
        positions=positions, causal=causal, window=window,
        prefix_len=prefix_len, softcap=cfg.attn_logit_softcap,
        layer_cache=layer_cache,
        rope=(cfg.pos_embed == "rope"),
        runtime=runtime,
    )
    x = x + a
    if cross_kv is not None:
        hx = apply_norm(p["ln_x"], x, cfg)
        cx, _ = attn.apply_attention(
            p["xattn"], hx, cfg, positions=positions, cross_kv=cross_kv,
            rope=False,
        )
        x = x + cx
    h = runtime.activation(apply_norm(p["ln2"], x, cfg))
    aux = jnp.zeros((), jnp.float32)
    if kind == "moe":
        m, aux = moe_mod.moe_apply(p["moe"], h, cfg, runtime)
    else:
        m = apply_mlp(p["mlp"], h, cfg)
    x = runtime.activation(x + m)
    return x, new_cache, aux


def _remat(fn, cfg, mode: str):
    if mode != "train" or cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def apply_stack(
    groups: Params,
    x: jax.Array,
    cfg,
    runtime,
    *,
    positions: jax.Array,
    mode: str = "train",
    causal: bool = True,
    prefix_len: int | jax.Array = 0,
    cache=None,  # stacked over groups (and dense-sublayers)
    cross_kv=None,  # stacked (n_groups[, n_dense], B, Se, H, hd) k/v pair
    window_flags: Optional[jax.Array] = None,  # per-group window override
) -> Tuple[jax.Array, Any, jax.Array]:
    """Scan the group stack.  Returns (x, new_cache, aux_sum)."""
    n_groups, n_dense, has_moe = _group_structure(cfg)
    window = jnp.array(cfg.sliding_window, jnp.int32) if cfg.sliding_window else None

    def one_layer(pl, xc, kind, lcache, lcross):
        return apply_layer(
            pl, xc, cfg, runtime, kind=kind, positions=positions,
            causal=causal, window=window, prefix_len=prefix_len,
            layer_cache=lcache, cross_kv=lcross,
        )

    use_cache = cache is not None
    use_cross = cross_kv is not None
    key = "moe" if has_moe else "dense"

    def sub(tree, name):
        return None if tree is None else tree[name]

    def group_fn(x, gp, gcache, gcross):
        aux = jnp.zeros((), jnp.float32)
        new_cache = {}
        if has_moe and n_dense:
            def dense_fn(xc, dxs):
                dp, dcache, dcross = dxs
                y, c, a = one_layer(dp, xc, "dense", dcache, dcross)
                return y, (c, a)
            dxs = tuple(
                t for t in (gp["dense"], sub(gcache, "dense"), sub(gcross, "dense"))
            )
            x, (dc, da) = jax.lax.scan(
                _remat(dense_fn, cfg, mode), x, dxs
            )
            new_cache["dense"] = dc
            aux += jnp.sum(da)
        fn = _remat(
            lambda pp, xx, lc, lx: one_layer(pp, xx, key, lc, lx), cfg, mode
        )
        x, c, a = fn(gp[key], x, sub(gcache, key), sub(gcross, key))
        new_cache[key] = c
        aux += a
        return x, new_cache, aux

    def scan_body(x, xs_):
        gp = xs_[0]
        gcache = xs_[1] if use_cache else None
        gcross = xs_[-1] if use_cross else None
        x, new_cache, aux = group_fn(x, gp, gcache, gcross)
        ys = (aux,) + ((new_cache,) if use_cache else ())
        return x, ys

    xs = (groups,) + ((cache,) if use_cache else ()) + ((cross_kv,) if use_cross else ())
    x, ys = jax.lax.scan(scan_body, x, xs, unroll=cfg.scan_unroll)
    new_cache = ys[1] if use_cache else None
    return x, new_cache, jnp.sum(ys[0])


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """KV cache stacked to mirror the group structure."""
    n_groups, n_dense, has_moe = _group_structure(cfg)

    def one(n_layers_axis):
        return attn.init_kv_cache(cfg, batch, max_len, n_layers_axis, dtype)

    if not has_moe:
        return {"dense": one(n_groups)}
    cache = {"moe": one(n_groups)}
    if n_dense:
        c = attn.init_kv_cache(cfg, batch, max_len, n_groups * n_dense, dtype)
        cache["dense"] = jax.tree.map(
            lambda a: a.reshape((n_groups, n_dense) + a.shape[1:]), c
        )
    return cache
