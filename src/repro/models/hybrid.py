"""Hymba-style hybrid layer: attention heads and Mamba/SSM heads run in
PARALLEL on the same layer input; per-path RMS normalization + learnable
mixing, then a shared MLP.  128 learnable meta tokens are prepended to the
sequence at the model level (always attendable via ``prefix_len`` even under
sliding-window masking).  First/middle/last layers use global attention, the
rest sliding-window — expressed as a per-layer window array so the layer
stack stays scan-homogeneous.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    ParamDef,
    apply_mlp,
    apply_norm,
    mlp_schema,
    norm_schema,
    stacked,
)

Params = Any

GLOBAL_WINDOW = 2**30  # "unbounded" window sentinel for global-attention layers


def hymba_layer_schema(cfg) -> Dict:
    return {
        "ln1": norm_schema(cfg),
        "attn": attn.attn_schema(cfg),
        "ssm": ssm_mod.ssm_schema(cfg),
        "attn_scale": ParamDef((cfg.d_model,), ("embed",), "ones"),
        "ssm_scale": ParamDef((cfg.d_model,), ("embed",), "ones"),
        "ln2": norm_schema(cfg),
        "mlp": mlp_schema(cfg),
    }


def hymba_schema(cfg) -> Dict:
    return {
        "embed": ParamDef((cfg.vocab_padded, cfg.d_model), ("vocab", "embed"), "embed"),
        "meta": ParamDef((cfg.meta_tokens, cfg.d_model), (None, "embed"), "embed"),
        "layers": stacked(hymba_layer_schema(cfg), cfg.num_layers),
        "ln_f": norm_schema(cfg),
        "head": ParamDef((cfg.vocab_padded, cfg.d_model), ("vocab", "embed")),
    }


def window_per_layer(cfg) -> jnp.ndarray:
    """Global attention on first / middle / last layer, sliding elsewhere."""
    L = cfg.num_layers
    w = jnp.full((L,), cfg.sliding_window or GLOBAL_WINDOW, jnp.int32)
    for g in {0, L // 2, L - 1}:
        w = w.at[g].set(GLOBAL_WINDOW)
    return w


def _rms_mix(p: Params, a: jax.Array, s: jax.Array, cfg) -> jax.Array:
    def nrm(v, scale):
        ms = jnp.mean(jnp.square(v.astype(jnp.float32)), -1, keepdims=True)
        return v.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6) * scale

    out = 0.5 * (nrm(a, p["attn_scale"].astype(jnp.float32))
                 + nrm(s, p["ssm_scale"].astype(jnp.float32)))
    return out.astype(a.dtype)


def apply_hymba_layer(
    p: Params,
    x: jax.Array,
    cfg,
    runtime,
    *,
    positions: jax.Array,
    window: jax.Array,
    prefix_len: int,
    layer_cache=None,  # {"kv": attn cache, "conv":..., "ssm":...} or None
) -> Tuple[jax.Array, Any]:
    x = runtime.activation(x)
    h = apply_norm(p["ln1"], x, cfg)
    kv_cache = None if layer_cache is None else layer_cache["kv"]
    a, new_kv = attn.apply_attention(
        p["attn"], h, cfg, positions=positions, causal=True,
        window=window, prefix_len=prefix_len, layer_cache=kv_cache,
        runtime=runtime,
    )
    ssm_state = (
        None if layer_cache is None
        else {"conv": layer_cache["conv"], "ssm": layer_cache["ssm"]}
    )
    s, new_ssm = ssm_mod.ssm_apply(p["ssm"], h, cfg, state=ssm_state)
    x = x + _rms_mix(p, a, s, cfg)
    h = apply_norm(p["ln2"], x, cfg)
    x = runtime.activation(x + apply_mlp(p["mlp"], h, cfg))
    new_cache = None
    if layer_cache is not None:
        new_cache = {"kv": new_kv, "conv": new_ssm["conv"], "ssm": new_ssm["ssm"]}
    return x, new_cache


def apply_hymba_stack(
    layers: Params,
    x: jax.Array,
    cfg,
    runtime,
    *,
    positions: jax.Array,
    mode: str = "train",
    cache=None,
) -> Tuple[jax.Array, Any]:
    windows = window_per_layer(cfg)
    prefix = cfg.meta_tokens

    def body(xc, xs):
        lp, w, lcache = xs
        fn = lambda pp, xx, lc: apply_hymba_layer(
            pp, xx, cfg, runtime, positions=positions, window=w,
            prefix_len=prefix, layer_cache=lc,
        )
        if mode == "train" and cfg.remat != "none":
            fn = jax.checkpoint(fn)
        y, c = fn(lp, xc, lcache)
        return y, c

    x, new_cache = jax.lax.scan(body, x, (layers, windows, cache),
                                unroll=cfg.scan_unroll)
    return x, new_cache


def init_hymba_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    L = cfg.num_layers
    kv = attn.init_kv_cache(cfg, batch, max_len + cfg.meta_tokens, L, dtype)
    ssm_state = ssm_mod.init_ssm_state(cfg, batch, L, dtype=dtype)
    return {"kv": kv, "conv": ssm_state["conv"], "ssm": ssm_state["ssm"]}
