"""GQA attention: chunked-softmax jnp path (memory-safe at 32k+), KV cache,
sliding-window / prefix-LM / cross-attention masking, RoPE.

The chunked path is mathematically identical to flash attention (online
softmax over KV chunks) and doubles as the large-shape oracle for the Pallas
kernels in ``repro.kernels``; ``repro.kernels.flash_attention.ops`` dispatches
to the Pallas kernel on TPU when ``use_pallas`` is set.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.models.layers import ParamDef, apply_rope

Params = Any

NEG_INF = -1e30


def attn_schema(cfg, cross: bool = False) -> Dict[str, ParamDef]:
    d, h = cfg.d_model, cfg.head_dim
    nh, nkv = cfg.num_heads, cfg.num_kv_heads
    if cross:
        nkv = nh  # whisper cross-attention is MHA
    return {
        "wq": ParamDef((d, nh * h), ("embed", "heads")),
        "wk": ParamDef((d, nkv * h), ("embed", "kv_heads")),
        "wv": ParamDef((d, nkv * h), ("embed", "kv_heads")),
        "wo": ParamDef((nh * h, d), ("heads", "embed")),
    }


def _split_heads(x: jax.Array, n: int, h: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (n, h))


def chunked_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, K, hd)
    v: jax.Array,  # (B, Skv, K, hd)
    *,
    q_positions: jax.Array,  # (B, Sq) absolute positions
    kv_positions: jax.Array,  # (B, Skv) absolute positions (invalid -> very negative)
    kv_len: Optional[jax.Array] = None,  # (B,) valid cache length, None = all
    causal: bool = True,
    window: Optional[jax.Array] = None,  # scalar; None/0 = unbounded
    prefix_len: int | jax.Array = 0,  # bidirectional prefix (prefix-LM / meta tokens)
    softcap: float = 0.0,
    chunk: int = 1024,
    return_stats: bool = False,  # return unnormalized (acc, m, l) for
    #                               cross-device softmax combination
) -> jax.Array:
    """Online-softmax attention over KV chunks.  Returns (B, Sq, H, hd),
    or ((B,K,G,Sq,hd) acc, (B,K,G,Sq) m, (B,K,G,Sq) l) when return_stats."""
    B, Sq, H, hd = q.shape
    _, Skv, K, _ = k.shape
    G = H // K
    chunk = min(chunk, Skv)
    # pad Skv to a multiple of chunk with masked slots
    pad = (-Skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)), constant_values=-(2**30))
    n_chunks = (Skv + pad) // chunk

    qg = _split_heads(q.reshape(B, Sq, H * hd), K, G * hd).reshape(B, Sq, K, G, hd)
    qg = qg.transpose(0, 2, 3, 1, 4)  # (B, K, G, Sq, hd)
    kc = k.transpose(0, 2, 1, 3).reshape(B, K, n_chunks, chunk, hd)
    vc = v.transpose(0, 2, 1, 3).reshape(B, K, n_chunks, chunk, hd)
    kpc = kv_positions.reshape(B, n_chunks, chunk)

    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qpos = q_positions[:, None, None, :, None]  # (B,1,1,Sq,1)

    def body(carry, idx):
        acc, m, l = carry
        kb = jax.lax.dynamic_index_in_dim(kc, idx, 2, keepdims=False)  # (B,K,chunk,hd)
        vb = jax.lax.dynamic_index_in_dim(vc, idx, 2, keepdims=False)
        kp = jax.lax.dynamic_index_in_dim(kpc, idx, 1, keepdims=False)  # (B,chunk)
        logits = jnp.einsum(
            "bkgsh,bkch->bkgsc", qg, kb, preferred_element_type=jnp.float32
        ) * scale
        if softcap > 0.0:
            logits = softcap * jnp.tanh(logits / softcap)
        kpb = kp[:, None, None, None, :]  # (B,1,1,1,chunk)
        ok = kpb > -(2**29)  # padded / unwritten slots masked out
        if kv_len is not None:
            slot = idx * chunk + jnp.arange(chunk)
            ok &= slot[None, None, None, None, :] < kv_len[:, None, None, None, None]
        if causal:
            allowed = kpb <= qpos
            pl = prefix_len
            both_prefix = (kpb < pl) & (qpos < pl)
            allowed |= both_prefix
            if window is not None:
                in_window = kpb > qpos - window
                allowed &= in_window | (kpb < pl)  # prefix (meta) always visible
            ok &= allowed
        logits = jnp.where(ok, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgsc,bkch->bkgsh", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, K, G, Sq, hd), jnp.float32)
    m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(n_chunks))
    if return_stats:
        return acc, m, l
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def init_kv_cache(cfg, batch: int, max_len: int, n_layers: int, dtype=jnp.bfloat16):
    """Stacked (layers-leading) KV cache for scan-over-layers decode."""
    K, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((n_layers, batch, max_len, K, hd), dtype),
        "v": jnp.zeros((n_layers, batch, max_len, K, hd), dtype),
        # absolute position stored per slot; very-negative = unwritten
        "pos": jnp.full((n_layers, batch, max_len), -(2**30), jnp.int32),
        "len": jnp.zeros((n_layers, batch), jnp.int32),
    }


def cache_update(
    layer_cache: Dict[str, jax.Array],
    k_new: jax.Array,  # (B, S_new, K, hd)
    v_new: jax.Array,
    positions: jax.Array,  # (B, S_new)
    start: jax.Array,  # (B,) write offset (== current length)
) -> Dict[str, jax.Array]:
    """Write S_new entries at ``start`` (sequential layout, no ring)."""

    def upd_one(ck, cv, cp, cl, kn, vn, pos, st):
        ck = jax.lax.dynamic_update_slice(ck, kn.astype(ck.dtype), (st, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, vn.astype(cv.dtype), (st, 0, 0))
        cp = jax.lax.dynamic_update_slice(cp, pos, (st,))
        return ck, cv, cp, cl + kn.shape[0]

    k, v, p, l = jax.vmap(upd_one)(
        layer_cache["k"], layer_cache["v"], layer_cache["pos"], layer_cache["len"],
        k_new, v_new, positions, start,
    )
    return {"k": k, "v": v, "pos": p, "len": l}


def flash_decode_tp(
    q: jax.Array,  # (B, 1, H, hd) — replicated over the TP axis
    cache: Dict[str, jax.Array],  # k/v (B,S,K,hd) seq-sharded, pos (B,S), len (B,)
    k_new: jax.Array,  # (B, 1, K, hd) this step's K (cache write)
    v_new: jax.Array,  # (B, 1, K, hd)
    q_positions: jax.Array,  # (B, 1)
    runtime,
    *,
    window: Optional[jax.Array],
    prefix_len: int | jax.Array,
    softcap: float,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Distributed flash decoding with a FUSED shard-local cache write.

    Each TP peer (a) writes the new token's K/V into its sequence shard iff
    the write position falls inside it, then (b) attends over its LOCAL KV
    shard; the partial online-softmax stats (acc, m, l) are combined with an
    O(B·H·hd) psum.  Neither the cache write nor the read ever all-gathers
    the O(B·S·K·hd) cache (beyond-paper optimization, EXPERIMENTS.md §Perf —
    replaces XLA's auto-sharding gathers on both paths).

    kv_pos carries ABSOLUTE positions, so causal/window/prefix masking is
    local-shard-correct by construction (padding slots are very negative).
    Returns (out (B,1,H,hd), updated cache dict).
    """
    from jax.sharding import PartitionSpec as P

    tp = runtime.tp_axis
    B = q.shape[0]
    ndp = 1
    for a in runtime.dp_axes:
        ndp *= runtime.axis_size(a)
    bspec = runtime.batch_axes if (B % max(ndp, 1) == 0 and B >= ndp) else None

    def local_fn(q_l, k_l, v_l, pos_l, len_l, kn_l, vn_l, qpos_l):
        S_loc = k_l.shape[1]
        start = jax.lax.axis_index(tp) * S_loc
        rel = len_l - start  # (Bl,) local write offset

        def write_one(buf, new, r):
            upd = jax.lax.dynamic_update_slice(
                buf, new.astype(buf.dtype), (jnp.clip(r, 0, S_loc - 1), 0, 0)
            )
            return jnp.where(jnp.logical_and(r >= 0, r < S_loc), upd, buf)

        k_l = jax.vmap(write_one)(k_l, kn_l, rel)
        v_l = jax.vmap(write_one)(v_l, vn_l, rel)

        def write_pos(pbuf, r, qp):
            upd = jax.lax.dynamic_update_slice(
                pbuf, qp, (jnp.clip(r, 0, S_loc - 1),)
            )
            return jnp.where(jnp.logical_and(r >= 0, r < S_loc), upd, pbuf)

        pos_l = jax.vmap(write_pos)(pos_l, rel, qpos_l)

        acc, m, l = chunked_attention(
            q_l, k_l.astype(q_l.dtype), v_l.astype(q_l.dtype),
            q_positions=qpos_l, kv_positions=pos_l,
            causal=True, window=window, prefix_len=prefix_len,
            softcap=softcap, return_stats=True,
        )
        m_g = jax.lax.pmax(m, tp)
        corr = jnp.exp(m - m_g)
        num = jax.lax.psum(acc * corr[..., None], tp)
        den = jax.lax.psum(l * corr, tp)
        out = num / jnp.maximum(den, 1e-30)[..., None]
        Bl, K, G, Sq, hd = out.shape
        out = out.transpose(0, 3, 1, 2, 4).reshape(Bl, Sq, K * G, hd)
        return out.astype(q_l.dtype), k_l, v_l, pos_l

    kv_spec = P(bspec, tp, None, None)
    fn = shard_map(
        local_fn,
        mesh=runtime.mesh,
        in_specs=(
            P(bspec, None, None, None),
            kv_spec, kv_spec, P(bspec, tp), P(bspec),
            P(bspec, None, None, None), P(bspec, None, None, None),
            P(bspec, None),
        ),
        out_specs=(P(bspec, None, None, None), kv_spec, kv_spec, P(bspec, tp)),
        check_vma=False,
    )
    out, k_upd, v_upd, pos_upd = fn(
        q, cache["k"], cache["v"], cache["pos"], cache["len"],
        k_new, v_new, q_positions,
    )
    new_cache = {
        "k": k_upd, "v": v_upd, "pos": pos_upd,
        "len": cache["len"] + k_new.shape[1],
    }
    return out, new_cache


def apply_attention(
    p: Params,
    x: jax.Array,  # (B, Sq, d)
    cfg,
    *,
    positions: jax.Array,  # (B, Sq)
    causal: bool = True,
    window: Optional[jax.Array] = None,
    prefix_len: int | jax.Array = 0,
    softcap: float = 0.0,
    layer_cache: Optional[Dict[str, jax.Array]] = None,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,  # encoder K,V (B,Se,K,hd)
    rope: bool = True,
    runtime=None,  # enables the TP flash-decode path when set
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Self- or cross-attention with optional KV cache read/write.

    Returns (output (B,Sq,d), updated layer cache or None).
    """
    B, Sq, _ = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    dt = x.dtype

    q = _split_heads(x @ p["wq"].astype(dt), H, hd)
    if rope:
        q = apply_rope(q, positions, cfg)

    new_cache = None
    if cross_kv is not None:
        k, v = cross_kv
        kv_pos = jnp.broadcast_to(jnp.arange(k.shape[1])[None], (B, k.shape[1]))
        out = chunked_attention(
            q, k, v, q_positions=positions, kv_positions=kv_pos,
            causal=False, softcap=softcap,
        )
    else:
        K = cfg.num_kv_heads
        k = _split_heads(x @ p["wk"].astype(dt), K, hd)
        v = _split_heads(x @ p["wv"].astype(dt), K, hd)
        if rope:
            k = apply_rope(k, positions, cfg)
        if layer_cache is not None:
            use_flash_tp = (
                runtime is not None and runtime.mesh is not None
                and getattr(runtime, "flash_decode", False)
                and Sq == 1 and causal
                and layer_cache["k"].shape[1]
                % runtime.axis_size(runtime.tp_axis) == 0
            )
            if use_flash_tp:
                out, new_cache = flash_decode_tp(
                    q, layer_cache, k, v, positions, runtime,
                    window=window, prefix_len=prefix_len, softcap=softcap,
                )
            else:
                new_cache = cache_update(
                    layer_cache, k, v, positions, layer_cache["len"]
                )
                kf, vf = new_cache["k"].astype(dt), new_cache["v"].astype(dt)
                out = chunked_attention(
                    q, kf, vf,
                    q_positions=positions, kv_positions=new_cache["pos"],
                    causal=causal, window=window, prefix_len=prefix_len, softcap=softcap,
                )
        else:
            kv_pos = jnp.broadcast_to(positions[:, :1] + jnp.arange(Sq)[None], (B, Sq))
            kv_pos = positions  # self-attention over the same tokens
            out = chunked_attention(
                q, k, v, q_positions=positions, kv_positions=kv_pos,
                causal=causal, window=window, prefix_len=prefix_len, softcap=softcap,
            )
    y = out.reshape(B, Sq, H * hd) @ p["wo"].astype(dt)
    return y, new_cache


def make_cross_kv(p: Params, enc_out: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """Precompute encoder K/V once for all decode steps (whisper)."""
    B, Se, _ = enc_out.shape
    H, hd = cfg.num_heads, cfg.head_dim
    dt = enc_out.dtype
    k = _split_heads(enc_out @ p["wk"].astype(dt), H, hd)
    v = _split_heads(enc_out @ p["wv"].astype(dt), H, hd)
    return k, v
