"""Mamba-style selective SSM block (used by hymba's parallel SSM heads).

Training/prefill uses a chunked linear-recurrence: within a chunk the
recurrence h_t = Ā_t h_{t-1} + B̄_t x_t is evaluated with an associative scan
(parallel on TPU); chunks are chained with a sequential ``lax.scan`` carrying
the (B, d_inner, N) state.  This bounds the materialized scan elements to
O(chunk · d_inner · N) instead of O(S · d_inner · N).

Decode is a single recurrent step against carried (conv_state, ssm_state).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef

Params = Any


def _dt_rank(cfg) -> int:
    return cfg.ssm.dt_rank or -(-cfg.d_model // 16)


def ssm_schema(cfg) -> Dict[str, ParamDef]:
    d = cfg.d_model
    di = cfg.ssm.expand * d
    n = cfg.ssm.state_dim
    r = _dt_rank(cfg)
    w = cfg.ssm.conv_width
    return {
        "in_proj": ParamDef((d, 2 * di), ("embed", "ssm_inner")),
        "conv_w": ParamDef((w, di), (None, "ssm_inner"), scale=0.5),
        "conv_b": ParamDef((di,), ("ssm_inner",), "zeros"),
        "x_proj": ParamDef((di, r + 2 * n), ("ssm_inner", None)),
        "dt_proj": ParamDef((r, di), (None, "ssm_inner")),
        "dt_bias": ParamDef((di,), ("ssm_inner",), "zeros"),
        "a_log": ParamDef((di, n), ("ssm_inner", "state"), "ones"),
        "d_skip": ParamDef((di,), ("ssm_inner",), "ones"),
        "out_proj": ParamDef((di, d), ("ssm_inner", "embed")),
    }


def _ssm_coeffs(p: Params, xz: jax.Array, cfg):
    """xz: (B, S, di) post-conv activations -> (dA (B,S,di,N), dBx, C)."""
    n = cfg.ssm.state_dim
    r = _dt_rank(cfg)
    proj = xz @ p["x_proj"].astype(xz.dtype)  # (B,S,r+2n)
    dt_r, bmat, cmat = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        dt_r @ p["dt_proj"].astype(xz.dtype)
        + p["dt_bias"].astype(xz.dtype)
    ).astype(jnp.float32)  # (B,S,di)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, N), negative
    dA = jnp.exp(dt[..., None] * a)  # (B,S,di,N)
    dBx = (dt * xz.astype(jnp.float32))[..., None] * bmat.astype(jnp.float32)[..., None, :]
    return dA, dBx, cmat.astype(jnp.float32)


def _conv1d_causal(p: Params, x: jax.Array, conv_state: Optional[jax.Array]):
    """Depthwise causal conv.  x: (B,S,di).  Returns (y, new_conv_state)."""
    w = p["conv_w"].astype(x.dtype)  # (W, di)
    W = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+W-1, di)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(W))
    y = y + p["conv_b"].astype(x.dtype)
    new_state = xp[:, -(W - 1) :] if W > 1 else pad[:, :0]
    return y, new_state


def _scan_chunked(p: Params, xi: jax.Array, cfg, h0, chunk: int):
    """Chunked selective scan: per chunk, compute coefficients, run the
    associative recurrence, and contract the state against C IN PLACE —
    the (B, chunk, di, N) tensors never exist for more than one chunk
    (memory O(B·chunk·di·N) instead of O(B·S·di·N)).

    xi: (B, S, di) post-conv activations.  h0: (B, di, N) fp32.
    Returns (y (B, S, di) fp32, h_last).
    """
    B, S, di = xi.shape
    N = cfg.ssm.state_dim
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        xi = jnp.pad(xi, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // chunk
    xc = xi.reshape(B, nc, chunk, di).transpose(1, 0, 2, 3)  # (nc,B,c,di)

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, a2 * b1 + b2

    def chunk_step(h, xk):
        dA, dBx, cmat = _ssm_coeffs(p, xk, cfg)  # (B,c,di,N) / (B,c,N)
        accA, accB = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        hs = accA * h[:, None] + accB  # (B, c, di, N)
        y = jnp.einsum("bcdn,bcn->bcd", hs, cmat)
        return hs[:, -1], y

    h_last, ys = jax.lax.scan(chunk_step, h0, xc)
    y = ys.transpose(1, 0, 2, 3).reshape(B, nc * chunk, di)[:, :S]
    return y, h_last


def ssm_apply(
    p: Params,
    x: jax.Array,  # (B, S, d)
    cfg,
    *,
    state: Optional[Dict[str, jax.Array]] = None,  # conv_state, ssm_state
    chunk: int = 128,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, d = x.shape
    di = cfg.ssm.expand * d
    dt_ = x.dtype
    xz = x @ p["in_proj"].astype(dt_)  # (B,S,2di)
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    xi, new_conv = _conv1d_causal(p, xi, conv_state)
    xi = jax.nn.silu(xi)
    h0 = (
        state["ssm"].astype(jnp.float32)
        if state is not None
        else jnp.zeros((B, di, cfg.ssm.state_dim), jnp.float32)
    )
    if S == 1 and state is not None:  # decode fast path
        dA, dBx, cmat = _ssm_coeffs(p, xi, cfg)
        h_last = dA[:, 0] * h0 + dBx[:, 0]
        y = jnp.einsum("bsdn,bsn->bsd", h_last[:, None], cmat)
    else:
        y, h_last = _scan_chunked(p, xi, cfg, h0, chunk)
    y = y + xi.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = (y.astype(dt_)) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt_)
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv.astype(state["conv"].dtype), "ssm": h_last}
    return out, new_state


def init_ssm_state(cfg, batch: int, n_layers: int, d_model: Optional[int] = None,
                   dtype=jnp.bfloat16):
    d = d_model or cfg.d_model
    di = cfg.ssm.expand * d
    return {
        "conv": jnp.zeros((n_layers, batch, cfg.ssm.conv_width - 1, di), dtype),
        "ssm": jnp.zeros((n_layers, batch, di, cfg.ssm.state_dim), jnp.float32),
    }
