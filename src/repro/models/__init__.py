"""Model zoo: one functional API over all assigned architecture families."""
from repro.models.model import (
    abstract_params,
    decode_step,
    forward_train,
    init_model_params,
    init_serve_cache,
    logical_axes,
    model_schema,
    prefill,
)

__all__ = [
    "abstract_params",
    "decode_step",
    "forward_train",
    "init_model_params",
    "init_serve_cache",
    "logical_axes",
    "model_schema",
    "prefill",
]
