"""Logical-axis sharding: rules, Runtime, and the LM head/embed helpers.

The parameter schemas (``repro.models.layers``) tag every dim with a
*logical* axis name; ``default_rules`` maps logical axes onto mesh axes
(TP over ``model``, FSDP over the data axes).  ``shardings_for_schema``
walks a schema and emits a matching ``NamedSharding`` tree, dropping any
assignment that does not divide or would reuse a mesh axis within one
spec — so the same rules apply unchanged from reduced CPU configs to the
production cell.

``Runtime`` carries the mesh context through the model code: activation
sharding constraints (batch over DP, sequence over TP when ``sp``),
expert-parallel enablement, and the TP flash-decode flag.  ``CPU_RUNTIME``
(no mesh) turns every constraint into a no-op, so the identical model code
is the single-device oracle.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

NEG_INF = -1e30


def _is_param_def(node: Any) -> bool:
    # duck-typed to avoid a circular import (models.layers imports us via
    # the repro.models package __init__)
    return hasattr(node, "shape") and hasattr(node, "axes") \
        and not isinstance(node, dict)

Rules = Dict[str, Union[None, str, Tuple[str, ...]]]


def default_rules() -> Rules:
    """Logical axis -> mesh axes.  TP over ``model``; the ``embed`` (d_model)
    dim is FSDP-sharded over the data axes (weights gather per layer)."""
    return {
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "ffn": "model",
        "experts": "model",
        "embed": ("pod", "data"),
        "experts_r": None,
        "expert_inner": None,
        "layers": None,
        None: None,
    }


def _axes_in_mesh(rule, mesh: Mesh) -> Tuple[str, ...]:
    if rule is None:
        return ()
    axes = (rule,) if isinstance(rule, str) else tuple(rule)
    return tuple(a for a in axes if a in mesh.axis_names)


def spec_for_leaf(leaf: Any, rules: Rules, mesh: Mesh) -> P:
    """PartitionSpec for one ParamDef: rule lookup + divisibility guard +
    no-axis-reuse guard (a mesh axis may appear once per spec)."""
    used: set = set()
    entries = []
    for dim, name in zip(leaf.shape, leaf.axes):
        axes = _axes_in_mesh(rules.get(name), mesh)
        axes = tuple(a for a in axes if a not in used)
        k = 1
        for a in axes:
            k *= int(mesh.shape[a])
        if not axes or k <= 1 or dim % k != 0:
            entries.append(None)
            continue
        used.update(axes)
        entries.append(axes if len(axes) > 1 else axes[0])
    return P(*entries)


def shardings_for_schema(schema: Any, rules: Rules, mesh: Mesh) -> Any:
    """NamedSharding tree mirroring a ParamDef schema tree."""
    if _is_param_def(schema):
        return NamedSharding(mesh, spec_for_leaf(schema, rules, mesh))
    return {k: shardings_for_schema(v, rules, mesh) for k, v in schema.items()}


# ---------------------------------------------------------------------------
# Runtime
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Runtime:
    """Mesh context threaded through the model code.  ``mesh=None`` is the
    single-device oracle: every method becomes the identity."""

    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ()
    tp_axis: str = "model"
    flash_decode: bool = False  # TP flash decoding (attention.flash_decode_tp)
    sp: bool = True  # sequence-parallel activation constraint

    @property
    def batch_axes(self):
        """PartitionSpec entry for the batch dim (tuple collapses to str)."""
        return self.dp_axes if len(self.dp_axes) != 1 else self.dp_axes[0]

    @property
    def rules(self) -> Rules:
        return default_rules()

    def axis_size(self, axis) -> int:
        if self.mesh is None:
            return 1
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        n = 1
        for a in axes:
            n *= int(self.mesh.shape[a])
        return n

    def dp_size(self) -> int:
        return self.axis_size(self.dp_axes) if self.dp_axes else 1

    def ep_enabled(self, cfg) -> bool:
        """Expert parallelism: experts must divide over the TP axis."""
        if self.mesh is None or self.tp_axis not in self.mesh.axis_names:
            return False
        return cfg.moe.num_experts % int(self.mesh.shape[self.tp_axis]) == 0

    def shard(self, x: jax.Array, *entries) -> jax.Array:
        """with_sharding_constraint with explicit PartitionSpec entries;
        identity off-mesh.  Entries beyond x.ndim are ignored."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*entries[: x.ndim]))
        )

    def activation(self, x: jax.Array) -> jax.Array:
        """Pin a (B, S, d) activation to (batch over DP, seq over TP when
        ``sp`` and divisible, replicated d).  Identity off-mesh or for
        non-3D arrays."""
        if self.mesh is None or x.ndim != 3:
            return x
        B, S, _ = x.shape
        ndp = self.dp_size()
        b_entry = self.batch_axes if (self.dp_axes and B % max(ndp, 1) == 0
                                      and B >= ndp) else None
        ntp = self.axis_size(self.tp_axis)
        s_entry = self.tp_axis if (self.sp and ntp > 1 and S % ntp == 0
                                   and S >= ntp) else None
        return self.shard(x, b_entry, s_entry, None)


CPU_RUNTIME = Runtime(mesh=None)


# ---------------------------------------------------------------------------
# Vocab-parallel embed / LM head
# ---------------------------------------------------------------------------

def embed_lookup(embed: jax.Array, tokens: jax.Array, runtime: Runtime) -> jax.Array:
    """tokens (B, S) -> embeddings (B, S, d).  With a vocab-sharded table the
    gather lowers to a masked partial lookup + all-reduce under GSPMD."""
    x = jnp.take(embed, tokens, axis=0)
    return runtime.activation(x)


def _masked_logits(x: jax.Array, head: jax.Array, valid_vocab: int) -> jax.Array:
    """(B, S, d) x (Vp, d) -> f32 logits with padded vocab masked out."""
    logits = jnp.einsum(
        "bsd,vd->bsv", x.astype(jnp.float32), head.astype(jnp.float32)
    )
    Vp = head.shape[0]
    if valid_vocab < Vp:
        mask = jnp.arange(Vp) < valid_vocab
        logits = jnp.where(mask[None, None, :], logits, NEG_INF)
    return logits


def lm_head_logits(
    x: jax.Array, head: jax.Array, runtime: Runtime, *, valid_vocab: int
) -> jax.Array:
    """f32 logits (B, S, Vp); padding vocab rows pinned to NEG_INF so
    sampling never selects them."""
    return _masked_logits(x, head, valid_vocab)


def lm_head_loss(
    x: jax.Array, head: jax.Array, labels: jax.Array, runtime: Runtime, *,
    valid_vocab: int,
) -> jax.Array:
    """Mean next-token cross-entropy over positions with labels >= 0."""
    logits = _masked_logits(x, head, valid_vocab)
    lse = jax.nn.logsumexp(logits, axis=-1)
    lab = jnp.clip(labels, 0, valid_vocab - 1)
    picked = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    nll = lse - picked
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
