"""Distribution layer: logical-axis sharding rules, runtime mesh context,
vocab-parallel embed/head helpers, gradient compression, and HLO collective
accounting.  Everything degrades to a single-device no-op when no mesh is
given (``CPU_RUNTIME``)."""
