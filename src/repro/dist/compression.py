"""Gradient compression for the cross-pod (DCI) all-reduce, with error
feedback: the compression residual is carried in the compressor state and
re-added before the next quantization, so the *running mean* of the
compressed stream is unbiased even though each step is lossy.

``apply(grads, state[, runtime]) -> (compressed_grads, new_state, metrics)``
operates leaf-wise on any gradient pytree and is jit-safe (pure jnp).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Tree = Any


class Compressor:
    """Base: error-feedback state is a residual tree shaped like the grads."""

    def init_state(self, tree: Tree) -> Tree:
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)

    def _roundtrip(self, t: jax.Array) -> jax.Array:
        raise NotImplementedError

    def apply(
        self, grads: Tree, state: Tree, runtime=None
    ) -> Tuple[Tree, Tree, Dict[str, jax.Array]]:
        target = jax.tree.map(
            lambda g, e: g.astype(jnp.float32) + e, grads, state
        )
        out = jax.tree.map(self._roundtrip, target)
        new_state = jax.tree.map(jnp.subtract, target, out)
        err_sq = sum(jnp.sum(jnp.square(e)) for e in jax.tree.leaves(new_state))
        return out, new_state, {"comp_err_norm": jnp.sqrt(err_sq)}


class Int8Compressor(Compressor):
    """Symmetric per-leaf int8 quantization (scale = max|g|/127)."""

    def _roundtrip(self, t: jax.Array) -> jax.Array:
        scale = jnp.max(jnp.abs(t)) / 127.0
        safe = jnp.maximum(scale, 1e-30)
        q = jnp.clip(jnp.round(t / safe), -127, 127).astype(jnp.int8)
        return q.astype(jnp.float32) * safe


class TopKCompressor(Compressor):
    """Keep the top ``frac`` entries of each leaf by magnitude, zero the
    rest (sparsified all-reduce); ties at the threshold are all kept."""

    def __init__(self, frac: float = 0.01):
        assert 0.0 < frac <= 1.0
        self.frac = frac

    def _roundtrip(self, t: jax.Array) -> jax.Array:
        flat = jnp.abs(t.reshape(-1))
        k = max(1, int(round(self.frac * flat.shape[0])))
        kth = jax.lax.top_k(flat, k)[0][-1]
        return jnp.where(jnp.abs(t) >= kth, t, 0.0)
