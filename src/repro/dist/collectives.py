"""HLO collective accounting: parse optimized HLO text and total the output
bytes moved per collective kind.  Used by the dry-run to report per-cell
collective volume (the quantity the mesh/DCI budget reasons about)."""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
          "collective-permute")

# "%x = f32[8,128]{1,0} all-reduce(" / "= (f32[2]{0}, f32[2]{0}) all-gather-start("
_OP_RE = re.compile(
    r"=\s*(?P<shapes>[^=]*?)\s*"
    r"(?P<kind>" + "|".join(_KINDS) + r")(?P<variant>-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        nb = _DTYPE_BYTES.get(dtype)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def collective_bytes_by_kind(hlo_text: str) -> Dict[str, float]:
    """{kind: total output bytes} over all collective ops in the HLO.
    Async pairs are counted once (at ``-start``; ``-done`` is skipped)."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        if m.group("variant") == "-done":
            continue
        b = _shape_bytes(m.group("shapes"))
        if b:
            out[m.group("kind")] = out.get(m.group("kind"), 0.0) + float(b)
    return out
