"""HLO collective accounting: parse optimized HLO text and total the output
bytes moved per collective kind.  Used by the dry-run to report per-cell
collective volume (the quantity the mesh/DCI budget reasons about).

Also home to the ANALYTIC cost model for the boundary exchange
(:func:`boundary_exchange_bytes`): the same per-superstep quantity, derived
from (num_boundary, devices, backend) instead of parsed from HLO, so the
comm-backend choice (``repro.core.comm``) can be costed before anything is
lowered.  The measured and analytic views are cross-checked in
``tests/test_comm_backends.py`` — the dense backend must lower to
``all-reduce`` ops and the ring backend to ``collective-permute`` ops.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
          "collective-permute")

# "%x = f32[8,128]{1,0} all-reduce(" / "= (f32[2]{0}, f32[2]{0}) all-gather-start("
_OP_RE = re.compile(
    r"=\s*(?P<shapes>[^=]*?)\s*"
    r"(?P<kind>" + "|".join(_KINDS) + r")(?P<variant>-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        nb = _DTYPE_BYTES.get(dtype)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def collective_bytes_by_kind(hlo_text: str) -> Dict[str, float]:
    """{kind: total output bytes} over all collective ops in the HLO.
    Async pairs are counted once (at ``-start``; ``-done`` is skipped)."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        if m.group("variant") == "-done":
            continue
        b = _shape_bytes(m.group("shapes"))
        if b:
            out[m.group("kind")] = out.get(m.group("kind"), 0.0) + float(b)
    return out


def boundary_exchange_bytes(
    num_boundary: int,
    n_devices: int,
    backend: str = "dense",
    *,
    dtype_bytes: int = 4,
    boundary_nnz: int | None = None,
) -> Dict[str, float]:
    """Analytic per-superstep comm cost of one boundary exchange.

    ``boundary_nnz`` — the boundary vertices actually published
    (``BlockedGraph.boundary_nnz``), as opposed to the block-padded
    ``num_boundary`` buffer length.  When given it replaces
    ``num_boundary`` in the byte model: that is the payload a
    sparse-aware exchange moves, and the quantity backend selection
    should reason about (a padded buffer can overstate a tiny cut by a
    whole block).

    Returns ``{"kind", "hops", "bytes_per_device", "bytes_total"}`` for a
    (num_boundary,)-float buffer combined across ``n_devices`` partitions:

    * ``dense`` — XLA's ring all-reduce moves ``2 (n-1)/n × NB`` bytes per
      device (reduce-scatter + all-gather), in ``2 (n-1)`` latency hops.
    * ``ring``  — the ``ppermute`` circulate-and-fold sends the full NB
      buffer on ``n-1`` hops per device: MORE total bytes than the dense
      all-reduce, but every transfer is strictly neighbor-to-neighbor, so
      on a bandwidth-asymmetric topology (multi-pod DCI) each slow link
      carries exactly one NB buffer per hop instead of the all-reduce
      tree's cross-section traffic — latency-bound small cuts prefer
      ``dense``, DCI-bandwidth-bound large cuts prefer ``ring``.
    * ``ring-rs`` — the v2 ring: chunked reduce-scatter + all-gather over
      the same neighbor-to-neighbor ``ppermute`` ring.  Each hop moves an
      NB/n chunk instead of the full buffer, so per-device bytes drop to
      the bandwidth-optimal ``2 (n-1)/n × NB`` (same volume as the dense
      all-reduce) while KEEPING the strictly point-to-point transfer
      pattern — at ``2 (n-1)`` latency hops, double the circulate ring.
      Wins when the DCI cut is so large that ring traffic itself is
      bandwidth-bound.
    * ``host``  — no device collective: every partition ships its NB
      buffer to the host, which returns one combined buffer (``n × NB``
      up, ``n × NB`` down across PCIe/Ethernet, 2 logical hops).

    >>> boundary_exchange_bytes(1000, 4, "dense")["bytes_per_device"]
    6000.0
    >>> boundary_exchange_bytes(1000, 4, "ring")["hops"]
    3
    >>> boundary_exchange_bytes(1000, 4, "ring-rs")["bytes_per_device"]
    6000.0
    >>> boundary_exchange_bytes(1000, 4, "ring-rs")["hops"]
    6
    >>> boundary_exchange_bytes(1000, 4, "host")["kind"]
    'host-gather'
    >>> boundary_exchange_bytes(1024, 4, "dense",  # padded NB overstates
    ...                         boundary_nnz=37)["bytes_per_device"]
    222.0
    """
    if backend not in ("dense", "ring", "ring-rs", "host"):
        raise ValueError(f"unknown comm backend {backend!r}")
    eff = num_boundary if boundary_nnz is None else boundary_nnz
    nb = float(eff * dtype_bytes)
    n = int(n_devices)
    if backend == "dense":
        per_dev = 2.0 * (n - 1) / max(n, 1) * nb
        return {"kind": "all-reduce", "hops": 2 * (n - 1),
                "bytes_per_device": per_dev, "bytes_total": per_dev * n}
    if backend == "ring":
        per_dev = (n - 1) * nb
        return {"kind": "collective-permute", "hops": n - 1,
                "bytes_per_device": per_dev, "bytes_total": per_dev * n}
    if backend == "ring-rs":
        per_dev = 2.0 * (n - 1) / max(n, 1) * nb
        return {"kind": "collective-permute", "hops": 2 * (n - 1),
                "bytes_per_device": per_dev, "bytes_total": per_dev * n}
    return {"kind": "host-gather", "hops": 2,
            "bytes_per_device": 2.0 * nb, "bytes_total": 2.0 * nb * n}
