"""Multi-process Gopher driver: N local workers over one GoFS deployment.

The paper's deployment shape (§V) — every worker computes on the shard it
hosts — as a runnable entrypoint:

  PYTHONPATH=src python -m repro.launch.cluster_graph \\
      --num-processes 2 --apps sssp,pagerank --size tiny --check

The parent deploys the collection (once), picks a free coordinator port,
and spawns ``--num-processes`` workers of THIS module (``--worker``).
Each worker boots its :class:`~repro.cluster.runtime.ClusterRuntime`,
opens a :class:`~repro.gopher.session.GopherSession` bound to it — so
staging is shard-local and the boundary exchange is the real
inter-process gather — runs every requested app, and writes its results
(values, finals, superstep counts, per-host staged bytes) to an ``.npz``
in ``--out``.

``--check`` makes the parent ALSO run the identical apps in a plain
single-process session and assert the cluster acceptance:

* every worker's values/finals are **bitwise identical** to the
  single-process run (and to each other);
* every worker's staged bytes are **strictly less** than the
  single-process staging cost (shard-local staging is real).

Exit status is non-zero on any violation — this is the CI multi-process
lane's command.
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import time
from typing import Dict, List

import numpy as np

APP_PARAMS: Dict[str, dict] = {
    "sssp": {"source": 0},            # sequential pattern
    "pagerank": {"iters": 10},        # independent pattern
    "components": {},                 # independent, symmetrized graph
}


def free_port() -> int:
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def run_apps(sess, apps: List[str]) -> Dict[str, Dict[str, np.ndarray]]:
    """Run each app through the session, recording result arrays and the
    staging economy of its pass."""
    out: Dict[str, Dict[str, np.ndarray]] = {}
    for app in apps:
        plan = sess.plan(app, staging="async", **APP_PARAMS[app])
        res = sess.run_many([plan])[0]
        eng = res.engine
        out[app] = {
            "values": np.asarray(eng.values),
            "final": np.asarray(eng.final),
            "supersteps": np.asarray(eng.stats["supersteps"]),
            "staged_bytes": np.asarray(
                int(sess.last_run_report["staged_bytes"])),
        }
    return out


def worker_main(args) -> None:
    from repro.cluster.runtime import init_cluster
    from repro.gopher import GopherSession
    from repro.launch.run_graph import ensure_deployment

    rt = init_cluster(transport=args.transport)  # GOFFISH_* env from parent
    cfg, store = ensure_deployment(args.size, args.deploy, args.cache_slots)
    sess = GopherSession(store, block_size=cfg.block_size, cluster=rt)
    results = run_apps(sess, args.apps.split(","))
    flat = {f"{app}/{k}": v for app, r in results.items()
            for k, v in r.items()}
    os.makedirs(args.out, exist_ok=True)
    np.savez(os.path.join(args.out, f"worker_{rt.process_id}.npz"), **flat)
    rt.barrier("done")
    rt.close()


def launch_workers(args, coordinator: str) -> List[subprocess.Popen]:
    from repro.cluster import runtime as cr

    procs = []
    for pid in range(args.num_processes):
        env = dict(
            os.environ,
            **{cr.ENV_COORDINATOR: coordinator,
               cr.ENV_NUM_PROCESSES: str(args.num_processes),
               cr.ENV_PROCESS_ID: str(pid),
               cr.ENV_TRANSPORT: args.transport},
        )
        cmd = [
            sys.executable, "-m", "repro.launch.cluster_graph", "--worker",
            "--apps", args.apps, "--size", args.size,
            "--deploy", args.deploy, "--out", args.out,
            "--transport", args.transport,
            "--cache-slots", str(args.cache_slots),
        ]
        procs.append(subprocess.Popen(
            cmd, env=env,
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True))
    return procs


def wait_workers(procs: List[subprocess.Popen], timeout: float) -> None:
    deadline = time.monotonic() + timeout
    for i, p in enumerate(procs):
        try:
            out, _ = p.communicate(
                timeout=max(1.0, deadline - time.monotonic()))
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            raise SystemExit(f"worker {i} timed out")
        if p.returncode != 0:
            sys.stderr.write(out or "")
            raise SystemExit(f"worker {i} exited with {p.returncode}")


def check_parity(args) -> Dict[str, dict]:
    """Single-process reference run + the acceptance assertions."""
    from repro.gopher import GopherSession
    from repro.launch.run_graph import ensure_deployment

    apps = args.apps.split(",")
    cfg, store = ensure_deployment(args.size, args.deploy, args.cache_slots)
    ref = run_apps(GopherSession(store, block_size=cfg.block_size), apps)

    workers = []
    for pid in range(args.num_processes):
        path = os.path.join(args.out, f"worker_{pid}.npz")
        assert os.path.exists(path), f"worker {pid} left no results"
        workers.append(np.load(path))

    report: Dict[str, dict] = {}
    for app in apps:
        single = int(ref[app]["staged_bytes"])
        per_host = []
        for pid, w in enumerate(workers):
            for key in ("values", "final", "supersteps"):
                got, want = w[f"{app}/{key}"], ref[app][key]
                assert np.array_equal(got, want), \
                    f"{app}: worker {pid} {key} diverges from the " \
                    f"single-process run"
            per_host.append(int(w[f"{app}/staged_bytes"]))
        # components stages its symmetrized variant through the
        # materialized path (full-width, engine-sliced); only streamed
        # template apps must show the per-host byte saving
        if single > 0 and app != "components":
            for pid, b in enumerate(per_host):
                assert b < single, \
                    f"{app}: worker {pid} staged {b} bytes, single-process " \
                    f"staged {single} — shard staging saved nothing"
        report[app] = {"single_staged_bytes": single,
                       "per_host_staged_bytes": per_host}
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true",
                    help="internal: run as one spawned worker process")
    ap.add_argument("--num-processes", type=int, default=2)
    ap.add_argument("--apps", default="sssp,pagerank",
                    help=f"comma list from {sorted(APP_PARAMS)}")
    ap.add_argument("--size", default="tiny",
                    choices=["tiny", "small", "full"])
    ap.add_argument("--deploy", default="/tmp/gofs_cluster")
    ap.add_argument("--out", default="/tmp/gofs_cluster_out")
    ap.add_argument("--transport", default="tcp",
                    choices=["tcp", "jax", "auto"],
                    help="tcp: host-lane exchange only (CI default); "
                         "jax: also initialize jax.distributed")
    ap.add_argument("--cache-slots", type=int, default=14)
    ap.add_argument("--timeout", type=float, default=300.0)
    ap.add_argument("--check", action="store_true",
                    help="run the single-process reference and assert "
                         "bitwise parity + per-host staged-byte savings")
    args = ap.parse_args()
    for app in args.apps.split(","):
        assert app in APP_PARAMS, f"unknown app {app!r}"

    if args.worker:
        worker_main(args)
        return

    from repro.launch.run_graph import ensure_deployment

    ensure_deployment(args.size, args.deploy, args.cache_slots)  # once
    coordinator = f"127.0.0.1:{free_port()}"
    t0 = time.time()
    procs = launch_workers(args, coordinator)
    wait_workers(procs, args.timeout)
    print(f"[cluster] {args.num_processes} workers x {args.apps} done "
          f"in {time.time()-t0:.1f}s")
    if args.check:
        report = check_parity(args)
        print(f"[cluster] parity OK: {json.dumps(report)}")


if __name__ == "__main__":
    main()
