"""Training driver: config -> mesh -> sharded train loop with
checkpoint/restart, NaN-skip, retry, and async checkpointing.

CPU-runnable end-to-end with reduced configs (``--reduced``); on real
hardware the same entry point drives the production mesh (the dry-run
proves the sharded step compiles for every assigned cell).

  PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --reduced \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

COMPRESSORS = ("none", "int8", "topk")


def make_compressor(compress, *, topk_frac: float = 0.01):
    """Resolve the ``--compress`` choice to a gradient compressor.

    Accepts the legacy boolean form (``True`` = int8) and the named
    backends: ``int8`` (symmetric quantization) or ``topk`` (magnitude
    sparsification at ``topk_frac``), both with error feedback
    (``repro.dist.compression``).  Returns ``None`` for no compression.
    """
    if compress in (None, False, "none"):
        return None
    if compress in (True, "int8"):
        return Int8Compressor()
    if compress == "topk":
        return TopKCompressor(frac=topk_frac)
    raise ValueError(
        f"unknown compressor {compress!r}; pick from {COMPRESSORS}"
    )

from repro.configs import get_config
from repro.dist.compression import Int8Compressor, TopKCompressor
from repro.dist.sharding import CPU_RUNTIME, Runtime, default_rules, shardings_for_schema
from repro.models import init_model_params, model_schema
from repro.train import checkpoint as ckpt
from repro.train.data import SyntheticLMDataset
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step


def train_loop(
    cfg,
    *,
    steps: int,
    global_batch: int,
    seq_len: int,
    runtime: Runtime = CPU_RUNTIME,
    oc: Optional[OptConfig] = None,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    keep: int = 3,
    accum_steps: int = 1,
    compress=False,  # False/"none" | True/"int8" | "topk"
    topk_frac: float = 0.01,
    seed: int = 0,
    log_every: int = 10,
    max_step_retries: int = 2,
) -> Dict[str, Any]:
    """Returns {"params", "opt_state", "history", "resumed_from"}."""
    oc = oc or OptConfig(total_steps=steps)
    compressor = make_compressor(compress, topk_frac=topk_frac)
    step_fn = make_train_step(
        cfg, runtime, oc, accum_steps=accum_steps, compressor=compressor
    )
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    params = init_model_params(jax.random.key(seed), cfg)
    opt_state = init_opt_state(params, oc)
    comp_state = compressor.init_state(params) if compressor else None
    data = SyntheticLMDataset(cfg.vocab_size, seq_len, global_batch, seed=seed)

    start_step = 0
    resumed_from = None
    saver = ckpt.AsyncCheckpointer(ckpt_dir, keep=keep) if ckpt_dir else None
    if ckpt_dir and ckpt.list_steps(ckpt_dir):
        state, start_step = ckpt.restore(
            ckpt_dir, {"params": params, "opt": opt_state}
        )
        params, opt_state = state["params"], state["opt"]
        resumed_from = start_step
        print(f"[train] resumed from step {start_step}")

    history = []
    t0 = time.time()
    for step in range(start_step, steps):
        batch_np = data.batch_at(step)  # seekable: exact resume stream
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        for attempt in range(max_step_retries + 1):
            try:
                if compressor:
                    params, opt_state, metrics, comp_state = jit_step(
                        params, opt_state, batch, comp_state
                    )
                else:
                    params, opt_state, metrics = jit_step(params, opt_state, batch)
                break
            except Exception:  # noqa: BLE001 — transient failure: retry
                if attempt == max_step_retries:
                    raise
                print(f"[train] step {step} failed (attempt {attempt}), retrying")
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            print(f"[train] step {step:5d} loss={m['loss']:.4f} "
                  f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e} "
                  f"skip={int(m['skipped'])} ({dt:.1f}s)")
            history.append({"step": step, **m})
        if saver and (step + 1) % ckpt_every == 0:
            saver.save(step + 1, {"params": params, "opt": opt_state})
    if saver:
        saver.save(steps, {"params": params, "opt": opt_state})
        saver.wait()
    return {
        "params": params, "opt_state": opt_state,
        "history": history, "resumed_from": resumed_from,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress", nargs="?", const="int8", default="none",
                    choices=COMPRESSORS,
                    help="gradient all-reduce compression (bare flag = "
                         "int8; 'topk' keeps --topk-frac by magnitude "
                         "with error feedback)")
    ap.add_argument("--topk-frac", type=float, default=0.01)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    oc = OptConfig(lr=args.lr, total_steps=args.steps,
                   warmup_steps=max(1, args.steps // 10))
    out = train_loop(
        cfg, steps=args.steps, global_batch=args.batch, seq_len=args.seq,
        oc=oc, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        accum_steps=args.accum, compress=args.compress,
        topk_frac=args.topk_frac,
    )
    losses = [h["loss"] for h in out["history"]]
    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
