"""Gopher driver: run a time-series graph analytics application over a GoFS
deployment (the paper's end-to-end path).

  PYTHONPATH=src python -m repro.launch.run_graph --app sssp --size small \
      --deploy /tmp/gofs --source 0

Apps: sssp (sequential), pagerank (independent), nhop (eventually),
tracking (sequential, Alg. 1), cc (independent).

``--engine blocked`` runs the TPU-adapted path through the declarative
Gopher session API (``repro.gopher``): the session reconstructs the
blocked structure straight from the deployed topology slices and
auto-selects layout/comm/staging — pass ``--comm``/``--layout``/
``--staging`` to override any knob, and ``--explain`` to print the chosen
plan with its cost estimates WITHOUT executing anything.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.configs import get_graph_config
from repro.core.algorithms import nhop, pagerank, sssp, tracking
from repro.core.generator import generate_collection
from repro.gofs import GoFSStore, deploy_collection


def ensure_deployment(size: str, root: str, cache_slots: int):
    cfg = get_graph_config(size)
    if not os.path.exists(os.path.join(root, "collection.json")):
        print(f"[gopher] deploying {cfg.name} to {root} ...")
        tsg = generate_collection(cfg)
        deploy_collection(tsg, cfg, root)
    return cfg, GoFSStore(
        root, cache_slots=cache_slots,
        vertex_projection=("plate", "outdeg_active"),
        edge_projection=("latency", "active"),
    )


def session_plan(store, cfg, args):
    """Build the declarative session + plan for the chosen app."""
    from repro.gopher import GopherSession

    sess = GopherSession(store, block_size=cfg.block_size)
    knobs = dict(comm=args.comm, layout=args.layout, staging=args.staging)
    if args.app == "sssp":
        plan = sess.plan("sssp", source=args.source, **knobs)
    elif args.app == "pagerank":
        plan = sess.plan("pagerank", iters=10, **knobs)
    elif args.app == "nhop":
        plan = sess.plan("nhop", source=args.source, n_hops=6, **knobs)
    elif args.app == "tracking":
        plan = sess.plan("tracking", plate=args.plate,
                         initial_vertex=args.source, **knobs)
    else:  # cc
        plan = sess.plan("components", **knobs)
    return sess, plan


def report_blocked(app: str, res) -> None:
    out = res.output
    if app == "sssp":
        dist = out["final"]
        ss = res.engine.stats["supersteps"].tolist()
        print(f"[gopher] SSSP reached {int(np.isfinite(dist).sum())}; "
              f"supersteps/timestep={ss}")
    elif app == "pagerank":
        print(f"[gopher] PageRank top vertex (t=0): "
              f"{int(out['ranks'][0].argmax())}")
    elif app == "nhop":
        print(f"[gopher] N-hop composite: {out['composite']}")
    elif app == "tracking":
        print(f"[gopher] track: {out['trace']}")
    else:
        counts = [len(np.unique(l)) for l in out["labels"]]
        print(f"[gopher] components per instance: {counts}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="sssp",
                    choices=["sssp", "pagerank", "nhop", "tracking", "cc"])
    ap.add_argument("--size", default="small", choices=["tiny", "small", "full"])
    ap.add_argument("--deploy", default="/tmp/gofs_deploy")
    ap.add_argument("--engine", default="host", choices=["host", "blocked"])
    ap.add_argument("--source", type=int, default=0)
    ap.add_argument("--plate", type=int, default=3)
    ap.add_argument("--cache-slots", type=int, default=14)
    ap.add_argument("--workers", type=int, default=0)
    ap.add_argument("--comm", default=None,
                    choices=["dense", "ring", "host"],
                    help="override the planned boundary-exchange backend "
                         "(repro.core.comm; default: planner-selected)")
    ap.add_argument("--layout", default=None, choices=["dense", "sparse"],
                    help="override the planned tile layout")
    ap.add_argument("--staging", default=None, choices=["sync", "async"],
                    help="override the planned staging mode")
    ap.add_argument("--explain", action="store_true",
                    help="print the execution plan (auto-selected knobs + "
                         "cost estimates) and exit without executing")
    args = ap.parse_args()

    cfg, store = ensure_deployment(args.size, args.deploy, args.cache_slots)

    if args.explain:
        sess, plan = session_plan(store, cfg, args)
        print(plan.explain())
        return

    t0 = time.time()
    if args.engine == "host":
        if args.app == "sssp":
            dist, res = sssp.run_host(store, args.source, workers=args.workers)
            reached = sum(int(np.isfinite(d).sum()) for d in dist.values())
            print(f"[gopher] SSSP reached {reached} vertices; "
                  f"supersteps={res.stats.supersteps} "
                  f"msgs={res.stats.superstep_messages}")
        elif args.app == "pagerank":
            ranks, res = pagerank.run_host(
                store, store.meta["num_vertices"], iters=10,
                workers=args.workers)
            print(f"[gopher] PageRank over {store.num_timesteps()} instances; "
                  f"supersteps={res.stats.supersteps}")
        elif args.app == "nhop":
            merged, res = nhop.run_host(store, args.source, n_hops=6,
                                        workers=args.workers)
            print(f"[gopher] N-hop composite histogram: {merged['composite']}")
        elif args.app == "tracking":
            trace, res = tracking.run_host(store, args.plate, args.source)
            print(f"[gopher] track: {trace}")
        else:
            raise SystemExit("cc requires --engine blocked")
    else:
        sess, plan = session_plan(store, cfg, args)
        print(plan.explain())
        res = sess.run(plan)
        report_blocked(args.app, res)

    print(f"[gopher] {args.app}/{args.engine} done in {time.time()-t0:.1f}s; "
          f"GoFS stats: {store.snapshot_stats()}")


if __name__ == "__main__":
    main()
