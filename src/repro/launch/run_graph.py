"""Gopher driver: run a time-series graph analytics application over a GoFS
deployment (the paper's end-to-end path).

  PYTHONPATH=src python -m repro.launch.run_graph --app sssp --size small \
      --deploy /tmp/gofs --source 0

Apps: sssp (sequential), pagerank (independent), nhop (eventually),
tracking (sequential, Alg. 1), cc (independent).  ``--engine blocked`` runs
the TPU-adapted blocked engine instead of the faithful host engine;
``--comm dense|ring|host`` picks its boundary-exchange backend
(repro.core.comm — identical results, different byte movement).
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.configs import get_graph_config
from repro.core.algorithms import components, nhop, pagerank, sssp, tracking
from repro.core.blocked import build_blocked
from repro.core.generator import generate_collection
from repro.core.partition import discover_subgraphs, edge_cut, partition_graph
from repro.gofs import GoFSStore, deploy_collection


def ensure_deployment(size: str, root: str, cache_slots: int):
    cfg = get_graph_config(size)
    if not os.path.exists(os.path.join(root, "collection.json")):
        print(f"[gopher] deploying {cfg.name} to {root} ...")
        tsg = generate_collection(cfg)
        deploy_collection(tsg, cfg, root)
    return cfg, GoFSStore(
        root, cache_slots=cache_slots,
        vertex_projection=("plate", "outdeg_active"),
        edge_projection=("latency", "active"),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="sssp",
                    choices=["sssp", "pagerank", "nhop", "tracking", "cc"])
    ap.add_argument("--size", default="small", choices=["tiny", "small", "full"])
    ap.add_argument("--deploy", default="/tmp/gofs_deploy")
    ap.add_argument("--engine", default="host", choices=["host", "blocked"])
    ap.add_argument("--source", type=int, default=0)
    ap.add_argument("--plate", type=int, default=3)
    ap.add_argument("--cache-slots", type=int, default=14)
    ap.add_argument("--workers", type=int, default=0)
    ap.add_argument("--comm", default="dense",
                    choices=["dense", "ring", "host"],
                    help="blocked-engine boundary exchange (repro.core.comm)")
    args = ap.parse_args()

    cfg, store = ensure_deployment(args.size, args.deploy, args.cache_slots)
    t0 = time.time()

    if args.engine == "host":
        if args.app == "sssp":
            dist, res = sssp.run_host(store, args.source, workers=args.workers)
            reached = sum(int(np.isfinite(d).sum()) for d in dist.values())
            print(f"[gopher] SSSP reached {reached} vertices; "
                  f"supersteps={res.stats.supersteps} "
                  f"msgs={res.stats.superstep_messages}")
        elif args.app == "pagerank":
            ranks, res = pagerank.run_host(
                store, store.meta["num_vertices"], iters=10,
                workers=args.workers)
            print(f"[gopher] PageRank over {store.num_timesteps()} instances; "
                  f"supersteps={res.stats.supersteps}")
        elif args.app == "nhop":
            merged, res = nhop.run_host(store, args.source, n_hops=6,
                                        workers=args.workers)
            print(f"[gopher] N-hop composite histogram: {merged['composite']}")
        elif args.app == "tracking":
            trace, res = tracking.run_host(store, args.plate, args.source)
            print(f"[gopher] track: {trace}")
        else:
            raise SystemExit("cc requires --engine blocked")
    else:
        # blocked engine needs template arrays: regenerate deterministically
        tsg = generate_collection(cfg)
        tmpl = tsg.template
        assign = partition_graph(tmpl, cfg.num_partitions, seed=cfg.seed)
        bg = build_blocked(tmpl, assign, cfg.block_size)
        I = len(tsg)
        if args.app == "sssp":
            w = np.stack([tsg.edge_values(t, "latency") for t in range(I)])
            dist, stats = sssp.run_blocked(bg, w, args.source,
                                           comm=args.comm)
            print(f"[gopher] SSSP reached {int(np.isfinite(dist).sum())}; "
                  f"supersteps/timestep={stats['supersteps'].tolist()}")
        elif args.app == "pagerank":
            a = np.stack([tsg.edge_values(t, "active") for t in range(I)])
            ranks, iters = pagerank.run_blocked(
                bg, tmpl.src, a, num_vertices=tmpl.num_vertices, iters=10,
                comm=args.comm)
            print(f"[gopher] PageRank top vertex (t=0): {int(ranks[0].argmax())}")
        elif args.app == "nhop":
            w = np.stack([tsg.edge_values(t, "latency") for t in range(I)])
            comp, per = nhop.run_blocked(bg, w, args.source, n_hops=6,
                                         comm=args.comm)
            print(f"[gopher] N-hop composite: {comp}")
        elif args.app == "tracking":
            plates = np.stack([tsg.vertex_values(t, "plate") for t in range(I)])
            trace = tracking.run_blocked(bg, plates, args.plate,
                                         args.source, comm=args.comm)
            print(f"[gopher] track: {trace}")
        else:
            a = tsg.edge_values(0, "active")
            labels = components.run_blocked(bg, tmpl.src, tmpl.dst, a,
                                            comm=args.comm)
            print(f"[gopher] components: {len(np.unique(labels))}")

    print(f"[gopher] {args.app}/{args.engine} done in {time.time()-t0:.1f}s; "
          f"GoFS stats: {store.snapshot_stats()}")


if __name__ == "__main__":
    main()
