"""Analytic serving driver: a warm GopherService over a GoFS deployment.

  PYTHONPATH=src python -m repro.launch.serve_graph --size small \
      --deploy /tmp/gofs --queries 16 --clients 4

Deploys (or reuses) a collection, starts one :class:`~repro.gopher
.GopherService`, optionally prestages the hot analytics, then fires a
mixed query workload from ``--clients`` concurrent submitter threads —
SSSP and N-hop requests with random seed vertices, which the service
coalesces on the source axis into multi-source engine passes.  Prints
per-request p50/p95 latency, throughput, batch shape, and the warm
staging cache's economy (bytes staged once, hit counts).
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from repro.gopher import GopherService
from repro.launch.run_graph import ensure_deployment


def build_workload(rng, cfg, n_queries: int):
    """A mixed interactive workload: mostly SSSP point queries, some
    N-hop — all over the same two staged batches, seeds drawn at random
    (the shape source-axis batching is designed for)."""
    reqs = []
    for _ in range(n_queries):
        v = int(rng.integers(0, cfg.num_vertices))
        if rng.random() < 0.75:
            reqs.append(("sssp", {"source": v}))
        else:
            reqs.append(("nhop", {"source": v, "n_hops": 4}))
    return reqs


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--size", default="small")
    p.add_argument("--deploy", default="/tmp/gofs_serve")
    p.add_argument("--cache-slots", type=int, default=14)
    p.add_argument("--queries", type=int, default=16)
    p.add_argument("--clients", type=int, default=4,
                   help="concurrent submitter threads")
    p.add_argument("--max-batch", type=int, default=32)
    p.add_argument("--cache-bytes", type=float, default=256 << 20,
                   help="session-lifetime staging cache budget")
    p.add_argument("--no-prestage", action="store_true",
                   help="skip warming the caches before timing")
    p.add_argument("--seed", type=int, default=7)
    args = p.parse_args(argv)

    cfg, store = ensure_deployment(args.size, args.deploy, args.cache_slots)
    rng = np.random.default_rng(args.seed)
    reqs = build_workload(rng, cfg, args.queries)

    with GopherService(store, block_size=cfg.block_size,
                       staging_cache_bytes=args.cache_bytes,
                       max_batch_queries=args.max_batch) as svc:
        if not args.no_prestage:
            t0 = time.perf_counter()
            svc.prestage("sssp", source=0)
            svc.prestage("nhop", source=0)
            # one throwaway query per analytic compiles the runners
            svc.query_many([("sssp", {"source": 0}),
                            ("nhop", {"source": 0, "n_hops": 4})])
            print(f"[serve] prestage+compile "
                  f"{time.perf_counter() - t0:.2f}s")

        chunks = np.array_split(np.arange(len(reqs)), max(1, args.clients))
        t0 = time.perf_counter()

        def client(idx):
            svc.query_many([reqs[i] for i in idx])

        threads = [threading.Thread(target=client, args=(c,))
                   for c in chunks if len(c)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0

        rep = svc.report()
        print(f"[serve] {args.queries} queries from {args.clients} "
              f"clients in {wall:.2f}s "
              f"({args.queries / wall:.1f} q/s wall)")
        print(f"[serve] p50 {rep['p50_ms']:.1f} ms   "
              f"p95 {rep['p95_ms']:.1f} ms   "
              f"batches {rep['batches']} (widest {rep['widest_batch']})")
        sc = rep["staging_cache"]
        if sc:
            print(f"[serve] staging cache: {sc['entries']} resident "
                  f"batches, {sc['resident_bytes'] / 1e6:.1f} MB, "
                  f"{sc['hits']} hits / {sc['staging_passes']} passes")


if __name__ == "__main__":
    main()
