"""Abstract input/state specs for every (arch x shape) dry-run cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct, no
allocation) for the step function selected by the shape kind:

* train_*   -> train_step(params, opt_state, batch)
* prefill_* -> prefill(params, {tokens, cache, [patches|frames]})
* decode_*  -> decode_step(params, {tokens, pos, cache})

``cell_shardings`` maps every leaf onto the production mesh: params/opt via
the logical-axis rules, batches over the DP axes, KV caches over
(data=batch, model=sequence) — sequence-parallel KV is what lets a ~1.5 TB
32k-decode cache (mistral-large) fit 256 chips.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.dist.sharding import Runtime, default_rules, shardings_for_schema
from repro.models import abstract_params, model_schema
from repro.models.model import init_serve_cache
from repro.train.optimizer import OptConfig

Params = Any


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def runtime_for(mesh: Optional[Mesh]) -> Runtime:
    if mesh is None:
        from repro.dist.sharding import CPU_RUNTIME

        return CPU_RUNTIME
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return Runtime(mesh=mesh, dp_axes=dp, tp_axis="model")


def abstract_opt_state(params: Params, oc: OptConfig) -> Dict[str, Any]:
    dt = jnp.dtype(oc.state_dtype)
    mom = jax.tree.map(lambda p: _sds(p.shape, dt), params)
    return {"mu": mom, "nu": mom, "step": _sds((), jnp.int32)}


def batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    out = {
        "tokens": _sds((B, S), jnp.int32),
        "labels": _sds((B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        out["patches"] = _sds((B, cfg.num_image_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        out["frames"] = _sds((B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    return out


def serve_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Prefill: prompt of seq_len fills a cache of exactly seq_len.  Decode:
    one new token against a cache of seq_len."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: init_serve_cache(cfg, B, S))
    if shape.kind == "prefill":
        out = {"tokens": _sds((B, S), jnp.int32), "cache": cache}
        if cfg.family == "vlm":
            out["patches"] = _sds(
                (B, cfg.num_image_patches, cfg.d_model), jnp.bfloat16
            )
        if cfg.family == "audio":
            out["frames"] = _sds((B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
        return out
    return {
        "tokens": _sds((B, 1), jnp.int32),
        "pos": _sds((B,), jnp.int32),
        "cache": cache,
    }


def input_specs(cfg: ArchConfig, shape: ShapeConfig, oc: OptConfig = OptConfig(),
                *, params_dtype=jnp.float32):
    """(params, opt_state, batch) for train; (params, batch) for serving.

    ``params_dtype=bf16`` models the distributed-optimizer configuration
    (bf16 live weights, f32 masters inside the optimizer state) — all
    forward/backward collectives move bf16 by construction (§Perf)."""
    params = abstract_params(cfg, params_dtype)
    if shape.is_train:
        return params, abstract_opt_state(params, oc), batch_specs(cfg, shape)
    return params, serve_specs(cfg, shape)


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------

def _dp(mesh: Mesh):
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return dp if len(dp) > 1 else dp[0]


def _dp_size(mesh: Mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        if a in ("pod", "data"):
            n *= int(mesh.shape[a])
    return n


def _cache_leaf_spec(
    path: Tuple[str, ...], name: str, x, mesh: Mesh, B: int, seq_shard: bool
) -> P:
    """Sharding for one cache leaf by its (path, name) and fixed layout.

    Layouts (leading dims are layer stacks of any depth):
      k/v   (..., B, S, K, hd)   batch @ -4, seq @ -3
      pos   (..., B, S)          batch @ -2, seq @ -1
      len   (..., B)             batch @ -1
      conv  (..., B, W-1, di)    batch @ -3, channels @ -1
      ssm   (..., B, di, N)      batch @ -3, channels @ -2
      mlstm c (..., B, H, hd, hd)  batch @ -4
      mlstm n (..., B, H, hd)      batch @ -3
      mlstm m (..., B, H)          batch @ -2
      slstm h/c/n/m (..., B, d)    batch @ -2, d @ -1
    """
    dp = _dp(mesh)
    dpn = _dp_size(mesh)
    ntp = mesh.shape["model"]
    axes: list = [None] * x.ndim
    in_slstm = "slstm" in path
    in_mlstm = "mlstm" in path

    def set_batch(i: int):
        if B > 1 and B % dpn == 0 and x.shape[i] == B:
            axes[i] = dp

    def set_model(i: int):
        if x.shape[i] % ntp == 0:
            axes[i] = "model"

    if name in ("k", "v"):
        set_batch(x.ndim - 4)
        if seq_shard:
            set_model(x.ndim - 3)
    elif name == "pos":
        set_batch(x.ndim - 2)
        if seq_shard:
            set_model(x.ndim - 1)
    elif name == "len":
        set_batch(x.ndim - 1)
    elif name == "conv":
        set_batch(x.ndim - 3)
        set_model(x.ndim - 1)
    elif name == "ssm":
        set_batch(x.ndim - 3)
        set_model(x.ndim - 2)
    elif in_slstm:  # h / c / n / m: (..., B, d)
        set_batch(x.ndim - 2)
        set_model(x.ndim - 1)
    elif in_mlstm:
        if name == "c":
            set_batch(x.ndim - 4)
        elif name == "n":
            set_batch(x.ndim - 3)
        elif name == "m":
            set_batch(x.ndim - 2)
    return P(*axes)


def _cache_spec_tree(cache_abs: Any, mesh: Mesh, B: int, seq_shard: bool) -> Any:
    def walk(tree, path: Tuple[str, ...]):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, tuple):  # whisper cross-KV (k, v)
            names = ("k", "v")
            return tuple(
                _cache_leaf_spec(path, names[i], v, mesh, B, seq_shard)
                for i, v in enumerate(tree)
            )
        return _cache_leaf_spec(path[:-1], path[-1], tree, mesh, B, seq_shard)

    specs = walk(cache_abs, ())
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def cell_shardings(
    cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, *, seq_shard_kv: bool = True,
    serve_replicated_weights: bool = False,
):
    """NamedSharding trees matching ``input_specs`` for this cell.

    ``serve_replicated_weights``: serving has no optimizer state, so the
    FSDP ("embed" over data) sharding only forces per-step weight gathers —
    replicating weights over the data axis removes them (§Perf; pair with
    bf16 weights for the memory headroom)."""
    rules = default_rules()
    if serve_replicated_weights and not shape.is_train:
        rules = {**rules, "embed": None}
    schema = model_schema(cfg)
    p_sh = shardings_for_schema(schema, rules, mesh)
    dp = _dp(mesh)
    if shape.is_train:
        o_sh = {
            "mu": p_sh, "nu": p_sh,
            "step": NamedSharding(mesh, P()),
        }
        b = {
            "tokens": NamedSharding(mesh, P(dp, None)),
            "labels": NamedSharding(mesh, P(dp, None)),
        }
        if cfg.family == "vlm":
            b["patches"] = NamedSharding(mesh, P(dp, None, None))
        if cfg.family == "audio":
            b["frames"] = NamedSharding(mesh, P(dp, None, None))
        return p_sh, o_sh, b

    cache_abs = jax.eval_shape(
        lambda: init_serve_cache(cfg, shape.global_batch, shape.seq_len)
    )
    c_sh = _cache_spec_tree(cache_abs, mesh, shape.global_batch, seq_shard_kv)
    bspec = P(dp, None) if shape.global_batch % _dp_size(mesh) == 0 \
        and shape.global_batch > 1 else P(None, None)
    if shape.kind == "prefill":
        b = {
            "tokens": NamedSharding(mesh, bspec),
            "cache": c_sh,
        }
        if cfg.family == "vlm":
            b["patches"] = NamedSharding(mesh, P(*bspec, None))
        if cfg.family == "audio":
            b["frames"] = NamedSharding(mesh, P(*bspec, None))
        return p_sh, b
    b = {
        "tokens": NamedSharding(mesh, bspec),
        "pos": NamedSharding(mesh, P(bspec[0])),
        "cache": c_sh,
    }
    return p_sh, b
