"""Live-tailing driver: streaming ingestion into a served GoFS collection.

  PYTHONPATH=src python -m repro.launch.tail_graph --size small \
      --deploy /tmp/gofs_tail --prefix 4 --batch 2 --analytic sssp

Deploys a PREFIX of the configured collection, starts a
:class:`~repro.gopher.GopherService` with a tailing subscription
(:meth:`GopherService.subscribe`), then streams the remaining instances
into the deployment from a feeder thread
(:func:`~repro.gofs.append_instances`) — the serve loop observes each
append at a batch boundary and delivers one warm incremental
:class:`~repro.gopher.session.TailUpdate` per append.  Prints each
update's mode/latency and finishes with an exactness check against a
cold full re-run over the grown collection.
"""
from __future__ import annotations

import argparse
import os
import shutil
import threading
import time

import numpy as np

from repro.core.generator import generate_collection
from repro.core.graph import TimeSeriesGraph
from repro.gofs import GoFSStore, append_instances, deploy_collection
from repro.gopher import GopherService, GopherSession
from repro.launch.run_graph import get_graph_config


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--size", default="small")
    p.add_argument("--deploy", default="/tmp/gofs_tail")
    p.add_argument("--prefix", type=int, default=None,
                   help="instances deployed before serving starts "
                        "(default: half the collection)")
    p.add_argument("--batch", type=int, default=1,
                   help="instances per streamed append")
    p.add_argument("--interval", type=float, default=0.1,
                   help="seconds between appends")
    p.add_argument("--analytic", default="sssp",
                   choices=["sssp", "pagerank"])
    p.add_argument("--source", type=int, default=0,
                   help="seed vertex (sssp)")
    p.add_argument("--cache-slots", type=int, default=14)
    p.add_argument("--fresh", action="store_true",
                   help="wipe an existing deployment at --deploy")
    args = p.parse_args(argv)

    cfg = get_graph_config(args.size)
    tsg = generate_collection(cfg)
    n_total = len(tsg)
    prefix = args.prefix if args.prefix is not None else max(1, n_total // 2)
    assert 0 < prefix <= n_total, (prefix, n_total)

    manifest = os.path.join(args.deploy, "collection.json")
    if os.path.exists(manifest):
        if not args.fresh:
            raise SystemExit(
                f"{args.deploy} already holds a collection; pass --fresh "
                f"to wipe it")
        shutil.rmtree(args.deploy)
    print(f"[tail] deploying {prefix}/{n_total} instances of {cfg.name} "
          f"to {args.deploy} ...")
    deploy_collection(
        TimeSeriesGraph(template=tsg.template, instances=tsg.instances[:prefix]),
        cfg, args.deploy)
    store = GoFSStore(args.deploy, cache_slots=args.cache_slots)

    params = {"source": args.source} if args.analytic == "sssp" else {}
    t0 = time.perf_counter()
    updates = []

    def on_update(u):
        updates.append((time.perf_counter() - t0, u))
        print(f"[tail] +{updates[-1][0]:6.2f}s  {u.mode:<11} "
              f"n={u.result.engine.values.shape[-2]}  "
              f"new={u.new_instances}  version={u.version}")

    def feeder():
        for k in range(prefix, n_total, args.batch):
            time.sleep(args.interval)
            chunk = tsg.instances[k:k + args.batch]
            append_instances(
                TimeSeriesGraph(template=tsg.template, instances=chunk),
                args.deploy)
            print(f"[tail] appended instances "
                  f"[{k}, {k + len(chunk)}) to the deployment")

    with GopherService(store, block_size=cfg.block_size,
                       poll_interval=min(0.05, args.interval / 2)) as svc:
        sub = svc.subscribe(args.analytic, callback=on_update, **params)
        sub.wait_update(1, timeout=120)  # initial full run (compiles too)
        th = threading.Thread(target=feeder, daemon=True)
        th.start()
        th.join()
        # boundary refreshes may coalesce appends into one update — wait
        # until the subscription covers the fully-grown collection
        deadline = time.perf_counter() + 120
        while time.perf_counter() < deadline:
            u = sub.last
            if u is not None and int(
                    np.asarray(u.result.engine.values).shape[-2]) == n_total:
                break
            time.sleep(0.05)
        else:
            raise SystemExit(
                f"subscriber never caught up to {n_total} instances")
        rep = svc.report()
        sub.cancel()

    last = updates[-1][1]
    cold = GopherSession(GoFSStore(args.deploy, cache_slots=args.cache_slots),
                         block_size=cfg.block_size)
    ref = cold.run(cold.plan(args.analytic, **params))
    exact = all(
        np.array_equal(np.asarray(last.result.output[k]), np.asarray(v))
        for k, v in ref.output.items())
    print(f"[tail] {len(updates)} updates "
          f"({sum(1 for _, u in updates if u.mode == 'incremental')} "
          f"incremental), {rep['appends_observed']} appends observed, "
          f"final version {last.version}")
    print(f"[tail] tail result vs cold full re-run: "
          f"{'bitwise identical' if exact else 'MISMATCH'}")
    if not exact:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
