"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run must set
XLA_FLAGS before anything initializes devices.

Mesh shapes:
  single pod : (16, 16)      axes (data, model)      = 256 chips (v5e pod)
  multi-pod  : (2, 16, 16)   axes (pod, data, model) = 512 chips

``pod`` composes with ``data`` for data parallelism: the only cross-pod
(DCI) collective in steady state is the gradient all-reduce, optionally
int8-compressed (repro.dist.compression).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over host devices for CPU tests (needs
    XLA_FLAGS=--xla_force_host_platform_device_count >= data*model)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def recommended_comm(
    mesh: Optional[Mesh], model_axes: Tuple[str, ...] = ("model",)
) -> str:
    """Default boundary-exchange backend for a placement
    (``repro.core.comm``; full selection table in docs/ARCHITECTURE.md).

    What matters is whether the EXCHANGE axes (``model_axes`` — the axes
    the boundary combine actually runs over) cross DCI, not whether the
    mesh is multi-pod: on the standard production mesh ``pod`` composes
    with ``data`` and the model axis stays intra-pod on ICI, so the dense
    all-reduce remains the right default there.

    * no mesh                      -> ``"host"``  (mesh-free CPU cluster:
      combine per-partition buffers on the host, no shard_map at all)
    * ``pod`` among the exchange axes -> ``"ring"`` (the combine crosses
      DCI; neighbor-to-neighbor hops keep each slow link at one
      buffer/hop)
    * otherwise                    -> ``"dense"`` (ICI all-reduce is
      latency-optimal for the O(cut) boundary buffer)
    """
    if mesh is None:
        return "host"
    if "pod" in model_axes:
        return "ring"
    return "dense"
