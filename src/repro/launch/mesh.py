"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run must set
XLA_FLAGS before anything initializes devices.

Mesh shapes:
  single pod : (16, 16)      axes (data, model)      = 256 chips (v5e pod)
  multi-pod  : (2, 16, 16)   axes (pod, data, model) = 512 chips

``pod`` composes with ``data`` for data parallelism: the only cross-pod
(DCI) collective in steady state is the gradient all-reduce, optionally
int8-compressed (repro.dist.compression).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over host devices for CPU tests (needs
    XLA_FLAGS=--xla_force_host_platform_device_count >= data*model)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
