"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run must set
XLA_FLAGS before anything initializes devices.

Mesh shapes:
  single pod : (16, 16)      axes (data, model)      = 256 chips (v5e pod)
  multi-pod  : (2, 16, 16)   axes (pod, data, model) = 512 chips

``pod`` composes with ``data`` for data parallelism: the only cross-pod
(DCI) collective in steady state is the gradient all-reduce, optionally
int8-compressed (repro.dist.compression).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over host devices for CPU tests (needs
    XLA_FLAGS=--xla_force_host_platform_device_count >= data*model)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# Below ~64 KiB of actual cut payload the DCI exchange is latency-bound,
# not bandwidth-bound: the all-reduce tree's 2(P-1) short hops beat the
# ring's P-1 full-buffer circulations even across pods.
RING_MIN_CUT_BYTES = 1 << 16

# Above ~1 MiB of cut payload the circulate ring's own (P-1)×NB traffic
# becomes the bandwidth bottleneck; the reduce-scatter + all-gather ring
# ("ring-rs") halves per-device bytes to 2(P-1)/P×NB while keeping every
# transfer neighbor-to-neighbor, and its doubled hop count is noise at
# this payload size.
RING_RS_MIN_CUT_BYTES = 1 << 20


def recommended_comm(
    mesh: Optional[Mesh], model_axes: Tuple[str, ...] = ("model",),
    *,
    boundary_nnz: Optional[int] = None,
) -> str:
    """Default boundary-exchange backend for a placement
    (``repro.core.comm``; full selection table in docs/ARCHITECTURE.md).

    What matters is whether the EXCHANGE axes (``model_axes`` — the axes
    the boundary combine actually runs over) cross DCI, not whether the
    mesh is multi-pod: on the standard production mesh ``pod`` composes
    with ``data`` and the model axis stays intra-pod on ICI, so the dense
    all-reduce remains the right default there.

    ``boundary_nnz`` — the boundary vertices actually published
    (``BlockedGraph.boundary_nnz``), NOT the block-padded buffer length:
    sparse cuts flip the DCI recommendation back to ``dense`` when the
    real payload (``4·nnz`` bytes) is too small for byte volume to beat
    hop latency (``RING_MIN_CUT_BYTES``).

    * no mesh                      -> ``"host"``  (mesh-free CPU cluster:
      combine per-partition buffers on the host, no shard_map at all)
    * ``pod`` among the exchange axes and the cut huge
      (``>= RING_RS_MIN_CUT_BYTES``) -> ``"ring-rs"`` (the exchange is
      bandwidth-bound even over the ring; the reduce-scatter + all-gather
      schedule halves per-device bytes at double the hop count)
    * ``pod`` among the exchange axes and the cut large (or unknown)
      -> ``"ring"`` (the combine crosses DCI; neighbor-to-neighbor hops
      keep each slow link at one buffer/hop)
    * otherwise                    -> ``"dense"`` (ICI all-reduce is
      latency-optimal for the O(cut) boundary buffer)

    >>> recommended_comm(None)
    'host'
    """
    if mesh is None:
        return "host"
    if "pod" in model_axes:
        if (boundary_nnz is not None
                and boundary_nnz * 4 < RING_MIN_CUT_BYTES):
            return "dense"
        if (boundary_nnz is not None
                and boundary_nnz * 4 >= RING_RS_MIN_CUT_BYTES):
            return "ring-rs"
        return "ring"
    return "dense"
