import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, extract memory/cost/collective analysis.

MUST be run as a module entry point (``python -m repro.launch.dryrun``) so
the XLA_FLAGS above land before jax initializes devices — hence they are
the first statements in the file, before any other import.

Per cell this emits a JSON record with:
  * memory_analysis     — bytes per device (proves it fits)
  * cost_analysis       — HLO FLOPs / bytes (per device)
  * collective bytes    — parsed from optimized HLO, by kind and mesh axis
  * two-point scan fit  — per-layer body costs recovered from compiles at
    L and L/2 (cost_analysis counts while-loop bodies once)

Usage:
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out results.jsonl
  python -m repro.launch.dryrun --graph       # GoFFish SSSP workload cell
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


def _fit_points(cfg):
    """Two small UNROLLED configs for the scan-cost fit.

    cost_analysis counts while-loop bodies once regardless of trip count, so
    costs are CONSTANT in depth when layers are scanned — differencing full
    and half depth recovers nothing.  Instead we compile at 2 and 4 scan
    units with the layer scans fully unrolled; the per-unit body cost is
    (c4 - c2)/2 and totals extrapolate as outside + body * units(full).
    """
    return (
        cfg.with_units(2).with_overrides(scan_unroll=True),
        cfg.with_units(4).with_overrides(scan_unroll=True),
    )


def _jsonable(d: Optional[Dict[str, Any]]) -> Dict[str, Any]:
    if not d:
        return {}
    out = {}
    for k, v in d.items():
        try:
            out[k] = float(v)
        except (TypeError, ValueError):
            pass
    return out


def compile_cell(
    arch_id: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    seq_shard_kv: bool = True,
    fit: bool = True,
    remat: Optional[str] = None,
    donate: bool = True,
    flash_decode: bool = False,  # §Perf: TP flash decoding
    cast_params: bool = False,  # §Perf: bf16-before-gather FSDP
    params_dtype: str = "float32",  # §Perf: bf16 live weights (dist. opt)
    serve_replicated_weights: bool = False,  # §Perf: no FSDP at serve
    no_sp: bool = False,  # §Perf: classic TP (replicated activations)
) -> Dict[str, Any]:
    """Lower+compile one cell; returns the roofline-input record."""
    from repro.configs import cell_applicable, get_config, shape_by_name
    from repro.dist.collectives import collective_bytes_by_kind
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import cell_shardings, input_specs, runtime_for
    from repro.models.model import decode_step, prefill
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import make_train_step

    cfg = get_config(arch_id)
    if remat is not None:
        cfg = cfg.with_overrides(remat=remat)
    shape = shape_by_name(shape_name)
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    runtime = runtime_for(mesh)
    import dataclasses as _dc

    if flash_decode:
        runtime = _dc.replace(runtime, flash_decode=True)
    if no_sp:
        runtime = _dc.replace(runtime, sp=False)
    oc = OptConfig(state_dtype="bfloat16")

    def one_compile(cfg_c) -> Dict[str, Any]:
        t0 = time.time()
        with mesh:
            if shape.is_train:
                step = make_train_step(cfg_c, runtime, oc,
                                       cast_params_once=cast_params)
                p, o, b = input_specs(cfg_c, shape, oc,
                                      params_dtype=jnp.dtype(params_dtype))
                p_sh, o_sh, b_sh = cell_shardings(cfg_c, shape, mesh)
                jitted = jax.jit(
                    step,
                    in_shardings=(p_sh, o_sh, b_sh),
                    donate_argnums=(0, 1) if donate else (),
                )
                lowered = jitted.lower(p, o, b)
            elif shape.kind == "prefill":
                fn = lambda params, batch: prefill(params, batch, cfg_c, runtime)
                p, b = input_specs(cfg_c, shape,
                                   params_dtype=jnp.dtype(params_dtype))
                p_sh, b_sh = cell_shardings(
                    cfg_c, shape, mesh, seq_shard_kv=seq_shard_kv,
                    serve_replicated_weights=serve_replicated_weights,
                )
                jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
                lowered = jitted.lower(p, b)
            else:  # decode
                fn = lambda params, batch: decode_step(params, batch, cfg_c, runtime)
                p, b = input_specs(cfg_c, shape,
                                   params_dtype=jnp.dtype(params_dtype))
                p_sh, b_sh = cell_shardings(
                    cfg_c, shape, mesh, seq_shard_kv=seq_shard_kv,
                    serve_replicated_weights=serve_replicated_weights,
                )
                jitted = jax.jit(
                    fn, in_shardings=(p_sh, b_sh),
                    donate_argnums=(1,) if donate else (),
                )
                lowered = jitted.lower(p, b)
            compiled = lowered.compile()
        ca = _jsonable(compiled.cost_analysis())
        ma = compiled.memory_analysis()
        mem = {}
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            mem[f] = getattr(ma, f, None)
        hlo = compiled.as_text()
        coll = collective_bytes_by_kind(hlo)
        return {
            "flops": ca.get("flops", 0.0),
            "bytes": ca.get("bytes accessed", 0.0),
            "cost_analysis": ca,
            "memory": mem,
            "collectives": coll,
            "compile_seconds": time.time() - t0,
            "hlo_lines": hlo.count("\n"),
        }

    rec: Dict[str, Any] = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "num_devices": 512 if multi_pod else 256,
        "seq_shard_kv": seq_shard_kv,
        "remat": cfg.remat,
        "flash_decode": flash_decode,
        "cast_params": cast_params,
        "params_dtype": params_dtype,
    }
    rec["full"] = one_compile(cfg)
    if fit:
        cfg2, cfg4 = _fit_points(cfg)
        rec["u2"] = one_compile(cfg2)
        rec["u4"] = one_compile(cfg4)
        U = cfg.scan_units()
        fit_out = {"units": U}
        for key in ("flops", "bytes"):
            body = (rec["u4"][key] - rec["u2"][key]) / 2.0
            outside = rec["u2"][key] - 2.0 * body
            fit_out[key] = {
                "per_unit": body, "outside": outside,
                "total": outside + body * U,
            }
        kinds = set(rec["u4"]["collectives"]) | set(rec["u2"]["collectives"])
        coll_fit = {}
        for k in kinds:
            c4 = rec["u4"]["collectives"].get(k, 0)
            c2 = rec["u2"]["collectives"].get(k, 0)
            body = (c4 - c2) / 2.0
            outside = c2 - 2.0 * body
            coll_fit[k] = {
                "per_unit": body, "outside": outside,
                "total": outside + body * U,
            }
        fit_out["collectives"] = coll_fit
        rec["fit"] = fit_out
    return rec


def compile_graph_cell(*, multi_pod: bool = False,
                       tile_dtype: str = "float32",
                       spmd: bool = False) -> Dict[str, Any]:
    """The paper's own workload as the 11th architecture: one temporal-SSSP
    superstep (local min-plus sweep + boundary exchange) on the full-size TR
    spec, partitions sharded over the whole mesh."""
    from repro.configs import get_graph_config
    from repro.dist.collectives import collective_bytes_by_kind
    from repro.launch.mesh import make_production_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = 512 if multi_pod else 256
    gc = get_graph_config("full")
    B = gc.block_size
    P_parts = n_dev  # one partition per device
    V = gc.num_vertices
    vp = -(-V // P_parts // B) * B
    E_local = int(V * gc.avg_degree * 0.7) // P_parts
    # tile count assumption: ~32 edges/tile occupancy after subgraph-ordered
    # numbering (documented in EXPERIMENTS.md §Dry-run)
    T = max(1, E_local // 32)
    NB = -(-int(V * 0.05) // B) * B  # ~5% boundary vertices
    Tb = max(1, T // 8)
    O = NB // P_parts * 4

    import numpy as np

    from repro.core.semiring import MIN_PLUS
    from repro.core.superstep import Comm, DeviceGraph
    from repro.core import superstep as ss

    axes = mesh.axis_names  # partitions over every axis
    part_axes = tuple(axes)

    tdt = jnp.dtype(tile_dtype)

    def sds(shape, dt=None):
        return jax.ShapeDtypeStruct(shape, dt if dt is not None else tdt)

    dg_abs = DeviceGraph(
        block_size=B, num_boundary=NB,
        rows=sds((P_parts, T), jnp.int32), cols=sds((P_parts, T), jnp.int32),
        tiles=sds((P_parts, T, B, B)),
        brows=sds((P_parts, Tb), jnp.int32), bcols=sds((P_parts, Tb), jnp.int32),
        btiles=sds((P_parts, Tb, B, B)),
        out_slot=sds((P_parts, O), jnp.int32),
        out_local=sds((P_parts, O), jnp.int32),
        out_mask=sds((P_parts, O), jnp.bool_),
        vmask=sds((P_parts, vp), jnp.bool_),
    )
    x_abs = sds((P_parts, vp))

    if spmd:
        # production lowering: explicit shard_map, boundary = one pmin
        superstep_fn = ss.make_spmd_superstep(mesh, MIN_PLUS)(NB)
    else:
        comm = Comm(axis_name=None)  # stacked baseline: XLA auto-shards

        def superstep_fn(x, rows, cols, tiles, brows, bcols, btiles,
                         out_slot, out_local, out_mask, vmask):
            dg = DeviceGraph(
                block_size=B, num_boundary=NB, rows=rows, cols=cols,
                tiles=tiles, brows=brows, bcols=bcols, btiles=btiles,
                out_slot=out_slot, out_local=out_local, out_mask=out_mask,
                vmask=vmask,
            )
            x = ss._local_sweep(x, dg, MIN_PLUS, False)
            boundary = ss._publish(x, dg, MIN_PLUS, comm)
            return ss._consume(x, boundary, dg, MIN_PLUS, False)

    spec = NamedSharding(mesh, P(part_axes))

    def shard_like(x):
        return NamedSharding(mesh, P(part_axes, *([None] * (len(x.shape) - 1))))

    args = (x_abs, dg_abs.rows, dg_abs.cols, dg_abs.tiles, dg_abs.brows,
            dg_abs.bcols, dg_abs.btiles, dg_abs.out_slot, dg_abs.out_local,
            dg_abs.out_mask, dg_abs.vmask)
    shardings = tuple(shard_like(a) for a in args)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(superstep_fn, in_shardings=shardings).lower(*args)
        compiled = lowered.compile()
    ca = _jsonable(compiled.cost_analysis())
    ma = compiled.memory_analysis()
    return {
        "arch": "goffish-sssp-superstep",
        "shape": (f"TR-full V={V} E/part={E_local} T={T} B={B} "
                  f"dtype={tile_dtype} {'spmd' if spmd else 'jit'}"),
        "mesh": "2x16x16" if multi_pod else "16x16",
        "num_devices": n_dev,
        "full": {
            "flops": ca.get("flops", 0.0),
            "bytes": ca.get("bytes accessed", 0.0),
            "cost_analysis": ca,
            "memory": {
                f: getattr(ma, f, None)
                for f in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes")
            },
            "collectives": collective_bytes_by_kind(compiled.as_text()),
            "compile_seconds": time.time() - t0,
        },
    }


def compile_graph_temporal_cell(*, multi_pod: bool = False) -> Dict[str, Any]:
    """Independent-pattern cell: 16 PageRank instances in flight over the
    `data` axis x 256 partitions over `model` (paper §IV-B temporal
    concurrency on the mesh)."""
    from repro.configs import get_graph_config
    from repro.core.temporal import make_temporal_pagerank
    from repro.dist.collectives import collective_bytes_by_kind
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = 512 if multi_pod else 256
    gc = get_graph_config("full")
    B = gc.block_size
    P_parts = 256
    V = gc.num_vertices
    vp = -(-V // P_parts // B) * B
    E_local = int(V * gc.avg_degree * 0.7) // P_parts
    T = max(1, E_local // 32)
    NB = -(-int(V * 0.05) // B) * B
    Tb = max(1, T // 8)
    O = NB // P_parts * 4
    I = 32 if multi_pod else 16  # instances in flight over data(+pod)

    def sds(shape, dt=jnp.float32):
        return jax.ShapeDtypeStruct(shape, dt)

    data_axes = ("pod", "data") if multi_pod else ("data",)
    fn = make_temporal_pagerank(
        mesh, block_size=B, num_boundary=NB, num_vertices=V, iters=30,
        data_axis=data_axes if len(data_axes) > 1 else data_axes[0],
        model_axes=("model",),
    )
    args = (
        sds((I, P_parts, T, B, B)), sds((I, P_parts, Tb, B, B)),
        sds((P_parts, T), jnp.int32), sds((P_parts, T), jnp.int32),
        sds((P_parts, Tb), jnp.int32), sds((P_parts, Tb), jnp.int32),
        sds((P_parts, O), jnp.int32), sds((P_parts, O), jnp.int32),
        sds((P_parts, O), jnp.bool_), sds((P_parts, vp), jnp.bool_),
    )
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn).lower(*args)
        compiled = lowered.compile()
    ca = _jsonable(compiled.cost_analysis())
    ma = compiled.memory_analysis()
    return {
        "arch": "goffish-pagerank-temporal",
        "shape": f"TR-full I={I} P={P_parts} T={T} B={B} iters=30",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "num_devices": n_dev,
        "full": {
            "flops": ca.get("flops", 0.0),
            "bytes": ca.get("bytes accessed", 0.0),
            "cost_analysis": ca,
            "memory": {
                f: getattr(ma, f, None)
                for f in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes")
            },
            "collectives": collective_bytes_by_kind(compiled.as_text()),
            "compile_seconds": time.time() - t0,
        },
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--graph", action="store_true")
    ap.add_argument("--graph-temporal", action="store_true")
    ap.add_argument("--graph-dtype", default="float32")
    ap.add_argument("--graph-spmd", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-fit", action="store_true")
    ap.add_argument("--no-seq-shard-kv", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--flash-decode", action="store_true")
    ap.add_argument("--cast-params", action="store_true")
    ap.add_argument("--params-dtype", default="float32")
    ap.add_argument("--serve-replicated-weights", action="store_true")
    ap.add_argument("--no-sp", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.configs import ARCH_IDS, LM_SHAPES

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in LM_SHAPES:
                cells.append((a, s.name))
    elif args.arch:
        shapes = [args.shape] if args.shape else [s.name for s in LM_SHAPES]
        cells = [(args.arch, s) for s in shapes]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    out_f = open(args.out, "a") if args.out else None
    n_fail = 0
    for multi_pod in meshes:
        if args.graph_temporal:
            rec = compile_graph_temporal_cell(multi_pod=multi_pod)
            line = json.dumps(rec)
            print(f"[{rec['mesh']}] {rec['arch']}: ok", flush=True)
            if out_f:
                out_f.write(line + "\n")
                out_f.flush()
        if args.graph:
            rec = compile_graph_cell(multi_pod=multi_pod,
                                     tile_dtype=args.graph_dtype,
                                     spmd=args.graph_spmd)
            line = json.dumps(rec)
            print(line if not out_f else rec["arch"] + " ok")
            if out_f:
                out_f.write(line + "\n")
                out_f.flush()
        for arch, shape in cells:
            try:
                rec = compile_cell(
                    arch, shape, multi_pod=multi_pod,
                    seq_shard_kv=not args.no_seq_shard_kv,
                    fit=not args.no_fit, remat=args.remat,
                    flash_decode=args.flash_decode,
                    cast_params=args.cast_params,
                    params_dtype=args.params_dtype,
                    serve_replicated_weights=args.serve_replicated_weights,
                    no_sp=args.no_sp,
                )
                status = rec.get("skipped", "ok")
            except Exception as e:  # noqa: BLE001 — record and continue
                rec = {
                    "arch": arch, "shape": shape,
                    "mesh": "2x16x16" if multi_pod else "16x16",
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:],
                }
                status = "ERROR"
                n_fail += 1
            line = json.dumps(rec)
            mesh_name = "2x16x16" if multi_pod else "16x16"
            print(f"[{mesh_name}] {arch} x {shape}: {status}", flush=True)
            if out_f:
                out_f.write(line + "\n")
                out_f.flush()
            elif "error" in rec:
                print(rec["traceback"], file=sys.stderr)
    if out_f:
        out_f.close()
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
