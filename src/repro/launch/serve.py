"""Serving driver: batched prefill + decode over a request queue.

CPU-runnable with reduced configs; the production path shares the same
step functions with the dry-run cells (prefill_32k / decode_32k shapes).

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \
      --requests 8 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.dist.sharding import CPU_RUNTIME, Runtime
from repro.models import init_model_params, init_serve_cache
from repro.train.serve_step import make_decode_step, make_prefill_step


@dataclass
class Request:
    rid: int
    tokens: np.ndarray  # (S,)
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Static-batch server: groups requests into fixed (B, S) slots, runs
    one prefill per batch then steps decode until every slot finishes.
    Continuous batching (slot refill mid-decode) is a straightforward
    extension; static batching keeps the jit cache to two programs."""

    def __init__(self, cfg, runtime: Runtime = CPU_RUNTIME, *,
                 batch_size: int = 8, max_len: int = 256):
        self.cfg = cfg
        self.runtime = runtime
        self.batch_size = batch_size
        self.max_len = max_len
        self.prefill = make_prefill_step(cfg, runtime)
        self.decode = make_decode_step(cfg, runtime)
        self.extra_inputs: Dict[str, Any] = {}

    def _pad_batch(self, reqs: List[Request]) -> jnp.ndarray:
        S = max(len(r.tokens) for r in reqs)
        B = self.batch_size
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.tokens):] = r.tokens  # left-pad
        return jnp.asarray(toks)

    def serve(self, requests: List[Request]) -> List[Request]:
        t0 = time.time()
        done: List[Request] = []
        queue = list(requests)
        while queue:
            batch_reqs = queue[: self.batch_size]
            queue = queue[self.batch_size:]
            while len(batch_reqs) < self.batch_size:  # pad with a dummy
                batch_reqs.append(Request(rid=-1, tokens=np.zeros(1, np.int32),
                                          max_new=1))
            toks = self._pad_batch(batch_reqs)
            B, S = toks.shape
            cache = init_serve_cache(self.cfg, B, self.max_len)
            logits, cache = self.prefill(
                {"tokens": toks, "cache": cache, **self.extra_inputs}
            )
            nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            offset = self.cfg.meta_tokens + (
                self.cfg.num_image_patches if self.cfg.family == "vlm" else 0
            )
            max_new = max(r.max_new for r in batch_reqs)
            for i, r in enumerate(batch_reqs):
                r.out.append(int(nxt[i]))
            for step in range(max_new - 1):
                pos = jnp.full((B,), S + step + offset, jnp.int32)
                nxt, logits, cache = self.decode(
                    {"tokens": nxt[:, None], "pos": pos, "cache": cache}
                )
                for i, r in enumerate(batch_reqs):
                    if len(r.out) < r.max_new:
                        r.out.append(int(nxt[i]))
            for r in batch_reqs:
                if r.rid >= 0:
                    r.done = True
                    done.append(r)
        dt = time.time() - t0
        n_tok = sum(len(r.out) for r in done)
        print(f"[serve] {len(done)} requests, {n_tok} tokens, {dt:.1f}s "
              f"({n_tok / max(dt, 1e-9):.1f} tok/s)")
        return done


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(0)
    params = init_model_params(jax.random.key(0), cfg)
    server = BatchedServer(cfg, batch_size=args.batch,
                           max_len=args.prompt_len + args.max_new + 8
                           + cfg.meta_tokens + cfg.num_image_patches)
    server.params = params

    # monkey-free binding: wrap step fns with params
    pf, dc = server.prefill, server.decode
    server.prefill = lambda batch: pf(params, batch)
    server.decode = lambda batch: dc(params, batch)
    if cfg.family == "vlm":
        server.extra_inputs["patches"] = jnp.zeros(
            (args.batch, cfg.num_image_patches, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        server.extra_inputs["frames"] = jnp.zeros(
            (args.batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32)

    reqs = [
        Request(rid=i,
                tokens=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    done = server.serve(reqs)
    for r in done[:4]:
        print(f"  req {r.rid}: {r.out[:8]}...")


if __name__ == "__main__":
    main()
