"""SlicePrefetcher + async engine staging: the double-buffered GoFS read
pipeline must be invisible in the results — async-vs-sync staging parity is
BITWISE on all three iBSP patterns — and clean under cancellation (no
leaked threads, prefetch_depth=1 degenerates to thread-free sync reads)."""
import threading

import numpy as np
import pytest

from repro.core.blocked import build_blocked
from repro.core.engine import (
    TemporalEngine,
    min_plus_program,
    pagerank_program,
    source_init,
)
from repro.core.algorithms import pagerank
from repro.gofs import GoFSStore
from repro.gofs.prefetch import THREAD_PREFIX, SlicePrefetcher

from tests.conftest import TINY


def _prefetch_threads():
    return [t for t in threading.enumerate()
            if t.name.startswith(THREAD_PREFIX)]


@pytest.fixture(scope="module")
def env(tiny_collection, tiny_partitioned, tiny_gofs):
    tmpl, assign, sg_ids, subs = tiny_partitioned
    bg = build_blocked(tmpl, assign, TINY.block_size)
    store = GoFSStore(tiny_gofs, cache_slots=TINY.cache_slots)
    I = len(tiny_collection)
    weights = np.stack([tiny_collection.edge_values(t, "latency")
                        for t in range(I)])
    active = np.stack([tiny_collection.edge_values(t, "active")
                       for t in range(I)])
    return tmpl, bg, store, weights, active


# ---------------------------------------------------------------- staging
def test_fill_batch_out_buffer_in_place(env):
    tmpl, bg, store, weights, active = env
    ref_l = bg.fill_local_batch(weights)
    ref_b = bg.fill_boundary_batch(weights)
    buf_l, buf_b = bg.alloc_batch_buffers(weights.shape[0])
    buf_l[...] = -7.0  # stale data from a previous ring pass
    buf_b[...] = -7.0
    got_l = bg.fill_local_batch(weights, out=buf_l)
    got_b = bg.fill_boundary_batch(weights, out=buf_b)
    assert np.array_equal(got_l, ref_l) and np.array_equal(got_b, ref_b)
    # in place: no second copy
    assert np.shares_memory(got_l, buf_l) and np.shares_memory(got_b, buf_b)


def test_edge_attr_rows_matches_matrix(env):
    tmpl, bg, store, weights, active = env
    full = store.edge_attr_matrix("latency")
    rows = store.edge_attr_rows("latency", [2, 0])
    assert np.array_equal(rows[0], full[2])
    assert np.array_equal(rows[1], full[0])


def test_stream_chunks_match_bulk_load(env):
    tmpl, bg, store, weights, active = env
    tiles, btiles = store.load_blocked(bg, "latency")
    for depth in (1, 2, 3):
        pf = store.load_blocked_stream(bg, "latency", prefetch_depth=depth,
                                       chunk_instances=2)
        got_t, got_b, starts = [], [], []
        with pf:
            for ch in pf:
                starts.append(ch.start)
                got_t.append(ch.tiles)  # chunk-owned: safe to hold
                got_b.append(ch.btiles)
        assert starts == list(range(0, store.num_timesteps(), 2))
        assert np.array_equal(np.concatenate(got_t), tiles)
        assert np.array_equal(np.concatenate(got_b), btiles)


# ------------------------------------------------------- engine parity
def test_async_staging_bitwise_parity_all_patterns(env):
    """TemporalEngine(staging="async") == sync staging, bit for bit, on
    sequential / independent / eventually (the acceptance contract)."""
    tmpl, bg, store, weights, active = env
    sync = TemporalEngine(bg)
    async_ = TemporalEngine(bg, staging="async", chunk_instances=1)
    prog = min_plus_program("sssp", init=source_init(0))
    for pattern in ("sequential", "independent"):
        a = sync.run(prog, weights, pattern=pattern)
        b = async_.run(prog, weights, pattern=pattern)
        assert np.array_equal(a.values, b.values), pattern
        assert np.array_equal(a.final, b.final), pattern
        assert np.array_equal(a.stats["supersteps"], b.stats["supersteps"])
    pw = pagerank.edge_weights_for_instances(tmpl.src, active,
                                             tmpl.num_vertices)
    pp = pagerank_program(tmpl.num_vertices, iters=8)
    a = sync.run(pp, pw, pattern="eventually", merge="mean")
    b = async_.run(pp, pw, pattern="eventually", merge="mean")
    assert np.array_equal(a.values, b.values)
    assert np.array_equal(a.merged, b.merged)


def test_async_parity_many_chunks_in_flight(env):
    """Many more chunks than the prefetch window: chunk buffers must stay
    untouched after handoff while the device aliases them (JAX's device
    put zero-copy-aliases host buffers on CPU and defers host reads even
    under copy=True — a reused staging ring corrupts in-flight chunks;
    this is the regression test that caught it)."""
    tmpl, bg, store, weights, active = env
    w9 = np.concatenate([weights, weights * 2.0, weights * 3.0])  # I=9
    sync = TemporalEngine(bg)
    # depth=2, chunk=1 -> 9 chunks stream through a 2-deep window
    async_ = TemporalEngine(bg, staging="async", prefetch_depth=2,
                            chunk_instances=1)
    prog = min_plus_program("sssp", init=source_init(0))
    for pattern in ("sequential", "independent"):
        a = sync.run(prog, w9, pattern=pattern)
        b = async_.run(prog, w9, pattern=pattern)
        assert np.array_equal(a.values, b.values), pattern
        assert np.array_equal(a.final, b.final), pattern


def test_gofs_stream_engine_matches_sync(env):
    """End-to-end disk path: engine consuming load_blocked_stream chunks
    equals the one-shot load_blocked staging."""
    tmpl, bg, store, weights, active = env
    eng = TemporalEngine(bg)
    prog = min_plus_program("sssp", init=source_init(0))
    tiles, btiles = store.load_blocked(bg, "latency")
    a = eng.run(prog, tiles=tiles, btiles=btiles, pattern="sequential")
    b = eng.run(prog, pattern="sequential",
                stream=store.load_blocked_stream(bg, "latency"))
    assert np.array_equal(a.values, b.values)
    assert np.array_equal(a.final, b.final)
    assert _prefetch_threads() == []  # pool joined at stream exhaustion


# ------------------------------------------------ depth/cancel semantics
def test_depth1_is_synchronous_no_threads(env):
    tmpl, bg, store, weights, active = env
    pf = store.load_blocked_stream(bg, "latency", prefetch_depth=1,
                                   chunk_instances=1)
    seen = 0
    for ch in pf:
        assert _prefetch_threads() == []  # no pool in degenerate mode
        seen += 1
    assert seen == store.num_timesteps()


def test_close_mid_stream_no_leaked_threads(env):
    tmpl, bg, store, weights, active = env
    pf = store.load_blocked_stream(bg, "latency", prefetch_depth=3,
                                   chunk_instances=1)
    it = iter(pf)
    first = next(it)
    assert first.start == 0
    assert _prefetch_threads() != []  # pool live mid-stream
    pf.close()
    assert _prefetch_threads() == [] or all(
        not t.is_alive() for t in _prefetch_threads()
    )
    assert list(it) == []  # cancelled stream yields nothing further


def test_close_from_another_thread(env):
    """close() may race the consumer's own submits (watchdog/timeout
    threads): the pool/pending handoff is locked, so a mid-iteration
    close from outside must neither crash the consumer nor leak."""
    tmpl, bg, store, weights, active = env
    pf = store.load_blocked_stream(bg, "latency", prefetch_depth=2,
                                   chunk_instances=1)
    closer_done = threading.Event()
    seen = []
    it = iter(pf)
    seen.append(next(it).start)

    def closer():
        pf.close()
        closer_done.set()

    t = threading.Thread(target=closer)
    t.start()
    for ch in it:  # either ends early or finishes; must not raise
        seen.append(ch.start)
    t.join(timeout=10)
    assert closer_done.is_set()
    assert all(not th.is_alive() for th in _prefetch_threads())
    assert seen == sorted(set(seen))  # in-order, no duplicates


def test_prefetcher_reiterates_after_close(env):
    tmpl, bg, store, weights, active = env
    pf = store.load_blocked_stream(bg, "latency", prefetch_depth=2,
                                   chunk_instances=2)
    it = iter(pf)
    next(it)
    pf.close()
    counts = [c.count for c in pf]  # fresh pass after cancel
    assert sum(counts) == store.num_timesteps()
    assert _prefetch_threads() == []
