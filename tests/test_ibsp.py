"""iBSP engine semantics + algorithm equivalence vs oracles (paper §IV, VI)."""
import numpy as np
import pytest

from repro.core.algorithms import components, nhop, pagerank, sssp, tracking
from repro.core.blocked import build_blocked
from repro.core.ibsp import InMemoryProvider, run_ibsp
from repro.core.semiring import INF

from tests.conftest import TINY


@pytest.fixture(scope="module")
def env(tiny_collection, tiny_partitioned):
    tmpl, assign, sg_ids, subs = tiny_partitioned
    prov = InMemoryProvider(
        tiny_collection, subs,
        vertex_attrs=("plate", "outdeg_active"),
        edge_attrs=("latency", "active"),
    )
    bg = build_blocked(tmpl, assign, TINY.block_size)
    weights = np.stack([tiny_collection.edge_values(t, "latency")
                        for t in range(len(tiny_collection))])
    active = np.stack([tiny_collection.edge_values(t, "active")
                       for t in range(len(tiny_collection))])
    plates = np.stack([tiny_collection.vertex_values(t, "plate")
                       for t in range(len(tiny_collection))])
    return tmpl, assign, subs, prov, bg, weights, active, plates


# ---------------------------------------------------------------------------
# Engine semantics
# ---------------------------------------------------------------------------

def test_bulk_message_delivery(env):
    """Messages sent in superstep s are visible exactly at superstep s+1."""
    tmpl, assign, subs, prov, *_ = env
    seen = {}

    def compute(ctx):
        if ctx.superstep == 1:
            for g in subs:
                if g != ctx.subgraph.sgid:
                    ctx.send_to_subgraph(g, ("hello", ctx.subgraph.sgid))
        elif ctx.superstep == 2:
            seen[ctx.subgraph.sgid] = sorted(m[1] for m in ctx.messages)
        ctx.vote_to_halt()

    run_ibsp(prov, compute, pattern="independent")
    for g in subs:
        expect = sorted(x for x in subs if x != g)
        # every timestep delivers once; set equality per sgid
        assert sorted(set(seen[g])) == expect


def test_halt_quiescence(env):
    """A compute that halts immediately runs exactly one superstep/timestep."""
    tmpl, assign, subs, prov, *_ = env
    calls = []

    def compute(ctx):
        calls.append((ctx.timestep, ctx.superstep))
        ctx.vote_to_halt()

    res = run_ibsp(prov, compute, pattern="sequential")
    assert res.stats.supersteps == prov.num_timesteps()
    assert max(s for _, s in calls) == 1


def test_sequential_timestep_handoff(env):
    """SendToNextTimeStep messages arrive at superstep 1 of the next
    timestep (paper §IV-B message-passing semantics)."""
    tmpl, assign, subs, prov, *_ = env
    got = {}

    def compute(ctx):
        if ctx.timestep > 0 and ctx.superstep == 1:
            got.setdefault(ctx.timestep, []).extend(ctx.messages)
        ctx.send_to_next_timestep(("t", ctx.timestep))
        ctx.vote_to_halt()

    run_ibsp(prov, compute, pattern="sequential")
    for t in range(1, prov.num_timesteps()):
        assert all(m == ("t", t - 1) for m in got[t])
        assert len(got[t]) == len(subs)


def test_eventually_merge_collects_all(env):
    tmpl, assign, subs, prov, *_ = env

    def compute(ctx):
        ctx.send_message_to_merge((ctx.timestep, ctx.subgraph.sgid))
        ctx.vote_to_halt()

    def merge(mctx):
        mctx.emit(len(mctx.messages))

    res = run_ibsp(prov, compute, pattern="eventually", merge=merge)
    assert res.merge_result == prov.num_timesteps() * len(subs)


def test_workers_equivalent(env):
    """Thread-pooled execution gives the same result as serial."""
    tmpl, assign, subs, prov, *_ = env
    a, _ = sssp.run_host(prov, 0, workers=0)
    b, _ = sssp.run_host(prov, 0, workers=4)
    for g in a:
        np.testing.assert_allclose(a[g], b[g], equal_nan=True)


# ---------------------------------------------------------------------------
# Algorithms: host == blocked == oracle
# ---------------------------------------------------------------------------

def test_sssp_three_way(env):
    tmpl, assign, subs, prov, bg, weights, active, plates = env
    d_o = sssp.oracle(tmpl.src, tmpl.dst, weights, tmpl.num_vertices, 0)
    d_b, stats = sssp.run_blocked(bg, weights, 0)
    res_h, _ = sssp.run_host(prov, 0)
    d_h = np.full(tmpl.num_vertices, INF)
    for g, dist in res_h.items():
        d_h[subs[g].vertices] = dist
    finite = np.isfinite(d_o)
    assert np.array_equal(np.isfinite(d_b), finite)
    assert np.array_equal(np.isfinite(d_h), finite)
    np.testing.assert_allclose(d_b[finite], d_o[finite], rtol=1e-4)
    np.testing.assert_allclose(d_h[finite], d_o[finite], rtol=1e-6)


def test_sssp_vertex_centric_same_result_more_supersteps(env):
    tmpl, assign, subs, prov, bg, weights, *_ = env
    d_sg, st_sg = sssp.run_blocked(bg, weights, 0, subgraph_centric=True)
    d_vc, st_vc = sssp.run_blocked(bg, weights, 0, subgraph_centric=False,
                                   max_supersteps=256)
    finite = np.isfinite(d_sg)
    np.testing.assert_allclose(d_vc[finite], d_sg[finite], rtol=1e-5)
    # the paper's claim: subgraph-centric needs no MORE supersteps
    assert int(st_sg["supersteps"].sum()) <= int(st_vc["supersteps"].sum())


def test_pagerank_three_way(env):
    tmpl, assign, subs, prov, bg, weights, active, plates = env
    iters = 12
    pr_o = pagerank.oracle(tmpl.src, tmpl.dst, active[0], tmpl.num_vertices,
                           iters=iters)
    pr_b, _ = pagerank.run_blocked(bg, tmpl.src, active[:1],
                                   num_vertices=tmpl.num_vertices, iters=iters)
    prh, _ = pagerank.run_host(prov, tmpl.num_vertices, iters=iters)
    pr_h = np.zeros(tmpl.num_vertices)
    for (t, g), r in prh.items():
        if t == 0:
            pr_h[subs[g].vertices] = r
    np.testing.assert_allclose(pr_b[0], pr_o, rtol=1e-4, atol=1e-9)
    np.testing.assert_allclose(pr_h, pr_o, rtol=1e-6, atol=1e-12)


def test_pagerank_mass_conservation(env):
    """Invariant: with no dangling redistribution, total rank stays within
    [1-d, 1] after any number of iterations."""
    tmpl, assign, subs, prov, bg, weights, active, plates = env
    pr_b, _ = pagerank.run_blocked(bg, tmpl.src, active[:1],
                                   num_vertices=tmpl.num_vertices, iters=8)
    total = pr_b[0].sum()
    assert 0.05 <= total <= 1.0 + 1e-6


def test_nhop_three_way(env):
    tmpl, assign, subs, prov, bg, weights, active, plates = env
    n_hops = 4
    h_o = sum(
        nhop.oracle(tmpl.src, tmpl.dst, weights[t], tmpl.num_vertices, 0,
                    n_hops=n_hops)
        for t in range(weights.shape[0])
    )
    comp_b, per_b = nhop.run_blocked(bg, weights, 0, n_hops=n_hops)
    merged, _ = nhop.run_host(prov, 0, n_hops=n_hops)
    assert np.array_equal(comp_b, h_o)
    assert np.array_equal(merged["composite"], h_o)


def test_components_vs_union_find(env):
    tmpl, assign, subs, prov, bg, weights, active, plates = env
    lab_b = components.run_blocked(bg, tmpl.src, tmpl.dst, active[0])
    lab_o = components.oracle(tmpl.src, tmpl.dst, active[0], tmpl.num_vertices)
    assert np.array_equal(lab_b, lab_o)


def test_tracking_host_blocked_agree(env):
    tmpl, assign, subs, prov, bg, weights, active, plates = env
    plate = 2
    where = np.nonzero(plates[0] == plate)[0]
    start = int(where[0]) if len(where) else 0
    tr_b = tracking.run_blocked(bg, plates, plate, start, search_depth=5)
    tr_h, _ = tracking.run_host(prov, plate, start, search_depth=5)
    assert tr_b == tr_h
