"""Per-architecture reduced-config smoke tests: one forward/train step +
prefill + decode on CPU, asserting output shapes and finiteness."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (
    decode_step, forward_train, init_model_params, init_serve_cache, prefill,
)


def _inputs(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_image_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq_len, cfg.d_model)), jnp.float32)
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_env(request):
    cfg = get_config(request.param).reduced()
    params = init_model_params(jax.random.key(0), cfg)
    return request.param, cfg, params


def test_train_step_shapes_and_finite(arch_env):
    aid, cfg, params = arch_env
    batch = _inputs(cfg)
    loss, metrics = jax.jit(lambda p, b: forward_train(p, b, cfg))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{aid}: loss {loss}"
    assert 0.0 < float(loss) < 20.0


def test_serve_prefill_decode(arch_env):
    aid, cfg, params = arch_env
    batch = _inputs(cfg)
    B, S = batch["tokens"].shape
    cache = init_serve_cache(cfg, B, S + 8)
    pf = {"tokens": batch["tokens"], "cache": cache}
    for k in ("patches", "frames"):
        if k in batch:
            pf[k] = batch[k]
    logits, cache = prefill(params, pf, cfg)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_padded
    assert bool(jnp.all(jnp.isfinite(logits)))
    off = cfg.meta_tokens + (cfg.num_image_patches if cfg.family == "vlm" else 0)
    d = {"tokens": jnp.zeros((B, 1), jnp.int32),
         "pos": jnp.full((B,), S + off, jnp.int32), "cache": cache}
    logits2, _ = decode_step(params, d, cfg)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    # padded-vocab logits are masked out of sampling
    assert float(jnp.max(logits2[..., cfg.vocab_size:], initial=-jnp.inf)) <= -1e29 \
        or cfg.vocab_padded == cfg.vocab_size


def test_decode_matches_prefill_continuation(arch_env):
    """Teacher-forcing parity: prefilling [t0..t3] then decoding t4 gives the
    same logits as prefilling [t0..t4] (within fp tolerance)."""
    aid, cfg, params = arch_env
    if cfg.family == "audio":
        pytest.skip("cross-attn cache dtype differs between paths (bf16)")
    if cfg.is_moe:
        # capacity dropping is batch-dependent (prefill tokens compete for
        # expert slots, a lone decode token does not) — that asymmetry is
        # inherent to capacity-bounded MoE serving.  Test the math parity
        # with a no-drop capacity.
        import dataclasses as _dc

        cfg = cfg.with_overrides(moe=_dc.replace(cfg.moe, capacity_factor=64.0))
        params = init_model_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(1)
    B, S = 1, 8
    toks = rng.integers(0, cfg.vocab_size, (B, S + 1)).astype(np.int32)
    extra = {}
    if cfg.family == "vlm":
        extra["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_image_patches, cfg.d_model)), jnp.float32)
    # full prefill of S+1 tokens
    cache_a = init_serve_cache(cfg, B, S + 9, dtype=jnp.float32)
    la, _ = prefill(params, {"tokens": jnp.asarray(toks), "cache": cache_a,
                             **extra}, cfg)
    # prefill S then decode 1
    cache_b = init_serve_cache(cfg, B, S + 9, dtype=jnp.float32)
    _, cache_b = prefill(params, {"tokens": jnp.asarray(toks[:, :S]),
                                  "cache": cache_b, **extra}, cfg)
    off = cfg.meta_tokens + (cfg.num_image_patches if cfg.family == "vlm" else 0)
    lb, _ = decode_step(params, {"tokens": jnp.asarray(toks[:, S:]),
                                 "pos": jnp.full((B,), S + off, jnp.int32),
                                 "cache": cache_b}, cfg)
    va, vb = np.asarray(la[:, -1], np.float32), np.asarray(lb[:, -1], np.float32)
    va, vb = va[..., :cfg.vocab_size], vb[..., :cfg.vocab_size]
    np.testing.assert_allclose(va, vb, rtol=5e-2, atol=5e-2)
    # top-1 agreement is the functional requirement — but only where the
    # top-2 margin exceeds the fp tolerance (near-ties may flip)
    for row_a, row_b in zip(va, vb):
        top2 = np.sort(row_a)[-2:]
        if top2[1] - top2[0] > 2e-2:
            assert row_a.argmax() == row_b.argmax()


def test_param_count_within_family_budget(arch_env):
    """Instantiated parameter count is within 25% of the analytic count used
    for the 6·N·D roofline cross-check."""
    aid, cfg_r, _ = arch_env
    cfg = get_config(aid)
    analytic = cfg.param_count()
    from repro.models import abstract_params

    tree = abstract_params(cfg)
    actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
    assert abs(actual - analytic) / actual < 0.25, (aid, analytic, actual)
