"""Launch-spec construction for the full 40-cell grid (no compilation):
input_specs and cell_shardings must build for every applicable cell, with
consistent tree structures — catches schema/sharding regressions without
the 512-device dry-run.  Runs on a small forced-host-device mesh in a
subprocess.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, LM_SHAPES, cell_applicable, get_config
from repro.launch.specs import cell_shardings, input_specs
from repro.train.optimizer import OptConfig

mesh = jax.make_mesh((2, 4), ("data", "model"))
oc = OptConfig(state_dtype="bfloat16")
n = 0
for aid in ARCH_IDS:
    cfg = get_config(aid)
    for shape in LM_SHAPES:
        ok, why = cell_applicable(cfg, shape)
        if not ok:
            continue
        specs = input_specs(cfg, shape, oc)
        shardings = cell_shardings(cfg, shape, mesh)
        assert len(specs) == len(shardings), (aid, shape.name)
        # structure match: shardings tree mirrors the spec tree
        for sp, sh in zip(specs, shardings):
            a = jax.tree.structure(sp)
            b = jax.tree.structure(sh)
            assert a == b, (aid, shape.name, a, b)
        # every sharded dim divides
        for sp, sh in zip(specs, shardings):
            leaves_sp = jax.tree.leaves(sp)
            leaves_sh = jax.tree.leaves(sh)
            for x, s in zip(leaves_sp, leaves_sh):
                spec = s.spec
                for dim, ax in zip(x.shape, tuple(spec) + (None,) * 10):
                    if ax is None:
                        continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    k = 1
                    for a_ in axes:
                        k *= mesh.shape[a_]
                    assert dim % k == 0, (aid, shape.name, x.shape, spec)
        n += 1
print(f"SPECS OK {n}")
assert n == 32, n
"""


@pytest.mark.slow
def test_all_cell_specs_construct():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "SPECS OK 32" in r.stdout
