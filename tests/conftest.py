"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512."""
import numpy as np
import pytest

from repro.configs.base import GraphConfig
from repro.core.generator import generate_collection
from repro.core.partition import discover_subgraphs, partition_graph
from repro.core.subgraph import build_subgraphs

# --- optional hypothesis: property tests skip cleanly when absent ----------
try:
    from hypothesis import given, settings, strategies as hyp_st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _StrategyStub:
        """Stands in for hypothesis.strategies at decoration time only."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    hyp_st = _StrategyStub()


TINY = GraphConfig(
    name="tiny", num_vertices=300, avg_degree=3.0, num_instances=3,
    num_partitions=3, block_size=32, instances_per_slice=2,
    bins_per_partition=2, cache_slots=4, seed=11,
)


@pytest.fixture(scope="session")
def tiny_collection():
    return generate_collection(TINY, num_plates=6)


@pytest.fixture(scope="session")
def tiny_partitioned(tiny_collection):
    tmpl = tiny_collection.template
    assign = partition_graph(tmpl, TINY.num_partitions, seed=TINY.seed)
    sg_ids = discover_subgraphs(tmpl, assign)
    subs = build_subgraphs(tmpl, assign, sg_ids)
    return tmpl, assign, sg_ids, subs


@pytest.fixture(scope="session")
def tiny_gofs(tiny_collection, tmp_path_factory):
    from repro.gofs import deploy_collection

    root = str(tmp_path_factory.mktemp("gofs"))
    deploy_collection(tiny_collection, TINY, root)
    return root
