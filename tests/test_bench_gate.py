"""The bench regression gate is wired into tier-1 (not hand-invoked only).

Two layers:

* fast — the committed ``BENCH_temporal.json`` must satisfy the gate's
  own thresholds when replayed as "fresh" results.  This catches schema
  drift (a renamed row/field makes the gate vacuous), threshold drift
  (a floor raised past the committed numbers), and a stale baseline —
  without re-measuring anything.
* slow — actually re-measure the serving row (the economy this PR adds)
  and hold it to the committed acceptance floors: >=2x throughput vs
  one-session-per-query at Q>=4, zero bytes re-staged on repeat queries.
  Marked ``slow`` alongside the other multi-minute rows; CI's tier-1
  lane runs ``-m "not slow"``.
"""
import json
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(REPO, "BENCH_temporal.json")


def _bench_temporal():
    sys.path.insert(0, REPO)  # benchmarks/ is not a package on PYTHONPATH
    try:
        from benchmarks import bench_temporal
    finally:
        sys.path.pop(0)
    return bench_temporal


def test_committed_baseline_passes_its_own_gate():
    bt = _bench_temporal()
    assert os.path.exists(BASELINE), \
        "BENCH_temporal.json must be committed (run benchmarks/bench_temporal.py)"
    with open(BASELINE) as f:
        committed = json.load(f)
    failures = bt.check_against_baseline(committed, path=BASELINE)
    assert not failures, failures


def test_every_threshold_row_exists_in_baseline():
    """A threshold pointing at a missing row/field means the gate silently
    stopped gating that quantity — fail loudly instead."""
    bt = _bench_temporal()
    with open(BASELINE) as f:
        committed = json.load(f)
    missing = [f"{row}.{field}" for (row, field) in bt.THRESHOLDS
               if committed.get(row, {}).get(field) is None]
    assert not missing, missing


def test_serving_row_schema_in_baseline():
    """The serving row's reported fields (docs/BENCHMARKS.md schema)."""
    with open(BASELINE) as f:
        row = json.load(f)["serving"]
    for field in ("q", "p50_ms", "p95_ms", "widest_batch", "warm_batch_s",
                  "per_query_s", "throughput_ratio",
                  "restaged_bytes_repeat", "restaging_passes_repeat"):
        assert field in row, field
    assert row["q"] >= 4


@pytest.mark.slow
def test_serving_row_meets_acceptance_floors():
    bt = _bench_temporal()
    row = bt.serving_row()
    assert row["q"] >= 4
    assert row["throughput_ratio"] >= 2.0, row
    assert row["restaged_bytes_repeat"] == 0, row
    assert row["restaging_passes_repeat"] == 0, row
    # and the freshly measured row passes the committed gate's thresholds
    failures = [f for f in bt.check_against_baseline({"serving": row},
                                                     path=BASELINE)
                if f.startswith("serving.")]
    assert not failures, failures
