"""Forced-parallel CPU lane: the mesh + fused-kernel paths exercised
IN-PROCESS on forced host devices.

The other mesh tests isolate ``XLA_FLAGS=--xla_force_host_platform_
device_count`` in subprocesses so the main pytest process keeps its
single real CPU device.  CI additionally runs this module in a dedicated
lane that sets the flag for the WHOLE process (see
``.github/workflows/ci.yml``) — there the skips below turn into real
runs and shard_map, the ring/ring-rs exchanges, and the fused superstep
kernel execute without a subprocess boundary around every assertion.
Locally the module is skipped unless the flag is already set.
"""
import functools
import os

import numpy as np
import pytest

jax = pytest.importorskip("jax")

needs_devices = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices (set "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4)",
)


@pytest.fixture(scope="module")
def mesh_env(tiny_collection, tiny_partitioned):
    from repro.core.blocked import build_blocked

    from tests.conftest import TINY
    from tests.test_sparse_blocked import _banded

    tmpl, assign, _, _ = tiny_partitioned
    # the model axis must match the partition count; repartition the tiny
    # template to 4 so the (1, 4) mesh maps one partition per device
    from repro.core.partition import partition_graph

    assign4 = partition_graph(tmpl, 4, seed=TINY.seed)
    bg = build_blocked(tmpl, assign4, TINY.block_size)
    I = len(tiny_collection)
    w = np.stack([tiny_collection.edge_values(t, "latency")
                  for t in range(I)])
    wb, live = _banded(bg, tmpl, w)
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    return tmpl, bg, wb, mesh


@needs_devices
def test_device_count_lane_contract():
    """The CI lane's contract: when the forced-device flag is set the
    process really does see the devices (guards against the lane
    silently degrading to single-device runs)."""
    if "--xla_force_host_platform_device_count" in \
            os.environ.get("XLA_FLAGS", ""):
        assert jax.device_count() >= 4


@needs_devices
def test_mesh_fused_matches_stacked_oracle(mesh_env):
    """shard_map + fused kernel (interpret) in-process: bitwise vs the
    stacked jnp oracle for min-plus, both layouts."""
    from repro.core.engine import (TemporalEngine, min_plus_program,
                                   source_init)

    tmpl, bg, wb, mesh = mesh_env
    prog = min_plus_program("sssp", init=source_init(0), max_supersteps=16)
    w2 = wb[:2]
    ref = TemporalEngine(bg).run(prog, w2, pattern="sequential")
    for lay in ({}, dict(layout="sparse")):
        eng = TemporalEngine(bg, mesh=mesh, use_pallas="fused", **lay)
        got = eng.run(prog, w2, pattern="sequential")
        assert np.array_equal(ref.values, got.values), lay
        assert np.array_equal(ref.stats["supersteps"],
                              got.stats["supersteps"]), lay


@needs_devices
@pytest.mark.parametrize("backend", ["dense", "ring", "ring-rs"])
def test_mesh_comm_backends_in_process(mesh_env, backend):
    """All mesh comm backends agree bitwise on min-plus in-process,
    composed with the fused kernel."""
    from repro.core.engine import (TemporalEngine, min_plus_program,
                                   source_init)

    tmpl, bg, wb, mesh = mesh_env
    prog = min_plus_program("sssp", init=source_init(0), max_supersteps=16)
    w2 = wb[:2]
    ref = TemporalEngine(bg).run(prog, w2, pattern="independent")
    eng = TemporalEngine(bg, mesh=mesh, comm=backend, use_pallas="fused")
    got = eng.run(prog, w2, pattern="independent")
    assert np.array_equal(ref.values, got.values)


@needs_devices
def test_ring_rs_combine_parity_in_process():
    """RingExchange rs_ag vs circulate vs dense, raw combine_boundary
    under shard_map: min-plus bitwise, ragged and tiny buffer widths."""
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.comm import make_comm
    from repro.core.semiring import MIN_PLUS

    mesh = jax.make_mesh((4,), ("model",))
    rng = np.random.default_rng(3)
    for nb in (12, 13, 1, 7):
        buf = rng.normal(size=(8, nb)).astype(np.float32)
        buf[rng.random(buf.shape) < 0.3] = np.inf
        want = functools.reduce(MIN_PLUS.add,
                                [jnp.asarray(buf[i]) for i in range(8)])
        for name in ("dense", "ring", "ring-rs"):
            comm = make_comm(name, mesh=mesh, model_axes=("model",))
            f = shard_map(lambda b, c=comm: c.combine_boundary(b, MIN_PLUS),
                          mesh=mesh, in_specs=P("model", None),
                          out_specs=P(), check_rep=False)
            got = jax.jit(f)(jnp.asarray(buf))
            assert np.array_equal(np.asarray(got), np.asarray(want)), \
                (name, nb)
