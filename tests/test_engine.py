"""TemporalEngine parity: the unified blocked runner must agree with the
faithful host iBSP engine (run_ibsp) on every execution pattern (paper
§IV-B) — sequential (SSSP), independent (PageRank, components), eventually
dependent (N-hop Merge) — and report comparable BSPStats."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.algorithms import components, nhop, pagerank, sssp
from repro.core.blocked import build_blocked
from repro.core.engine import (
    TemporalEngine,
    min_plus_program,
    pagerank_program,
    source_init,
)
from repro.core.ibsp import BSPStats, InMemoryProvider
from repro.core.semiring import INF

from tests.conftest import TINY


@pytest.fixture(scope="module")
def env(tiny_collection, tiny_partitioned):
    tmpl, assign, sg_ids, subs = tiny_partitioned
    prov = InMemoryProvider(
        tiny_collection, subs,
        vertex_attrs=("plate", "outdeg_active"),
        edge_attrs=("latency", "active"),
    )
    bg = build_blocked(tmpl, assign, TINY.block_size)
    I = len(tiny_collection)
    weights = np.stack([tiny_collection.edge_values(t, "latency")
                        for t in range(I)])
    active = np.stack([tiny_collection.edge_values(t, "active")
                       for t in range(I)])
    return tmpl, subs, prov, bg, weights, active


def test_sequential_sssp_host_vs_engine(env):
    tmpl, subs, prov, bg, weights, active = env
    res_h, ibsp = sssp.run_host(prov, 0)
    d_h = np.full(tmpl.num_vertices, INF)
    for g, dist in res_h.items():
        d_h[subs[g].vertices] = dist

    eng = TemporalEngine(bg)
    res = eng.run(min_plus_program("sssp", init=source_init(0)), weights,
                  pattern="sequential")
    finite = np.isfinite(d_h)
    assert np.array_equal(np.isfinite(res.final), finite)
    np.testing.assert_allclose(res.final[finite], d_h[finite], rtol=1e-4)
    # stats comparable to the host engine's accounting
    st = res.bsp_stats()
    assert isinstance(st, BSPStats)
    assert st.supersteps > 0 and st.compute_calls >= st.supersteps
    assert st.timestep_messages > 0  # sequential handoff carried state


def test_independent_pagerank_host_vs_engine(env):
    tmpl, subs, prov, bg, weights, active = env
    iters = 10
    prh, _ = pagerank.run_host(prov, tmpl.num_vertices, iters=iters)
    I = active.shape[0]
    w = pagerank.edge_weights_for_instances(tmpl.src, active,
                                            tmpl.num_vertices)
    eng = TemporalEngine(bg)
    res = eng.run(pagerank_program(tmpl.num_vertices, iters=iters), w,
                  pattern="independent")
    for t in range(I):
        pr_h = np.zeros(tmpl.num_vertices)
        for (ts, g), r in prh.items():
            if ts == t:
                pr_h[subs[g].vertices] = r
        np.testing.assert_allclose(res.values[t], pr_h, rtol=1e-4, atol=1e-9)
    assert res.bsp_stats().merge_messages == 0


def test_independent_components_engine_vs_oracle(env):
    tmpl, subs, prov, bg, weights, active = env
    labels = components.run_blocked_temporal(bg, tmpl.src, tmpl.dst, active)
    for t in range(active.shape[0]):
        oracle = components.oracle(tmpl.src, tmpl.dst, active[t],
                                   tmpl.num_vertices)
        assert np.array_equal(labels[t], oracle), t


def test_eventually_nhop_host_vs_engine(env):
    tmpl, subs, prov, bg, weights, active = env
    n_hops = 4
    merged, _ = nhop.run_host(prov, 0, n_hops=n_hops)
    comp_b, per_b = nhop.run_blocked(bg, weights, 0, n_hops=n_hops)
    assert np.array_equal(comp_b, merged["composite"])
    assert per_b.shape[0] == weights.shape[0]


def test_engine_merge_mean_matches_values(env):
    tmpl, subs, prov, bg, weights, active = env
    w = pagerank.edge_weights_for_instances(tmpl.src, active,
                                            tmpl.num_vertices)
    eng = TemporalEngine(bg)
    res = eng.run(pagerank_program(tmpl.num_vertices, iters=6), w,
                  pattern="eventually", merge="mean")
    assert res.merged is not None
    np.testing.assert_allclose(res.merged, res.values.mean(0), atol=1e-6)
    assert res.bsp_stats().merge_messages == w.shape[0]


def test_merge_requires_eventually(env):
    tmpl, subs, prov, bg, weights, active = env
    eng = TemporalEngine(bg)
    with pytest.raises(AssertionError, match="eventually"):
        eng.run(min_plus_program("sssp", init=source_init(0)), weights,
                pattern="independent", merge="mean")


def test_prestaged_tiles_match_weights_path(env):
    """GoFS-style pre-staged tensors and the (I, E) weights path agree."""
    tmpl, subs, prov, bg, weights, active = env
    eng = TemporalEngine(bg)
    prog = min_plus_program("sssp", init=source_init(0))
    tiles, btiles = eng.stage(weights, prog.zero_fill)
    a = eng.run(prog, weights, pattern="sequential")
    b = eng.run(prog, tiles=tiles, btiles=btiles, x0=source_init(0)(bg),
                pattern="sequential")
    fin = np.isfinite(a.final)
    assert np.array_equal(np.isfinite(b.final), fin)
    np.testing.assert_allclose(a.final[fin], b.final[fin])


MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.configs.base import GraphConfig
from repro.core.generator import generate_collection
from repro.core.partition import partition_graph
from repro.core.blocked import build_blocked
from repro.core.engine import (TemporalEngine, min_plus_program,
                               pagerank_program, source_init)
from repro.core.algorithms import pagerank

cfg = GraphConfig(name="t", num_vertices=400, avg_degree=3.0,
                  num_instances=4, num_partitions=4, block_size=32, seed=9)
tsg = generate_collection(cfg)
tmpl = tsg.template
assign = partition_graph(tmpl, 4, seed=9)
bg = build_blocked(tmpl, assign, 32)
w = np.stack([tsg.edge_values(t, "latency") for t in range(4)])
active = np.stack([tsg.edge_values(t, "active") for t in range(4)])
mesh = jax.make_mesh((2, 4), ("data", "model"))
eng_m = TemporalEngine(bg, mesh=mesh)
eng_s = TemporalEngine(bg)
prog = min_plus_program("sssp", init=source_init(0))
for pattern in ("sequential", "independent"):
    rm = eng_m.run(prog, w, pattern=pattern)
    rs = eng_s.run(prog, w, pattern=pattern)
    for t in range(4):
        f = np.isfinite(rs.values[t])
        assert np.array_equal(np.isfinite(rm.values[t]), f), (pattern, t)
        assert np.allclose(rm.values[t][f], rs.values[t][f]), (pattern, t)
pw = pagerank.edge_weights_for_instances(tmpl.src, active, tmpl.num_vertices)
pp = pagerank_program(tmpl.num_vertices, iters=10)
rm = eng_m.run(pp, pw, pattern="eventually", merge="mean")
rs = eng_s.run(pp, pw, pattern="eventually", merge="mean")
assert np.abs(rm.values - rs.values).max() < 1e-6
assert np.abs(rm.merged - rs.merged).max() < 1e-6
# async staging under the mesh: per-chunk shard_map dispatch, same results
rm_async = eng_m.run(prog, w, pattern="independent", staging="async")
rm_sync = eng_m.run(prog, w, pattern="independent")
assert np.array_equal(rm_async.values, rm_sync.values)
# single-instance probes (I=1 < data axis) fall back to replicated instances
r1m = eng_m.run(prog, w[:1], pattern="independent")
r1s = eng_s.run(prog, w[:1], pattern="independent")
f1 = np.isfinite(r1s.values[0])
assert np.array_equal(np.isfinite(r1m.values[0]), f1)
assert np.allclose(r1m.values[0][f1], r1s.values[0][f1])
from repro.core.algorithms import nhop
cm, _ = nhop.run_blocked(bg, w, 0, n_hops=4, mesh=mesh)
cs, _ = nhop.run_blocked(bg, w, 0, n_hops=4)
assert np.array_equal(cm, cs)
print("ENGINE MESH OK")
"""


@pytest.mark.slow
def test_engine_mesh_matches_stacked():
    """All three patterns agree between stacked and temporal-parallel mesh
    execution (fixpoint AND iterate programs — not just PageRank)."""
    env_ = dict(os.environ)
    env_.pop("XLA_FLAGS", None)
    env_["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", MESH_SCRIPT], env=env_, capture_output=True,
        text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "ENGINE MESH OK" in r.stdout
