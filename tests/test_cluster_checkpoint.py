"""Resumable analytics: checkpoint atomicity, fault injection, resume parity.

The acceptance this file gates: kill a run mid-save or mid-superstep, and
(a) the previous checkpoint is never corrupted — torn writes are
invisible to ``list_steps``/``latest`` — and (b) the resumed run is
**bitwise identical** to an uninterrupted one.
"""
import json
import os
import shutil
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from conftest import TINY

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def ck_store_root(tiny_collection, tmp_path_factory):
    from repro.gofs import deploy_collection

    root = str(tmp_path_factory.mktemp("gofs_ck"))
    deploy_collection(tiny_collection, TINY, root)
    return root


def _session(root):
    from repro.gofs import GoFSStore
    from repro.gopher import GopherSession

    return GopherSession(GoFSStore(root))


# ----------------------------------------------------------- checkpointer

def test_checkpointer_roundtrip_and_fingerprint(tmp_path):
    from repro.cluster.checkpoint import AnalyticCheckpointer, ResumeMismatch

    ck = AnalyticCheckpointer(str(tmp_path), keep=2)
    fp = {"analytic": "sssp", "chunk": 2, "params": "(('source', 0),)"}
    assert ck.latest(fp) is None  # empty dir: fresh run
    ck.save(2, {"final": np.arange(3, dtype=np.float32)}, fp)
    ck.save(4, {"final": np.arange(3, dtype=np.float32) + 1}, fp)
    state, cursor = ck.latest(fp)
    assert cursor == 4
    assert np.array_equal(state["final"], np.arange(3, dtype=np.float32) + 1)
    with pytest.raises(ResumeMismatch):
        ck.latest({"analytic": "pagerank", "chunk": 2})
    # retention: keep=2 drops the oldest after a third save
    ck.save(6, {"final": np.zeros(3, np.float32)}, fp)
    from repro.train import checkpoint as _ckpt

    assert _ckpt.list_steps(str(tmp_path)) == [4, 6]


def test_torn_checkpoint_is_invisible(tmp_path):
    """A crash mid-write leaves a .tmp dir or a dir without MANIFEST —
    neither may ever be loaded."""
    from repro.cluster.checkpoint import AnalyticCheckpointer
    from repro.train import checkpoint as _ckpt

    ck = AnalyticCheckpointer(str(tmp_path))
    fp = {"analytic": "sssp"}
    ck.save(2, {"final": np.ones(3, np.float32)}, fp)
    # torn artifacts AFTER the good snapshot
    os.makedirs(tmp_path / "step_00000004.tmp")
    np.save(tmp_path / "step_00000004.tmp" / "final.npy", np.zeros(3))
    os.makedirs(tmp_path / "step_00000006")  # renamed dir missing manifest
    assert _ckpt.list_steps(str(tmp_path)) == [2]
    state, cursor = ck.latest(fp)
    assert cursor == 2 and np.array_equal(state["final"], np.ones(3))


# ------------------------------------------------------------ run parity

@pytest.mark.parametrize("app,params", [
    ("sssp", {"source": 0}),          # sequential: carry IS the pattern
    ("pagerank", {"iters": 5}),       # independent: cold spans
])
def test_checkpointed_run_bitwise_and_resume(ck_store_root, tmp_path,
                                             app, params):
    from repro.train import checkpoint as _ckpt

    sess = _session(ck_store_root)
    plan = sess.plan(app, **params)
    ref = sess.run(plan)

    d = str(tmp_path / app)
    got = sess.run(plan, checkpoint_dir=d, checkpoint_chunk=1)
    for key in ("values", "final"):
        assert np.array_equal(np.asarray(getattr(ref.engine, key)),
                              np.asarray(getattr(got.engine, key))), key
    assert np.array_equal(np.asarray(ref.engine.stats["supersteps"]),
                          np.asarray(got.engine.stats["supersteps"]))

    # drop everything after the FIRST snapshot, then resume
    steps = _ckpt.list_steps(d)
    assert len(steps) >= 2
    for s in steps[1:]:
        shutil.rmtree(os.path.join(d, f"step_{s:08d}"))
    res = sess.run(plan, checkpoint_dir=d, checkpoint_chunk=1, resume=True)
    for key in ("values", "final"):
        assert np.array_equal(np.asarray(getattr(ref.engine, key)),
                              np.asarray(getattr(res.engine, key))), key


def test_resume_refuses_different_run(ck_store_root, tmp_path):
    from repro.cluster.checkpoint import ResumeMismatch

    sess = _session(ck_store_root)
    d = str(tmp_path / "ck")
    sess.run(sess.plan("sssp", source=0), checkpoint_dir=d,
             checkpoint_chunk=1)
    with pytest.raises(ResumeMismatch):
        sess.run(sess.plan("sssp", source=1), checkpoint_dir=d,
                 checkpoint_chunk=1, resume=True)


def test_resume_needs_checkpoint_dir(ck_store_root):
    sess = _session(ck_store_root)
    with pytest.raises(AssertionError):
        sess.run(sess.plan("sssp", source=0), resume=True)


# -------------------------------------------------------- fault injection

CRASH_CHILD = textwrap.dedent("""\
    import os, sys
    import numpy as np
    mode, root, ckdir = sys.argv[1], sys.argv[2], sys.argv[3]

    from repro.train import checkpoint as _ckpt

    if mode == "mid-save":
        # die INSIDE the second commit: tmp dir fully written, rename
        # never happens -> torn .tmp next to the intact first snapshot
        real_rename = os.rename
        calls = {"n": 0}

        def dying_rename(src, dst):
            if os.path.basename(dst).startswith("step_"):
                calls["n"] += 1
                if calls["n"] == 2:
                    os._exit(1)
            return real_rename(src, dst)

        _ckpt.os.rename = dying_rename
    elif mode == "mid-superstep":
        # die during the second span's compute: first snapshot committed,
        # nothing else written
        from repro.core.engine import TemporalEngine

        real_run_many = TemporalEngine.run_many
        calls = {"n": 0}

        def dying_run_many(self, *a, **k):
            calls["n"] += 1
            if calls["n"] == 2:
                os._exit(1)
            return real_run_many(self, *a, **k)

        TemporalEngine.run_many = dying_run_many
    else:
        raise SystemExit(f"unknown mode {mode}")

    from repro.gofs import GoFSStore
    from repro.gopher import GopherSession

    sess = GopherSession(GoFSStore(root))
    sess.run(sess.plan("sssp", source=0), checkpoint_dir=ckdir,
             checkpoint_chunk=1, checkpoint_every=1)
    os._exit(0)  # should be unreachable: the crash fires first
""")


@pytest.mark.parametrize("mode", ["mid-save", "mid-superstep"])
def test_kill_and_resume_bitwise(ck_store_root, tmp_path, mode):
    child = tmp_path / "crash_child.py"
    child.write_text(CRASH_CHILD)
    ckdir = str(tmp_path / "ck")

    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, str(child), mode, ck_store_root, ckdir],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 1, (proc.stdout, proc.stderr)

    # previous checkpoint intact, torn state invisible
    from repro.train import checkpoint as _ckpt

    steps = _ckpt.list_steps(ckdir)
    assert steps == [1], steps  # exactly the first span's snapshot
    if mode == "mid-save":
        # the interrupted commit left its torn tmp dir behind
        assert any(d.endswith(".tmp") for d in os.listdir(ckdir))
    with open(os.path.join(ckdir, "step_00000001",
                           _ckpt.MANIFEST)) as f:
        json.load(f)  # committed manifest parses

    # resume finishes the run bitwise-identically to an uninterrupted one
    sess = _session(ck_store_root)
    plan = sess.plan("sssp", source=0)
    ref = sess.run(plan)
    res = sess.run(plan, checkpoint_dir=ckdir, checkpoint_chunk=1,
                   resume=True)
    for key in ("values", "final"):
        assert np.array_equal(np.asarray(getattr(ref.engine, key)),
                              np.asarray(getattr(res.engine, key))), key
    assert np.array_equal(np.asarray(ref.engine.stats["supersteps"]),
                          np.asarray(res.engine.stats["supersteps"]))
