"""Concurrency stress for streaming ingestion (slow tier).

An appender thread grows a deployed collection one instance at a time
while GopherService query threads hammer the same service and a tailing
subscriber rides every append.  The service refreshes only at batch
boundaries, so the invariants under test are:

* **no deadlock** — every thread joins within its timeout;
* **no torn reads** — every query result corresponds bitwise to SOME
  committed version of the collection (pre- or post-append), never a mix;
* **budget honored** — the session-lifetime staging cache never exceeds
  its byte budget even as appends extend staged batches in place.

Version identification is structural: a result computed over n instances
has ``engine.values.shape[-2] == n``, and the per-n reference is a cold
run over an independent deployment of the first n instances.
"""
import dataclasses
import os
import threading
import time

import numpy as np
import pytest

from repro.configs.base import GraphConfig
from repro.core.generator import generate_collection
from repro.core.graph import TimeSeriesGraph
from repro.gofs import GoFSStore, append_instances, deploy_collection
from repro.gopher import GopherService, GopherSession

CFG = GraphConfig(
    name="stress-stream", num_vertices=256, avg_degree=3.0,
    num_instances=8, num_partitions=2, block_size=16,
    instances_per_slice=2, cache_slots=8, seed=23,
)
PREFIX = 4
BUDGET = 64 << 20
# pinned knobs: the planner's auto choices may legitimately flip as the
# collection grows (occupancy, delta ratio); pinning keeps every version's
# reference comparable to the live service bitwise
KNOBS = {"layout": "dense", "warm": False, "staging": "sync"}


def _collection():
    col = generate_collection(CFG)
    rng = np.random.default_rng(CFG.seed)
    E = np.asarray(col.template.src).shape[0]
    ws = [np.asarray(col.edge_values(0, "latency"), np.float32)]
    for _t in range(1, len(col)):
        f = np.where(rng.random(E) < 0.3, rng.uniform(0.6, 1.0, E), 1.0)
        ws.append((ws[-1] * f).astype(np.float32))
    insts = [dataclasses.replace(
        col.instances[t],
        edge_values={**col.instances[t].edge_values, "latency": ws[t]})
        for t in range(len(col))]
    return TimeSeriesGraph(template=col.template, instances=insts)


def _prefix_deploy(col, root, n):
    deploy_collection(
        TimeSeriesGraph(template=col.template, instances=col.instances[:n]),
        CFG, root, sparse_absent={"latency": np.inf})


@pytest.mark.slow
def test_streaming_appender_vs_queries_vs_subscriber(tmp_path):
    col = _collection()
    total = len(col)

    # per-version bitwise references: a cold session over an independent
    # deployment of exactly the first n instances
    refs = {}
    for n in range(PREFIX, total + 1):
        root_n = str(tmp_path / f"ref_{n}")
        _prefix_deploy(col, root_n, n)
        cold = GopherSession(GoFSStore(root_n, cache_slots=CFG.cache_slots),
                             block_size=CFG.block_size)
        refs[n] = cold.run(cold.plan("sssp", source=0, **KNOBS))

    live = str(tmp_path / "live")
    _prefix_deploy(col, live, PREFIX)
    store = GoFSStore(live, cache_slots=CFG.cache_slots)

    stop = threading.Event()
    results, errors, updates = [], [], []

    with GopherService(store, block_size=CFG.block_size,
                       poll_interval=0.01,
                       staging_cache_bytes=BUDGET) as svc:
        sub = svc.subscribe("sssp", source=0, plan_kw=dict(KNOBS),
                            callback=updates.append)
        sub.wait_update(1, timeout=120)  # initial full run compiled

        def querier():
            try:
                while not stop.is_set():
                    results.append(svc.query(
                        "sssp", source=0, plan_kw=dict(KNOBS), timeout=120))
            except Exception as e:  # surfaced below, not swallowed
                errors.append(e)

        threads = [threading.Thread(target=querier, daemon=True)
                   for _ in range(3)]
        for t in threads:
            t.start()

        for k in range(PREFIX, total):  # appender races the query threads
            append_instances(
                TimeSeriesGraph(template=col.template,
                                instances=col.instances[k:k + 1]),
                live)
            time.sleep(0.05)

        # the serve loop refreshes at batch boundaries, so one update may
        # coalesce several appends — wait until the subscription has
        # caught up to the fully-grown collection, not for a fixed count
        deadline = time.time() + 120
        while time.time() < deadline:
            u = sub.last
            if u is not None and sub.error is None and int(
                    np.asarray(u.result.engine.values).shape[-2]) == total:
                break
            time.sleep(0.05)
        else:
            pytest.fail(f"subscriber never caught up to {total} instances "
                        f"(last={sub.last and sub.last.mode}, "
                        f"err={sub.error})")
        stop.set()
        for t in threads:
            t.join(timeout=60)
        assert not any(t.is_alive() for t in threads), "query thread hung"
        assert not errors, errors
        stats = svc.session.staging_cache_stats()
        rep = svc.report()
        sub.cancel()

    assert sub.error is None
    assert rep["appends_observed"] >= 1  # boundary refreshes coalesce

    # --- no torn reads: every result IS some committed version, bitwise
    assert results, "query threads produced nothing"
    seen_ns = set()
    for res in results:
        vals = np.asarray(res.engine.values)
        n = int(vals.shape[-2])
        assert n in refs, f"result over {n} instances matches no version"
        seen_ns.add(n)
        rv = np.asarray(refs[n].engine.values)
        if vals.ndim == rv.ndim + 1:
            # continuous batching merged concurrent identical queries
            # into one Q-wide source batch — every row must match
            assert all(np.array_equal(v, rv) for v in vals), \
                f"torn read at version n={n}"
        else:
            assert np.array_equal(vals, rv), f"torn read at version n={n}"
        assert np.array_equal(np.asarray(res.output["final"]),
                              np.asarray(refs[n].output["final"]))
    assert PREFIX in seen_ns or len(seen_ns) >= 1

    # --- the subscriber's last update is the fully-grown collection
    last = updates[-1]
    assert int(np.asarray(last.result.engine.values).shape[-2]) == total
    assert np.array_equal(np.asarray(last.result.output["final"]),
                          np.asarray(refs[total].output["final"]))
    modes = [u.mode for u in updates]
    assert modes[0] == "full" and set(modes[1:]) <= {"incremental"}
    assert sum(u.new_instances for u in updates
               if u.mode == "incremental") == total - PREFIX

    # --- staging-cache byte budget held under concurrent extension
    assert stats is not None
    assert stats["resident_bytes"] <= BUDGET
    assert stats["byte_budget"] == BUDGET
