"""Training substrate: optimizer, train step, NaN guard, accumulation,
checkpoint/restart, data determinism, compression."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.dist.compression import Int8Compressor, TopKCompressor
from repro.models import forward_train, init_model_params
from repro.train import checkpoint as ckpt
from repro.train.data import PackedShardDataset, SyntheticLMDataset, write_packed_shards
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state, lr_at
from repro.train.train_step import make_train_step

CFG = get_config("glm4-9b").reduced()


@pytest.fixture(scope="module")
def params():
    return init_model_params(jax.random.key(0), CFG)


def _batch(step=0, B=4, S=32):
    d = SyntheticLMDataset(CFG.vocab_size, S, B, seed=0)
    return {k: jnp.asarray(v) for k, v in d.batch_at(step).items()}


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_lr_schedule_shape():
    oc = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_at(jnp.asarray(s), oc)) for s in range(100)]
    assert lrs[0] < lrs[9]  # warmup rises
    assert abs(lrs[10] - 1e-3) < 1e-9  # peak at end of warmup
    assert lrs[-1] < lrs[50] < lrs[11]  # cosine decays
    assert lrs[-1] >= 1e-4 - 1e-9  # floor


def test_adamw_reduces_loss(params):
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=50)
    state = init_opt_state(params, oc)
    step = jax.jit(make_train_step(CFG, oc=oc))
    batch = _batch()
    losses = []
    p = params
    for i in range(8):
        p, state, m = step(p, state, batch)  # same batch -> must overfit
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses


def test_grad_clip_bounds_update(params):
    oc = OptConfig(lr=1.0, clip_norm=1e-6, warmup_steps=0, weight_decay=0.0)
    state = init_opt_state(params, oc)
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 100.0, params)
    new_p, _, m = adamw_update(params, grads, state, oc)
    # clipped: per-leaf movement bounded by lr * (mhat/sqrt(nhat)+eps) ~ lr
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(new_p), jax.tree.leaves(params)))
    assert float(m["grad_norm"]) > 1.0
    assert delta < 1.5  # no explosion despite grad 100


def test_nan_guard_skips_update(params):
    oc = OptConfig(lr=1e-3)
    state = init_opt_state(params, oc)
    step = jax.jit(make_train_step(CFG, oc=oc))
    batch = _batch()
    bad = dict(batch)
    # poison by making tokens out of a valid-loss range impossible — instead
    # inject NaN through labels=-1 everywhere + zero mask -> loss 0/0?  The
    # robust poison: run one good step, then overwrite params with NaN grads
    # via a NaN batch is impossible for int tokens; instead check the guard
    # directly: a non-finite grad norm leaves params untouched.
    p1, s1, m1 = step(params, state, batch)
    nan_params = jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), params)
    p2, s2, m2 = step(nan_params, state, batch)
    assert int(m2["skipped"]) == 1
    # params unchanged (still NaN inputs, but not *new* garbage)
    assert bool(jnp.all(jnp.isnan(jax.tree.leaves(p2)[0])))


def test_grad_accumulation_equivalence(params):
    """accum_steps=2 over a 2x batch == single step over the same data.

    Compared at the GRADIENT level: the first Adam step moves each weight by
    ~sign(g)*lr, so fp-noise-level gradient differences near zero flip the
    update by 2*lr — parameter-level comparison would only test noise.
    """
    cfg32 = CFG.with_overrides(dtype="float32")  # bf16 rounding would drown
    params32 = init_model_params(jax.random.key(0), cfg32)
    batch = _batch(B=8)

    def grads_for(accum):
        def loss_fn(p, mb):
            return forward_train(p, mb, cfg32)[0]

        if accum == 1:
            return jax.grad(loss_fn)(params32, batch), float(
                forward_train(params32, batch, cfg32)[0]
            )
        mbs = {k: v.reshape((accum, v.shape[0] // accum) + v.shape[1:])
               for k, v in batch.items()}
        g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params32)
        tot = 0.0
        for i in range(accum):
            mb = {k: v[i] for k, v in mbs.items()}
            g = jax.tree.map(jnp.add, g, jax.grad(loss_fn)(params32, mb))
            tot += float(forward_train(params32, mb, cfg32)[0])
        return jax.tree.map(lambda x: x / accum, g), tot / accum

    g1, l1 = grads_for(1)
    g2, l2 = grads_for(2)
    gn = float(jnp.sqrt(sum(jnp.sum(jnp.square(x))
                            for x in jax.tree.leaves(g1))))
    dn = float(jnp.sqrt(sum(jnp.sum(jnp.square(a - b)) for a, b in
                            zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))))
    assert dn / gn < 1e-4, (dn, gn)
    assert abs(l1 - l2) < 5e-4


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, params):
    oc = OptConfig()
    state = {"params": params, "opt": init_opt_state(params, oc)}
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, state)
    restored, step = ckpt.restore(d, state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path, params):
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4):
        ckpt.save(d, s, {"p": params}, keep=2)
    assert ckpt.list_steps(d) == [3, 4]
    _, step = ckpt.restore(d, {"p": params})
    assert step == 4


def test_checkpoint_incomplete_dir_skipped(tmp_path, params):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"p": params})
    # simulate a crash mid-save: dir without manifest
    os.makedirs(os.path.join(d, "step_00000002"))
    assert ckpt.list_steps(d) == [1]
    _, step = ckpt.restore(d, {"p": params})
    assert step == 1


def test_async_checkpointer(tmp_path, params):
    d = str(tmp_path / "ck")
    ac = ckpt.AsyncCheckpointer(d, keep=2)
    ac.save(5, {"p": params})
    ac.wait()
    assert ckpt.list_steps(d) == [5]


def test_elastic_restore_same_logical_shapes(tmp_path, params):
    """Checkpoints store full logical shapes: restoring into an identical
    abstract tree works regardless of the writing mesh (resharding happens
    at the jit boundary)."""
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, {"p": params})
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), {"p": params}
    )
    restored, _ = ckpt.restore(d, abstract)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves({"p": params})):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_data_seekable():
    d = SyntheticLMDataset(1000, 16, 4, seed=3)
    a = d.batch_at(17)
    b = d.batch_at(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    it = d.iter_from(17)
    c = next(it)
    np.testing.assert_array_equal(a["tokens"], c["tokens"])


def test_packed_shards_roundtrip(tmp_path):
    tokens = np.arange(10_000, dtype=np.int32)
    d = str(tmp_path / "shards")
    write_packed_shards(d, tokens, shard_tokens=1024)
    ds = PackedShardDataset(d, seq_len=16, global_batch=4)
    b0 = ds.batch_at(0)
    np.testing.assert_array_equal(b0["tokens"][0], np.arange(16))
    np.testing.assert_array_equal(b0["labels"][0], np.arange(1, 17))
    # deterministic + seekable
    b5a, b5b = ds.batch_at(5), ds.batch_at(5)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_int8_error_feedback_unbiased(params):
    comp = Int8Compressor()
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.3, params)
    state = comp.init_state(grads)
    acc = jax.tree.map(jnp.zeros_like, grads)
    for _ in range(8):
        g, state, m = comp.apply(grads, state)
        acc = jax.tree.map(jnp.add, acc, g)
    # error feedback: running mean converges to the true gradient
    mean = jax.tree.leaves(jax.tree.map(lambda a: a / 8, acc))[0]
    np.testing.assert_allclose(np.asarray(mean), 0.3, rtol=2e-2)


def test_topk_keeps_largest(params):
    comp = TopKCompressor(frac=0.1)
    g = {"w": jnp.asarray(np.linspace(-1, 1, 100), jnp.float32)}
    state = comp.init_state(g)
    out, state, _ = comp.apply(g, state)
    kept = np.asarray(out["w"]) != 0
    assert kept.sum() <= 12
    assert kept[0] and kept[-1]  # extremes kept


def test_topk_error_feedback_round_trip():
    """Round trip: compressed + residual == target exactly (top-k keeps
    exact values), and the error-fed running mean converges to the true
    gradient even though each step drops 90% of the entries."""
    comp = TopKCompressor(frac=0.1)
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=128), jnp.float32)}
    state = comp.init_state(grads)
    acc = jax.tree.map(jnp.zeros_like, grads)
    # an entry of magnitude m is sent every ~thresh/m steps, so the mean's
    # error is bounded by thresh/steps — run enough steps to pin it down
    steps = 96
    for _ in range(steps):
        target = jax.tree.map(jnp.add, grads, state)
        out, state, m = comp.apply(grads, state)
        # lossless round trip of what was sent + what was carried
        np.testing.assert_array_equal(
            np.asarray(out["w"]) + np.asarray(state["w"]),
            np.asarray(target["w"]))
        assert float(m["comp_err_norm"]) >= 0.0
        acc = jax.tree.map(jnp.add, acc, out)
    mean = np.asarray(acc["w"]) / steps
    np.testing.assert_allclose(mean, np.asarray(grads["w"]),
                               rtol=0.2, atol=0.06)


def test_train_step_with_topk_compressor(params):
    """TopK wired into the gradient path of the train step (the launch
    driver's --compress topk): loss still falls, comp metrics present."""
    comp = TopKCompressor(frac=0.05)
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=50)
    state = init_opt_state(params, oc)
    step = jax.jit(make_train_step(CFG, oc=oc, compressor=comp))
    comp_state = comp.init_state(params)
    batch = _batch()
    p = params
    losses = []
    for _ in range(8):
        p, state, m, comp_state = step(p, state, batch, comp_state)
        losses.append(float(m["loss"]))
        assert "comp_err_norm" in m
    assert losses[-1] < losses[0] - 0.05, losses


def test_make_compressor_resolution():
    from repro.launch.train import make_compressor

    assert make_compressor("none") is None
    assert make_compressor(False) is None
    assert isinstance(make_compressor(True), Int8Compressor)
    assert isinstance(make_compressor("int8"), Int8Compressor)
    topk = make_compressor("topk", topk_frac=0.25)
    assert isinstance(topk, TopKCompressor) and topk.frac == 0.25
    with pytest.raises(ValueError, match="unknown compressor"):
        make_compressor("gzip")
