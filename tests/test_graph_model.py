"""Time-series graph model, partitioning, subgraph discovery (paper §III-IV)."""
import numpy as np
import pytest

from tests.conftest import given, settings, hyp_st as st

from repro.core.graph import AttributeDef, GraphInstance, GraphTemplate, TimeSeriesGraph
from repro.core.partition import (
    bin_pack_subgraphs, build_partitions, discover_subgraphs, edge_cut,
    partition_graph,
)
from repro.core.subgraph import build_subgraphs


def _random_template(rng, V, E):
    src = rng.integers(0, V, E)
    dst = rng.integers(0, V, E)
    keep = src != dst
    return GraphTemplate(num_vertices=V, src=src[keep].astype(np.int64),
                         dst=dst[keep].astype(np.int64))


def test_partition_covers_all_vertices(tiny_partitioned):
    tmpl, assign, sg_ids, subs = tiny_partitioned
    assert assign.shape == (tmpl.num_vertices,)
    assert assign.min() >= 0 and assign.max() < 3


def test_partition_is_disjoint_and_complete(tiny_partitioned):
    tmpl, assign, sg_ids, subs = tiny_partitioned
    parts = build_partitions(tmpl, assign, sg_ids)
    all_vs = np.concatenate([p.vertices for p in parts])
    assert len(all_vs) == tmpl.num_vertices
    assert len(np.unique(all_vs)) == tmpl.num_vertices  # disjoint
    # every edge is local xor remote exactly once
    n_local = sum(len(p.local_src) for p in parts)
    n_remote = sum(len(p.remote_src) for p in parts)
    assert n_local + n_remote == tmpl.num_edges
    assert n_remote == edge_cut(tmpl, assign)


def test_subgraphs_are_connected_components_of_local_edges(tiny_partitioned):
    tmpl, assign, sg_ids, subs = tiny_partitioned
    # same subgraph -> same partition
    for g, topo in subs.items():
        assert len(set(assign[topo.vertices])) == 1
    # local edges never cross subgraphs, remote edges always do
    for g, topo in subs.items():
        assert np.all(sg_ids[tmpl.src[topo.local_edge_id]] == g)
        assert np.all(sg_ids[tmpl.dst[topo.local_edge_id]] == g)
        assert np.all(sg_ids[tmpl.src[topo.remote_edge_id]] == g)
        assert np.all(sg_ids[tmpl.dst[topo.remote_edge_id]] != g)


def test_subgraph_edge_totals(tiny_partitioned):
    tmpl, assign, sg_ids, subs = tiny_partitioned
    n_local = sum(t.num_local_edges for t in subs.values())
    n_remote = sum(len(t.remote_src) for t in subs.values())
    assert n_local + n_remote == tmpl.num_edges


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(20, 120), st.data())
def test_partition_subgraph_invariants_random(n_parts, V, data):
    """Property: for any random digraph, partitioning + subgraph discovery
    preserve the §IV-A definitions."""
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    tmpl = _random_template(rng, V, V * 3)
    assign = partition_graph(tmpl, n_parts, seed=0)
    sg_ids = discover_subgraphs(tmpl, assign)
    # vertex in exactly one partition
    assert assign.shape == (V,)
    # subgraph-local connectivity: endpoints of a local edge share sg id
    local = assign[tmpl.src] == assign[tmpl.dst]
    assert np.all(
        sg_ids[tmpl.src[local]] == sg_ids[tmpl.src[local]]
    )
    # union-find oracle on local edges only
    parent = np.arange(V)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in zip(tmpl.src[local], tmpl.dst[local]):
        ru, rv = find(int(u)), find(int(v))
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    roots = np.array([find(int(i)) for i in range(V)])
    # same root <-> same subgraph id
    _, ids_a = np.unique(roots, return_inverse=True)
    _, ids_b = np.unique(sg_ids, return_inverse=True)
    remap = {}
    for a, b in zip(ids_a, ids_b):
        assert remap.setdefault(a, b) == b


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 10), st.lists(st.integers(1, 500), min_size=1, max_size=60))
def test_bin_packing_balances(n_bins, sizes):
    sizes = np.asarray(sizes, np.int64)
    ids = np.arange(len(sizes))
    bins = bin_pack_subgraphs(sizes, ids, n_bins)
    # every id appears exactly once
    got = np.sort(np.concatenate([b for b in bins if len(b)]))
    assert np.array_equal(got, ids)
    # greedy largest-first bound: max load <= sum/bins + max item
    loads = np.array([sizes[np.isin(ids, b)].sum() for b in bins])
    assert loads.max() <= sizes.sum() / n_bins + sizes.max()


def test_value_inheritance(tiny_collection):
    tsg = tiny_collection
    # constant attribute comes from schema, identical across instances
    v0 = tsg.edge_values(0, "mtu")
    v1 = tsg.edge_values(1, "mtu")
    assert np.all(v0 == 1500) and np.all(v1 == 1500)
    # instance-overridden attribute differs across instances
    l0, l1 = tsg.vertex_values(0, "plate"), tsg.vertex_values(1, "plate")
    assert not np.array_equal(l0, l1)


def test_time_filter(tiny_collection):
    tsg = tiny_collection
    t0, t1 = tsg.time_range()
    mid = (t0 + t1) / 2
    idx = tsg.filter_time(mid, t1)
    assert len(idx) >= 1
    assert all(tsg.instances[i].t_end > mid for i in idx)
