"""Gopher session API: registry, planner, and shared-staging executor.

Contracts pinned here:

* registry — duplicate registration and unknown analytics/params error
  loudly; the five stock algorithms are registered.
* planner — plans are deterministic (same store root -> ``==`` plans) and
  never read a value slice when store-backed; auto-selection picks sparse
  at low recorded occupancy and falls back to dense when activity is
  unknowable.
* executor — the auto-selected plan reproduces the explicit-kwarg engine
  BITWISE for min-plus across all three iBSP patterns x both layouts x
  all three comm backends; ``run_many`` shares staging (fewer passes,
  fewer bytes) with identical results.
* engine — the staged-batch device cache re-uploads nothing when the
  same staged graph is reused (regression: counts ``_device_put`` calls).
* legacy — every ``run_blocked`` wrapper fires ``DeprecationWarning`` and
  matches its pre-session result.
"""
import numpy as np
import pytest

import repro.core.engine as engine_mod
from repro.core.blocked import build_blocked
from repro.core.engine import (
    RunSpec,
    TemporalEngine,
    min_plus_program,
    pagerank_program,
    source_init,
)
from repro.core.generator import generate_collection
from repro.core.graph import GraphTemplate
from repro.core.partition import partition_graph
from repro.core.semiring import INF
from repro.gopher import (
    GopherSession,
    REQUIRED,
    get_analytic,
    list_analytics,
    register_analytic,
)

from tests.conftest import TINY


# --------------------------------------------------------------------------
# fixtures
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny(tiny_collection):
    tsg = tiny_collection
    tmpl = tsg.template
    assign = partition_graph(tmpl, TINY.num_partitions, seed=TINY.seed)
    bg = build_blocked(tmpl, assign, TINY.block_size)
    I = len(tsg)
    w = np.stack([tsg.edge_values(t, "latency") for t in range(I)])
    active = np.stack([tsg.edge_values(t, "active") for t in range(I)])
    plates = np.stack([tsg.vertex_values(t, "plate") for t in range(I)])
    return tsg, tmpl, bg, w, active, plates


@pytest.fixture(scope="module")
def sparse_store_root(tiny_collection, tmp_path_factory):
    """Deployment with recorded tile maps for latency (sparse staging)."""
    from repro.gofs import deploy_collection

    root = str(tmp_path_factory.mktemp("gofs_gopher"))
    deploy_collection(tiny_collection, TINY, root,
                      sparse_absent={"latency": np.inf})
    return root


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

def test_stock_analytics_registered():
    assert {"sssp", "pagerank", "components", "nhop", "tracking"} \
        <= set(list_analytics())


def test_duplicate_registration_rejected():
    from repro.gopher.registry import _REGISTRY

    try:
        @register_analytic("_dup_probe", pattern="sequential",
                           attr="latency", zero_fill=INF)
        def _p1(ctx):
            raise NotImplementedError

        with pytest.raises(ValueError, match="already registered"):
            @register_analytic("_dup_probe", pattern="sequential",
                               attr="latency", zero_fill=INF)
            def _p2(ctx):
                raise NotImplementedError
    finally:
        # the registry is module-global; leaking the probe would make the
        # registry doctest (exact list_analytics output) order-dependent
        _REGISTRY.pop("_dup_probe", None)


def test_unknown_analytic_lists_registered():
    with pytest.raises(KeyError, match="sssp"):
        get_analytic("ssssp")


def test_param_validation(tiny):
    _, _, bg, w, _, _ = tiny
    sess = GopherSession.from_blocked(bg, weights={"latency": w})
    with pytest.raises(TypeError, match="unknown parameter"):
        sess.plan("sssp", source=0, sources=1)
    with pytest.raises(TypeError, match="required parameter"):
        sess.plan("sssp")


# --------------------------------------------------------------------------
# planner
# --------------------------------------------------------------------------

def test_plan_deterministic_from_store(sparse_store_root):
    from repro.gofs import GoFSStore

    p1 = GopherSession(GoFSStore(sparse_store_root)).plan("sssp", source=0)
    p2 = GopherSession(GoFSStore(sparse_store_root)).plan("sssp", source=0)
    assert p1 == p2
    assert p1.explain() == p2.explain()


def test_plan_reads_no_value_slice(sparse_store_root):
    """Planning is metadata-only: no attribute value slice is opened."""
    from repro.gofs import GoFSStore

    store = GoFSStore(sparse_store_root)
    sess = GopherSession(store)  # reads templates + metadata
    store.reset_stats()
    sess.plan("sssp", source=0)
    sess.plan("nhop", source=0)
    # the only array slice planning may touch is the tile map
    assert store.stats.slices_read <= 1


def test_auto_layout_thresholds(tiny):
    _, tmpl, bg, w, _, _ = tiny
    # dense weights: every tile live -> dense layout
    sess = GopherSession.from_blocked(bg, weights={"latency": w})
    plan = sess.plan("sssp", source=0)
    assert plan.layout.value == "dense" and plan.layout.source == "auto"
    # mask to a sliver of edges -> low occupancy -> sparse layout
    wl = np.where(np.arange(w.shape[1])[None, :] % 16 == 0, w, np.inf)
    sess_lo = GopherSession.from_blocked(
        bg, weights={"latency": wl.astype(np.float32)})
    plan_lo = sess_lo.plan("sssp", source=0)
    occ = plan_lo.estimate_dict["occupancy"]
    if occ <= 0.25:  # structure-dependent; assert consistency either way
        assert plan_lo.layout.value == "sparse"
    else:
        assert plan_lo.layout.value == "dense"
    # override always wins
    assert sess.plan("sssp", source=0,
                     layout="sparse").layout.source == "override"


def test_plan_explain_mentions_choices(tiny):
    _, _, bg, w, _, _ = tiny
    sess = GopherSession.from_blocked(bg, weights={"latency": w})
    text = sess.explain("sssp", source=0)
    for needle in ("layout", "comm", "staging", "placement",
                   "boundary exchange", "staged bytes"):
        assert needle in text, text


def test_plan_unknown_activity_stays_dense(sparse_store_root):
    """No tile map for 'active' -> occupancy unknowable -> dense."""
    from repro.gofs import GoFSStore

    sess = GopherSession(GoFSStore(sparse_store_root))
    plan = sess.plan("pagerank")
    assert plan.layout.value == "dense"
    assert plan.estimate_dict["occupancy"] is None


# --------------------------------------------------------------------------
# executor: auto plan == hand-configured engine, bitwise (min-plus)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("pattern", ["sequential", "independent",
                                     "eventually"])
@pytest.mark.parametrize("layout", ["dense", "sparse"])
def test_session_matches_engine_bitwise(tiny, pattern, layout):
    _, _, bg, w, _, _ = tiny
    merge = "mean" if pattern == "eventually" else None
    sess = GopherSession.from_blocked(bg, weights={"latency": w})
    plan = sess.plan("sssp", source=0, pattern=pattern, merge=merge,
                     layout=layout)
    res = sess.run(plan)
    eng = TemporalEngine(bg, layout=layout)
    ref = eng.run(min_plus_program(
        "sssp", init=source_init(0)), w, pattern=pattern, merge=merge)
    assert np.array_equal(res.engine.values, ref.values)
    assert np.array_equal(res.engine.final, ref.final)
    if merge:
        assert np.array_equal(res.engine.merged, ref.merged)


@pytest.mark.parametrize("comm", ["dense", "ring", "host"])
@pytest.mark.parametrize("layout", ["dense", "sparse"])
def test_session_matches_engine_across_comms(tiny, comm, layout):
    _, _, bg, w, _, _ = tiny
    sess = GopherSession.from_blocked(bg, weights={"latency": w})
    res = sess.run(sess.plan("sssp", source=0, comm=comm, layout=layout))
    ref = TemporalEngine(bg, comm=comm, layout=layout).run(
        min_plus_program("sssp", init=source_init(0)), w,
        pattern="sequential")
    assert np.array_equal(res.engine.values, ref.values)


def test_store_session_matches_in_memory(sparse_store_root, tiny):
    """The store-backed session (blocked structure reconstructed from
    topology slices) reproduces the in-memory engine bitwise — auto plan
    included (async staging, recorded-map layout)."""
    from repro.gofs import GoFSStore

    _, _, bg, w, _, _ = tiny
    sess = GopherSession(GoFSStore(sparse_store_root))
    plan = sess.plan("sssp", source=0)
    assert plan.staging.value == "async"  # streaming from the store
    res = sess.run(plan)
    ref = TemporalEngine(bg).run(
        min_plus_program("sssp", init=source_init(0)), w,
        pattern="sequential")
    assert np.array_equal(res.output["final"], ref.final)
    assert np.array_equal(res.engine.values, ref.values)


# --------------------------------------------------------------------------
# run_many: shared staging
# --------------------------------------------------------------------------

def test_run_many_shares_staging_bitwise(tiny):
    _, _, bg, w, _, plates = tiny
    sess = GopherSession.from_blocked(
        bg, weights={"latency": w}, vertex_attrs={"plate": plates})
    plans = [
        sess.plan("sssp", source=0),
        sess.plan("sssp", source=1, pattern="independent"),
        sess.plan("nhop", source=0, n_hops=3),
        sess.plan("tracking", plate=3, initial_vertex=0),
    ]
    rs = sess.run_many(plans)
    shared = dict(sess.last_run_report)
    # sssp + sssp + nhop share the latency batch; nhop's hop probe and
    # tracking share the unit-weight batch -> exactly two staging passes
    assert shared["staging_passes"] == 2
    # identical to independent executions
    singles = []
    bytes_indep = 0
    for p in plans:
        s2 = GopherSession.from_blocked(
            bg, weights={"latency": w}, vertex_attrs={"plate": plates})
        singles.append(s2.run(p))
        bytes_indep += s2.last_run_report["staged_bytes"]
    assert bytes_indep > shared["staged_bytes"]
    for got, ref in zip(rs, singles):
        if got.engine is not None:
            assert np.array_equal(got.engine.values, ref.engine.values)
        assert set(got.output) == set(ref.output)
        for k in got.output:
            assert np.array_equal(got.output[k], ref.output[k]), k


def test_run_many_streamed_group(sparse_store_root):
    """N async program plans over one attribute: ONE prefetch pass feeds
    N runners; results match per-plan runs bitwise."""
    from repro.gofs import GoFSStore

    sess = GopherSession(GoFSStore(sparse_store_root))
    plans = [sess.plan("sssp", source=0), sess.plan("sssp", source=1)]
    assert all(p.staging.value == "async" for p in plans)
    rs = sess.run_many(plans)
    assert sess.last_run_report["staging_passes"] == 1
    for p, r in zip(plans, rs):
        ref = GopherSession(GoFSStore(sparse_store_root)).run(p)
        assert np.array_equal(r.engine.values, ref.engine.values)


def test_run_many_mixed_comm_shares_staging(sparse_store_root):
    """One staging key split across comm backends still stages once
    (via the cache, not one private stream per backend)."""
    from repro.gofs import GoFSStore

    sess = GopherSession(GoFSStore(sparse_store_root))
    plans = [sess.plan("sssp", source=0),
             sess.plan("sssp", source=1, comm="host")]
    rs = sess.run_many(plans)
    assert sess.last_run_report["staging_passes"] == 1
    ref = GopherSession(GoFSStore(sparse_store_root)).run(plans[0])
    assert np.array_equal(rs[0].engine.values, ref.engine.values)


def test_engine_run_many_matches_run(tiny):
    """Engine-level hook: N specs over one staged batch == N runs."""
    _, tmpl, bg, w, active, _ = tiny
    from repro.core.algorithms.pagerank import edge_weights_for_instances

    eng = TemporalEngine(bg)
    pw = edge_weights_for_instances(tmpl.src, active, tmpl.num_vertices)
    prog = pagerank_program(tmpl.num_vertices, iters=5)
    specs = [RunSpec(prog, "independent"),
             RunSpec(prog, "eventually", merge="mean")]
    many = eng.run_many(specs, pw)
    one_a = eng.run(prog, pw, pattern="independent")
    one_b = eng.run(prog, pw, pattern="eventually", merge="mean")
    assert np.array_equal(many[0].values, one_a.values)
    assert np.array_equal(many[1].values, one_b.values)
    assert np.array_equal(many[1].merged, one_b.merged)


def test_engine_run_many_rejects_mixed_zero_fill(tiny):
    _, tmpl, bg, w, _, _ = tiny
    eng = TemporalEngine(bg)
    specs = [RunSpec(min_plus_program("a", init=source_init(0)),
                     "sequential"),
             RunSpec(pagerank_program(tmpl.num_vertices, iters=2),
                     "independent")]
    with pytest.raises(AssertionError, match="zero_fill"):
        eng.run_many(specs, w)


# --------------------------------------------------------------------------
# engine staged-batch device cache (no re-upload on reuse)
# --------------------------------------------------------------------------

def _count_device_puts(monkeypatch):
    calls = []
    orig = engine_mod._device_put

    def counted(x):
        calls.append(1)
        return orig(x)

    monkeypatch.setattr(engine_mod, "_device_put", counted)
    return calls


def test_sparse_batch_uploaded_once(tiny, monkeypatch):
    _, _, bg, w, _, _ = tiny
    eng = TemporalEngine(bg, layout="sparse")
    prog = min_plus_program("sssp", init=source_init(0))
    sp = eng.stage_sparse(w, prog.zero_fill)
    calls = _count_device_puts(monkeypatch)
    eng.run(prog, sparse=sp, pattern="sequential")
    first = len(calls)
    assert first == 6  # tiles, btiles, rows, cols, brows, bcols
    eng.run(prog, sparse=sp, pattern="independent")
    eng.run(min_plus_program("sssp2", init=source_init(1)), sparse=sp,
            pattern="sequential")
    assert len(calls) == first, "staged sparse batch was re-uploaded"


def test_dense_host_batch_uploaded_once(tiny, monkeypatch):
    _, _, bg, w, _, _ = tiny
    eng = TemporalEngine(bg)
    prog = min_plus_program("sssp", init=source_init(0))
    tiles = bg.fill_local_batch(w)
    btiles = bg.fill_boundary_batch(w)
    calls = _count_device_puts(monkeypatch)
    r1 = eng.run(prog, tiles=tiles, btiles=btiles, pattern="sequential")
    assert len(calls) == 2  # tiles, btiles
    r2 = eng.run(prog, tiles=tiles, btiles=btiles, pattern="sequential")
    assert len(calls) == 2, "staged dense batch was re-uploaded"
    assert np.array_equal(r1.values, r2.values)


# --------------------------------------------------------------------------
# legacy wrappers: deprecation + parity
# --------------------------------------------------------------------------

def test_run_blocked_wrappers_deprecated_and_identical(tiny):
    from repro.core.algorithms import (
        components, nhop, pagerank, sssp, tracking,
    )

    _, tmpl, bg, w, active, plates = tiny

    with pytest.warns(DeprecationWarning, match="sssp.run_blocked"):
        d, stats = sssp.run_blocked(bg, w, 0)
    ref = TemporalEngine(bg).run(
        min_plus_program("sssp", init=source_init(0)), w,
        pattern="sequential")
    assert np.array_equal(d, ref.final)
    assert np.array_equal(stats["supersteps"], ref.stats["supersteps"])

    with pytest.warns(DeprecationWarning, match="pagerank.run_blocked"):
        ranks, _ = pagerank.run_blocked(
            bg, tmpl.src, active, num_vertices=tmpl.num_vertices, iters=5)
    from repro.core.algorithms.pagerank import edge_weights_for_instances

    pw = edge_weights_for_instances(tmpl.src, active, tmpl.num_vertices)
    ref_pr = TemporalEngine(bg).run(
        pagerank_program(tmpl.num_vertices, iters=5), pw,
        pattern="independent")
    assert np.array_equal(ranks, ref_pr.values)

    with pytest.warns(DeprecationWarning, match="components"):
        labels = components.run_blocked(bg, tmpl.src, tmpl.dst, active[0])
    from repro.core.algorithms.components import oracle as cc_oracle

    assert np.array_equal(
        labels, cc_oracle(tmpl.src, tmpl.dst, active[0],
                          tmpl.num_vertices).astype(np.float32))

    with pytest.warns(DeprecationWarning, match="nhop.run_blocked"):
        comp, hists = nhop.run_blocked(bg, w, 0, n_hops=3)
    assert comp.sum() == hists.sum()

    with pytest.warns(DeprecationWarning, match="tracking.run_blocked"):
        trace = tracking.run_blocked(bg, plates, 3, 0)
    assert isinstance(trace, list)


# --------------------------------------------------------------------------
# GoFS occupancy stats (planner input, no value read)
# --------------------------------------------------------------------------

def test_tile_occupancy_from_maps(sparse_store_root, tiny):
    from repro.gofs import GoFSStore

    _, _, bg, w, _, _ = tiny
    store = GoFSStore(sparse_store_root)
    occ = store.tile_occupancy(bg, "latency")
    # maps-only value matches a full-value activity scan
    act_l, act_b = bg.active_tile_maps(w, zero=np.inf)
    denom = w.shape[0] * (int(bg.n_tiles.sum()) + int(bg.n_btiles.sum()))
    assert occ == pytest.approx(
        (int(act_l.sum()) + int(act_b.sum())) / denom)
    # no recorded map for this attribute -> unknown
    assert store.tile_occupancy(bg, "active", zero=0.0) is None
    # mismatched blocked structure falls back to the recorded scalar
    bg2 = build_blocked(
        GraphTemplate(num_vertices=len(bg.part_of),
                      src=tiny[1].src, dst=tiny[1].dst),
        partition_graph(tiny[1], TINY.num_partitions, seed=TINY.seed),
        TINY.block_size * 2,
    )
    occ2 = store.tile_occupancy(bg2, "latency")
    assert occ2 is not None and 0.0 < occ2 <= 1.0


# --------------------------------------------------------------------------
# staging-cache keys: transform / zero_fill must never alias
# --------------------------------------------------------------------------

def _halved_latency(ctx, w):
    return np.asarray(w, np.float32) * np.float32(0.5)


def test_staging_keys_never_alias_across_transform_or_zero(tiny, monkeypatch):
    """Regression: the staging cache keys on (graph, attr, transform,
    zero_fill, layout).  Three analytics sharing ``attr`` but differing
    in weights transform or semiring zero must each stage their OWN batch
    (aliasing would silently feed one analytic another's tiles), while a
    warm repeat re-uses all three with zero staging passes and zero
    device uploads (extends the PR-5 upload-once counting to the
    session-lifetime cache)."""
    from repro.gopher.registry import _REGISTRY

    _, _, bg, w, _, _ = tiny

    def _probe(name, weights=None, zero=INF):
        @register_analytic(name, pattern="sequential", attr="latency",
                           zero_fill=zero, params={"source": REQUIRED},
                           weights=weights)
        def _prog(ctx, *, source):
            from repro.core.engine import min_plus_program
            return min_plus_program(name, init=source_init(source))

    names = ("_key_raw", "_key_halved", "_key_zero0")
    try:
        _probe("_key_raw")
        _probe("_key_halved", weights=_halved_latency)
        _probe("_key_zero0", zero=0.0)
        sess = GopherSession.from_blocked(
            bg, weights={"latency": w}, staging_cache_bytes=1 << 30)
        plans = [sess.plan(n, source=0, layout="dense") for n in names]
        rs = sess.run_many(plans)
        # three DISTINCT staged batches despite the shared attribute
        assert sess.last_run_report["staging_passes"] == 3
        assert sess.staging_cache_stats()["entries"] == 3
        # ...holding genuinely different values: halved weights exactly
        # halve finite min-plus distances (x0.5 is exact in fp32), and a
        # 0-valued semiring zero collapses them
        raw, halved, z0 = (r.engine.values for r in rs)
        finite = np.isfinite(raw)
        assert np.array_equal(halved[finite], raw[finite] * np.float32(0.5))
        assert not np.array_equal(z0, raw)

        # warm repeat: all three served from the session cache — no
        # staging pass, no device upload, bitwise-identical results
        calls = _count_device_puts(monkeypatch)
        rs2 = sess.run_many(plans)
        assert len(calls) == 0, "warm repeat re-uploaded staged tiles"
        assert sess.last_run_report["staging_passes"] == 0
        assert sess.last_run_report["cache_hits"] == 3
        for a, b in zip(rs, rs2):
            assert np.array_equal(a.engine.values, b.engine.values)
    finally:
        for n in names:
            _REGISTRY.pop(n, None)
