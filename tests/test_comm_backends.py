"""Comm-backend parity: the boundary exchange is pluggable (paper §IV-B
merge, §V deployment) and must be INVISIBLE to every algorithm — dense
all-reduce, collective-permute ring, and host-gather produce the same
results for all three iBSP patterns, fixpoint and iterate programs, sync
and async staging, stacked and mesh placement.

Exactness contract (see ``repro.core.comm``): min-plus combines are
bitwise identical across backends everywhere; plus-mul (PageRank) is
bitwise in stacked/host modes and reassociated (float-tolerance) on the
mesh ring.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.algorithms import components, nhop, pagerank, sssp, tracking
from repro.core.blocked import build_blocked
from repro.core.comm import (
    COMM_BACKENDS,
    DenseAllReduce,
    HostGather,
    RingExchange,
    make_comm,
)
from repro.core.engine import (
    TemporalEngine,
    min_plus_program,
    pagerank_program,
    source_init,
)
from repro.dist.collectives import boundary_exchange_bytes

from tests.conftest import TINY


@pytest.fixture(scope="module")
def env(tiny_collection, tiny_partitioned):
    tmpl, assign, sg_ids, subs = tiny_partitioned
    bg = build_blocked(tmpl, assign, TINY.block_size)
    I = len(tiny_collection)
    weights = np.stack([tiny_collection.edge_values(t, "latency")
                        for t in range(I)])
    active = np.stack([tiny_collection.edge_values(t, "active")
                       for t in range(I)])
    plates = np.stack([tiny_collection.vertex_values(t, "plate")
                       for t in range(I)]).astype(np.int64)
    return tmpl, bg, weights, active, plates


# ---------------------------------------------------------------------------
# Backend construction / binding
# ---------------------------------------------------------------------------

def test_make_comm_binds_placement():
    assert make_comm("dense").name == "dense"
    assert make_comm("dense").axis_name is None
    ring = make_comm("ring")
    assert isinstance(ring, RingExchange) and ring.axis_name is None
    assert isinstance(make_comm("host"), HostGather)
    # correctly-bound instances pass through untouched
    pre = RingExchange()
    assert make_comm(pre) is pre
    with pytest.raises(ValueError, match="unknown comm backend"):
        make_comm("nope")


def test_host_gather_rejects_mesh():
    jax = pytest.importorskip("jax")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="mesh-free"):
        make_comm("host", mesh=mesh)
    # dense/ring bind the model axis and (ring) its static size
    assert make_comm("dense", mesh=mesh).axis_name == ("model",)
    r = make_comm("ring", mesh=mesh)
    assert r.axis_sizes == (1,)


def test_make_comm_validates_prebuilt_instances():
    """A mis-bound instance must be rejected, not silently accepted — an
    unbound backend inside shard_map would fold only the local shard."""
    jax = pytest.importorskip("jax")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="unbound"):
        make_comm(DenseAllReduce(), mesh=mesh)  # axis_name=None on a mesh
    with pytest.raises(ValueError, match="mesh-free"):
        make_comm(HostGather(), mesh=mesh)
    with pytest.raises(ValueError, match="no mesh was given"):
        make_comm(DenseAllReduce(axis_name=("model",)))  # bound, no mesh
    with pytest.raises(ValueError, match="only has axes"):
        make_comm(DenseAllReduce(axis_name=("nope",)), mesh=mesh)
    with pytest.raises(ValueError, match="do not match the mesh shape"):
        make_comm(RingExchange(axis_name=("model",), axis_sizes=(4,)),
                  mesh=mesh)
    ok = RingExchange(axis_name=("model",), axis_sizes=(1,))
    assert make_comm(ok, mesh=mesh) is ok


def test_recommended_comm_follows_exchange_axes():
    """Ring is recommended only when the EXCHANGE axes cross DCI: the
    standard production mesh keeps model intra-pod, so dense stays the
    default even multi-pod."""
    jax = pytest.importorskip("jax")
    from repro.launch.mesh import recommended_comm

    assert recommended_comm(None) == "host"
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert recommended_comm(mesh) == "dense"
    assert recommended_comm(mesh, model_axes=("pod", "model")) == "ring"


def test_bind_sync_is_ring_only():
    r = RingExchange(axis_name=("model",), axis_sizes=(4,))
    assert r.bind_sync(("data",)).sync_axes == ("data",)
    assert r.sync_axes == ()  # frozen: binding returns a new instance
    d = DenseAllReduce(axis_name=("model",))
    assert d.bind_sync(("data",)) is d  # group-scoped: nothing to sync
    h = HostGather()
    assert h.bind_sync(("data",)) is h  # mesh-free: nothing to sync


# ---------------------------------------------------------------------------
# Engine parity: patterns × programs × backends (stacked)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", COMM_BACKENDS)
def test_fixpoint_parity_all_patterns(env, backend):
    """Min-plus fixpoint: bitwise-identical values, final, merged, and
    stats under every backend, for all three iBSP patterns."""
    tmpl, bg, weights, active, plates = env
    prog = min_plus_program("sssp", init=source_init(0))
    ref_eng = TemporalEngine(bg)
    eng = TemporalEngine(bg, comm=backend)
    for pattern, merge in (("sequential", None), ("independent", None),
                           ("eventually", "mean")):
        ref = ref_eng.run(prog, weights, pattern=pattern, merge=merge)
        res = eng.run(prog, weights, pattern=pattern, merge=merge)
        assert np.array_equal(res.values, ref.values), (backend, pattern)
        assert np.array_equal(res.final, ref.final), (backend, pattern)
        if merge == "mean":
            assert np.array_equal(res.merged, ref.merged), backend
        for k in ref.stats:
            assert np.array_equal(res.stats[k], ref.stats[k]), (backend, k)


@pytest.mark.parametrize("backend", COMM_BACKENDS)
def test_iterate_parity(env, backend):
    """Plus-mul iterate (PageRank): stacked backends share the same fold
    association, so results match to float tolerance (bitwise in
    practice; only the MESH ring reassociates — see the slow mesh test)."""
    tmpl, bg, weights, active, plates = env
    pw = pagerank.edge_weights_for_instances(tmpl.src, active,
                                             tmpl.num_vertices)
    prog = pagerank_program(tmpl.num_vertices, iters=8)
    ref = TemporalEngine(bg).run(prog, pw, pattern="eventually",
                                 merge="mean")
    res = TemporalEngine(bg, comm=backend).run(prog, pw,
                                               pattern="eventually",
                                               merge="mean")
    np.testing.assert_allclose(res.values, ref.values, rtol=0, atol=1e-6)
    np.testing.assert_allclose(res.merged, ref.merged, rtol=0, atol=1e-6)


@pytest.mark.parametrize("backend", ("ring", "host"))
def test_all_five_algorithms_parity(env, backend):
    """Every algorithm entry point accepts comm= and returns results
    identical to the dense default (bitwise for the min-plus four,
    1e-6 for plus-mul PageRank)."""
    tmpl, bg, weights, active, plates = env
    V = tmpl.num_vertices

    d_ref, _ = sssp.run_blocked(bg, weights, 0)
    d_alt, _ = sssp.run_blocked(bg, weights, 0, comm=backend)
    assert np.array_equal(d_ref, d_alt)

    l_ref = components.run_blocked_temporal(bg, tmpl.src, tmpl.dst, active)
    l_alt = components.run_blocked_temporal(bg, tmpl.src, tmpl.dst, active,
                                            comm=backend)
    assert np.array_equal(l_ref, l_alt)

    c_ref, p_ref = nhop.run_blocked(bg, weights, 0, n_hops=4)
    c_alt, p_alt = nhop.run_blocked(bg, weights, 0, n_hops=4, comm=backend)
    assert np.array_equal(c_ref, c_alt) and np.array_equal(p_ref, p_alt)

    t_ref = tracking.run_blocked(bg, plates, plate=2, initial_vertex=0)
    t_alt = tracking.run_blocked(bg, plates, plate=2, initial_vertex=0,
                                 comm=backend)
    assert t_ref == t_alt

    r_ref, _ = pagerank.run_blocked(bg, tmpl.src, active, num_vertices=V,
                                    iters=8)
    r_alt, _ = pagerank.run_blocked(bg, tmpl.src, active, num_vertices=V,
                                    iters=8, comm=backend)
    np.testing.assert_allclose(r_alt, r_ref, rtol=0, atol=1e-6)


@pytest.mark.parametrize("backend", COMM_BACKENDS)
def test_async_staging_parity(env, backend):
    """The double-buffered staging path composes with every backend:
    chunked dispatch + sequential carry + eventually Merge stay bitwise
    identical to the dense sync run."""
    tmpl, bg, weights, active, plates = env
    prog = min_plus_program("sssp", init=source_init(0))
    ref = TemporalEngine(bg).run(prog, weights, pattern="sequential")
    eng = TemporalEngine(bg, comm=backend, staging="async",
                         chunk_instances=2)
    res = eng.run(prog, weights, pattern="sequential")
    assert np.array_equal(res.values, ref.values), backend
    ref_e = TemporalEngine(bg).run(prog, weights, pattern="eventually",
                                   merge="mean")
    res_e = eng.run(prog, weights, pattern="eventually", merge="mean")
    assert np.array_equal(res_e.merged, ref_e.merged), backend


# ---------------------------------------------------------------------------
# Analytic cost model (repro.dist.collectives)
# ---------------------------------------------------------------------------

def test_boundary_exchange_cost_model():
    nb, n = 1000, 8
    dense = boundary_exchange_bytes(nb, n, "dense")
    ring = boundary_exchange_bytes(nb, n, "ring")
    rs = boundary_exchange_bytes(nb, n, "ring-rs")
    host = boundary_exchange_bytes(nb, n, "host")
    assert dense["kind"] == "all-reduce"
    assert ring["kind"] == "collective-permute"
    assert rs["kind"] == "collective-permute"
    assert host["kind"] == "host-gather"
    # ring: full buffer on each of n-1 hops; dense: 2(n-1)/n per device
    assert ring["hops"] == n - 1
    assert ring["bytes_per_device"] == (n - 1) * nb * 4
    assert dense["bytes_per_device"] == pytest.approx(2 * (n - 1) / n * nb * 4)
    # the ring trades MORE bytes for neighbor-only transfers
    assert ring["bytes_per_device"] > dense["bytes_per_device"]
    # ring-rs: bandwidth-optimal — the all-reduce's byte volume at double
    # the circulate ring's hop count, still strictly neighbor-to-neighbor
    assert rs["hops"] == 2 * (n - 1)
    assert rs["bytes_per_device"] == pytest.approx(dense["bytes_per_device"])
    assert rs["bytes_per_device"] < ring["bytes_per_device"]
    with pytest.raises(ValueError, match="unknown comm backend"):
        boundary_exchange_bytes(nb, n, "nope")


# ---------------------------------------------------------------------------
# Mesh: ring vs dense under shard_map (forced host devices, subprocess)
# ---------------------------------------------------------------------------

MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.configs.base import GraphConfig
from repro.core.generator import generate_collection
from repro.core.partition import partition_graph
from repro.core.blocked import build_blocked
from repro.core.engine import (TemporalEngine, min_plus_program,
                               pagerank_program, source_init)
from repro.core.algorithms import pagerank
from repro.dist.collectives import collective_bytes_by_kind

cfg = GraphConfig(name="t", num_vertices=400, avg_degree=3.0,
                  num_instances=4, num_partitions=4, block_size=32, seed=9)
tsg = generate_collection(cfg)
tmpl = tsg.template
assign = partition_graph(tmpl, 4, seed=9)
bg = build_blocked(tmpl, assign, 32)
w = np.stack([tsg.edge_values(t, "latency") for t in range(4)])
active = np.stack([tsg.edge_values(t, "active") for t in range(4)])
mesh = jax.make_mesh((2, 4), ("data", "model"))
prog = min_plus_program("sssp", init=source_init(0))
eng_d = TemporalEngine(bg, mesh=mesh)
eng_r = TemporalEngine(bg, mesh=mesh, comm="ring")
eng_rs = TemporalEngine(bg, mesh=mesh, comm="ring-rs")

# min-plus: bitwise parity on every pattern (including data-sharded
# instances, where the ring's vote syncs trip counts over the data axis);
# both ring variants — circulate and reduce-scatter + all-gather
for pattern in ("sequential", "independent"):
    rd = eng_d.run(prog, w, pattern=pattern)
    rr = eng_r.run(prog, w, pattern=pattern)
    rrs = eng_rs.run(prog, w, pattern=pattern)
    assert np.array_equal(rd.values, rr.values), pattern
    assert np.array_equal(rd.values, rrs.values), pattern

# single-instance probe: replicated-instance fallback, ring still exact
r1d = eng_d.run(prog, w[:1], pattern="independent")
r1r = eng_r.run(prog, w[:1], pattern="independent")
assert np.array_equal(r1d.values, r1r.values)

# async staging under the mesh with ring comm
ra = eng_r.run(prog, w, pattern="independent", staging="async")
rs = eng_r.run(prog, w, pattern="independent")
assert np.array_equal(ra.values, rs.values)

# plus-mul: the mesh ring reassociates the boundary sum (documented)
pw = pagerank.edge_weights_for_instances(tmpl.src, active, tmpl.num_vertices)
pp = pagerank_program(tmpl.num_vertices, iters=10)
pd = eng_d.run(pp, pw, pattern="eventually", merge="mean")
pr = eng_r.run(pp, pw, pattern="eventually", merge="mean")
assert np.abs(pd.values - pr.values).max() < 1e-6
assert np.abs(pd.merged - pr.merged).max() < 1e-6

# HLO accounting: dense lowers the exchange to all-reduce, ring to
# collective-permute (the only all-reduce left is the 4-byte halt vote)
def kinds(eng):
    tiles, btiles = eng.stage(w, prog.zero_fill)
    run_fn = eng._runner(prog, "independent", None, 4)
    with eng.mesh:
        hlo = run_fn.lower(tiles, btiles,
                           jnp.asarray(prog.init(bg), jnp.float32),
                           *eng._struct).compile().as_text()
    return collective_bytes_by_kind(hlo)

kd, kr, krs = kinds(eng_d), kinds(eng_r), kinds(eng_rs)
assert "all-reduce" in kd and "collective-permute" not in kd, kd
assert "collective-permute" in kr, kr
assert kr.get("all-reduce", 0) <= 8, kr  # just the halt-vote flag
assert "collective-permute" in krs, krs
assert krs.get("all-reduce", 0) <= 8, krs
# the rs+ag schedule moves strictly fewer permute bytes than circulate
assert krs["collective-permute"] < kr["collective-permute"], (krs, kr)
print("COMM MESH OK")
"""


@pytest.mark.slow
def test_mesh_ring_matches_dense():
    """Ring and dense agree under shard_map temporal parallelism, and the
    backends lower to the collective kinds the cost model names."""
    env_ = dict(os.environ)
    env_.pop("XLA_FLAGS", None)
    env_["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", MESH_SCRIPT], env=env_, capture_output=True,
        text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "COMM MESH OK" in r.stdout
