"""GoFS storage: layout, projection, filtering, caching, provider parity."""
import os

import numpy as np
import pytest

from repro.core.algorithms import sssp
from repro.core.ibsp import InMemoryProvider
from repro.gofs import GoFSStore, deploy_collection
from repro.gofs.cache import SliceCache

from tests.conftest import TINY


def test_roundtrip_values(tiny_gofs, tiny_collection, tiny_partitioned):
    tmpl, assign, sg_ids, subs = tiny_partitioned
    store = GoFSStore(tiny_gofs, vertex_projection=("plate",),
                      edge_projection=("latency",))
    for g in store.subgraph_ids():
        si = store.get_instance(1, g)
        ref_v = tiny_collection.vertex_values(1, "plate")[subs[g].vertices]
        ref_e = tiny_collection.edge_values(1, "latency")[subs[g].local_edge_id]
        np.testing.assert_array_equal(si.vertex_values["plate"], ref_v)
        np.testing.assert_array_equal(si.local_edge_values["latency"], ref_e)


def test_topology_roundtrip(tiny_gofs, tiny_partitioned):
    tmpl, assign, sg_ids, subs = tiny_partitioned
    store = GoFSStore(tiny_gofs)
    for g in store.subgraph_ids():
        topo = store.get_topology(g)
        np.testing.assert_array_equal(topo.vertices, subs[g].vertices)
        np.testing.assert_array_equal(topo.local_edge_id, subs[g].local_edge_id)
        np.testing.assert_array_equal(topo.remote_edge_id, subs[g].remote_edge_id)


def test_bin_major_iteration_order(tiny_gofs):
    """Subgraph iterator follows bin-major order within each partition."""
    store = GoFSStore(tiny_gofs)
    order = store.subgraph_ids()
    homes = [store._sg_home[g] for g in order]
    # (pid, bin) must be non-decreasing lexicographically
    assert homes == sorted(homes)


def test_constant_attr_not_on_disk(tiny_gofs):
    """Constant attributes live in the template schema, not attribute
    slices (paper §V-B)."""
    for p in os.listdir(tiny_gofs):
        if p.startswith("part_"):
            for f in os.listdir(os.path.join(tiny_gofs, p)):
                assert "mtu" not in f and "ip_class" not in f
    store = GoFSStore(tiny_gofs, edge_projection=("mtu",))
    si = store.get_instance(0, store.subgraph_ids()[0])
    assert np.all(si.local_edge_values["mtu"] == 1500)


def test_projection_reads_fewer_slices(tiny_gofs):
    s_all = GoFSStore(tiny_gofs, cache_slots=0)
    s_one = GoFSStore(tiny_gofs, cache_slots=0, vertex_projection=("plate",),
                      edge_projection=("latency",))
    g = s_all.subgraph_ids()[0]
    s_all.reset_stats()
    s_one.reset_stats()
    s_all.get_instance(0, g)
    s_one.get_instance(0, g)
    assert s_one.stats.slices_read < s_all.stats.slices_read


def test_time_filter_restricts(tiny_gofs):
    full = GoFSStore(tiny_gofs)
    n = full.num_timesteps()
    t1 = full.timestamps[1]
    part = GoFSStore(tiny_gofs, time_range=(t1, 1e18))
    assert part.num_timesteps() == n - 1
    g = full.subgraph_ids()[0]
    a = part.get_instance(0, g)  # first visible = global instance 1
    b = full.get_instance(1, g)
    for k in a.vertex_values:
        np.testing.assert_array_equal(a.vertex_values[k], b.vertex_values[k])


def test_cache_lru_eviction():
    c = SliceCache(slots=2)
    loads = []
    for key in ["a", "b", "a", "c", "b"]:
        c.get(key, lambda k=key: loads.append(k))
    # a,b -> miss; a hit; c miss (evicts b); b miss again
    assert loads == ["a", "b", "c", "b"]
    assert c.hits == 1 and c.misses == 4


def test_caching_reduces_reads(tiny_gofs):
    cold = GoFSStore(tiny_gofs, cache_slots=0, vertex_projection=(),
                     edge_projection=("latency",))
    warm = GoFSStore(tiny_gofs, cache_slots=14, vertex_projection=(),
                     edge_projection=("latency",))
    g = cold.subgraph_ids()[0]
    cold.reset_stats()
    warm.reset_stats()
    for t in range(cold.num_timesteps()):
        cold.get_instance(t, g)
        warm.get_instance(t, g)
    assert warm.stats.slices_read < cold.stats.slices_read


def test_temporal_packing_amortizes(tiny_collection, tmp_path):
    """i2 packing + cache reads fewer slices than i1 for a time scan."""
    import dataclasses

    cfg1 = dataclasses.replace(TINY, instances_per_slice=1)
    cfg2 = dataclasses.replace(TINY, instances_per_slice=2)
    r1, r2 = str(tmp_path / "i1"), str(tmp_path / "i2")
    deploy_collection(tiny_collection, cfg1, r1)
    deploy_collection(tiny_collection, cfg2, r2)
    outs = []
    for root in (r1, r2):
        st = GoFSStore(root, cache_slots=14, vertex_projection=(),
                       edge_projection=("latency",))
        st.reset_stats()
        for g in st.subgraph_ids():
            for t in range(st.num_timesteps()):
                st.get_instance(t, g)
        outs.append(st.stats.slices_read)
    assert outs[1] < outs[0]


def test_gofs_provider_matches_inmemory(tiny_gofs, tiny_collection,
                                        tiny_partitioned):
    tmpl, assign, sg_ids, subs = tiny_partitioned
    store = GoFSStore(tiny_gofs, vertex_projection=(),
                      edge_projection=("latency", "active"))
    mem = InMemoryProvider(tiny_collection, subs, vertex_attrs=(),
                           edge_attrs=("latency", "active"))
    a, _ = sssp.run_host(store, 0)
    b, _ = sssp.run_host(mem, 0)
    assert set(a) == set(b)
    for g in a:
        np.testing.assert_allclose(a[g], b[g], equal_nan=True)


# ---------------------------------------------------------------------------
# Property: deploy -> read is the identity for ANY layout configuration
# ---------------------------------------------------------------------------

from tests.conftest import given, settings, hyp_st as st  # noqa: E402


@settings(max_examples=5, deadline=None)
@given(
    ipack=st.integers(1, 4),
    bins=st.integers(1, 5),
    slots=st.sampled_from([0, 4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gofs_roundtrip_any_layout(tmp_path_factory, ipack, bins, slots, seed):
    import dataclasses

    from repro.core.generator import generate_collection
    from repro.core.partition import discover_subgraphs, partition_graph
    from repro.core.subgraph import build_subgraphs

    cfg = dataclasses.replace(
        TINY, num_vertices=150, num_instances=3, seed=seed % 1000,
        instances_per_slice=ipack, bins_per_partition=bins,
    )
    tsg = generate_collection(cfg, num_plates=3)
    root = str(tmp_path_factory.mktemp(f"g{ipack}{bins}{slots}"))
    deploy_collection(tsg, cfg, root)
    store = GoFSStore(root, cache_slots=slots,
                      vertex_projection=("plate",),
                      edge_projection=("latency",))
    assign = partition_graph(tsg.template, cfg.num_partitions, seed=cfg.seed)
    sg_ids = discover_subgraphs(tsg.template, assign)
    subs = build_subgraphs(tsg.template, assign, sg_ids)
    assert sorted(store.subgraph_ids()) == sorted(subs)
    for g in store.subgraph_ids():
        for t in range(store.num_timesteps()):
            si = store.get_instance(t, g)
            np.testing.assert_array_equal(
                si.vertex_values["plate"],
                tsg.vertex_values(t, "plate")[subs[g].vertices],
            )
            np.testing.assert_array_equal(
                si.local_edge_values["latency"],
                tsg.edge_values(t, "latency")[subs[g].local_edge_id],
            )
