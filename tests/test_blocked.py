"""Blocked layout + semiring SpMV: structure invariants and oracle checks."""
import numpy as np
import jax.numpy as jnp
import pytest

from tests.conftest import given, settings, hyp_st as st

from repro.core.blocked import build_blocked
from repro.core.graph import GraphTemplate
from repro.core.partition import partition_graph
from repro.core.semiring import INF, MIN_PLUS, PLUS_MUL
from repro.kernels.semiring_spmm.ops import spmv_blocked


def _template(rng, V, E):
    src = rng.integers(0, V, E)
    dst = rng.integers(0, V, E)
    keep = src != dst
    return GraphTemplate(num_vertices=V, src=src[keep].astype(np.int64),
                         dst=dst[keep].astype(np.int64))


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(16, 100), st.integers(8, 32),
       st.integers(0, 2**31 - 1))
def test_blocked_structure_roundtrip(n_parts, V, B, seed):
    """Property: scatter/gather of vertex values is the identity; every edge
    lands in exactly one tile slot."""
    B = (B // 8) * 8
    rng = np.random.default_rng(seed)
    tmpl = _template(rng, V, V * 3)
    assign = partition_graph(tmpl, n_parts, seed=0)
    bg = build_blocked(tmpl, assign, B)
    vals = rng.random(V).astype(np.float32)
    assert np.allclose(bg.gather_vertex(bg.scatter_vertex(vals, INF)), vals)
    assert len(bg.le_edge_id) + len(bg.re_edge_id) == tmpl.num_edges
    # tiles sorted col-major per partition (Pallas kernel invariant)
    for p in range(bg.n_parts):
        n = int(bg.n_tiles[p])
        cols = bg.tiles_rc[p, :n, 1]
        assert np.all(np.diff(cols) >= 0)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 3), st.integers(30, 80), st.integers(0, 2**31 - 1))
def test_full_graph_spmv_matches_edge_oracle(n_parts, V, seed):
    """local SpMV + boundary SpMV over all partitions == one global
    relaxation over the full edge list (min-plus)."""
    rng = np.random.default_rng(seed)
    tmpl = _template(rng, V, V * 3)
    if tmpl.num_edges == 0:
        return
    assign = partition_graph(tmpl, n_parts, seed=0)
    bg = build_blocked(tmpl, assign, 16)
    w = rng.random(tmpl.num_edges).astype(np.float32)
    x = rng.random(V).astype(np.float32)

    lt = bg.fill_local(w)
    bt = bg.fill_boundary(w)
    xp = jnp.asarray(bg.scatter_vertex(x, INF))
    # local contribution
    ys = []
    for p in range(bg.n_parts):
        y = spmv_blocked(jnp.asarray(lt[p]), jnp.asarray(bg.tiles_rc[p, :, 0]),
                         jnp.asarray(bg.tiles_rc[p, :, 1]), xp[p], MIN_PLUS)
        ys.append(np.asarray(y))
    ys = np.stack(ys)
    # boundary contribution
    buf = np.full(bg.num_boundary, INF, np.float32)
    valid = bg.bslot_of_src >= 0
    buf[valid] = x[bg.bslot_of_src[valid]]
    nob = bg.vp // bg.block_size
    for p in range(bg.n_parts):
        yb = spmv_blocked(jnp.asarray(bt[p]), jnp.asarray(bg.btiles_rc[p, :, 0]),
                          jnp.asarray(bg.btiles_rc[p, :, 1]), jnp.asarray(buf),
                          MIN_PLUS, n_out_blocks=nob)
        ys[p] = np.minimum(ys[p], np.asarray(yb))
    got = np.array([ys[bg.part_of[v], bg.local_of[v]] for v in range(V)])
    # oracle: one global min-plus relaxation
    want = np.full(V, INF, np.float32)
    np.minimum.at(want, tmpl.dst, x[tmpl.src] + w)
    finite = np.isfinite(want)
    assert np.array_equal(np.isfinite(got), finite)
    assert np.allclose(got[finite], want[finite], rtol=1e-5, atol=1e-5)


def test_fill_combines_parallel_edges():
    """Duplicate (src, dst) edges must combine with the semiring add."""
    tmpl = GraphTemplate(num_vertices=4,
                         src=np.array([0, 0, 1], np.int64),
                         dst=np.array([1, 1, 2], np.int64))
    assign = np.zeros(4, np.int32)
    bg = build_blocked(tmpl, assign, 8)
    w = np.array([5.0, 2.0, 1.0], np.float32)
    lt = bg.fill_local(w)  # min combine
    x = jnp.asarray(bg.scatter_vertex(np.array([0.0, INF, INF, INF]), INF))
    y = spmv_blocked(jnp.asarray(lt[0]), jnp.asarray(bg.tiles_rc[0, :, 0]),
                     jnp.asarray(bg.tiles_rc[0, :, 1]), x[0], MIN_PLUS)
    assert float(y[bg.local_of[1]]) == 2.0  # min(5, 2), not last-write 2 or 5
    lt_add = bg.fill_local(w, zero=0.0)  # sum combine
    yp = spmv_blocked(jnp.asarray(lt_add[0]), jnp.asarray(bg.tiles_rc[0, :, 0]),
                      jnp.asarray(bg.tiles_rc[0, :, 1]),
                      jnp.asarray(bg.scatter_vertex(np.ones(4), 0.0)[0]),
                      PLUS_MUL)
    assert float(yp[bg.local_of[1]]) == 7.0  # 5 + 2
