"""Docs stay true: markdown links in docs/ must resolve to real files, the
module paths the paper-to-code map names must exist, and the runnable
snippets in the engine/prefetcher docstrings must actually run (doctest).
This rides in the default tier-1 verify path so documentation rot fails CI
like any other regression."""
import doctest
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = os.path.join(REPO, "docs")

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_PATH = re.compile(r"`((?:src|benchmarks|examples|docs|tests)/[A-Za-z0-9_./-]+)`")


def _doc_files():
    return sorted(
        os.path.join(DOCS, f) for f in os.listdir(DOCS) if f.endswith(".md")
    )


def test_docs_exist():
    names = {os.path.basename(p) for p in _doc_files()}
    assert {"ARCHITECTURE.md", "BENCHMARKS.md"} <= names


@pytest.mark.parametrize("md", _doc_files(), ids=os.path.basename)
def test_markdown_links_resolve(md):
    """Every relative link target (file or anchor-bearing) must exist."""
    text = open(md).read()
    missing = []
    for target in MD_LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = os.path.normpath(
            os.path.join(os.path.dirname(md), target.split("#")[0])
        )
        if not os.path.exists(path):
            missing.append(target)
    assert not missing, f"{os.path.basename(md)} has dead links: {missing}"


@pytest.mark.parametrize("md", _doc_files(), ids=os.path.basename)
def test_named_module_paths_exist(md):
    """Backticked repo paths (the paper-to-code map entries) must be real —
    every paper concept must point at an actual module."""
    text = open(md).read()
    missing = [
        p for p in CODE_PATH.findall(text)
        if not os.path.exists(os.path.join(REPO, p))
    ]
    assert not missing, f"{os.path.basename(md)} names dead paths: {missing}"


@pytest.mark.parametrize(
    "modname",
    ["repro.core.engine", "repro.core.comm", "repro.core.blocked",
     "repro.gofs.prefetch", "repro.dist.collectives",
     "repro.launch.mesh", "repro.gopher.session", "repro.gopher.registry",
     "repro.gopher.planner", "repro.gopher.service",
     "repro.cluster.runtime", "repro.cluster.gather",
     "repro.cluster.checkpoint"],
)
def test_docstring_examples_run(modname):
    """The per-pattern snippets documented on TemporalEngine /
    SemiringProgram / the CommBackend implementations / SlicePrefetcher /
    the comm cost model are executable contracts (equivalent to
    `pytest --doctest-modules` on these modules)."""
    mod = __import__(modname, fromlist=["_"])
    result = doctest.testmod(mod, verbose=False)
    assert result.attempted > 0, f"{modname} lost its doctests"
    assert result.failed == 0, f"{modname} doctests failed"
