"""Per-kernel interpret-mode allclose sweeps against the pure-jnp oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.semiring import MIN_PLUS, PLUS_MUL
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.semiring_spmm.ops import spmv_blocked

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# semiring_spmm
# ---------------------------------------------------------------------------

def _random_block_structure(B, nvb, T_valid, T_pad):
    cols = np.sort(RNG.integers(0, nvb, T_valid)).astype(np.int32)
    rows = RNG.integers(0, nvb, T_valid).astype(np.int32)
    rows = np.concatenate([rows, np.full(T_pad, -1, np.int32)])
    cols = np.concatenate([cols, np.full(T_pad, -1, np.int32)])
    return rows, cols


@pytest.mark.parametrize("B", [8, 16, 128])
@pytest.mark.parametrize("sr", [MIN_PLUS, PLUS_MUL], ids=lambda s: s.name)
@pytest.mark.parametrize("density", [0.05, 0.5])
def test_spmv_kernel_vs_ref(B, sr, density):
    nvb = int(RNG.integers(2, 6))
    T_valid = int(RNG.integers(1, 14))
    rows, cols = _random_block_structure(B, nvb, T_valid, int(RNG.integers(0, 4)))
    T = len(rows)
    tiles = np.full((T, B, B), sr.zero, np.float32)
    for t in range(T_valid):
        m = RNG.random((B, B)) < density
        tiles[t][m] = RNG.random(int(m.sum()))
    x = RNG.random(nvb * B).astype(np.float32)
    args = (jnp.asarray(tiles), jnp.asarray(rows), jnp.asarray(cols),
            jnp.asarray(x), sr)
    yk = np.asarray(spmv_blocked(*args, use_pallas=True, interpret=True))
    yr = np.asarray(spmv_blocked(*args, use_pallas=False))
    inf_k, inf_r = ~np.isfinite(yk), ~np.isfinite(yr)
    assert np.array_equal(inf_k, inf_r)
    np.testing.assert_allclose(yk[~inf_k], yr[~inf_r], rtol=2e-5, atol=2e-5)


def test_spmv_empty_structure():
    """All-padding tile list -> all-zero (semiring) output."""
    B, nvb = 8, 3
    rows = np.full(4, -1, np.int32)
    cols = np.full(4, -1, np.int32)
    tiles = np.full((4, B, B), MIN_PLUS.zero, np.float32)
    x = np.ones(nvb * B, np.float32)
    y = spmv_blocked(jnp.asarray(tiles), jnp.asarray(rows), jnp.asarray(cols),
                     jnp.asarray(x), MIN_PLUS, use_pallas=True, interpret=True)
    assert np.all(np.isinf(np.asarray(y)))


@pytest.mark.parametrize("sr", [MIN_PLUS, PLUS_MUL], ids=lambda s: s.name)
@pytest.mark.parametrize("nnz", [0, 3, 7])
def test_spmv_packed_walk_nnz(sr, nnz):
    """Block-sparse packed list (interpret mode): the Pallas walk with the
    ``nnz`` padding-skip == the walk without it == the jnp segment-reduce
    oracle, for every semiring and valid-tile count (0 = fully padded)."""
    B, nvb, T = 8, 4, 7
    cols = np.sort(RNG.integers(0, nvb, nnz)).astype(np.int32)
    rows = RNG.integers(0, nvb, nnz).astype(np.int32)
    rows = np.concatenate([rows, np.full(T - nnz, -1, np.int32)])
    cols = np.concatenate([cols, np.full(T - nnz, -1, np.int32)])
    tiles = np.full((T, B, B), sr.zero, np.float32)
    tiles[:nnz] = RNG.random((nnz, B, B))
    x = RNG.random(nvb * B).astype(np.float32)
    args = (jnp.asarray(tiles), jnp.asarray(rows), jnp.asarray(cols),
            jnp.asarray(x), sr)
    y_ref = np.asarray(spmv_blocked(*args, use_pallas=False))
    y_pal = np.asarray(spmv_blocked(*args, use_pallas=True, interpret=True))
    y_nnz = np.asarray(spmv_blocked(
        *args, use_pallas=True, interpret=True,
        nnz=jnp.asarray(nnz, jnp.int32),
    ))
    assert np.array_equal(y_pal, y_nnz)
    fin = np.isfinite(y_ref)
    assert np.array_equal(np.isfinite(y_nnz), fin)
    np.testing.assert_allclose(y_nnz[fin], y_ref[fin], rtol=2e-5, atol=2e-5)


def test_spmv_packed_subset_matches_dense_walk():
    """Dropping all-zero tiles from the walked list must not change the
    output (the sparse layout's core claim, at kernel level, bitwise)."""
    B, nvb = 8, 4
    T = 10
    cols = np.sort(RNG.integers(0, nvb, T)).astype(np.int32)
    rows = RNG.integers(0, nvb, T).astype(np.int32)
    for sr in (MIN_PLUS, PLUS_MUL):
        tiles = np.full((T, B, B), sr.zero, np.float32)
        live = RNG.random(T) < 0.5
        for t in np.nonzero(live)[0]:
            tiles[t] = RNG.random((B, B))
        x = RNG.random(nvb * B).astype(np.float32)
        k = int(live.sum())
        packed = np.full((T, B, B), sr.zero, np.float32)
        prows = np.full(T, -1, np.int32)
        pcols = np.full(T, -1, np.int32)
        packed[:k] = tiles[live]
        prows[:k] = rows[live]
        pcols[:k] = cols[live]
        for use_pallas in (False, True):
            kw = dict(use_pallas=use_pallas, n_out_blocks=nvb)
            if use_pallas:
                kw["interpret"] = True
            y_dense = np.asarray(spmv_blocked(
                jnp.asarray(tiles), jnp.asarray(rows), jnp.asarray(cols),
                jnp.asarray(x), sr, **kw))
            y_packed = np.asarray(spmv_blocked(
                jnp.asarray(packed), jnp.asarray(prows), jnp.asarray(pcols),
                jnp.asarray(x), sr,
                nnz=jnp.asarray(k, jnp.int32) if use_pallas else None, **kw))
            assert np.array_equal(y_dense, y_packed), (sr.name, use_pallas)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

FLASH_SWEEP = [
    # (B, Sq, Skv, H, K, d, causal, window, q_offset, dtype)
    (2, 64, 64, 4, 2, 32, True, 0, 0, jnp.float32),
    (1, 128, 128, 8, 8, 64, True, 0, 0, jnp.float32),
    (2, 32, 32, 4, 1, 16, False, 0, 0, jnp.float32),
    (1, 64, 64, 2, 2, 32, True, 24, 0, jnp.float32),
    (1, 32, 96, 4, 2, 32, True, 0, 64, jnp.float32),
    (1, 64, 64, 4, 2, 32, True, 0, 0, jnp.bfloat16),
    (1, 128, 128, 2, 2, 128, True, 0, 0, jnp.float32),
]


@pytest.mark.parametrize("case", FLASH_SWEEP,
                         ids=[f"case{i}" for i in range(len(FLASH_SWEEP))])
def test_flash_attention_vs_ref(case):
    B, Sq, Skv, H, K, d, causal, window, qoff, dt = case
    q = jnp.asarray(RNG.normal(size=(B, Sq, H, d)), dt)
    k = jnp.asarray(RNG.normal(size=(B, Skv, K, d)), dt)
    v = jnp.asarray(RNG.normal(size=(B, Skv, K, d)), dt)
    kw = dict(causal=causal, window=window, q_offset=qoff)
    o_ref = flash_attention(q, k, v, use_pallas=False, **kw)
    o_pal = flash_attention(q, k, v, use_pallas=True, interpret=True,
                            bq=32, bk=32, **kw)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(o_pal, np.float32), np.asarray(o_ref, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_matches_model_chunked_path():
    """The model's chunked-softmax path is the production jnp attention; it
    must agree with the flash oracle."""
    from repro.models.attention import chunked_attention

    B, S, H, K, d = 2, 96, 4, 2, 32
    q = jnp.asarray(RNG.normal(size=(B, S, H, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, K, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, K, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o_chunk = chunked_attention(q, k, v, q_positions=pos, kv_positions=pos,
                                causal=True, chunk=32)
    o_ref = flash_attention(q, k, v, causal=True, use_pallas=False)
    np.testing.assert_allclose(np.asarray(o_chunk), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

DECODE_SWEEP = [
    (2, 128, 4, 2, 32, 0, jnp.float32),
    (1, 256, 8, 1, 64, 0, jnp.float32),
    (3, 128, 4, 4, 32, 48, jnp.float32),
    (2, 128, 8, 2, 64, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("case", DECODE_SWEEP,
                         ids=[f"case{i}" for i in range(len(DECODE_SWEEP))])
def test_decode_attention_vs_ref(case):
    B, S, H, K, d, window, dt = case
    q = jnp.asarray(RNG.normal(size=(B, H, d)), dt)
    k = jnp.asarray(RNG.normal(size=(B, S, K, d)), dt)
    v = jnp.asarray(RNG.normal(size=(B, S, K, d)), dt)
    lens = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32)
    o_ref = decode_attention(q, k, v, lens, window=window, use_pallas=False)
    o_pal = decode_attention(q, k, v, lens, window=window, use_pallas=True,
                             interpret=True, bk=64)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(o_pal, np.float32), np.asarray(o_ref, np.float32),
        rtol=tol, atol=tol,
    )
