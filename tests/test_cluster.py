"""Multi-process cluster runtime: exchange protocol, shard staging, parity.

Thread-level tests drive the raw :class:`TcpExchange` / runtime pair in
one process (generous socket timeouts — two peers may compile/fill at
very different speeds); the end-to-end engine parity runs REAL worker
processes through ``repro.launch.cluster_graph --check`` (the CI
multi-process lane's command), asserting bitwise-identical results and
per-host staged bytes below the single-process cost.
"""
import os
import socket
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.cluster.runtime import (ClusterRuntime, ExchangeError,
                                   TcpExchange)
from conftest import TINY

TIMEOUT = 900.0  # compile skew between peers can be minutes, not seconds


@pytest.fixture(scope="module")
def cluster_store_root(tiny_collection, tmp_path_factory):
    from repro.gofs import deploy_collection

    root = str(tmp_path_factory.mktemp("gofs_cluster"))
    deploy_collection(tiny_collection, TINY, root)
    return root


def free_port() -> int:
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def two_runtimes(fn):
    """Run ``fn(runtime)`` on two in-process peers; return [r0, r1]."""
    port = free_port()
    results = [None, None]
    errors = [None, None]

    def peer(pid):
        try:
            if pid == 0:
                ex = TcpExchange.listen(port, 2, host="127.0.0.1",
                                        timeout=TIMEOUT)
            else:
                ex = TcpExchange.connect("127.0.0.1", port, pid, 2,
                                         timeout=TIMEOUT)
            rt = ClusterRuntime(pid, 2, exchange=ex)
            try:
                results[pid] = fn(rt)
            finally:
                rt.close()
        except BaseException as e:  # noqa: BLE001 - surfaced to the test
            errors[pid] = e

    ts = [threading.Thread(target=peer, args=(i,)) for i in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(TIMEOUT)
    assert not any(t.is_alive() for t in ts), "peer thread hung"
    for e in errors:
        if e is not None:
            raise e
    return results


# --------------------------------------------------------------- runtime

def test_partition_shard_contiguous_cover():
    from repro.cluster.runtime import shard_range

    rt = ClusterRuntime(0, 1)
    assert rt.partition_shard(5) == (0, 5)
    spans = [shard_range(7, pid, 3) for pid in range(3)]
    assert spans == [(0, 3), (3, 5), (5, 7)]  # remainder to low ranks
    # contiguous concat covers exactly 0..n_parts
    assert spans[0][0] == 0 and spans[-1][1] == 7
    for a, b in zip(spans, spans[1:]):
        assert a[1] == b[0]


def test_shard_of_partition_inverts_shards():
    from repro.cluster.runtime import shard_range

    for n_procs in (1, 2, 3):
        for n_parts in (1, 4, 7):
            if n_procs > n_parts:
                continue
            for p in range(n_parts):
                owners = [pid for pid in range(n_procs)
                          if shard_range(n_parts, pid, n_procs)[0] <= p
                          < shard_range(n_parts, pid, n_procs)[1]]
                assert len(owners) == 1  # every partition has ONE owner


def test_tcp_allgather_ordered_and_barrier():
    def body(rt):
        out = []
        for i in range(3):
            parts = rt.allgather(f"round/{i}",
                                 {"pid": rt.process_id, "i": i})
            out.append(parts)
            rt.barrier(f"b/{i}")
        return out

    r0, r1 = two_runtimes(body)
    assert r0 == r1  # every peer sees the identical rank-ordered payloads
    for i, parts in enumerate(r0):
        assert parts == [{"pid": 0, "i": i}, {"pid": 1, "i": i}]


def test_allgather_concat_rank_order():
    def body(rt):
        lo = rt.process_id * 2
        shard = np.arange(lo, lo + 2, dtype=np.float32).reshape(2, 1)
        return rt.allgather_concat(shard, axis=0, tag="cat")

    r0, r1 = two_runtimes(body)
    want = np.arange(4, dtype=np.float32).reshape(4, 1)
    assert np.array_equal(r0, want) and np.array_equal(r1, want)


def test_all_reduce_or_votes():
    def body(rt):
        return (rt.all_reduce_or(rt.process_id == 0, tag="v1"),
                rt.all_reduce_or(False, tag="v2"))

    for got in two_runtimes(body):
        assert got == (True, False)


def test_tag_divergence_raises():
    def body(rt):
        # peers disagree on what this exchange IS -> both must fail fast
        rt.allgather(f"tag-{rt.process_id}", 1)

    with pytest.raises(ExchangeError):
        two_runtimes(body)


def test_check_consistent_divergence_raises():
    def body(rt):
        rt.check_consistent("chunk/0", ("span", rt.process_id))

    with pytest.raises(ExchangeError):
        two_runtimes(body)


# ------------------------------------------------------- gather backend

def test_cluster_gather_matches_host_fold():
    """The distributed combine must be BITWISE the single-process fold."""
    import jax.numpy as jnp

    from repro.cluster.gather import ClusterGather
    from repro.core.comm import HostGather
    from repro.core.semiring import MIN_PLUS, PLUS_MUL

    rng = np.random.default_rng(7)
    buf = rng.random((4, 9), dtype=np.float32)
    buf_min = np.where(rng.random((4, 9)) < 0.3, np.inf, buf)

    for sr, full in ((MIN_PLUS, buf_min), (PLUS_MUL, buf)):
        want = np.asarray(HostGather().combine_boundary(
            jnp.asarray(full), sr))

        def body(rt, sr=sr, full=full):
            lo, hi = rt.partition_shard(4)
            cg = ClusterGather(runtime=rt)
            return np.asarray(cg.combine_boundary(
                jnp.asarray(full[lo:hi]), sr))

        for got in two_runtimes(body):
            assert np.array_equal(got, want), sr.name


# ------------------------------------------------------- shard staging

def test_edge_attr_rows_halo_completes_boundary(cluster_store_root):
    """Regression: a partition's INCOMING cut edges live in the PEER
    partitions' remote slices — without the halo read the boundary tiles
    stage as semiring-zero and cross-shard propagation dies."""
    from repro.gofs import GoFSStore
    from repro.gopher import GopherSession

    store = GoFSStore(cluster_store_root)
    sess = GopherSession(store)
    bg, P = sess.bg, sess.bg.n_parts
    I = int(store.meta["num_instances"])
    name = next(n for n, a in store._e_attrs.items() if a.constant is None)

    w = store.edge_attr_rows(name, range(I))
    full_t = bg.fill_local_batch(w, zero=np.inf)
    full_b = bg.fill_boundary_batch(w, zero=np.inf)
    # which cut edges arrive from OUTSIDE a shard range: source partition
    # of each boundary-scattered edge vs the owned range
    spart = np.asarray(bg.part_of)[sess.src[np.asarray(bg.re_edge_id)]]
    for parts in [(0, P // 2), (P // 2, P)]:
        lo, hi = parts
        wsh = store.edge_attr_rows(name, range(I), parts=range(lo, hi),
                                   fill=np.inf, halo=True)
        st = bg.fill_local_batch(wsh, zero=np.inf, parts=parts)
        sb = bg.fill_boundary_batch(wsh, zero=np.inf, parts=parts)
        assert np.array_equal(st, full_t[:, lo:hi])
        assert np.array_equal(sb, full_b[:, lo:hi])
        # and WITHOUT halo the boundary fill is incomplete exactly when
        # some owned partition has an incoming cut edge from a peer shard
        dst_in = (np.asarray(bg.re_part) >= lo) & (np.asarray(bg.re_part) < hi)
        external = bool(np.any(dst_in & ((spart < lo) | (spart >= hi))))
        wnh = store.edge_attr_rows(name, range(I), parts=range(lo, hi),
                                   fill=np.inf, halo=False)
        sb_nh = bg.fill_boundary_batch(wnh, zero=np.inf, parts=parts)
        assert np.array_equal(sb_nh, sb) == (not external)


def test_shard_stream_bytes_halve(cluster_store_root):
    """Each peer's materialized bytes are its shard fraction; the spans
    and layouts are consistency-checked at every chunk boundary."""
    from repro.cluster.staging import shard_stream
    from repro.gofs import GoFSStore
    from repro.gopher import GopherSession

    store = GoFSStore(cluster_store_root)
    sess = GopherSession(store)
    name = next(n for n, a in store._e_attrs.items() if a.constant is None)

    # single-process total (runtime=None -> full partition range)
    with shard_stream(store, sess.bg, name, None, zero=np.inf) as full:
        for _ in full:
            pass
        total = full.staged_bytes
    assert total > 0

    def body(rt):
        with shard_stream(store, sess.bg, name, rt, zero=np.inf) as st:
            for _ in st:
                pass
            return st.staged_bytes, st.chunks

    (b0, c0), (b1, c1) = two_runtimes(body)
    assert c0 == c1 > 0
    assert b0 < total and b1 < total
    assert b0 + b1 == total  # contiguous shards partition the tile bytes


def test_shard_stream_span_divergence_raises(cluster_store_root):
    from repro.cluster.staging import shard_stream
    from repro.gofs import GoFSStore
    from repro.gopher import GopherSession

    store = GoFSStore(cluster_store_root)
    sess = GopherSession(store)
    name = next(n for n, a in store._e_attrs.items() if a.constant is None)

    def body(rt):
        # peers disagree on the chunk grain -> first boundary check fails
        with shard_stream(store, sess.bg, name, rt, zero=np.inf,
                          chunk_instances=1 + rt.process_id) as st:
            for _ in st:
                pass

    with pytest.raises(ExchangeError):
        two_runtimes(body)


# ------------------------------------------------- end-to-end processes

def test_two_process_parity_end_to_end(tmp_path):
    """The tentpole acceptance: REAL worker processes, shard-local
    staging, inter-process gather — results bitwise-identical to the
    single-process run, per-host staged bytes strictly below it.
    (Same command as the CI multi-process lane, sssp-only for speed.)"""
    env = dict(os.environ, PYTHONPATH="src")
    cmd = [
        sys.executable, "-m", "repro.launch.cluster_graph",
        "--num-processes", "2", "--apps", "sssp", "--size", "tiny",
        "--deploy", str(tmp_path / "gofs"),
        "--out", str(tmp_path / "out"), "--check",
    ]
    proc = subprocess.run(cmd, env=env, cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=TIMEOUT)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "parity OK" in proc.stdout
