"""SliceCache under concurrent load: budget, pins, and liveness.

The cache sits between the GoFS store and both the caller's thread and
the prefetcher's worker pool; a serving process (GopherService) adds more
submitter threads on top.  Invariants hammered here with a thread storm:

* no lost pins — pinned entries (tile maps, delta payload pools) survive
  any amount of LRU churn and never re-invoke their loader;
* budget honored — resident bytes never exceed ``byte_budget`` once the
  storm settles, and internal byte accounting stays consistent with the
  per-key size map;
* no deadlock — every worker joins within the timeout (loaders run
  outside the lock, so slow loads must not serialize the cache);
* counters sane — hits + misses add up, evictions only ever grow.
"""
import threading

import numpy as np
import pytest

from repro.gofs.cache import SliceCache, _value_nbytes

KEYS = 40
VALUE_BYTES = 8 * 1024  # 2048 float32 per value
N_THREADS = 8
OPS_PER_THREAD = 300


def _value_for(key: int) -> np.ndarray:
    return np.full(VALUE_BYTES // 4, key, np.float32)


def _storm(cache, pinned_keys, fail_after_first_pin_load=False):
    """N threads hammer overlapping key ranges; returns collected errors."""
    barrier = threading.Barrier(N_THREADS)
    errors = []
    pin_loads = {k: 0 for k in pinned_keys}
    pin_lock = threading.Lock()

    def pin_loader(k):
        def load():
            with pin_lock:
                pin_loads[k] += 1
            return _value_for(k)
        return load

    def worker(tid):
        rng = np.random.default_rng(tid)
        try:
            barrier.wait(timeout=30)
            for i in range(OPS_PER_THREAD):
                if i % 7 == 0:
                    k = int(rng.choice(pinned_keys))
                    got = cache.get(f"pin/{k}", pin_loader(k), pin=True)
                else:
                    k = int(rng.integers(0, KEYS))
                    got = cache.get(f"lru/{k}", lambda k=k: _value_for(k))
                assert got[0] == k, "value for the wrong key"
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append((tid, e))

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "cache deadlocked (worker did not join)"
    return errors, pin_loads


@pytest.mark.parametrize("slots,budget", [
    (6, 3 * VALUE_BYTES),   # byte budget binds before the slot count
    (4, None),              # slot count only (pre-budget behavior)
    (64, 5 * VALUE_BYTES),  # slots slack, budget binds
])
def test_concurrent_storm_keeps_invariants(slots, budget):
    cache = SliceCache(slots=slots, byte_budget=budget)
    pinned = [100, 101, 102]
    errors, pin_loads = _storm(cache, pinned)
    assert not errors, errors

    stats = cache.stats()
    # budget honored at rest (eviction runs under the insert lock, so a
    # settled cache can never sit above it)
    assert stats["resident"] <= slots
    if budget is not None:
        assert stats["resident_bytes"] <= budget
    # internal byte accounting consistent with the per-key sizes
    with cache._lock:
        assert cache._bytes == sum(cache._sizes.values())
        assert set(cache._sizes) == set(cache._data)
        assert all(v == VALUE_BYTES for v in cache._sizes.values())

    # no lost pins: each pinned key loaded at most... a cold-key race may
    # load twice, but the cache must have kept ONE copy and must never
    # reload it now
    for k in pinned:
        def must_not_load():  # pragma: no cover - the assertion
            raise AssertionError("pinned entry was lost")
        got = cache.get(f"pin/{k}", must_not_load, pin=True)
        assert got[0] == k
        assert pin_loads[k] >= 1

    total = stats["hits"] + stats["misses"]  # captured before the re-checks
    assert total == N_THREADS * OPS_PER_THREAD
    assert stats["evictions"] >= 0


def test_slots_zero_still_pins_under_concurrency():
    """c0 (value caching disabled) must still keep pinned metadata — and
    stay correct when many threads hit it."""
    cache = SliceCache(slots=0, byte_budget=None)
    errors, _ = _storm(cache, pinned_keys=[7, 8])
    assert not errors, errors
    stats = cache.stats()
    assert stats["resident"] == 0 and stats["resident_bytes"] == 0
    assert stats["pinned"] == 2


def test_oversized_value_never_resident():
    """A single value larger than the whole budget is evicted before the
    insert returns — residency may not exceed the budget even briefly at
    rest."""
    cache = SliceCache(slots=8, byte_budget=VALUE_BYTES // 2)
    big = cache.get("big", lambda: _value_for(1))
    assert big[0] == 1  # caller still gets the loaded value
    stats = cache.stats()
    assert stats["resident"] == 0
    assert stats["resident_bytes"] == 0
    assert stats["evictions"] == 1


def test_value_nbytes_covers_containers():
    arr = np.zeros(16, np.float32)
    assert _value_nbytes(arr) == 64
    assert _value_nbytes({"a": arr, "b": [arr, arr]}) == 192
    assert _value_nbytes(("x", 3)) == 0  # metadata-grade: not budgeted


def test_invalidate_drops_matching_lru_and_pinned_only():
    """The append-observation hook: matching entries leave BOTH tiers
    (a stale pinned delta pool must not survive), survivors stay
    resident with correct byte accounting, and dropped keys reload."""
    cache = SliceCache(slots=8, byte_budget=None)
    for k in range(4):
        cache.get(f"lru/{k}", lambda k=k: _value_for(k))
    cache.get("pin/tilemap", lambda: _value_for(99), pin=True)
    cache.get("pin/delta", lambda: _value_for(98), pin=True)

    dropped = cache.invalidate(
        lambda key: key.startswith("pin/") or key == "lru/3")
    assert dropped == 3

    loads = []
    got = cache.get("lru/0", lambda: (loads.append(1), _value_for(0))[1])
    assert got[0] == 0 and not loads  # survivor: served resident, no load
    got = cache.get("pin/delta",
                    lambda: (loads.append(1), _value_for(55))[1], pin=True)
    assert got[0] == 55 and loads == [1]  # dropped: loader re-ran

    stats = cache.stats()
    assert stats["pinned"] == 1  # the reloaded delta pool only
    # lru/0..2 survived; bytes track exactly (no drift from the drops)
    assert stats["resident"] == 3
    assert stats["resident_bytes"] == 3 * VALUE_BYTES


def test_invalidate_races_getters_without_deadlock():
    """Repeated targeted invalidation (an appender observing growth)
    racing reader threads: no deadlock, every read sees its own key's
    value, budget still binds afterwards."""
    budget = 4 * VALUE_BYTES
    cache = SliceCache(slots=16, byte_budget=budget)
    stop = threading.Event()
    errors = []

    def worker(tid):
        rng = np.random.default_rng(tid)
        try:
            while not stop.is_set():
                k = int(rng.integers(0, KEYS))
                pin = k % 5 == 0
                tier = "pin" if pin else "lru"
                got = cache.get(f"{tier}/{k}",
                                lambda k=k: _value_for(k), pin=pin)
                assert got[0] == k, "value for the wrong key"
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append((tid, e))

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for _ in range(300):  # the "append observed" hot loop
        cache.invalidate(lambda key: key.endswith(("0", "5")))
    stop.set()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive(), "cache deadlocked under invalidation"
    assert not errors, errors
    assert cache.stats()["resident_bytes"] <= budget
