"""Property-based parity harness: every execution strategy is invisible.

The engine's whole contract is that HOW a run executes — dense vs
block-sparse staging, full loads vs the delta chain, cold vs warm-started
fixpoints, one source vs a Q-wide multi-source batch — never changes WHAT
it computes.  This harness generates random small collections and asserts
bitwise equality across those axes for the min-plus semiring (exact in
float32: min/plus introduce no reassociation).

Two entry points share one generator + one checker:

* ``test_parity_property_*`` — hypothesis drives the case seed (and
  shrinks on failure).  Skips cleanly when hypothesis isn't installed
  (``tests/conftest.py`` stubs ``given``/``hyp_st``).
* ``test_parity_fixed_seeds`` — the same checker over a fixed seed sweep,
  so the parity surface is exercised on every tier-1 run even without
  hypothesis.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.blocked import build_blocked
from repro.core.graph import GraphTemplate
from repro.gopher import GopherSession

from tests.conftest import HAVE_HYPOTHESIS, given, hyp_st, settings


# --------------------------------------------------------------------------
# case generator + checker (shared by both entry points)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Case:
    bg: object
    w: np.ndarray  # (I, E) latencies, monotone-tightening chain
    sources: list  # Q distinct seed vertices


def _random_case(seed: int) -> Case:
    rng = np.random.default_rng(seed)
    V = int(rng.integers(12, 64))
    E = int(rng.integers(2 * V, 4 * V))
    I = int(rng.integers(1, 5))
    P = int(rng.integers(2, 4))
    B = int(rng.choice([4, 8, 16]))
    Q = int(rng.integers(1, 5))
    src = rng.integers(0, V, E)
    dst = rng.integers(0, V, E)
    bg = build_blocked(GraphTemplate(num_vertices=V, src=src, dst=dst),
                       rng.integers(0, P, V), block_size=B)
    # monotone-tightening chain: instance t's weights <= instance t-1's,
    # the regime where warm-started fixpoints are EXACT (a min-plus
    # fixpoint can only relax downward, so stale t-1 distances are valid
    # upper bounds for t) — cold-vs-warm parity is part of the property
    w = np.empty((I, E), np.float32)
    w[0] = rng.uniform(0.5, 2.0, E).astype(np.float32)
    for t in range(1, I):
        f = np.where(rng.random(E) < 0.25,
                     rng.uniform(0.6, 1.0, E), 1.0)
        w[t] = (w[t - 1] * f).astype(np.float32)
    sources = rng.choice(V, size=Q, replace=False).tolist()
    return Case(bg=bg, w=w, sources=sources)


def _assert_parity(case: Case) -> None:
    sess = GopherSession.from_blocked(case.bg, weights={"latency": case.w})

    def run(**plan_kw):
        return sess.run(sess.plan("sssp", **plan_kw)).output["final"]

    # reference: Q independent single-source runs, dense/cold
    refs = np.stack([
        run(source=s, layout="dense", warm=False) for s in case.sources
    ])

    # axis 1: source batching — Q-wide pass, bitwise per row; Q=1 keeps
    # the leading axis but not the values
    batched = run(source=case.sources, layout="dense", warm=False)
    assert batched.shape == refs.shape
    assert np.array_equal(batched, refs), "multi-source vs single-source"

    # axis 2: layout — block-sparse staging, single and batched
    assert np.array_equal(
        run(source=case.sources[0], layout="sparse", warm=False), refs[0]
    ), "sparse vs dense (single)"
    assert np.array_equal(
        run(source=case.sources, layout="sparse", warm=False), refs
    ), "sparse vs dense (batched)"

    # axis 3: warm-started fixpoints (exact on the monotone chain),
    # single and batched
    assert np.array_equal(
        run(source=case.sources[0], layout="dense", warm=True), refs[0]
    ), "warm vs cold (single)"
    assert np.array_equal(
        run(source=case.sources, layout="dense", warm=True), refs
    ), "warm vs cold (batched)"


# --------------------------------------------------------------------------
# hypothesis entry point (skips when hypothesis is absent)
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=hyp_st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_parity_property_staging_warm_sources(seed):
    _assert_parity(_random_case(seed))


# --------------------------------------------------------------------------
# deterministic entry point (always runs)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_parity_fixed_seeds(seed):
    _assert_parity(_random_case(seed))


def test_hypothesis_stub_marks_skip():
    """The harness must degrade to SKIP (not silently pass) when
    hypothesis is absent; when present the property test must not carry
    a skip mark."""
    marks = [m.name for m in getattr(
        test_parity_property_staging_warm_sources, "pytestmark", [])]
    if HAVE_HYPOTHESIS:
        assert "skip" not in marks
    else:
        assert "skip" in marks


# --------------------------------------------------------------------------
# delta staging parity (store-backed, deployed once per run)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def delta_store(tmp_path_factory):
    """Slowly-varying sparse collection with recorded delta chains."""
    from repro.configs.base import GraphConfig
    from repro.core.generator import generate_collection
    from repro.core.graph import TimeSeriesGraph
    from repro.gofs import GoFSStore, deploy_collection

    cfg = GraphConfig(name="parity-delta", num_vertices=256, avg_degree=3.0,
                      num_instances=4, num_partitions=2, block_size=16,
                      instances_per_slice=2, seed=3)
    col = generate_collection(cfg)
    rng = np.random.default_rng(3)
    src, dst = np.asarray(col.template.src), np.asarray(col.template.dst)
    live = (src < 64) & (dst < 64)  # localized support -> sparse tiles
    w = np.where(live, np.asarray(col.edge_values(0, "latency"), np.float32),
                 np.float32(np.inf)).astype(np.float32)
    ws = [w]
    idx = np.nonzero(live)[0]
    for _t in range(1, len(col)):
        w = ws[-1].copy()
        band = rng.choice(idx, size=max(1, len(idx) // 8), replace=False)
        w[band] = (w[band] * 0.7).astype(np.float32)  # mostly-unchanged tiles
        ws.append(w)
    insts = [dataclasses.replace(col.instances[t],
                                 edge_values={**col.instances[t].edge_values,
                                              "latency": ws[t]})
             for t in range(len(col))]
    root = str(tmp_path_factory.mktemp("parity_delta"))
    deploy_collection(TimeSeriesGraph(template=col.template, instances=insts),
                      cfg, root, sparse_absent={"latency": np.inf})
    return GoFSStore(root, cache_slots=4)


def test_parity_delta_staging(delta_store):
    """Delta-chain reconstruction is invisible: full sparse loads vs the
    deduplicated payload pools, single and multi-source."""
    sess = GopherSession(delta_store, block_size=16)

    def run(**plan_kw):
        return sess.run(sess.plan("sssp", **plan_kw)).output["final"]

    for source in (0, [0, 9, 33]):
        full = run(source=source, layout="sparse", delta=False)
        dlt = run(source=source, layout="sparse", delta=True)
        assert np.array_equal(full, dlt), f"delta vs full (source={source})"


# --------------------------------------------------------------------------
# streaming parity: append + tail is invisible too
# --------------------------------------------------------------------------
#
# The streaming contract extends the property above to ingestion: deploy
# the first k instances, stream the rest in via ``append_instances`` in
# random-sized batches, ``tail()`` after each append — and the final tail
# result must be bitwise identical to a cold full run over the grown
# collection, for every knob combination (dense/sparse, warm/cold,
# sync/async staging, sequential/independent pattern).

def _streaming_case(seed: int):
    from repro.configs.base import GraphConfig
    from repro.core.generator import generate_collection
    from repro.core.graph import TimeSeriesGraph

    rng = np.random.default_rng(seed)
    cfg = GraphConfig(
        name=f"parity-stream-{seed % 97}",
        num_vertices=int(rng.integers(48, 128)), avg_degree=3.0,
        num_instances=int(rng.integers(4, 8)),
        num_partitions=int(rng.integers(2, 4)),
        block_size=int(rng.choice([8, 16])), instances_per_slice=2,
        cache_slots=6, seed=int(seed % 1009) + 1,
    )
    col = generate_collection(cfg)
    # monotone-tightening latency chain (see _random_case): appends can
    # then be tailed warm AND cold with bitwise-identical answers
    E = np.asarray(col.template.src).shape[0]
    ws = [np.asarray(col.edge_values(0, "latency"), np.float32)]
    for _t in range(1, len(col)):
        f = np.where(rng.random(E) < 0.3, rng.uniform(0.6, 1.0, E), 1.0)
        ws.append((ws[-1] * f).astype(np.float32))
    insts = [dataclasses.replace(
        col.instances[t],
        edge_values={**col.instances[t].edge_values, "latency": ws[t]})
        for t in range(len(col))]
    return cfg, TimeSeriesGraph(template=col.template, instances=insts), rng


def _assert_streaming_parity(seed: int) -> None:
    import shutil
    import tempfile

    from repro.core.graph import TimeSeriesGraph
    from repro.gofs import GoFSStore, append_instances, deploy_collection

    cfg, col, rng = _streaming_case(seed)
    n_total = len(col)
    k = int(rng.integers(1, n_total))  # random split point
    knobs = dict(
        source=int(rng.integers(0, cfg.num_vertices)),
        pattern=str(rng.choice(["sequential", "independent"])),
        layout=str(rng.choice(["dense", "sparse"])),
        staging=str(rng.choice(["sync", "async"])),
        warm=bool(rng.integers(0, 2)),
    )
    root = tempfile.mkdtemp(prefix="parity_stream_")
    try:
        deploy_collection(
            TimeSeriesGraph(template=col.template, instances=col.instances[:k]),
            cfg, root, sparse_absent={"latency": np.inf})
        sess = GopherSession(GoFSStore(root, cache_slots=cfg.cache_slots),
                             block_size=cfg.block_size)
        update = sess.tail("sssp", **knobs)
        assert update.mode == "full" and update.new_instances == k
        pos = k
        while pos < n_total:  # random-sized append batches
            b = int(rng.integers(1, n_total - pos + 1))
            append_instances(
                TimeSeriesGraph(template=col.template,
                                instances=col.instances[pos:pos + b]),
                root)
            pos += b
            update = sess.tail("sssp", **knobs)
            assert update.mode == "incremental", (update.mode, knobs)
            assert update.new_instances == b

        # the whole point: the tail of tails == a cold full run over the
        # grown deployment, bitwise, same knobs
        cold = GopherSession(GoFSStore(root, cache_slots=cfg.cache_slots),
                             block_size=cfg.block_size)
        ref = cold.run(cold.plan("sssp", **knobs))
        for key, vref in ref.output.items():
            got = np.asarray(update.result.output[key])
            assert np.array_equal(got, np.asarray(vref)), \
                f"tail vs cold mismatch on {key!r} (knobs={knobs}, k={k})"
    finally:
        shutil.rmtree(root, ignore_errors=True)


@settings(max_examples=5, deadline=None)
@given(seed=hyp_st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_streaming_parity_property(seed):
    _assert_streaming_parity(seed)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_streaming_parity_fixed_seeds(seed):
    _assert_streaming_parity(seed)
