"""Block-sparse layout parity: the packed active-tile format
(``repro.core.blocked.SparseBlocked``, ``TemporalEngine(layout="sparse")``)
must be bitwise-identical to the dense layout for min-plus across all
three iBSP patterns, fixpoint AND iterate programs, sync and async
staging, stacked and mesh (subprocess) — plus the GoFS recorded-tile-map
staging path, the engine's Pallas walk, and the boundary-nnz comm cost
model satellites."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.blocked import build_blocked, pow2_bucket
from repro.core.engine import (
    SemiringProgram,
    TemporalEngine,
    min_plus_program,
    pagerank_program,
    source_init,
)
from repro.core.graph import GraphInstance, GraphTemplate, TimeSeriesGraph
from repro.core.semiring import INF, MIN_PLUS

from tests.conftest import TINY


def _banded(bg, tmpl, w, n_bands=4):
    """Mask weights so instance i only activates one tile-aligned band —
    every tile is fully live or fully absent per instance.  The banding
    itself is the bench's workload generator (one shared implementation)."""
    from benchmarks.bench_temporal import _edge_bands

    band = _edge_bands(bg, tmpl.src, tmpl.dst, n_bands)
    live = band[None, :] == (np.arange(w.shape[0]) % n_bands)[:, None]
    return np.where(live, w, np.inf).astype(np.float32), live


@pytest.fixture(scope="module")
def env(tiny_collection, tiny_partitioned):
    tmpl, assign, _, _ = tiny_partitioned
    bg = build_blocked(tmpl, assign, TINY.block_size)
    I = len(tiny_collection)
    w = np.stack([tiny_collection.edge_values(t, "latency")
                  for t in range(I)])
    wb, live = _banded(bg, tmpl, w)
    return tmpl, bg, wb, live


def bellman_iterate_program(source: int, iters: int = 5) -> SemiringProgram:
    """A min-plus ITERATE program (fixed supersteps, no convergence vote):
    the fixed-count analogue of SSSP, exercising the iterate engine path
    under an idempotent semiring so parity can be asserted bitwise."""
    from repro.core.superstep import _consume, _local_sweep, _publish

    def step(x, dg, comm, use_pallas):
        x1 = _local_sweep(x, dg, MIN_PLUS, use_pallas)
        boundary = _publish(x1, dg, MIN_PLUS, comm)
        return _consume(x1, boundary, dg, MIN_PLUS, use_pallas)

    return SemiringProgram(
        name="bellman_iterate", semiring=MIN_PLUS, zero_fill=INF,
        kind="iterate", iters=iters, step=step, init=source_init(source),
    )


# ---------------------------------------------------------------------------
# Format
# ---------------------------------------------------------------------------

def test_pow2_bucket():
    assert [pow2_bucket(n) for n in (0, 1, 2, 3, 4, 5, 17)] == \
        [1, 1, 2, 4, 4, 8, 32]


def test_sparse_fill_reconstructs_dense(env):
    """Scattering the packed tiles back into template slots must exactly
    rebuild the dense fill; inactive slots hold only the semiring zero."""
    tmpl, bg, wb, live = env
    dense_l = bg.fill_local_batch(wb)
    dense_b = bg.fill_boundary_batch(wb)
    sp = bg.stage_sparse(wb)
    assert 0.0 < sp.occupancy() < 1.0
    for dense, tiles, rows, cols, nnz, rc in (
        (dense_l, sp.tiles, sp.rows, sp.cols, sp.nnz, bg.tiles_rc),
        (dense_b, sp.btiles, sp.brows, sp.bcols, sp.bnnz, bg.btiles_rc),
    ):
        rec = np.full_like(dense, INF)
        for i in range(sp.num_instances):
            for p in range(bg.n_parts):
                n = int(nnz[i, p])
                # padding slots carry -1 index and zero values
                assert np.all(rows[i, p, n:] == -1)
                assert np.all(cols[i, p, n:] == -1)
                assert np.all(tiles[i, p, n:] == np.float32(INF))
                # packed cols stay sorted (the kernel's output-run invariant)
                assert np.all(np.diff(cols[i, p, :n]) >= 0)
                for k in range(n):
                    t = np.nonzero(
                        (rc[p, :, 0] == rows[i, p, k])
                        & (rc[p, :, 1] == cols[i, p, k])
                    )[0]
                    assert len(t) == 1
                    rec[i, p, t[0]] = tiles[i, p, k]
        assert np.array_equal(rec, dense)


def test_bucket_too_small_rejected(env):
    tmpl, bg, wb, live = env
    with pytest.raises(AssertionError, match="bucket"):
        bg.fill_local_batch_sparse(wb, bucket=1)


def test_staged_bytes_shrink_with_occupancy(env):
    tmpl, bg, wb, live = env
    sp = bg.stage_sparse(wb)
    dense_bytes = bg.fill_local_batch(wb).nbytes \
        + bg.fill_boundary_batch(wb).nbytes
    assert sp.staged_bytes() < dense_bytes


# ---------------------------------------------------------------------------
# Engine parity: bitwise for min-plus, every pattern x program kind x staging
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pattern", ["sequential", "independent",
                                     "eventually"])
def test_fixpoint_bitwise_all_patterns(env, pattern):
    tmpl, bg, wb, live = env
    prog = min_plus_program("sssp", init=source_init(0))
    kw = dict(merge="mean") if pattern == "eventually" else {}
    rd = TemporalEngine(bg).run(prog, wb, pattern=pattern, **kw)
    rs = TemporalEngine(bg, layout="sparse").run(prog, wb, pattern=pattern,
                                                 **kw)
    assert np.array_equal(rd.values, rs.values)
    assert np.array_equal(rd.final, rs.final)
    assert np.array_equal(rd.stats["supersteps"], rs.stats["supersteps"])
    if pattern == "eventually":
        assert np.array_equal(rd.merged, rs.merged)
    assert rd.occupancy is None and rs.occupancy is not None


@pytest.mark.parametrize("pattern", ["sequential", "independent"])
def test_iterate_bitwise(env, pattern):
    """Min-plus ITERATE program (fixed supersteps): sparse == dense
    bitwise on the iterate engine path too."""
    tmpl, bg, wb, live = env
    prog = bellman_iterate_program(0, iters=4)
    rd = TemporalEngine(bg).run(prog, wb, pattern=pattern)
    rs = TemporalEngine(bg, layout="sparse").run(prog, wb, pattern=pattern)
    assert np.array_equal(rd.values, rs.values)
    assert np.array_equal(rd.final, rs.final)


def test_prestaged_batches_override_engine_layout(env):
    """Pre-staged batches carry their own layout, symmetrically: sparse=
    on a dense engine runs the sparse runner, tiles=/btiles= on a sparse
    engine runs the dense runner — nothing is silently dropped."""
    tmpl, bg, wb, live = env
    prog = min_plus_program("sssp", init=source_init(0))
    ref = TemporalEngine(bg).run(prog, wb, pattern="sequential")
    eng_sp = TemporalEngine(bg, layout="sparse")
    tiles, btiles = eng_sp.stage(wb, prog.zero_fill)
    r_dense_on_sparse = eng_sp.run(prog, tiles=tiles, btiles=btiles,
                                   pattern="sequential")
    assert np.array_equal(ref.values, r_dense_on_sparse.values)
    assert r_dense_on_sparse.occupancy is None  # the call ran dense
    sp = TemporalEngine(bg).stage_sparse(wb, prog.zero_fill)
    r_sparse_on_dense = TemporalEngine(bg).run(prog, sparse=sp,
                                               pattern="sequential")
    assert np.array_equal(ref.values, r_sparse_on_dense.values)
    assert r_sparse_on_dense.occupancy is not None
    with pytest.raises(AssertionError, match="not both"):
        eng_sp.run(prog, tiles=tiles, btiles=btiles, sparse=sp,
                   pattern="sequential")


def test_async_staging_bitwise(env):
    """Sparse chunks through the prefetcher: async sparse == sync dense."""
    tmpl, bg, wb, live = env
    prog = min_plus_program("sssp", init=source_init(0))
    rd = TemporalEngine(bg).run(prog, wb, pattern="sequential")
    eng = TemporalEngine(bg, layout="sparse", staging="async",
                         chunk_instances=2)
    rs = eng.run(prog, wb, pattern="sequential")
    assert np.array_equal(rd.values, rs.values)
    assert rs.occupancy is not None and 0.0 < rs.occupancy < 1.0


def test_pagerank_sparse_matches_dense(env):
    """Plus-mul: skipped tiles add exact 0.0, so the sparse iterate run
    tracks dense to float-exactness on one device."""
    tmpl, bg, wb, live = env
    from repro.core.algorithms.pagerank import edge_weights_for_instances

    pw = edge_weights_for_instances(tmpl.src, live.astype(np.float32),
                                    tmpl.num_vertices)
    prog = pagerank_program(tmpl.num_vertices, iters=8)
    rd = TemporalEngine(bg).run(prog, pw, pattern="independent")
    rs = TemporalEngine(bg, layout="sparse").run(prog, pw,
                                                 pattern="independent")
    np.testing.assert_allclose(rs.values, rd.values, atol=1e-7)


def test_engine_pallas_walk_bitwise(env):
    """The Pallas kernel (interpret mode) walking packed tiles inside the
    engine: use_pallas x layout, all four combinations agree bitwise."""
    tmpl, bg, wb, live = env
    prog = min_plus_program("sssp", init=source_init(0), max_supersteps=8)
    w2 = wb[:2]
    ref = TemporalEngine(bg).run(prog, w2, pattern="sequential")
    for kw in (dict(use_pallas=True),
               dict(use_pallas=True, layout="sparse")):
        got = TemporalEngine(bg, **kw).run(prog, w2, pattern="sequential")
        assert np.array_equal(ref.values, got.values), kw


# ---------------------------------------------------------------------------
# Fused superstep kernel (kernels/semiring_superstep): the whole local
# stage — tile walk, semiring combine, halt vote — as ONE pallas_call
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pattern", ["sequential", "independent",
                                     "eventually"])
@pytest.mark.parametrize("layout", ["dense", "sparse"])
def test_fused_bitwise_all_patterns(env, pattern, layout):
    """min-plus: fused superstep kernel == per-stage SpMV kernel == jnp
    oracle, BITWISE (values, final state, AND superstep counts — the
    in-kernel halt vote must fire on exactly the same superstep) across
    all three iBSP patterns x both layouts, interpret mode."""
    tmpl, bg, wb, live = env
    w2 = wb[:3]
    prog = min_plus_program("sssp", init=source_init(0), max_supersteps=16)
    kw = dict(merge="mean") if pattern == "eventually" else {}
    lay = {} if layout == "dense" else dict(layout="sparse")
    ref = TemporalEngine(bg, **lay).run(prog, w2, pattern=pattern, **kw)
    for up in ("spmv", "fused"):
        got = TemporalEngine(bg, use_pallas=up, **lay).run(
            prog, w2, pattern=pattern, **kw)
        assert np.array_equal(ref.values, got.values), (up, pattern, layout)
        assert np.array_equal(ref.final, got.final), (up, pattern, layout)
        assert np.array_equal(ref.stats["supersteps"],
                              got.stats["supersteps"]), (up, pattern, layout)
        if pattern == "eventually":
            assert np.array_equal(ref.merged, got.merged), (up, layout)


def test_fused_async_staging_bitwise(env):
    """Fused kernel under the async sparse prefetch pipeline."""
    tmpl, bg, wb, live = env
    prog = min_plus_program("sssp", init=source_init(0))
    ref = TemporalEngine(bg).run(prog, wb, pattern="sequential")
    eng = TemporalEngine(bg, use_pallas="fused", layout="sparse",
                         staging="async", chunk_instances=2)
    got = eng.run(prog, wb, pattern="sequential")
    assert np.array_equal(ref.values, got.values)


def test_fused_query_axis_bitwise(env):
    """The query axis vmaps the fused pallas_call over Q sources: batched
    == oracle == per-source runs, bitwise."""
    from repro.core.engine import sources_init

    tmpl, bg, wb, live = env
    w2 = wb[:2]
    sources = [0, 7, 23]
    progs = {s: min_plus_program("sssp", init=source_init(s),
                                 max_supersteps=16) for s in sources}
    batched = min_plus_program("sssp", init=sources_init(sources),
                               max_supersteps=16)
    ref = TemporalEngine(bg).run(batched, w2, pattern="sequential")
    got = TemporalEngine(bg, use_pallas="fused").run(
        batched, w2, pattern="sequential")
    assert np.array_equal(ref.values, got.values)
    for q, s in enumerate(sources):
        one = TemporalEngine(bg, use_pallas="fused").run(
            progs[s], w2, pattern="sequential")
        assert np.array_equal(got.values[q], one.values), s


def test_fused_warm_start_bitwise(env):
    """Warm-started fixpoints re-enter the fused path with a non-trivial
    x0 — still bitwise vs the oracle warm path."""
    tmpl, bg, wb, live = env
    prog = min_plus_program("sssp", init=source_init(0))
    ref = TemporalEngine(bg).run(prog, wb, pattern="independent",
                                 warm_start=True)
    got = TemporalEngine(bg, use_pallas="fused").run(
        prog, wb, pattern="independent", warm_start=True)
    assert np.array_equal(ref.values, got.values)
    assert np.array_equal(ref.stats["supersteps"], got.stats["supersteps"])


def test_fused_pagerank_tolerance(env):
    """plus-mul REASSOCIATES in the fused kernel (the sequential
    dot-product walk vs the oracle's segment sum), so PageRank parity is
    to float tolerance, not bitwise — documented contract."""
    tmpl, bg, wb, live = env
    from repro.core.algorithms.pagerank import edge_weights_for_instances

    pw = edge_weights_for_instances(tmpl.src, live.astype(np.float32),
                                    tmpl.num_vertices)[:2]
    prog = pagerank_program(tmpl.num_vertices, iters=8)
    ref = TemporalEngine(bg).run(prog, pw, pattern="independent")
    got = TemporalEngine(bg, use_pallas="fused").run(prog, pw,
                                                     pattern="independent")
    np.testing.assert_allclose(got.values, ref.values, atol=2e-6)


def test_fused_single_pallas_call_jaxpr(env):
    """The acceptance contract, pinned on the jaxpr: one fused local
    stage lowers to exactly ONE pallas_call — no per-partition launch
    loop (scan/map over partitions), and no state-sized XLA reduction
    for the halt vote outside the kernel (the vote is the kernel's SMEM
    output; only scalar post-processing remains)."""
    import jax
    import jax.numpy as jnp

    from repro.core.semiring import MIN_PLUS
    from repro.core.superstep import (_fused_sweep_vote, _local_sweep,
                                      device_graph)

    tmpl, bg, wb, live = env
    dg = device_graph(bg, bg.fill_local(wb[0]), bg.fill_boundary(wb[0]))
    x = jnp.asarray(np.where(np.asarray(dg.vmask), 1.0, INF), jnp.float32)

    def count(eqns, name, acc=None):
        acc = [] if acc is None else acc
        for e in eqns:
            if e.primitive.name == name:
                acc.append(e)
            for sub in e.params.values():
                if hasattr(sub, "jaxpr"):
                    count(sub.jaxpr.eqns, name, acc)
        return acc

    jx = jax.make_jaxpr(
        lambda xx: _fused_sweep_vote(xx, dg, MIN_PLUS, True))(x)
    assert len(count(jx.jaxpr.eqns, "pallas_call")) == 1
    # no partition-axis launch loop around the kernel
    assert count(jx.jaxpr.eqns, "scan") == []
    # the halt vote never materializes as a state-sized XLA reduce: every
    # reduce left in the jaxpr is over <= P elements (the per-partition
    # changed flags), not over the (P, Vp) state
    state_elems = int(np.prod(x.shape))
    for prim in ("reduce_or", "reduce_max", "reduce_min", "reduce_and"):
        for e in count(jx.jaxpr.eqns, prim):
            n_in = int(np.prod(e.invars[0].aval.shape))
            assert n_in <= dg.n_parts, (prim, e.invars[0].aval.shape)
    # contrast: the per-stage spmv path needs a separate state-sized vote
    jx_spmv = jax.make_jaxpr(
        lambda xx: _local_sweep(xx, dg, MIN_PLUS, ("spmv", True)))(x)
    assert len(count(jx_spmv.jaxpr.eqns, "pallas_call")) >= 1


def test_kernel_mode_resolution():
    """kernel_mode maps every accepted use_pallas spelling to a
    (mode, interpret) pair and rejects unknown modes."""
    from repro.core.superstep import kernel_mode

    assert kernel_mode(None) == ("off", None)
    assert kernel_mode(False) == ("off", None)
    assert kernel_mode(True) == ("spmv", None)
    assert kernel_mode("fused") == ("fused", None)
    assert kernel_mode(("fused", True)) == ("fused", True)
    with pytest.raises(ValueError, match="kernel mode"):
        kernel_mode("warp")


def test_planner_kernel_auto_selection(env):
    """Planner kernel knob: off on non-TPU backends, fused for TPU +
    sparse-regime occupancy, spmv for TPU dense; overrides win."""
    from repro.gopher import GopherSession, get_analytic
    from repro.gopher.planner import plan_analytic

    tmpl, bg, wb, live = env
    sess = GopherSession.from_blocked(bg, weights={"latency": wb})
    # this process runs on CPU: auto -> off, recorded on the plan
    p = sess.plan("sssp", source=0)
    assert p.kernel.value == "off" and p.kernel.source == "auto"
    assert "kernel" in p.explain()
    # session-wide use_pallas becomes a per-plan override
    s2 = GopherSession.from_blocked(bg, weights={"latency": wb},
                                    use_pallas="fused")
    p2 = s2.plan("sssp", source=0)
    assert p2.kernel.value == "fused" and p2.kernel.source == "override"
    # and the override actually reaches the engine the plan runs on
    r_auto = sess.run(p)
    r_fused = s2.run(p2)
    assert np.array_equal(r_auto.engine.values, r_fused.engine.values)
    # TPU rules, simulated through plan_analytic's backend input
    a = get_analytic("sssp")
    common = dict(bg=bg, mesh=None, model_axes=("model",),
                  store_backed=False, num_instances=2)
    low = plan_analytic(a, {"source": 0}, occupancy=0.1,
                        sparse_buckets=None, backend="tpu", **common)
    assert low.kernel.value == "fused"
    high = plan_analytic(a, {"source": 0}, occupancy=0.9,
                         sparse_buckets=None, backend="tpu", **common)
    assert high.kernel.value == "spmv"
    forced = plan_analytic(a, {"source": 0}, occupancy=0.9,
                           sparse_buckets=None, backend="tpu",
                           kernel="off", **common)
    assert forced.kernel.value == "off"
    assert forced.kernel.source == "override"


# ---------------------------------------------------------------------------
# GoFS: recorded per-pack tile maps -> packed staging
# ---------------------------------------------------------------------------

def _masked_collection(tiny_collection, bg):
    tmpl = tiny_collection.template
    w = np.stack([tiny_collection.edge_values(t, "latency")
                  for t in range(len(tiny_collection))])
    wb, _ = _banded(bg, tmpl, w)
    insts = []
    for t, g in enumerate(tiny_collection.instances):
        ev = dict(g.edge_values)
        ev["latency"] = wb[t]
        insts.append(GraphInstance(timestamp=g.timestamp,
                                   duration=g.duration,
                                   vertex_values=g.vertex_values,
                                   edge_values=ev))
    return TimeSeriesGraph(tmpl, insts), wb


def test_gofs_sparse_roundtrip(tiny_collection, tiny_partitioned, tmp_path):
    """Deploy with recorded tile maps -> sparse load/stream: identical to
    the value-scan staging, bitwise engine parity, buckets pinned from
    the maps without reading value slices."""
    from repro.gofs import GoFSStore, deploy_collection

    tmpl, assign, _, _ = tiny_partitioned
    bg = build_blocked(tmpl, assign, TINY.block_size)
    tsg, wb = _masked_collection(tiny_collection, bg)
    root = str(tmp_path / "gofs_sparse")
    meta = deploy_collection(tsg, TINY, root, assign=assign,
                             sparse_absent={"latency": float("inf")})
    assert meta["sparse_absent"] == {"latency": float("inf")}
    store = GoFSStore(root)
    maps = store.edge_tile_maps("latency")
    assert maps is not None and float(maps["absent"]) == INF

    # recorded maps == value-scan activity, field by field
    sp_rec = store.load_blocked(bg, "latency", layout="sparse")
    sp_scan = bg.stage_sparse(wb)
    for f in ("tiles", "btiles", "rows", "cols", "brows", "bcols",
              "nnz", "bnnz"):
        assert np.array_equal(getattr(sp_rec, f), getattr(sp_scan, f)), f

    # buckets derivable from maps alone (pre-stream, no value reads)
    assert store.sparse_buckets(bg, "latency") == \
        (sp_rec.bucket, sp_rec.bbucket)
    # absent-value mismatch falls back safely (no map, None buckets)
    assert store.sparse_buckets(bg, "latency", zero=0.0) is None

    prog = min_plus_program("sssp", init=source_init(0))
    tiles, btiles = store.load_blocked(bg, "latency")
    rd = TemporalEngine(bg).run(prog, tiles=tiles, btiles=btiles,
                                pattern="sequential")
    rs = TemporalEngine(bg, layout="sparse").run(prog, sparse=sp_rec,
                                                 pattern="sequential")
    stream = store.load_blocked_stream(bg, "latency", layout="sparse")
    rst = TemporalEngine(bg).run(prog, pattern="sequential", stream=stream)
    assert np.array_equal(rd.values, rs.values)
    assert np.array_equal(rd.values, rst.values)
    assert rst.occupancy == pytest.approx(sp_rec.occupancy())


def test_gofs_stale_map_falls_back(tiny_collection, tiny_partitioned,
                                   tmp_path):
    """A recorded map for a DIFFERENT blocked structure must be ignored,
    not trusted: staging falls back to scanning the values."""
    from repro.gofs import GoFSStore, deploy_collection

    tmpl, assign, _, _ = tiny_partitioned
    bg = build_blocked(tmpl, assign, TINY.block_size)
    tsg, wb = _masked_collection(tiny_collection, bg)
    root = str(tmp_path / "gofs_stale")
    deploy_collection(tsg, TINY, root, assign=assign,
                      sparse_absent={"latency": float("inf")})
    store = GoFSStore(root)
    bg2 = build_blocked(tmpl, assign, TINY.block_size * 2)  # other blocking
    assert store.sparse_buckets(bg2, "latency") is None
    sp = store.load_blocked(bg2, "latency", layout="sparse")  # still right
    sp_scan = bg2.stage_sparse(wb)
    assert np.array_equal(sp.tiles, sp_scan.tiles)


# ---------------------------------------------------------------------------
# Boundary-nnz comm costing satellites
# ---------------------------------------------------------------------------

def test_boundary_nnz_cost_model(env):
    from repro.dist.collectives import boundary_exchange_bytes

    tmpl, bg, wb, live = env
    nnz = bg.boundary_nnz
    assert 0 < nnz <= bg.num_boundary
    padded = boundary_exchange_bytes(bg.num_boundary, 4, "dense")
    actual = boundary_exchange_bytes(bg.num_boundary, 4, "dense",
                                     boundary_nnz=nnz)
    assert actual["bytes_per_device"] <= padded["bytes_per_device"]
    assert actual["bytes_per_device"] == \
        boundary_exchange_bytes(nnz, 4, "dense")["bytes_per_device"]


def test_recommended_comm_sparse_cut():
    from repro.launch.mesh import RING_MIN_CUT_BYTES, recommended_comm

    class FakeMesh:  # only truthiness/axis lookup is needed
        axis_names = ("pod", "data", "model")

    mesh = FakeMesh()
    axes = ("pod", "model")
    # unknown cut: conservative ring over DCI (unchanged behavior)
    assert recommended_comm(mesh, axes) == "ring"
    # tiny actual cut: latency-bound, all-reduce wins even across pods
    assert recommended_comm(mesh, axes, boundary_nnz=16) == "dense"
    big = RING_MIN_CUT_BYTES // 4 + 1
    assert recommended_comm(mesh, axes, boundary_nnz=big) == "ring"
    assert recommended_comm(None, boundary_nnz=16) == "host"


# ---------------------------------------------------------------------------
# Bench --check regression gate (pure comparison logic; no bench re-run)
# ---------------------------------------------------------------------------

def test_bench_check_gate(tmp_path):
    import copy
    import json

    from benchmarks.bench_temporal import check_against_baseline

    base = {
        "staging": {"speedup": 2.0},
        "gofs_staging": {"speedup": 1000.0},
        "async_staging": {"speedup": 1.0},
        "async_staging_bound": {"speedup": 2.0},
        "delta_staging": {"staged_bytes_ratio": 3.7, "load_speedup": 2.0},
        "warm_start": {"speedup": 9.0, "supersteps_saved": 682},
        "pagerank_runner": {"speedup": 2.0},
        "sparse": {"step_speedup": 4.0, "staged_bytes_ratio": 4.6,
                   "occupancy": 0.125},
        "plan_overhead": {"frac": 0.001},
        "shared_staging": {"staged_bytes_ratio": 2.0},
        "serving": {"throughput_ratio": 6.0, "restaged_bytes_repeat": 0,
                    "restaging_passes_repeat": 0},
        "streaming_ingest": {"speedup": 12.0, "incremental_steps": 4},
        "fused_superstep": {"fused_pallas_calls": 1, "state_vote_reduces": 0,
                            "eqn_ratio": 1.4},
        "cluster_scaling": {"max_per_host_fraction": 0.5},
    }
    p = str(tmp_path / "base.json")
    with open(p, "w") as f:
        json.dump(base, f)
    assert check_against_baseline(copy.deepcopy(base), p) == []
    # regression below both floor and baseline fraction -> caught
    bad = copy.deepcopy(base)
    bad["sparse"]["step_speedup"] = 1.0
    assert any("step_speedup" in v for v in check_against_baseline(bad, p))
    # occupancy is a deterministic cap
    bad2 = copy.deepcopy(base)
    bad2["sparse"]["occupancy"] = 0.5
    assert any("occupancy" in v for v in check_against_baseline(bad2, p))
    # the fused-kernel structural gates are deterministic too: a second
    # pallas_call or an escaped state-sized reduce is a fusion regression
    bad3 = copy.deepcopy(base)
    bad3["fused_superstep"]["fused_pallas_calls"] = 2
    assert any("fused_pallas_calls" in v
               for v in check_against_baseline(bad3, p))
    bad4 = copy.deepcopy(base)
    bad4["fused_superstep"]["state_vote_reduces"] = 1
    assert any("state_vote_reduces" in v
               for v in check_against_baseline(bad4, p))
    # noise-dominated rows gate on the absolute floor only: a big swing vs
    # baseline passes as long as the optimization clearly still exists
    noisy = copy.deepcopy(base)
    noisy["gofs_staging"]["speedup"] = 60.0
    assert check_against_baseline(noisy, p) == []
    noisy["gofs_staging"]["speedup"] = 3.0  # order(s) of magnitude lost
    assert any("gofs_staging" in v for v in check_against_baseline(noisy, p))
    # cluster staging economy is shard-derived: a host materializing the
    # whole collection again is a sharding regression, not noise
    bad5 = copy.deepcopy(base)
    bad5["cluster_scaling"]["max_per_host_fraction"] = 1.0
    assert any("max_per_host_fraction" in v
               for v in check_against_baseline(bad5, p))
    # missing rows and missing baseline are loud
    assert any("missing" in v
               for v in check_against_baseline({"staging": {}}, p))
    assert any("baseline" in v for v in check_against_baseline(
        base, str(tmp_path / "nope.json")))


# ---------------------------------------------------------------------------
# Mesh (subprocess): sparse == dense on the temporal-parallel lowering
# ---------------------------------------------------------------------------

MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.configs.base import GraphConfig
from repro.core.generator import generate_collection
from repro.core.partition import partition_graph
from repro.core.blocked import build_blocked
from repro.core.engine import (TemporalEngine, min_plus_program,
                               pagerank_program, source_init)
from tests.test_sparse_blocked import _banded, bellman_iterate_program

cfg = GraphConfig(name="sp", num_vertices=400, avg_degree=3.0,
                  num_instances=4, num_partitions=4, block_size=32, seed=9)
tsg = generate_collection(cfg)
tmpl = tsg.template
assign = partition_graph(tmpl, 4, seed=9)
bg = build_blocked(tmpl, assign, 32)
w = np.stack([tsg.edge_values(t, "latency") for t in range(4)])
wb, live = _banded(bg, tmpl, w)
mesh = jax.make_mesh((2, 4), ("data", "model"))
eng_s = TemporalEngine(bg)
eng_m = TemporalEngine(bg, mesh=mesh, layout="sparse")
prog = min_plus_program("sssp", init=source_init(0))
for pattern in ("sequential", "independent"):
    rm = eng_m.run(prog, wb, pattern=pattern)
    rs = eng_s.run(prog, wb, pattern=pattern)
    assert np.array_equal(rm.values, rs.values), pattern
# iterate program on the mesh sparse path
it = bellman_iterate_program(0, iters=4)
assert np.array_equal(eng_m.run(it, wb, pattern="independent").values,
                      eng_s.run(it, wb, pattern="independent").values)
# eventually + merge, sparse mesh vs dense stacked
pm = eng_m.run(prog, wb, pattern="eventually", merge="mean")
ps = eng_s.run(prog, wb, pattern="eventually", merge="mean")
assert np.array_equal(pm.values, ps.values)
assert np.array_equal(pm.merged, ps.merged)
# async sparse staging under the mesh
ra = eng_m.run(prog, wb, pattern="independent", staging="async")
assert np.array_equal(ra.values, rs.values)
# ring comm backend with sparse tiles (comm is layout-agnostic)
eng_r = TemporalEngine(bg, mesh=mesh, layout="sparse", comm="ring")
assert np.array_equal(eng_r.run(prog, wb, pattern="independent").values,
                      rs.values)
# fused superstep kernel (interpret) inside shard_map: both layouts,
# sequential AND independent, still bitwise vs the stacked oracle
for lay in ({}, dict(layout="sparse")):
    eng_f = TemporalEngine(bg, mesh=mesh, use_pallas="fused", **lay)
    for pattern in ("sequential", "independent"):
        rf = eng_f.run(prog, wb, pattern=pattern)
        ro = eng_s.run(prog, wb, pattern=pattern)
        assert np.array_equal(rf.values, ro.values), (lay, pattern)
        assert np.array_equal(rf.stats["supersteps"],
                              ro.stats["supersteps"]), (lay, pattern)
# fused kernel x ring-rs comm: the v2 exchange composes with the fused
# local stage (min-plus stays bitwise end to end)
eng_frs = TemporalEngine(bg, mesh=mesh, layout="sparse",
                         use_pallas="fused", comm="ring-rs")
assert np.array_equal(eng_frs.run(prog, wb, pattern="independent").values,
                      rs.values)
print("SPARSE MESH OK")
"""


@pytest.mark.slow
def test_sparse_mesh_matches_dense_stacked():
    env_ = dict(os.environ)
    env_.pop("XLA_FLAGS", None)
    env_["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", MESH_SCRIPT], env=env_, capture_output=True,
        text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "SPARSE MESH OK" in r.stdout
